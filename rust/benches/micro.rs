//! Microbenchmarks of the simulator's hot paths — the profile targets of
//! the §Perf optimization pass (EXPERIMENTS.md): NoC transfers, TLM HBM
//! accesses, ring collectives, and a full model iteration.

use npusim::config::{ChipConfig, ModelConfig};
use npusim::memmgr::planner::{plan, PlanRequest};
use npusim::memmgr::KvCache;
use npusim::model::exec::{run_iteration, ExecConfig};
use npusim::model::{BatchItem, IterBatch};
use npusim::parallel::collectives::ring_all_reduce;
use npusim::parallel::partition::PartitionStrategy;
use npusim::parallel::placement::{Placement, Region, TpGroup};
use npusim::sim::chip::ChipSim;
use npusim::sim::tracer::OpClass;
use npusim::util::bench::{black_box, Bench};

fn main() {
    let bench = Bench::new("micro").iters(10).warmup(2);

    // Raw mesh transfer throughput (events/s of the NoC model).
    bench.run("mesh_transfer_10k", || {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        for i in 0..10_000u64 {
            let src = npusim::sim::noc::Coord::new((i % 8) as usize, ((i / 8) % 8) as usize);
            let dst = npusim::sim::noc::Coord::new(((i + 3) % 8) as usize, ((i / 5) % 8) as usize);
            black_box(chip.mesh.transfer(src, dst, 4096, i));
        }
    });

    // TLM HBM accesses (burst pipeline).
    bench.run("hbm_access_10k", || {
        let chip = ChipConfig::large_core();
        let mut core =
            npusim::sim::CoreSim::new(&chip, npusim::sim::noc::Coord::new(0, 0), chip.core);
        for i in 0..10_000u64 {
            black_box(core.hbm_access(16 * 1024, OpClass::HbmWeight));
            let _ = i;
        }
    });

    // Ring AllReduce on an 8-core ring.
    bench.run("ring_allreduce_x100", || {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let g = TpGroup::place(Region::new(0, 0, 2, 4), Placement::Ring);
        for _ in 0..100 {
            black_box(ring_all_reduce(&mut chip, &g, 1 << 20));
        }
    });

    // One full Qwen3-4B prefill iteration (the serving inner loop).
    let model = ModelConfig::qwen3_4b();
    bench.run("prefill_iteration_512tok", || {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
        let p = plan(
            &chip.cfg.core,
            &model,
            &PlanRequest {
                layers: model.layers,
                tp: 4,
                iter_tokens: 512,
                kv_share: 0.5,
            },
        );
        let bpt = model.kv_bytes_per_token_layer() * model.layers as u64 / 4;
        let mut kv = KvCache::new(p.kv_bytes, 16, 4 << 30, bpt, 4096);
        kv.admit(1);
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, model.layers, true);
        let b = IterBatch::new(vec![BatchItem::prefill(1, 512, 512)]);
        black_box(run_iteration(
            &mut chip, &group, &model, &p, &exec, &b, &mut kv,
        ));
    });

    // Decode iteration at batch 16 (the TBT-critical path).
    bench.run("decode_iteration_b16", || {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let group = TpGroup::place(Region::new(0, 0, 2, 2), Placement::Ring);
        let p = plan(
            &chip.cfg.core,
            &model,
            &PlanRequest {
                layers: model.layers,
                tp: 4,
                iter_tokens: 16,
                kv_share: 0.5,
            },
        );
        let bpt = model.kv_bytes_per_token_layer() * model.layers as u64 / 4;
        let mut kv = KvCache::new(p.kv_bytes, 16, 4 << 30, bpt, 4096);
        let items: Vec<BatchItem> = (0..16)
            .map(|r| {
                kv.admit(r);
                kv.append(r, 511);
                BatchItem::decode(r, 512)
            })
            .collect();
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, model.layers, true);
        black_box(run_iteration(
            &mut chip,
            &group,
            &model,
            &p,
            &exec,
            &IterBatch::new(items),
            &mut kv,
        ));
    });

    // Simulation rate: simulated cycles per wall second on a small serving
    // run (the §Perf L3 target metric).
    let t0 = std::time::Instant::now();
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let w = npusim::config::WorkloadConfig::fixed_ratio(256, 32, 8);
    let m = npusim::serving::pd_fusion::simulate_fusion(
        &mut chip,
        &model,
        &w,
        &npusim::serving::pd_fusion::FusionConfig::default(),
    )
    .expect("serving run");
    let wall = t0.elapsed().as_secs_f64();
    bench.report_metric(
        "sim_cycles_per_wall_second",
        m.makespan() as f64 / wall,
        "cyc/s",
    );
}
