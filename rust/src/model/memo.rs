//! Operator-latency memoization for [`super::exec::run_iteration_memo`].
//!
//! The fig7–fig14 sweeps execute the same transformer layer millions of
//! times with identical inputs: every layer of a pipeline stage has the
//! same shapes, and consecutive decode iterations differ only by one KV
//! token. The memo caches the measured duration (and per-core tracer
//! deltas) of one detailed layer execution, keyed by the iteration's
//! *shape signature* — per item `(phase, query tokens, KV-length bucket,
//! HBM-residency bucket)` — and replays it for the remaining layers and
//! for later iterations with the same signature.
//!
//! This is an explicitly **approximate fast path** (off by default, like
//! the analytic `Fast` NoC/memory modes of Fig. 7b): KV lengths are
//! bucketed to SRAM-block multiples and HBM residency to 256 KiB, and a
//! replayed layer does not advance the NoC link/HBM bank state, so
//! cross-group contention is under-modelled. With the memo disabled the
//! execution path is bit-identical to the detailed simulator.

use crate::memmgr::{KvCache, KV_BLOCK_TOKENS};
use crate::model::batch::{IterBatch, Phase};
use crate::sim::tracer::OpClass;
use crate::util::units::Cycle;
use std::collections::HashMap;

/// HBM residency bucket width for memo keys.
const HBM_BUCKET_BYTES: u64 = 256 << 10;

/// One cached execution: duration plus per-core `(op class, cycles)`
/// tracer deltas (indexed in the worker group's coordinate order).
#[derive(Debug, Clone)]
pub struct MemoEntry {
    pub duration: Cycle,
    pub trace: Vec<Vec<(OpClass, Cycle)>>,
}

/// Per-worker latency memo (each `StageWorker` owns its own: group
/// geometry, layer shard and SRAM plan are constant per worker, so they
/// need not appear in the key).
#[derive(Debug, Default)]
pub struct LatencyMemo {
    entries: HashMap<u64, MemoEntry>,
    pub hits: u64,
    pub misses: u64,
}

impl LatencyMemo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Count a hit or miss for `key`; returns whether it is cached.
    /// Separated from [`peek`](LatencyMemo::peek) so the hit path can
    /// borrow the entry immutably without cloning it (replay is the hot
    /// path the memo exists to accelerate).
    pub fn note(&mut self, key: u64) -> bool {
        let hit = self.entries.contains_key(&key);
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        hit
    }

    /// Cached entry for `key` (no hit/miss accounting).
    pub fn peek(&self, key: u64) -> Option<&MemoEntry> {
        self.entries.get(&key)
    }

    pub fn put(&mut self, key: u64, entry: MemoEntry) {
        self.entries.insert(key, entry);
    }

    /// Signature of one transformer-layer execution for `batch`.
    pub fn key_layer(batch: &IterBatch, kv: &KvCache) -> u64 {
        let mut h = 0x4C41_5945_5221_7A31u64; // "LAYER!" tag
        for item in &batch.items {
            let phase = match item.phase {
                Phase::Prefill => 1u64,
                Phase::Decode => 2u64,
            };
            let kv_bucket = item.kv_tokens.div_ceil(KV_BLOCK_TOKENS);
            let hbm_bucket = kv
                .residency(item.request)
                .hbm_bytes
                .div_ceil(HBM_BUCKET_BYTES);
            for v in [phase, item.q_tokens, kv_bucket, hbm_bucket] {
                h = mix(h, v);
            }
        }
        h
    }

    /// Signature of the output-logits execution for `batch`.
    pub fn key_logits(batch: &IterBatch) -> u64 {
        mix(0x4C4F_4749_5453_2121, batch.logit_tokens()) // "LOGITS!!" tag
    }
}

fn mix(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::batch::BatchItem;

    fn kv() -> KvCache {
        KvCache::new(1 << 16, 16, 1 << 24, 8, 4096)
    }

    #[test]
    fn identical_shapes_share_a_key_across_requests() {
        let kv = kv();
        let a = IterBatch::new(vec![BatchItem::decode(1, 100)]);
        let b = IterBatch::new(vec![BatchItem::decode(2, 100)]);
        assert_eq!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&b, &kv));
    }

    #[test]
    fn kv_growth_within_a_block_shares_a_key() {
        let kv = kv();
        let a = IterBatch::new(vec![BatchItem::decode(1, 97)]);
        let b = IterBatch::new(vec![BatchItem::decode(1, 100)]);
        let c = IterBatch::new(vec![BatchItem::decode(1, 177)]);
        assert_eq!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&b, &kv));
        assert_ne!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&c, &kv));
    }

    #[test]
    fn phase_and_shape_changes_change_the_key() {
        let kv = kv();
        let d = IterBatch::new(vec![BatchItem::decode(1, 256)]);
        let p = IterBatch::new(vec![BatchItem::prefill(1, 1, 256)]);
        assert_ne!(LatencyMemo::key_layer(&d, &kv), LatencyMemo::key_layer(&p, &kv));
        let two = IterBatch::new(vec![BatchItem::decode(1, 256), BatchItem::decode(2, 256)]);
        assert_ne!(LatencyMemo::key_layer(&d, &kv), LatencyMemo::key_layer(&two, &kv));
    }

    #[test]
    fn hit_accounting() {
        let mut m = LatencyMemo::new();
        assert!(!m.note(42));
        assert!(m.peek(42).is_none());
        m.put(
            42,
            MemoEntry {
                duration: 10,
                trace: vec![vec![(OpClass::Gemm, 10)]],
            },
        );
        assert!(m.note(42));
        assert!(m.peek(42).is_some());
        assert_eq!((m.hits, m.misses), (1, 1));
        assert!((m.hit_rate() - 0.5).abs() < 1e-9);
    }
}
