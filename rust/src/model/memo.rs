//! Operator-latency memoization for [`super::exec::run_iteration_memo`].
//!
//! The fig7–fig14 sweeps execute the same transformer layer millions of
//! times with identical inputs: every layer of a pipeline stage has the
//! same shapes, and consecutive decode iterations differ only by one KV
//! token. The memo caches the measured duration (and per-core tracer
//! deltas) of one detailed layer execution, keyed by the iteration's
//! *shape signature* — per item `(phase, query tokens, KV-length bucket,
//! HBM-residency bucket)` — and replays it for the remaining layers and
//! for later iterations with the same signature.
//!
//! This is an explicitly **approximate fast path** (off by default, like
//! the analytic `Fast` NoC/memory modes of Fig. 7b): KV lengths are
//! bucketed to SRAM-block multiples and HBM residency to 256 KiB, and a
//! replayed layer does not advance the NoC link/HBM bank state, so
//! cross-group contention is under-modelled. With the memo disabled the
//! execution path is bit-identical to the detailed simulator.

use crate::config::{ChipConfig, ModelConfig};
use crate::memmgr::{KvCache, KV_BLOCK_TOKENS};
use crate::model::batch::{IterBatch, Phase};
use crate::parallel::partition::PartitionStrategy;
use crate::sim::compute;
use crate::sim::tracer::OpClass;
use crate::util::cli::CliEnum;
use crate::util::units::Cycle;
use std::collections::HashMap;

/// Simulation fidelity level (CLI `--sim-level`).
///
/// `Txn` is the transaction-level simulator: every operator reserves NoC
/// links, HBM banks and compute timelines. `Fast` replaces iteration
/// execution with the calibrated analytic [`Surrogate`] — closed-form
/// per-op latency (GEMM roofline over compute/HBM, ring-collective costs
/// over the placement) scaled by a per-shape-class ratio measured against
/// one transaction-level run of that shape class. KV bookkeeping stays
/// exact in both levels, so token conservation and exactly-once completion
/// hold regardless of level; only latency is approximated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimLevel {
    /// Transaction-level (bit-identical to the historical simulator).
    #[default]
    Txn,
    /// Calibrated analytic surrogate (approximate, orders faster).
    Fast,
}

impl CliEnum for SimLevel {
    const WHAT: &'static str = "sim level";
    const TABLE: &'static [(&'static str, &'static [&'static str], SimLevel)] = &[
        ("txn", &["transaction", "detailed"], SimLevel::Txn),
        ("fast", &["analytic", "surrogate"], SimLevel::Fast),
    ];
}

impl SimLevel {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Self::parse_cli(s)
    }

    pub fn name(&self) -> &'static str {
        self.cli_name()
    }
}

/// HBM residency bucket width for memo keys.
const HBM_BUCKET_BYTES: u64 = 256 << 10;

/// One cached execution: duration plus per-core `(op class, cycles)`
/// tracer deltas (indexed in the worker group's coordinate order).
#[derive(Debug, Clone)]
pub struct MemoEntry {
    pub duration: Cycle,
    pub trace: Vec<Vec<(OpClass, Cycle)>>,
}

/// Per-worker latency memo (each `StageWorker` owns its own: group
/// geometry, layer shard and SRAM plan are constant per worker, so they
/// need not appear in the key).
#[derive(Debug, Default)]
pub struct LatencyMemo {
    entries: HashMap<u64, MemoEntry>,
    pub hits: u64,
    pub misses: u64,
}

impl LatencyMemo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Count a hit or miss for `key`; returns whether it is cached.
    /// Separated from [`peek`](LatencyMemo::peek) so the hit path can
    /// borrow the entry immutably without cloning it (replay is the hot
    /// path the memo exists to accelerate).
    pub fn note(&mut self, key: u64) -> bool {
        let hit = self.entries.contains_key(&key);
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        hit
    }

    /// Cached entry for `key` (no hit/miss accounting).
    pub fn peek(&self, key: u64) -> Option<&MemoEntry> {
        self.entries.get(&key)
    }

    pub fn put(&mut self, key: u64, entry: MemoEntry) {
        self.entries.insert(key, entry);
    }

    /// Signature of one transformer-layer execution for `batch`.
    pub fn key_layer(batch: &IterBatch, kv: &KvCache) -> u64 {
        let mut h = 0x4C41_5945_5221_7A31u64; // "LAYER!" tag
        for item in &batch.items {
            let phase = match item.phase {
                Phase::Prefill => 1u64,
                Phase::Decode => 2u64,
            };
            let kv_bucket = item.kv_tokens.div_ceil(KV_BLOCK_TOKENS);
            let hbm_bucket = kv
                .residency(item.request)
                .hbm_bytes
                .div_ceil(HBM_BUCKET_BYTES);
            for v in [phase, item.q_tokens, kv_bucket, hbm_bucket] {
                h = mix(h, v);
            }
        }
        h
    }

    /// Signature of the output-logits execution for `batch`.
    pub fn key_logits(batch: &IterBatch) -> u64 {
        mix(0x4C4F_4749_5453_2121, batch.logit_tokens()) // "LOGITS!!" tag
    }
}

fn mix(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Execution-shape parameters of one GEMM/attention/vector inventory —
/// everything [`Surrogate::analytic_iteration_cycles`] needs besides the
/// batch itself. All fields are constant per worker.
#[derive(Debug, Clone, Copy)]
pub struct SurrogateShape {
    /// Tensor-parallel degree of the worker's group.
    pub tp: u64,
    /// HBM-resident weight bytes of this worker's layer shard (from the
    /// SRAM plan) — sets the weight-stream roofline.
    pub weight_hbm_bytes: u64,
}

/// Calibrated analytic latency surrogate (`--sim-level fast`).
///
/// The closed form prices one iteration from first principles: per-GEMM
/// systolic/vector/SRAM roofline ([`compute::matmul_cycles`]) on the
/// partition-sharded shapes, ring-collective bytes over the NoC link
/// bandwidth (the Table-2 cost model: AllReduce `2(p−1)/p·M·N`, AllGather
/// `(p−1)/p·M·K`), per-item attention over the KV length, and the
/// per-layer HBM weight stream as a lower bound. Closed forms drift from
/// the transaction-level simulator (no contention, no bank conflicts), so
/// each *shape class* — phase mix, log₂ batch tokens, KV-length bucket —
/// is calibrated once: its first occurrence runs transaction-level and the
/// measured/analytic ratio corrects every later prediction in the class.
#[derive(Debug, Default)]
pub struct Surrogate {
    ratios: HashMap<u64, f64>,
    /// Transaction-level calibration runs performed (one per shape class).
    pub calibrations: u64,
    /// Iterations priced analytically instead of simulated.
    pub replays: u64,
}

impl Surrogate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Shape-class signature of `batch`: phase mix (prefill / decode /
    /// mixed), log₂ bucket of total query tokens and of batch width, total
    /// KV length in 1 Ki-token buckets, and the logit-token count. Coarser
    /// than [`LatencyMemo::key_layer`] by design — within a class the
    /// analytic form tracks the residual scaling, so one calibration run
    /// covers the whole bucket.
    pub fn key(batch: &IterBatch) -> u64 {
        let mut phase_class = 0u64;
        let mut kv_total = 0u64;
        for item in &batch.items {
            phase_class |= match item.phase {
                Phase::Prefill => 1,
                Phase::Decode => 2,
            };
            kv_total += item.kv_tokens;
        }
        let log2 = |v: u64| 64 - v.max(1).leading_zeros() as u64;
        let mut h = 0x5355_5252_4F47_4154u64; // "SURROGAT" tag
        for v in [
            phase_class,
            log2(batch.total_q_tokens()),
            log2(batch.items.len() as u64),
            kv_total / 1024,
            batch.logit_tokens(),
        ] {
            h = mix(h, v);
        }
        h
    }

    /// Number of calibrated shape classes.
    pub fn len(&self) -> usize {
        self.ratios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ratios.is_empty()
    }

    /// Predicted duration for a calibrated shape class, or `None` when the
    /// class still needs its transaction-level calibration run.
    pub fn predict(&mut self, key: u64, analytic: f64) -> Option<Cycle> {
        let r = *self.ratios.get(&key)?;
        self.replays += 1;
        Some(((analytic * r).round() as Cycle).max(1))
    }

    /// Record the measured duration of the shape class's transaction-level
    /// calibration run.
    pub fn calibrate(&mut self, key: u64, measured: Cycle, analytic: f64) {
        self.calibrations += 1;
        let ratio = if analytic > 0.0 {
            measured as f64 / analytic
        } else {
            1.0
        };
        self.ratios.insert(key, ratio.max(f64::MIN_POSITIVE));
    }

    /// Closed-form iteration latency in cycles (before ratio correction).
    /// Mirrors the op inventory of [`crate::model::exec::run_iteration`]:
    /// per layer RMSNorm ×2, QKV / output / FFN GEMMs on the
    /// partition-sharded shapes plus their ring-collective traffic, RoPE,
    /// per-item attention, residual adds; the per-layer HBM weight stream
    /// as a roofline floor; and the vocab-sharded logits GEMM against its
    /// embedding stream.
    pub fn analytic_iteration_cycles(
        cfg: &ChipConfig,
        model: &ModelConfig,
        exec: &crate::model::exec::ExecConfig,
        shape: SurrogateShape,
        batch: &IterBatch,
    ) -> f64 {
        let core = &cfg.core;
        let m = batch.total_q_tokens();
        if m == 0 {
            return 0.0;
        }
        let tp = shape.tp.max(1);
        let h = model.hidden as u64;
        let qd = model.q_dim() as u64;
        let kvd = model.kv_dim() as u64;
        let dtype = model.dtype_bytes;
        let strategy = exec.strategy_for(m);
        let link_bpc = cfg.noc.link_bytes_per_cycle(cfg.freq_mhz).max(1e-9);
        let hbm_bpc = core.hbm_bytes_per_cycle(cfg.freq_mhz).max(1e-9);

        // One `[m,k]×[k,n]` GEMM: compute on the per-core shard + ring
        // collective bytes over one NoC link.
        let gemm = |m: u64, k: u64, n: u64| -> f64 {
            let (pm, pk, pn, comm_bytes) = match strategy {
                PartitionStrategy::InputOnly => (m.div_ceil(tp), k, n, 0.0),
                PartitionStrategy::OneDimMN => (
                    m,
                    k,
                    n.div_ceil(tp),
                    ((tp - 1) * m * k * dtype) as f64 / tp as f64,
                ),
                PartitionStrategy::OneDimK => (
                    m,
                    k.div_ceil(tp),
                    n,
                    (2 * (tp - 1) * m * n * dtype) as f64 / tp as f64,
                ),
                PartitionStrategy::TwoDim { rows, cols } => {
                    let (r, c) = (rows.max(1) as u64, cols.max(1) as u64);
                    (
                        m,
                        k.div_ceil(r),
                        n.div_ceil(c),
                        (2 * (r - 1) * m * n.div_ceil(c) * dtype) as f64 / r as f64
                            + ((c - 1) * m * k.div_ceil(r) * dtype) as f64 / c as f64,
                    )
                }
            };
            compute::matmul_cycles(cfg, core, pm, pk, pn) as f64 + comm_bytes / link_bpc
        };

        let mut layer = 0.0;
        layer += 2.0 * compute::rmsnorm_cycles(core, m, h.div_ceil(tp)) as f64;
        layer += gemm(m, h, qd + 2 * kvd);
        layer += compute::rope_cycles(core, m, (qd + kvd).div_ceil(tp)) as f64;
        let heads = (model.heads as u64).div_ceil(tp).max(1);
        for item in &batch.items {
            layer += compute::attention_cycles(
                cfg,
                core,
                heads,
                item.q_tokens,
                item.kv_tokens.max(1),
                model.head_dim as u64,
            ) as f64;
        }
        layer += gemm(m, qd, h);
        layer += 2.0 * compute::vector_cycles(core, m * h.div_ceil(tp), 1) as f64;
        // FFN; MoE layers are priced as their active-expert dense
        // equivalent (the calibration ratio absorbs dispatch/combine).
        let inter = match &model.moe {
            Some(moe) => moe.expert_intermediate as u64 * moe.top_k as u64,
            None => model.intermediate as u64,
        };
        layer += gemm(m, h, 2 * inter);
        layer += compute::swiglu_cycles(core, m, inter.div_ceil(tp)) as f64;
        layer += gemm(m, inter, h);

        // Weight-stream roofline: a layer can never finish before its HBM
        // weight shard has streamed in.
        let layers = exec.layers.max(1) as u64;
        let hbm_layer = (shape.weight_hbm_bytes / layers) as f64 / hbm_bpc;
        let mut total = layers as f64 * layer.max(hbm_layer);

        if exec.with_logits {
            let lm = batch.logit_tokens();
            if lm > 0 {
                let vocab_shard = (model.vocab as u64).div_ceil(tp);
                let logits = compute::matmul_cycles(cfg, core, lm, h, vocab_shard) as f64
                    + compute::rmsnorm_cycles(core, lm, h.div_ceil(tp)) as f64;
                let embed = (vocab_shard * h * dtype) as f64 / hbm_bpc;
                total += logits.max(embed);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::batch::BatchItem;

    fn kv() -> KvCache {
        KvCache::new(1 << 16, 16, 1 << 24, 8, 4096)
    }

    #[test]
    fn identical_shapes_share_a_key_across_requests() {
        let kv = kv();
        let a = IterBatch::new(vec![BatchItem::decode(1, 100)]);
        let b = IterBatch::new(vec![BatchItem::decode(2, 100)]);
        assert_eq!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&b, &kv));
    }

    #[test]
    fn kv_growth_within_a_block_shares_a_key() {
        let kv = kv();
        let a = IterBatch::new(vec![BatchItem::decode(1, 97)]);
        let b = IterBatch::new(vec![BatchItem::decode(1, 100)]);
        let c = IterBatch::new(vec![BatchItem::decode(1, 177)]);
        assert_eq!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&b, &kv));
        assert_ne!(LatencyMemo::key_layer(&a, &kv), LatencyMemo::key_layer(&c, &kv));
    }

    #[test]
    fn phase_and_shape_changes_change_the_key() {
        let kv = kv();
        let d = IterBatch::new(vec![BatchItem::decode(1, 256)]);
        let p = IterBatch::new(vec![BatchItem::prefill(1, 1, 256)]);
        assert_ne!(LatencyMemo::key_layer(&d, &kv), LatencyMemo::key_layer(&p, &kv));
        let two = IterBatch::new(vec![BatchItem::decode(1, 256), BatchItem::decode(2, 256)]);
        assert_ne!(LatencyMemo::key_layer(&d, &kv), LatencyMemo::key_layer(&two, &kv));
    }

    #[test]
    fn sim_level_parses_and_defaults_to_txn() {
        assert_eq!(SimLevel::default(), SimLevel::Txn);
        assert_eq!(SimLevel::parse("txn").unwrap(), SimLevel::Txn);
        assert_eq!(SimLevel::parse("fast").unwrap(), SimLevel::Fast);
        assert_eq!(SimLevel::parse("analytic").unwrap(), SimLevel::Fast);
        assert!(SimLevel::parse("warp").is_err());
    }

    #[test]
    fn surrogate_keys_bucket_shape_classes() {
        // Same phase/size bucket → same class.
        let a = IterBatch::new(vec![BatchItem::decode(1, 100)]);
        let b = IterBatch::new(vec![BatchItem::decode(9, 300)]);
        assert_eq!(Surrogate::key(&a), Surrogate::key(&b));
        // Phase flip or a KV jump past the bucket edge → new class.
        let p = IterBatch::new(vec![BatchItem::prefill(1, 100, 100)]);
        assert_ne!(Surrogate::key(&a), Surrogate::key(&p));
        let far = IterBatch::new(vec![BatchItem::decode(1, 5000)]);
        assert_ne!(Surrogate::key(&a), Surrogate::key(&far));
    }

    #[test]
    fn surrogate_predicts_only_after_calibration() {
        let mut s = Surrogate::new();
        let key = 7u64;
        assert_eq!(s.predict(key, 1000.0), None);
        s.calibrate(key, 2000, 1000.0); // measured 2× analytic
        assert_eq!(s.predict(key, 1000.0), Some(2000));
        // Ratio scales across the bucket.
        assert_eq!(s.predict(key, 500.0), Some(1000));
        assert_eq!((s.calibrations, s.replays), (1, 2));
    }

    #[test]
    fn analytic_cycles_scale_with_batch_and_kv() {
        use crate::config::ChipConfig;
        use crate::model::exec::ExecConfig;
        use crate::parallel::partition::PartitionStrategy;
        let cfg = ChipConfig::large_core();
        let model = crate::config::ModelConfig::qwen3_4b();
        let exec = ExecConfig::new(PartitionStrategy::OneDimK, 4, true);
        let shape = SurrogateShape {
            tp: 4,
            weight_hbm_bytes: 1 << 30,
        };
        let at = |b: &IterBatch| Surrogate::analytic_iteration_cycles(&cfg, &model, &exec, shape, b);
        let small = at(&IterBatch::new(vec![BatchItem::prefill(1, 128, 128)]));
        let big = at(&IterBatch::new(vec![BatchItem::prefill(1, 1024, 1024)]));
        assert!(small > 0.0);
        assert!(big > small, "more tokens must cost more: {big} vs {small}");
        let short_kv = at(&IterBatch::new(vec![BatchItem::decode(1, 128)]));
        let long_kv = at(&IterBatch::new(vec![BatchItem::decode(1, 8192)]));
        assert!(long_kv > short_kv, "longer KV must cost more");
        assert_eq!(at(&IterBatch::new(vec![])), 0.0);
    }

    #[test]
    fn hit_accounting() {
        let mut m = LatencyMemo::new();
        assert!(!m.note(42));
        assert!(m.peek(42).is_none());
        m.put(
            42,
            MemoEntry {
                duration: 10,
                trace: vec![vec![(OpClass::Gemm, 10)]],
            },
        );
        assert!(m.note(42));
        assert!(m.peek(42).is_some());
        assert_eq!((m.hits, m.misses), (1, 1));
        assert!((m.hit_rate() - 0.5).abs() < 1e-9);
    }
}
