//! # NpuSim — LLM serving on multi-core NPUs
//!
//! Reproduction of *"From Principles to Practice: A Systematic Study of LLM
//! Serving on Multi-core NPUs"* (Zhu et al., 2025).
//!
//! The crate is organised around the paper's two contributions:
//!
//! - **The simulator** ([`sim`]): a multi-level simulation framework —
//!   performance-model compute ([`sim::compute`]), transaction-level memory
//!   ([`sim::memory`]), and cycle-accurate 2D-mesh NoC routing
//!   ([`sim::noc`]) — glued together by a discrete-event engine
//!   ([`sim::engine`]).
//! - **The serving study** ([`parallel`], [`memmgr`], [`serving`]): tensor
//!   partition strategies and core placements, hierarchical KV-cache
//!   management across SRAM and HBM, and PD-disaggregation / PD-fusion
//!   scheduling with heterogeneous core designs ([`area`]).
//!
//! [`experiments`] regenerates every table and figure of the paper's
//! evaluation; [`baselines`] encodes the T10 / WaferLLM / WSC-LLM strategy
//! presets the paper compares against; [`runtime`] + [`coordinator`] run a
//! real (tiny) Qwen3-style model AOT-compiled from JAX through PJRT so the
//! serving stack can be exercised end-to-end with actual tokens.

pub mod area;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod memmgr;
pub mod model;
pub mod parallel;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;

pub use config::{ChipConfig, ModelConfig, WorkloadConfig};
pub use util::units::Cycle;
