//! Minimal JSON parser for the offline workspace (no serde).
//!
//! The serving bench emits `BENCH_serving.json` with hand-rolled
//! formatting; the CI bench gate (`tools/bench_check.rs`) needs to read it
//! (and the committed `BENCH_baseline.json`) back *structurally* to
//! compare metric fields within a tolerance. This is a strict
//! recursive-descent parser over the JSON grammar — objects keep their key
//! order (emission order is deterministic, so row matching can rely on
//! it), numbers are `f64`, and inputs must be a single complete value.

/// A parsed JSON value. Object keys keep insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// `get(key)` then `as_f64` (the common metric-field access).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    /// `get(key)` then `as_str`.
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }
}

/// Parse one complete JSON value (trailing whitespace allowed).
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(
        p.i == p.b.len(),
        "trailing content at byte {} of {}",
        p.i,
        p.b.len()
    );
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(c),
            "expected {:?} at byte {}",
            c as char,
            self.i
        );
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "expected `{s}` at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => {
                self.lit("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.lit("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'n') => {
                self.lit("null")?;
                Ok(Json::Null)
            }
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            kv.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            let Some(c) = self.peek() else {
                anyhow::bail!("unterminated string");
            };
            self.i += 1;
            match c {
                b'"' => {
                    return Ok(String::from_utf8(out)?);
                }
                b'\\' => {
                    let Some(e) = self.peek() else {
                        anyhow::bail!("unterminated escape");
                    };
                    self.i += 1;
                    let ch = match e {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'u' => {
                            anyhow::ensure!(
                                self.i + 4 <= self.b.len(),
                                "truncated \\u escape at byte {}",
                                self.i
                            );
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs are not needed for our ASCII
                            // metric files; map lone surrogates to U+FFFD.
                            char::from_u32(code).unwrap_or('\u{fffd}')
                        }
                        other => anyhow::bail!("bad escape \\{} at byte {}", other as char, self.i),
                    };
                    let mut buf = [0u8; 4];
                    out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                }
                _ => out.push(c),
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.i += 1;
        }
        anyhow::ensure!(self.i > start, "expected a value at byte {start}");
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number `{s}` at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures_in_order() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": null}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].str("b"), Some("c"));
        assert_eq!(j.get("d").unwrap().get("e"), Some(&Json::Null));
        if let Json::Obj(kv) = &j {
            assert_eq!(kv[0].0, "a");
            assert_eq!(kv[1].0, "d");
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn string_escapes_decode() {
        let j = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"open").is_err());
        assert!(parse("1.2.3").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn bench_shaped_document_round_trips_fields() {
        let text = r#"{
  "bench": "serving",
  "prefix_cache": [
    {"system": "fusion", "prefix_cache": true, "tokens_per_s": 123.456, "ttft_p99_s": 0.025}
  ],
  "cluster": [
    {"workload": "shared-prefix", "sched": "fusion", "router": "prefix", "chips": 2, "ttft_p50_s": 0.0125}
  ]
}"#;
        let j = parse(text).unwrap();
        assert_eq!(j.str("bench"), Some("serving"));
        let rows = j.get("prefix_cache").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].num("tokens_per_s"), Some(123.456));
        assert_eq!(rows[0].get("prefix_cache").unwrap().as_bool(), Some(true));
        let cluster = j.get("cluster").unwrap().as_arr().unwrap();
        assert_eq!(cluster[0].num("chips"), Some(2.0));
        assert_eq!(cluster[0].str("router"), Some("prefix"));
    }
}
