//! Criterion-like micro/macro benchmark harness (criterion is unavailable
//! offline). Used by every file in `rust/benches/` via `harness = false`.
//!
//! Provides warmup, repeated timed runs, and a mean/std/min/median report in
//! a stable text format so `cargo bench` output can be diffed across
//! optimization iterations (EXPERIMENTS.md §Perf).

use super::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark group (named section in the output).
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    max_total: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            warmup: 1,
            iters: 5,
            max_total: Duration::from_secs(60),
        }
    }

    /// Number of measured iterations (default 5).
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    /// Number of warmup iterations (default 1).
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Hard cap on total measured time; stops early once exceeded.
    pub fn max_total(mut self, d: Duration) -> Self {
        self.max_total = d;
        self
    }

    /// Run a case and print its report line. Returns the summary (seconds).
    pub fn run<F: FnMut()>(&self, case: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut summary = Summary::new();
        let started = Instant::now();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            summary.add(t0.elapsed().as_secs_f64());
            if started.elapsed() > self.max_total {
                break;
            }
        }
        let mut s = summary.clone();
        println!(
            "bench {:<40} {:>12} mean {:>12} min {:>12} median {:>12} std  (n={})",
            format!("{}/{}", self.name, case),
            fmt_dur(s.mean()),
            fmt_dur(s.min()),
            fmt_dur(s.median()),
            fmt_dur(s.std()),
            s.len(),
        );
        summary
    }

    /// Run a case that reports its own scalar metric (e.g. simulated
    /// latency, throughput) instead of wall time. Prints one stable line.
    pub fn report_metric(&self, case: &str, value: f64, unit: &str) {
        println!(
            "metric {:<40} {value:>14.4} {unit}",
            format!("{}/{}", self.name, case)
        );
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_samples() {
        let b = Bench::new("test").iters(3).warmup(0);
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert_eq!(s.len(), 3);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn max_total_stops_early() {
        let b = Bench::new("test")
            .iters(1000)
            .warmup(0)
            .max_total(Duration::from_millis(30));
        let s = b.run("sleep", || std::thread::sleep(Duration::from_millis(10)));
        assert!(s.len() < 1000);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("us"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }
}
