//! `hybrid_study` — fusion vs disaggregation vs the adaptive hybrid
//! scheduler across workload regimes.
//!
//! Extends the paper's §5.5 comparison (Figs. 11/14 treat PD-disagg vs
//! PD-fusion as a static choice) with the FlexNPU-style adaptive hybrid:
//! three workload regimes — bursty long-prompt (Mooncake-like), steady
//! Poisson conversational (ShareGPT-like), and a JSONL trace replay
//! (synthetic Mooncake trace round-tripped through the parser) — each run
//! under all three schedulers on the Table-3 large-core chip.

use crate::config::{ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::DisaggConfig;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::serving::scheduler::{self, HybridConfig, HybridScheduler, SchedulerConfig};
use crate::serving::trace;
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// The three compared schedulers, defaults tuned for the 64-core chip.
pub fn systems() -> [SchedulerConfig; 3] {
    [
        SchedulerConfig::Fusion(FusionConfig::default()),
        SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
        SchedulerConfig::Hybrid(HybridConfig::default()),
    ]
}

/// The swept workload regimes: `(label, requests)`.
pub fn workloads(opts: &Opts) -> anyhow::Result<Vec<(&'static str, Vec<Request>)>> {
    let n = opts.pick(24, 5);
    // Bursty long-prompt regime (Mooncake-like). Fast mode trims the tail
    // lengths so smoke runs stay quick without changing the regime's shape.
    let mut bursty = WorkloadConfig::mooncake_like(n);
    if opts.fast {
        bursty.input_len = LenDist::LogNormal {
            mu: 6.2,
            sigma: 0.8,
            min: 64,
            max: 2048,
        };
        bursty.output_len = LenDist::LogNormal {
            mu: 4.5,
            sigma: 0.5,
            min: 8,
            max: 128,
        };
    }
    // Steady Poisson conversational regime (ShareGPT-like).
    let mut poisson = WorkloadConfig::sharegpt_like(n);
    if opts.fast {
        poisson.input_len = LenDist::LogNormal {
            mu: 5.0,
            sigma: 0.8,
            min: 16,
            max: 1024,
        };
        poisson.output_len = LenDist::LogNormal {
            mu: 4.2,
            sigma: 0.6,
            min: 8,
            max: 128,
        };
    }
    // Trace replay: export the bursty trace to Mooncake JSONL and parse it
    // back, so the replay path (timestamps, re-basing, sorting) is the one
    // actually exercised — a true round-trip of the compared request list.
    let bursty_reqs = request::generate(&bursty);
    let replay = trace::parse_jsonl(&trace::to_jsonl(&bursty_reqs))?;
    Ok(vec![
        ("bursty", bursty_reqs),
        ("poisson", request::generate(&poisson)),
        ("trace-replay", replay),
    ])
}

/// Run one scheduler over one request list on a fresh large-core chip.
/// Returns the metrics and, for the hybrid, its re-partition count.
pub fn run_system(
    model: &ModelConfig,
    reqs: Vec<Request>,
    sys: &SchedulerConfig,
) -> anyhow::Result<(Metrics, u64)> {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    match sys {
        SchedulerConfig::Hybrid(c) => {
            let mut sched = HybridScheduler::new(*c);
            let m = scheduler::simulate_requests(&mut chip, model, reqs, &mut sched)?;
            Ok((m, sched.repartitions()))
        }
        other => {
            let mut sched = other.build();
            let m = scheduler::simulate_requests(&mut chip, model, reqs, sched.as_mut())?;
            Ok((m, 0))
        }
    }
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let mut cmp = Table::new(
        "hybrid study — fusion vs disagg vs adaptive hybrid (Qwen3-4B, 64 cores)",
        &[
            "workload",
            "system",
            "tok/s",
            "TTFT mean (s)",
            "TBT mean (ms)",
            "SLO att. (%)",
        ],
    );
    let mut adapt = Table::new(
        "hybrid study — adaptation activity",
        &["workload", "re-partitions"],
    );
    for (label, reqs) in workloads(opts)? {
        for sys in systems() {
            let (m, repartitions) = run_system(&model, reqs.clone(), &sys)?;
            cmp.row(&[
                label.to_string(),
                sys.name().to_string(),
                f3(m.tokens_per_s()),
                f3(m.ttft_s().mean()),
                f3(m.tbt_s().mean() * 1e3),
                f3(m.slo_attainment(2.0, 0.050) * 100.0),
            ]);
            if matches!(sys, SchedulerConfig::Hybrid(_)) {
                adapt.row(&[label.to_string(), repartitions.to_string()]);
            }
        }
    }
    Ok(vec![cmp, adapt])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 2);
        // 3 workloads x 3 systems.
        assert_eq!(tables[0].n_rows(), 9);
        assert_eq!(tables[1].n_rows(), 3);
    }

    #[test]
    fn hybrid_is_never_the_worst_on_the_bursty_workload() {
        // The acceptance property: on the bursty regime the adaptive hybrid
        // must not be strictly the worst of the three on output throughput.
        // (When its controller stays quiescent it is bit-identical to
        // fusion; the 10% tolerance absorbs adaptation overhead.)
        let model = ModelConfig::qwen3_4b();
        let opts = Opts::fast();
        let (_, reqs) = workloads(&opts)
            .unwrap()
            .into_iter()
            .find(|(l, _)| *l == "bursty")
            .unwrap();
        let [fusion_cfg, disagg_cfg, hybrid_cfg] = systems();
        let (f, _) = run_system(&model, reqs.clone(), &fusion_cfg).unwrap();
        let (d, _) = run_system(&model, reqs.clone(), &disagg_cfg).unwrap();
        let (h, _) = run_system(&model, reqs, &hybrid_cfg).unwrap();
        let floor = f.tokens_per_s().min(d.tokens_per_s());
        assert!(
            h.tokens_per_s() >= floor * 0.9,
            "hybrid {} tok/s is the strict worst (fusion {}, disagg {})",
            h.tokens_per_s(),
            f.tokens_per_s(),
            d.tokens_per_s()
        );
    }
}
