//! Deterministic fault injection for the multi-chip cluster.
//!
//! A [`FaultSchedule`] is a plain, sorted list of timed [`FaultEvent`]s —
//! chip crashes (with optional restart), interconnect link degradation,
//! and HBM throttling — consumed by the cluster driver
//! ([`crate::serving::cluster`]). Schedules are built three ways:
//! explicitly ([`FaultSchedule::new`]), from a compact CLI spec string
//! ([`FaultSchedule::parse`]), or drawn from a seeded RNG
//! ([`FaultSchedule::seeded`]) so chaos runs replay bit-for-bit and golden
//! tests can pin them.
//!
//! The schedule also carries the *recovery* knobs the frontend uses when a
//! crash strands in-flight requests: the heartbeat probe interval bounding
//! detection latency, the bounded retry budget with exponential backoff,
//! and the [`RecoveryPolicy`] (frontend-driven recovery vs the naive
//! client-timeout resubmit baseline the bench gates against).

use crate::util::rng::Rng;

/// What a [`FaultEvent`] does to its target chip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The chip dies: its clock stops, queued and in-flight requests are
    /// lost (KV included), and routers must steer around it. With
    /// `restart_after_s` the chip comes back cold (fresh scheduler, empty
    /// caches) after that downtime.
    ChipCrash { restart_after_s: Option<f64> },
    /// The chip's interconnect egress runs at `factor` × nominal bandwidth
    /// for `duration_s` (e.g. `0.25` = quarter speed). `factor` ∈ (0, 1].
    LinkDegrade { factor: f64, duration_s: f64 },
    /// The chip's HBM channels run at `factor` × nominal bandwidth for
    /// `duration_s`. `factor` ∈ (0, 1].
    HbmThrottle { factor: f64, duration_s: f64 },
}

/// One timed fault against one chip.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Injection time, in trace seconds.
    pub at_s: f64,
    /// Target chip index in the cluster.
    pub chip: usize,
    pub kind: FaultKind,
}

/// How the frontend handles requests stranded by a crash.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RecoveryPolicy {
    /// Heartbeat-driven recovery: on detection the dead chip's in-flight
    /// requests re-enter at the frontend as retries (bounded, backed off),
    /// re-prefilling on a surviving chip and reusing any cross-chip prefix
    /// copy that outlived the crash.
    Recover,
    /// The naive baseline: the frontend does nothing; each stranded
    /// request is resubmitted by its client after `client_timeout_s` and
    /// re-enters the normal (sheddable) admission path.
    Resubmit { client_timeout_s: f64 },
}

/// Default heartbeat probe interval (seconds): detection latency is at
/// most one interval after the crash.
pub const DEFAULT_HEARTBEAT_S: f64 = 0.01;
/// Default bounded retry budget per stranded request.
pub const DEFAULT_MAX_RETRIES: u32 = 3;
/// Default base of the retry backoff (seconds, doubled per attempt).
pub const DEFAULT_RETRY_BACKOFF_S: f64 = 0.002;

/// A deterministic, replayable fault schedule plus the recovery knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    /// Events sorted by `(at_s, chip)`; ties keep insertion order.
    pub events: Vec<FaultEvent>,
    /// Heartbeat probe interval in seconds; a crash at `t` is detected at
    /// the next probe tick strictly after `t`.
    pub heartbeat_s: f64,
    /// Retry budget per stranded request before it is shed.
    pub max_retries: u32,
    /// Base retry backoff in seconds (attempt `k` waits `base · 2^(k-1)`).
    pub retry_backoff_s: f64,
    pub recovery: RecoveryPolicy,
}

impl FaultSchedule {
    /// Build a schedule from explicit events (stably sorted by time, then
    /// chip, so injection order is deterministic regardless of input
    /// order).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| {
            a.at_s
                .partial_cmp(&b.at_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.chip.cmp(&b.chip))
        });
        FaultSchedule {
            events,
            heartbeat_s: DEFAULT_HEARTBEAT_S,
            max_retries: DEFAULT_MAX_RETRIES,
            retry_backoff_s: DEFAULT_RETRY_BACKOFF_S,
            recovery: RecoveryPolicy::Recover,
        }
    }

    /// Draw a schedule from a seeded RNG: exponential inter-fault gaps at
    /// fleet rate `n_chips / mttf_s` over `[0, horizon_s)`, uniform target
    /// chip, and a deterministic mix of crash / link / HBM faults. Same
    /// seed → byte-identical schedule.
    pub fn seeded(seed: u64, n_chips: usize, horizon_s: f64, mttf_s: f64) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA_17_5C_0E_D0_1E_55_AAu64);
        let mut events = Vec::new();
        let n = n_chips.max(1);
        let rate = n as f64 / mttf_s.max(1e-9);
        let mut t = 0.0f64;
        loop {
            t += rng.exponential(rate);
            if t >= horizon_s {
                break;
            }
            let chip = rng.range(0, n);
            let kind = match rng.range(0, 3) {
                0 => FaultKind::ChipCrash {
                    restart_after_s: if rng.chance(0.5) {
                        Some(mttf_s * (0.02 + 0.08 * rng.f64()))
                    } else {
                        None
                    },
                },
                1 => FaultKind::LinkDegrade {
                    factor: 0.2 + 0.6 * rng.f64(),
                    duration_s: mttf_s * (0.01 + 0.04 * rng.f64()),
                },
                _ => FaultKind::HbmThrottle {
                    factor: 0.3 + 0.5 * rng.f64(),
                    duration_s: mttf_s * (0.01 + 0.04 * rng.f64()),
                },
            };
            events.push(FaultEvent { at_s: t, chip, kind });
        }
        FaultSchedule::new(events)
    }

    /// Parse the compact `--faults` spec: semicolon-separated entries of
    /// - `crash:CHIP@T` — chip `CHIP` dies at `T` seconds, no restart;
    /// - `crash:CHIP@T:RESTART` — …and restarts after `RESTART` seconds;
    /// - `link:CHIP@T:FACTOR:DURATION` — egress at `FACTOR`× bandwidth;
    /// - `hbm:CHIP@T:FACTOR:DURATION` — HBM at `FACTOR`× bandwidth.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut events = Vec::new();
        for entry in spec.split(';').filter(|e| !e.trim().is_empty()) {
            let entry = entry.trim();
            let (kind_s, rest) = entry
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fault entry `{entry}`: expected KIND:CHIP@T..."))?;
            let mut parts = rest.split(':');
            let target = parts.next().unwrap_or("");
            let (chip_s, t_s) = target
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault entry `{entry}`: expected CHIP@T"))?;
            let chip: usize = chip_s
                .parse()
                .map_err(|_| anyhow::anyhow!("fault entry `{entry}`: bad chip `{chip_s}`"))?;
            let at_s: f64 = t_s
                .parse()
                .map_err(|_| anyhow::anyhow!("fault entry `{entry}`: bad time `{t_s}`"))?;
            let mut num = |name: &str| -> anyhow::Result<f64> {
                parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("fault entry `{entry}`: missing {name}"))?
                    .parse()
                    .map_err(|_| anyhow::anyhow!("fault entry `{entry}`: bad {name}"))
            };
            let kind = match kind_s {
                "crash" => FaultKind::ChipCrash {
                    restart_after_s: match num("restart") {
                        Ok(v) => Some(v),
                        Err(_) => None,
                    },
                },
                "link" => {
                    let factor = num("factor")?;
                    let duration_s = num("duration")?;
                    FaultKind::LinkDegrade { factor, duration_s }
                }
                "hbm" => {
                    let factor = num("factor")?;
                    let duration_s = num("duration")?;
                    FaultKind::HbmThrottle { factor, duration_s }
                }
                other => {
                    return Err(crate::util::cli::unknown_variant(
                        "fault kind",
                        other,
                        "crash|link|hbm",
                    ))
                }
            };
            if let FaultKind::LinkDegrade { factor, .. } | FaultKind::HbmThrottle { factor, .. } =
                kind
            {
                anyhow::ensure!(
                    factor > 0.0 && factor <= 1.0,
                    "fault entry `{entry}`: factor must be in (0, 1]"
                );
            }
            anyhow::ensure!(at_s >= 0.0, "fault entry `{entry}`: time must be >= 0");
            events.push(FaultEvent { at_s, chip, kind });
        }
        anyhow::ensure!(!events.is_empty(), "empty fault spec");
        Ok(FaultSchedule::new(events))
    }

    /// Override the heartbeat probe interval.
    pub fn with_heartbeat(mut self, heartbeat_s: f64) -> Self {
        self.heartbeat_s = heartbeat_s.max(1e-6);
        self
    }

    /// Override the retry budget.
    pub fn with_retries(mut self, max_retries: u32, retry_backoff_s: f64) -> Self {
        self.max_retries = max_retries;
        self.retry_backoff_s = retry_backoff_s.max(0.0);
        self
    }

    /// Override the recovery policy.
    pub fn with_recovery(mut self, recovery: RecoveryPolicy) -> Self {
        self.recovery = recovery;
        self
    }

    /// True when the schedule contains at least one crash (used by reports
    /// and sanity checks; degradation-only schedules never retry).
    pub fn has_crash(&self) -> bool {
        self.events
            .iter()
            .any(|e| matches!(e.kind, FaultKind::ChipCrash { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_events_sort_by_time_then_chip() {
        let s = FaultSchedule::new(vec![
            FaultEvent {
                at_s: 2.0,
                chip: 1,
                kind: FaultKind::ChipCrash { restart_after_s: None },
            },
            FaultEvent {
                at_s: 1.0,
                chip: 3,
                kind: FaultKind::HbmThrottle { factor: 0.5, duration_s: 1.0 },
            },
            FaultEvent {
                at_s: 1.0,
                chip: 0,
                kind: FaultKind::LinkDegrade { factor: 0.25, duration_s: 1.0 },
            },
        ]);
        let order: Vec<(f64, usize)> = s.events.iter().map(|e| (e.at_s, e.chip)).collect();
        assert_eq!(order, vec![(1.0, 0), (1.0, 3), (2.0, 1)]);
    }

    #[test]
    fn seeded_schedules_replay_bit_for_bit() {
        let a = FaultSchedule::seeded(42, 4, 10.0, 2.0);
        let b = FaultSchedule::seeded(42, 4, 10.0, 2.0);
        assert_eq!(a, b);
        assert!(!a.events.is_empty(), "10s horizon at 2s MTTF must fault");
        for e in &a.events {
            assert!(e.at_s >= 0.0 && e.at_s < 10.0);
            assert!(e.chip < 4);
            if let FaultKind::LinkDegrade { factor, .. }
            | FaultKind::HbmThrottle { factor, .. } = e.kind
            {
                assert!(factor > 0.0 && factor <= 1.0, "{e:?}");
            }
        }
        let c = FaultSchedule::seeded(43, 4, 10.0, 2.0);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn parse_round_trips_the_three_kinds() {
        let s = FaultSchedule::parse("crash:1@0.5;crash:2@0.75:0.3;link:0@1.0:0.25:0.5;hbm:3@0.2:0.4:0.1")
            .unwrap();
        assert_eq!(s.events.len(), 4);
        assert_eq!(
            s.events[0],
            FaultEvent {
                at_s: 0.2,
                chip: 3,
                kind: FaultKind::HbmThrottle { factor: 0.4, duration_s: 0.1 },
            }
        );
        assert_eq!(
            s.events[1].kind,
            FaultKind::ChipCrash { restart_after_s: None }
        );
        assert_eq!(
            s.events[2].kind,
            FaultKind::ChipCrash { restart_after_s: Some(0.3) }
        );
        assert_eq!(
            s.events[3].kind,
            FaultKind::LinkDegrade { factor: 0.25, duration_s: 0.5 }
        );
        assert!(s.has_crash());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultSchedule::parse("").is_err());
        assert!(FaultSchedule::parse("crash:xx@1").is_err());
        assert!(FaultSchedule::parse("melt:0@1").is_err());
        assert!(FaultSchedule::parse("link:0@1.0:1.5:0.5").is_err(), "factor > 1");
        assert!(FaultSchedule::parse("hbm:0@1.0:0.5").is_err(), "missing duration");
    }
}
