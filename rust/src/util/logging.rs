//! Minimal leveled logger (the `log`/`env_logger` crates' facade without
//! the dependency). Controlled by the `NPUSIM_LOG` environment variable:
//! `error|warn|info|debug|trace` (default `warn`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Warn,
        }
    }
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised
static INIT: OnceLock<()> = OnceLock::new();

/// Current log level (lazily read from `NPUSIM_LOG`).
pub fn level() -> Level {
    INIT.get_or_init(|| {
        let lvl = std::env::var("NPUSIM_LOG")
            .map(|v| Level::from_str(&v))
            .unwrap_or(Level::Warn);
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(lvl: Level) {
    INIT.get_or_init(|| ());
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Emit a log line if `lvl` is enabled.
pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        eprintln!("[{:<5} {module}] {msg}", lvl.tag());
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Warn < Level::Info);
    }

    #[test]
    fn set_level_overrides() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
    }

    #[test]
    fn from_str_parses() {
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Warn);
    }
}
