//! SRAM budget planner (§4.2 "weight and activation management").
//!
//! Given the model shard a core holds and the serving batch shape, the
//! planner splits the core's SRAM in the paper's priority order:
//!
//! 1. **Activations / inputs** — the dataflow staging buffers every
//!    inter-core transfer lands in (double-buffered).
//! 2. **Communication staging** — collective send/recv buffers.
//! 3. **Compute temporaries** — "a modest amount of buffer … is
//!    sufficient" for matrix intermediate results.
//! 4. **KV cache blocks** — best-effort from the remainder.
//! 5. **Resident weights** — whatever still remains pins hot weights; the
//!    rest streams from HBM per layer.
//!
//! The planner is what turns a `(ChipConfig, ModelConfig, batch)` into the
//! executor's memory behaviour, and what the Fig. 8/13 SRAM sweeps vary.

use crate::config::{CoreConfig, ModelConfig};

/// How a core's SRAM is divided, in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SramPlan {
    pub act_bytes: u64,
    pub comm_bytes: u64,
    pub temp_bytes: u64,
    pub kv_bytes: u64,
    pub weight_sram_bytes: u64,
    /// Weight bytes this core must stream from HBM each full model pass.
    pub weight_hbm_bytes: u64,
    /// Total weight bytes of the shard this core holds.
    pub shard_weight_bytes: u64,
}

impl SramPlan {
    /// Fraction of the core's weight shard resident in SRAM.
    pub fn weight_resident_fraction(&self) -> f64 {
        if self.shard_weight_bytes == 0 {
            return 1.0;
        }
        self.weight_sram_bytes as f64 / self.shard_weight_bytes as f64
    }

    /// Total planned bytes (must fit the core's SRAM).
    pub fn total(&self) -> u64 {
        self.act_bytes + self.comm_bytes + self.temp_bytes + self.kv_bytes + self.weight_sram_bytes
    }

    /// HBM weight bytes to stream for a `layers` sub-range of the shard
    /// (pipeline stages stream only their own layers).
    pub fn weight_hbm_bytes_for(&self, layer_fraction: f64) -> u64 {
        (self.weight_hbm_bytes as f64 * layer_fraction.clamp(0.0, 1.0)) as u64
    }
}

/// Inputs to the planner describing one core's role.
#[derive(Debug, Clone, Copy)]
pub struct PlanRequest {
    /// Layers this core's group executes (pipeline stage depth).
    pub layers: usize,
    /// Tensor-parallel degree within the group (shards weights and KV).
    pub tp: usize,
    /// Peak tokens per iteration (chunk size × micro-batch for prefill,
    /// batch size for decode).
    pub iter_tokens: usize,
    /// Fraction of the post-buffer remainder given to KV blocks before
    /// weights (best-effort split; 1.0 = all KV, 0.0 = all weights).
    pub kv_share: f64,
}

impl Default for PlanRequest {
    fn default() -> Self {
        PlanRequest {
            layers: 1,
            tp: 1,
            iter_tokens: 512,
            kv_share: 0.5,
        }
    }
}

/// Compute the SRAM plan for one core.
pub fn plan(core: &CoreConfig, model: &ModelConfig, req: &PlanRequest) -> SramPlan {
    let dtype = model.dtype_bytes;
    let hidden = model.hidden as u64;
    let tokens = req.iter_tokens.max(1) as u64;
    let tp = req.tp.max(1) as u64;

    // 1. Activation staging: input + output token slabs, double-buffered so
    //    the next iteration's input streams while this one computes.
    let act = 2 * 2 * tokens * hidden * dtype / tp.max(1);
    // 2. Communication staging: one shard of the largest collective payload
    //    (output activations) for send + recv.
    let widest = hidden.max(model.intermediate as u64);
    let comm = 2 * tokens * widest * dtype / tp;
    // 3. Compute temporaries: a few systolic tiles of partial sums (f32).
    let temp = 4 * core.sa_dim * core.sa_dim * 4;

    let reserved = act + comm + temp;
    let remainder = core.sram_bytes.saturating_sub(reserved);

    // The weight shard this core holds: its layers, TP-sharded.
    let shard_weight = model.layer_weight_bytes() * req.layers as u64 / tp;

    // 4/5. Best-effort split of the remainder between KV and weights. If
    //    weights fit entirely, give them priority (no streaming at all) and
    //    leave the rest to KV — the paper's observation that SRAM only pays
    //    off once the whole model fits (§5.3).
    let (kv, weight_sram) = if shard_weight <= remainder {
        (remainder - shard_weight, shard_weight)
    } else {
        let kv = (remainder as f64 * req.kv_share.clamp(0.0, 1.0)) as u64;
        (kv, remainder - kv)
    };

    SramPlan {
        act_bytes: act,
        comm_bytes: comm,
        temp_bytes: temp,
        kv_bytes: kv,
        weight_sram_bytes: weight_sram.min(shard_weight),
        weight_hbm_bytes: shard_weight.saturating_sub(weight_sram),
        shard_weight_bytes: shard_weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::util::prop::check;
    use crate::util::units::MB;

    fn core() -> CoreConfig {
        ChipConfig::large_core().core // 32 MB SRAM
    }

    #[test]
    fn plan_fits_sram() {
        let m = ModelConfig::qwen3_4b();
        let p = plan(
            &core(),
            &m,
            &PlanRequest {
                layers: 4,
                tp: 4,
                iter_tokens: 512,
                kv_share: 0.5,
            },
        );
        assert!(p.total() <= core().sram_bytes, "{p:?}");
        assert!(p.act_bytes > 0 && p.comm_bytes > 0 && p.temp_bytes > 0);
    }

    #[test]
    fn small_model_weights_fully_resident() {
        // 1 layer of qwen3-1.7B TP=4 is ~20 MB/4 = small vs 32 MB SRAM.
        let m = ModelConfig::qwen3_1_7b();
        let p = plan(
            &core(),
            &m,
            &PlanRequest {
                layers: 1,
                tp: 4,
                iter_tokens: 128,
                kv_share: 0.5,
            },
        );
        assert_eq!(p.weight_hbm_bytes, 0);
        assert!((p.weight_resident_fraction() - 1.0).abs() < 1e-9);
        assert!(p.kv_bytes > 0, "leftover goes to KV");
    }

    #[test]
    fn big_model_streams_weights() {
        // 16 layers of qwen3-32B on one core vastly exceed 32 MB.
        let m = ModelConfig::qwen3_32b();
        let p = plan(
            &core(),
            &m,
            &PlanRequest {
                layers: 16,
                tp: 4,
                iter_tokens: 512,
                kv_share: 0.5,
            },
        );
        assert!(p.weight_hbm_bytes > 0);
        assert!(p.weight_resident_fraction() < 0.1);
        assert!(p.kv_bytes > 0);
    }

    #[test]
    fn kv_share_shifts_the_split() {
        let m = ModelConfig::qwen3_32b();
        let mk = |share: f64| {
            plan(
                &core(),
                &m,
                &PlanRequest {
                    layers: 16,
                    tp: 4,
                    iter_tokens: 512,
                    kv_share: share,
                },
            )
        };
        let kv_heavy = mk(0.9);
        let w_heavy = mk(0.1);
        assert!(kv_heavy.kv_bytes > w_heavy.kv_bytes);
        assert!(kv_heavy.weight_sram_bytes < w_heavy.weight_sram_bytes);
    }

    #[test]
    fn bigger_sram_means_more_resident_weight() {
        let m = ModelConfig::qwen3_8b();
        let req = PlanRequest {
            layers: 9,
            tp: 4,
            iter_tokens: 512,
            kv_share: 0.5,
        };
        let mut small = core();
        small.sram_bytes = 16 * MB;
        let mut big = core();
        big.sram_bytes = 128 * MB;
        let ps = plan(&small, &m, &req);
        let pb = plan(&big, &m, &req);
        assert!(pb.weight_resident_fraction() > ps.weight_resident_fraction());
    }

    #[test]
    fn layer_fraction_scales_hbm_stream() {
        let m = ModelConfig::qwen3_32b();
        let p = plan(&core(), &m, &PlanRequest::default());
        assert_eq!(p.weight_hbm_bytes_for(1.0), p.weight_hbm_bytes);
        assert!(p.weight_hbm_bytes_for(0.5) <= p.weight_hbm_bytes / 2 + 1);
    }

    #[test]
    fn prop_plan_never_exceeds_sram_when_buffers_fit() {
        check("plan fits", 128, |rng| {
            let mut c = core();
            c.sram_bytes = rng.range_u64(8, 128) * MB;
            let models = ModelConfig::paper_models();
            let m = &models[rng.range(0, models.len())];
            let req = PlanRequest {
                layers: rng.range(1, 32),
                tp: 1 << rng.range(0, 5),
                iter_tokens: rng.range(1, 2048),
                kv_share: rng.f64(),
            };
            let p = plan(&c, m, &req);
            let reserved = p.act_bytes + p.comm_bytes + p.temp_bytes;
            if reserved <= c.sram_bytes {
                assert!(p.total() <= c.sram_bytes, "{p:?} vs {}", c.sram_bytes);
            }
            // Weight accounting always conserves the shard.
            assert_eq!(
                p.weight_sram_bytes + p.weight_hbm_bytes,
                p.shard_weight_bytes
            );
        });
    }
}
