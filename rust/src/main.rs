//! `npusim` — CLI for the NpuSim simulator and serving study.
//!
//! ```text
//! npusim experiment <id>|all [--fast] [--out results]   regenerate a paper figure/table
//! npusim simulate [--config f.toml] [--mode fusion|disagg|hybrid] [--chips N --router rr|least|prefix] ...   run one serving simulation (multi-chip with --chips)
//! npusim serve [--artifacts artifacts] [--prompt "1,2,3"] [--n 4]   real tokens via PJRT
//! npusim validate [--fast]     fig7 simulator validation
//! npusim info [--model name]   print chip/model presets
//! ```

use anyhow::{Context, Result};
use npusim::config::{ChipConfig, ModelConfig, PriorityMix, WorkloadConfig};
use npusim::coordinator::{Coordinator, GenRequest};
use npusim::experiments::{self, Opts};
use npusim::model::memo::SimLevel;
use npusim::parallel::plan::{self, ChipRole, DeploymentPlan, SpecConfig};
use npusim::serving::cluster::{
    simulate_cluster, simulate_cluster_requests, ClusterConfig, ClusterMetrics, RouterPolicy,
    ShedPolicy, ShedScope,
};
use npusim::serving::fleet::{ChipSpec, FleetSpec};
use npusim::serving::faults::{FaultSchedule, RecoveryPolicy};
use npusim::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::serving::scheduler::{self, HybridConfig, HybridScheduler, SchedulerConfig};
use npusim::serving::Metrics;
use npusim::sim::chip::ChipSim;
use npusim::util::cli::Args;
use npusim::util::table::{f3, Table};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(args),
        Some("simulate") => cmd_simulate(args),
        Some("serve") => cmd_serve(args),
        Some("validate") => {
            let opts = opts_from(args);
            experiments::run("fig7a", &opts)?;
            experiments::run("fig7b", &opts)?;
            Ok(())
        }
        Some("info") => cmd_info(args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?}; see --help in README"),
        None => {
            println!(
                "npusim — LLM serving on multi-core NPUs (paper reproduction)\n\
                 subcommands: experiment | simulate | serve | validate | info\n\
                 e.g.  npusim experiment fig9\n      npusim experiment all --fast\n      \
                 npusim experiment bench            # emits BENCH_serving.json\n      \
                 npusim simulate --mode fusion --model qwen3_4b --input 512 --output 64\n      \
                 npusim simulate --plan auto --input 512 --output 64   # auto-planned deployment\n      \
                 npusim simulate --mode hybrid --shared-prefix 1024 --prefix-cache --memo\n      \
                 npusim simulate --prefix-cache --hbm-tier --cross-pipe --shared-prefix 1024\n      \
                 npusim simulate --chips 4 --router prefix --prefix-cache --shared-prefix 1024\n      \
                 npusim simulate --chips 2 --priority-mix 0.2:0.3 --shed-policy drop --slo-ttft 1.0\n      \
                 npusim simulate --chips 4 --faults crash:0@0.5 --fault-recovery recover\n      \
                 npusim simulate --chips 4 --roles p,p,d,d        # fleet PD disaggregation\n      \
                 npusim simulate --chips 4 --fleet auto           # planner picks roles\n      \
                 npusim simulate --chips 4 --fault-seed 42 --chip-mttf 5.0 --shed-policy drop --shed-scope per-chip\n      \
                 npusim simulate --chips 16 --sim-level fast --sim-threads 8   # two-speed simulation\n      \
                 npusim simulate --mode fusion --spec gamma=4,accept=0.8   # speculative decoding\n      \
                 npusim serve --prompt \"1,2,3,4\""
            );
            Ok(())
        }
    }
}

fn opts_from(args: &Args) -> Opts {
    Opts {
        fast: args.flag("fast"),
        out_dir: match args.opt("out") {
            Some(dir) => Some(dir.into()),
            None => Some("results".into()),
        },
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .context("usage: npusim experiment <id>|all")?;
    let opts = opts_from(args);
    if id == "all" {
        for id in experiments::ALL {
            println!(">>> experiment {id}");
            experiments::run(id, &opts)?;
        }
    } else {
        experiments::run(id, &opts)?;
    }
    Ok(())
}

fn chip_from(args: &Args) -> Result<ChipConfig> {
    let mut chip = match args.opt_or("chip", "large_core") {
        "large_core" | "large" => ChipConfig::large_core(),
        "small_core" | "small" => ChipConfig::small_core(),
        "ascend" | "ascend910b" => ChipConfig::ascend910b_like(),
        other => anyhow::bail!("unknown chip {other:?}"),
    };
    if let Some(mb) = args.opt_parse::<u64>("sram-mb")? {
        chip = chip.with_sram_mb(mb);
    }
    if let Some(sa) = args.opt_parse::<u64>("sa-dim")? {
        chip = chip.with_sa_dim(sa);
    }
    if let Some(bw) = args.opt_parse::<f64>("hbm-bw")? {
        chip = chip.with_hbm_bw(bw);
    }
    chip.validate()?;
    Ok(chip)
}

/// `--hbm-tier-frac` with bound validation (plan-settable knob; the
/// default is the former fixed 1/8 carve).
fn tier_frac_from(args: &Args) -> Result<f64> {
    let f = args.opt_parse_or("hbm-tier-frac", plan::DEFAULT_HBM_TIER_FRAC)?;
    anyhow::ensure!(
        f > 0.0 && f < 1.0,
        "--hbm-tier-frac must be a fraction in (0, 1), got {f}"
    );
    Ok(f)
}

/// Fusion-pipeline knobs shared by `--mode fusion` and `--mode hybrid`.
fn fusion_cfg_from(args: &Args) -> Result<FusionConfig> {
    let defaults = FusionConfig::default();
    Ok(FusionConfig {
        tp: args.opt_parse_or("tp", 4)?,
        stages: args.opt_parse_or("stages", 4)?,
        chunk: args.opt_parse_or("chunk", 256)?,
        budget: args.opt_parse_or("budget", 288)?,
        prefix_cache: args.flag("prefix-cache"),
        hbm_tier: args.flag("hbm-tier"),
        hbm_tier_frac: tier_frac_from(args)?,
        cross_pipe: args.flag("cross-pipe"),
        affinity_gap: args.opt_parse_or("affinity-gap", defaults.affinity_gap)?,
        memo: args.flag("memo"),
        sim_level: sim_level_from(args)?,
        slo_preempt: args.opt_parse::<f64>("slo-preempt")?,
        spec: spec_from(args)?,
        ..defaults
    })
}

/// `--spec gamma=K,accept=P[,draft=F]` — speculative decoding. Unset
/// keeps vanilla one-token-per-iteration decode bit-identical.
fn spec_from(args: &Args) -> Result<Option<SpecConfig>> {
    match args.opt("spec") {
        Some(s) => Ok(Some(SpecConfig::parse(s)?)),
        None => Ok(None),
    }
}

/// `--sim-level txn|fast` (default txn, the bit-exact transaction level).
fn sim_level_from(args: &Args) -> Result<SimLevel> {
    match args.opt("sim-level") {
        Some(s) => SimLevel::parse(s),
        None => Ok(SimLevel::Txn),
    }
}

/// Disaggregation knobs for `--mode disagg`.
fn disagg_cfg_from(args: &Args) -> Result<DisaggConfig> {
    Ok(DisaggConfig {
        n_prefill: args.opt_parse_or("prefill-cores", 42)?,
        n_decode: args.opt_parse_or("decode-cores", 21)?,
        prefill_stages: args.opt_parse_or("stages", 6)?,
        prefix_cache: args.flag("prefix-cache"),
        hbm_tier: args.flag("hbm-tier"),
        hbm_tier_frac: tier_frac_from(args)?,
        cross_pipe: args.flag("cross-pipe"),
        memo: args.flag("memo"),
        sim_level: sim_level_from(args)?,
        spec: spec_from(args)?,
        ..DisaggConfig::default()
    })
}

/// Resolve `--plan auto|<preset>` into a concrete [`DeploymentPlan`]:
/// `auto` searches the feasible space for the (chip, model, workload)
/// triple and prints the top analytic candidates; a preset name loads it
/// directly. Cache/feature flags (`--prefix-cache --hbm-tier --cross-pipe
/// --memo --hbm-tier-frac --affinity-gap`) compose on top of the plan's
/// layout.
fn plan_from(
    args: &Args,
    which: &str,
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
) -> Result<DeploymentPlan> {
    let mut plan = if which == "auto" {
        let ranked = plan::auto_plan(chip, model, workload)?;
        let mut t = Table::new(
            &format!(
                "auto-planner — top candidates of {} feasible ({} / {} / {})",
                ranked.len(),
                chip.name,
                model.name,
                workload.name
            ),
            &["rank", "plan", "prefill cyc/tok", "decode cyc/tok", "score (Mcyc)"],
        );
        for (i, c) in ranked.iter().take(5).enumerate() {
            t.row(&[
                (i + 1).to_string(),
                c.plan.name.clone(),
                f3(c.score.prefill_cycles_per_token),
                f3(c.score.decode_cycles_per_token),
                f3(c.score.total_cycles / 1e6),
            ]);
        }
        t.print();
        ranked.into_iter().next().expect("auto_plan non-empty").plan
    } else {
        DeploymentPlan::preset(which)?
    };
    if args.flag("prefix-cache") {
        plan.prefix_cache = true;
    }
    if args.flag("hbm-tier") {
        plan.hbm_tier = true;
    }
    if args.flag("cross-pipe") {
        plan.cross_pipe = true;
    }
    if args.flag("memo") {
        plan.memo = true;
    }
    plan.hbm_tier_frac = tier_frac_from(args)?;
    if let Some(gap) = args.opt_parse::<usize>("affinity-gap")? {
        plan.affinity_gap = gap;
    }
    if let Some(spec) = spec_from(args)? {
        plan.spec = Some(spec);
    }
    println!("{}", plan.summary());
    Ok(plan)
}

/// Hybrid controller knobs for `--mode hybrid`.
fn hybrid_cfg_from(args: &Args) -> Result<HybridConfig> {
    let defaults = HybridConfig::default();
    Ok(HybridConfig {
        fusion: fusion_cfg_from(args)?,
        window: args.opt_parse_or("window", defaults.window)?,
        hysteresis: args.opt_parse_or("hysteresis", defaults.hysteresis)?,
        min_dwell: args.opt_parse_or("min-dwell", defaults.min_dwell)?,
        ..defaults
    })
}

/// `--mode` mapped onto a data-driven scheduler config (cluster path).
fn sched_cfg_from(args: &Args, mode: &str) -> Result<SchedulerConfig> {
    Ok(match mode {
        "fusion" => SchedulerConfig::Fusion(fusion_cfg_from(args)?),
        "disagg" => SchedulerConfig::Disagg(disagg_cfg_from(args)?),
        "hybrid" => SchedulerConfig::Hybrid(hybrid_cfg_from(args)?),
        other => anyhow::bail!("unknown mode {other:?} (fusion|disagg|hybrid)"),
    })
}

/// `--fault-recovery recover|resubmit[:timeout_s]`.
fn recovery_from(s: &str) -> Result<RecoveryPolicy> {
    match s {
        "recover" => Ok(RecoveryPolicy::Recover),
        "resubmit" => Ok(RecoveryPolicy::Resubmit {
            client_timeout_s: 1.0,
        }),
        other => match other.strip_prefix("resubmit:") {
            Some(t) => Ok(RecoveryPolicy::Resubmit {
                client_timeout_s: t
                    .parse::<f64>()
                    .context("--fault-recovery resubmit:<timeout seconds>")?,
            }),
            None => anyhow::bail!(
                "unknown recovery policy {other:?} (recover|resubmit[:timeout_s])"
            ),
        },
    }
}

/// Overload control-plane knobs shared by both cluster paths
/// (`--shed-policy none|drop|defer`, `--shed-scope global|per-chip`,
/// `--queue-cap N`, `--slo-ttft S`), plus fault injection
/// (`--faults SPEC` or `--fault-seed N --chip-mttf S`, tuned by
/// `--fault-heartbeat/--fault-retries/--fault-backoff/--fault-recovery`).
fn apply_control_plane(args: &Args, mut cfg: ClusterConfig) -> Result<ClusterConfig> {
    if let Some(policy) = args.opt("shed-policy") {
        let cap = args.opt_parse_or("queue-cap", cfg.queue_cap)?;
        cfg = cfg.with_shed(ShedPolicy::parse(policy)?, cap);
    }
    if let Some(scope) = args.opt("shed-scope") {
        cfg = cfg.with_shed_scope(ShedScope::parse(scope)?);
    }
    cfg.slo_ttft_s = args.opt_parse_or("slo-ttft", cfg.slo_ttft_s)?;
    cfg.sim_threads = args.opt_parse_or("sim-threads", cfg.sim_threads)?.max(1);
    // Fault injection: an explicit schedule, or a seeded chaos draw from
    // a per-chip MTTF over a horizon.
    let schedule = match (args.opt("faults"), args.opt_parse::<u64>("fault-seed")?) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--faults and --fault-seed are mutually exclusive")
        }
        (Some(spec), None) => Some(FaultSchedule::parse(spec)?),
        (None, Some(seed)) => {
            let mttf = args.opt_parse::<f64>("chip-mttf")?.context(
                "--fault-seed needs --chip-mttf <seconds> (per-chip mean time to failure)",
            )?;
            let horizon = args.opt_parse_or("fault-horizon", 10.0)?;
            Some(FaultSchedule::seeded(seed, cfg.n_chips(), horizon, mttf))
        }
        (None, None) => None,
    };
    match schedule {
        Some(mut s) => {
            if let Some(hb) = args.opt_parse::<f64>("fault-heartbeat")? {
                s = s.with_heartbeat(hb);
            }
            let retries = args.opt_parse_or("fault-retries", s.max_retries)?;
            let backoff = args.opt_parse_or("fault-backoff", s.retry_backoff_s)?;
            s = s.with_retries(retries, backoff);
            if let Some(r) = args.opt("fault-recovery") {
                s = s.with_recovery(recovery_from(r)?);
            }
            cfg = cfg.with_faults(s);
        }
        None => {
            // Tuning knobs without a schedule would be silently inert.
            for k in [
                "chip-mttf",
                "fault-horizon",
                "fault-heartbeat",
                "fault-retries",
                "fault-backoff",
                "fault-recovery",
            ] {
                anyhow::ensure!(
                    args.opt(k).is_none(),
                    "--{k} needs a fault schedule: pass --faults SPEC or --fault-seed N"
                );
            }
        }
    }
    Ok(cfg)
}

/// `--roles p,d,g,...` (one entry per chip): a role-specialized fleet.
/// Prefill chips get the compute-heavy silicon variant, decode chips the
/// HBM-heavy one; general chips keep the CLI-selected chip.
fn fleet_from_roles(
    spec: &str,
    n_chips: usize,
    general: ChipConfig,
    sched: SchedulerConfig,
) -> Result<FleetSpec> {
    let roles = spec
        .split(',')
        .map(|s| ChipRole::parse(s.trim()))
        .collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(
        roles.len() == n_chips,
        "--roles lists {} chips but --chips is {n_chips}",
        roles.len()
    );
    let chips = roles
        .into_iter()
        .map(|role| {
            let hw = match role {
                ChipRole::Prefill => ChipConfig::prefill_optimized(),
                ChipRole::Decode => ChipConfig::decode_optimized(),
                ChipRole::General => general.clone(),
            };
            ChipSpec::new(hw, sched).with_role(role)
        })
        .collect();
    Ok(FleetSpec::new(chips))
}

fn print_cluster(name: &str, cm: &ClusterMetrics, slo_ttft_s: f64, freq_mhz: f64) {
    let mut t = Table::new(
        &format!("cluster serving — {name}"),
        &[
            "chip",
            "requests",
            "tok/s",
            "TTFT p50 (s)",
            "TTFT p99 (s)",
            "TBT p99 (ms)",
        ],
    );
    for (i, m) in cm.per_chip.iter().enumerate() {
        let mut ttft = m.ttft_s();
        let mut tbt = m.tbt_s();
        t.row(&[
            format!("chip{i}"),
            m.n_requests().to_string(),
            f3(m.tokens_per_s()),
            f3(ttft.median()),
            f3(ttft.p99()),
            f3(tbt.p99() * 1e3),
        ]);
    }
    let agg = cm.aggregate();
    let mut ttft = agg.ttft_s();
    let mut tbt = agg.tbt_s();
    t.row(&[
        "aggregate".into(),
        agg.n_requests().to_string(),
        f3(agg.tokens_per_s()),
        f3(ttft.median()),
        f3(ttft.p99()),
        f3(tbt.p99() * 1e3),
    ]);
    t.print();
    println!(
        "routing: {:?}  |  migrations: {}  |  interconnect: {} transfers, {:.2} MB",
        cm.routed,
        cm.migrations,
        cm.interconnect.transfers,
        cm.interconnect.bytes as f64 / (1 << 20) as f64
    );
    let c = &agg.cache;
    if c.prefix_lookups > 0 {
        println!(
            "prefix cache: hit rate {:.1}%, {} prefill tokens skipped",
            c.prefix_hit_rate() * 100.0,
            c.prefill_tokens_skipped
        );
    }
    // Control-plane lines only when the overload machinery actually ran,
    // so legacy invocations keep byte-identical output.
    let ctl = &agg.control;
    if ctl.shed_requests + ctl.deferrals + ctl.preemptions + ctl.resumes > 0 {
        println!(
            "control plane: shed {} (H/N/L {}/{}/{}), deferrals {}, preemptions {}, \
             resumes {} (mean resume wait {:.0} cyc)",
            ctl.shed_requests,
            ctl.shed_by_class[2],
            ctl.shed_by_class[1],
            ctl.shed_by_class[0],
            ctl.deferrals,
            ctl.preemptions,
            ctl.resumes,
            ctl.mean_resume_wait()
        );
        println!(
            "goodput under SLO (TTFT<{:.2}s, TBT<50ms): {:.1} tok/s  |  shed rate {:.1}%",
            slo_ttft_s,
            agg.goodput_tokens_per_s(slo_ttft_s, 0.050),
            agg.shed_rate() * 100.0
        );
    }
    // Fault lines only when a fault actually fired, so fault-free runs
    // keep byte-identical output.
    let fs = &cm.faults;
    if fs.crashes + fs.degradations > 0 {
        println!(
            "faults: {} crash(es) ({} restarted, mean detection {:.1} ms), {} degradation window(s)",
            fs.crashes,
            fs.restarts,
            fs.mean_detect_s(freq_mhz) * 1e3,
            fs.degradations
        );
    }
    if fs.recovered + fs.retries + fs.recovery_shed > 0 {
        println!(
            "recovery: {} recovered in {} retries ({} shed after the retry budget), \
             tokens recomputed {} / restored from surviving KV {}",
            fs.recovered, fs.retries, fs.recovery_shed, fs.tokens_recomputed, fs.tokens_restored
        );
    }
}

fn print_metrics(name: &str, m: &Metrics, chip: &ChipSim) {
    let mut t = Table::new(
        &format!("serving metrics — {name}"),
        &["metric", "value"],
    );
    let mut ttft = m.ttft_s();
    let mut tbt = m.tbt_s();
    let e2e = m.e2e_s();
    t.row(&["requests".into(), m.n_requests().to_string()]);
    t.row(&["TTFT mean (s)".into(), f3(ttft.mean())]);
    t.row(&["TTFT p99 (s)".into(), f3(ttft.p99())]);
    t.row(&["TBT mean (ms)".into(), f3(tbt.mean() * 1e3)]);
    t.row(&["TBT p99 (ms)".into(), f3(tbt.p99() * 1e3)]);
    t.row(&["e2e mean (s)".into(), f3(e2e.mean())]);
    t.row(&["throughput (tok/s)".into(), f3(m.tokens_per_s())]);
    t.row(&["requests/s".into(), f3(m.requests_per_s())]);
    // SLO attainment at a typical interactive target (§4.3: scheduling is
    // driven by TTFT/TBT SLOs).
    t.row(&[
        "SLO attainment (TTFT<2s, TBT<50ms)".into(),
        f3(m.slo_attainment(2.0, 0.050) * 100.0),
    ]);
    // Prefix-cache / memo counters, when those features ran.
    let c = &m.cache;
    if c.prefix_lookups > 0 {
        t.row(&[
            "prefix-cache hit rate (%)".into(),
            f3(c.prefix_hit_rate() * 100.0),
        ]);
        t.row(&[
            "prefill tokens skipped".into(),
            format!("{} ({:.1}%)", c.prefill_tokens_skipped, c.token_skip_rate() * 100.0),
        ]);
        t.row(&[
            "KV bytes deduplicated (MB)".into(),
            f3(c.kv_bytes_deduped as f64 / (1 << 20) as f64),
        ]);
        t.row(&["COW copies".into(), c.cow_copies.to_string()]);
        t.row(&["prefix evictions".into(), c.prefix_evictions.to_string()]);
        if c.tier_demotions + c.tier_promotions + c.tier_dropped > 0 {
            t.row(&[
                "HBM tier demotions/promotions/drops".into(),
                format!(
                    "{}/{}/{}",
                    c.tier_demotions, c.tier_promotions, c.tier_dropped
                ),
            ]);
        }
        if c.noc_prefix_imports > 0 {
            t.row(&[
                "cross-pipe NoC imports (tokens)".into(),
                format!("{} ({})", c.noc_prefix_imports, c.noc_prefix_tokens),
            ]);
        }
    }
    if c.memo_hits + c.memo_misses > 0 {
        t.row(&[
            "op-latency memo hit rate (%)".into(),
            f3(c.memo_hit_rate() * 100.0),
        ]);
    }
    t.print();
    println!("\nper-op cycle breakdown:");
    for (class, cycles, pct) in chip.aggregate_tracer().breakdown() {
        println!("  {:<12} {:>14} cycles  {:>5.1}%", class.name(), cycles, pct);
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // Optional TOML config; flags override.
    let bundle = if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Some(npusim::config::load_sim_config(&text)?)
    } else {
        None
    };
    let chip_cfg = match &bundle {
        Some(b) => b.chip.clone(),
        None => chip_from(args)?,
    };
    let model = match args.opt("model") {
        Some(name) => ModelConfig::by_name(name)?,
        None => bundle
            .as_ref()
            .map(|b| b.model.clone())
            .unwrap_or_else(ModelConfig::qwen3_4b),
    };
    let n = args.opt_parse_or::<usize>("requests", 16)?;
    let mut workload = match (args.opt_parse::<usize>("input")?, args.opt_parse::<usize>("output")?)
    {
        (Some(i), Some(o)) => WorkloadConfig::fixed_ratio(i, o, n),
        _ => bundle
            .as_ref()
            .map(|b| b.workload.clone())
            .unwrap_or_else(|| WorkloadConfig::decode_dominated(n)),
    };
    // Shared-prefix / multi-turn structure (`--shared-prefix <tokens>`
    // switches it on; pair with `--prefix-cache` to reuse the blocks).
    if let Some(shared) = args.opt_parse::<usize>("shared-prefix")? {
        let defaults = npusim::config::PrefixSharing::default();
        workload = workload.with_prefix(npusim::config::PrefixSharing {
            shared_prefix_len: shared,
            n_groups: args.opt_parse_or("prefix-groups", defaults.n_groups)?,
            turns: args.opt_parse_or("turns", defaults.turns)?,
            think_time_s: args.opt_parse_or("think-time", defaults.think_time_s)?,
        });
        workload.name = format!("{}+prefix{shared}", workload.name);
    }

    // Priority classes (`--priority-mix HIGH:LOW`, e.g. `0.2:0.3`): the
    // remainder of the mass is normal-priority. Unset = every request is
    // normal and the generator stays bit-identical to the legacy trace.
    if let Some(mix) = args.opt("priority-mix") {
        workload = workload.with_priority_mix(PriorityMix::parse(mix)?);
        workload.name = format!("{}+prio{mix}", workload.name);
    }

    // Trace replay (`--trace file.jsonl`) overrides the synthetic workload.
    let trace = match args.opt("trace") {
        Some(path) => Some(npusim::serving::trace::load_jsonl(
            path,
            args.opt_parse::<usize>("requests")?,
        )?),
        None => None,
    };

    let mode = args.opt_or("mode", "fusion");
    if (args.flag("hbm-tier") || args.flag("cross-pipe")) && !args.flag("prefix-cache") {
        anyhow::bail!("--hbm-tier and --cross-pipe extend the prefix cache: pass --prefix-cache");
    }

    // Multi-chip cluster path (`--chips N --router rr|least|prefix`): N
    // identical chips behind streamed admission and the chosen router.
    let n_chips = args.opt_parse_or::<usize>("chips", 1)?;
    if n_chips <= 1 && (args.opt("router").is_some() || args.opt("migrate-gap").is_some()) {
        anyhow::bail!("--router/--migrate-gap need a multi-chip cluster: pass --chips N (N > 1)");
    }
    // Fleet specialization (`--fleet auto` or `--roles p,d,...`) is
    // cluster-frontend machinery as well.
    if n_chips <= 1 && (args.opt("fleet").is_some() || args.opt("roles").is_some()) {
        anyhow::bail!("--fleet/--roles need a multi-chip cluster: pass --chips N (N > 1)");
    }
    // The overload control plane (admission shedding, SLO accounting)
    // lives in the cluster frontend, so its knobs need `--chips`.
    if n_chips <= 1
        && (args.opt("shed-policy").is_some()
            || args.opt("queue-cap").is_some()
            || args.opt("slo-ttft").is_some())
    {
        anyhow::bail!(
            "--shed-policy/--queue-cap/--slo-ttft need a multi-chip cluster: pass --chips N (N > 1)"
        );
    }
    // Likewise fault injection and recovery: heartbeat detection and
    // retry routing are frontend machinery.
    if n_chips <= 1 {
        for k in [
            "faults",
            "fault-seed",
            "chip-mttf",
            "fault-horizon",
            "fault-heartbeat",
            "fault-retries",
            "fault-backoff",
            "fault-recovery",
            "shed-scope",
        ] {
            anyhow::ensure!(
                args.opt(k).is_none(),
                "--{k} needs a multi-chip cluster: pass --chips N (N > 1)"
            );
        }
    }

    // First-class deployment plan (`--plan auto|<preset>`): TP strategy,
    // placement, pipeline depth and PD mode come from the searched (or
    // preset) plan instead of `--mode`/`--tp`/`--stages`.
    if let Some(which) = args.opt("plan") {
        // Two planning paths cannot both decide the deployment.
        anyhow::ensure!(
            args.opt("fleet").is_none() && args.opt("roles").is_none(),
            "--fleet/--roles conflict with --plan: use one planning path"
        );
        // The plan owns the layout: a legacy layout flag alongside --plan
        // would be silently ignored, so reject the conflict outright
        // (the same stance `--router` without `--chips` takes above).
        for legacy in [
            "mode",
            "tp",
            "stages",
            "chunk",
            "budget",
            "prefill-cores",
            "decode-cores",
            "window",
            "hysteresis",
            "min-dwell",
        ] {
            anyhow::ensure!(
                args.opt(legacy).is_none(),
                "--{legacy} conflicts with --plan: the plan decides the layout \
                 (use --plan auto or edit a preset instead)"
            );
        }
        // The planner must rank against the traffic that will actually
        // run: on trace replay, distil the trace into a surrogate
        // workload (mean prompt/output lengths, request count) instead of
        // the synthetic default the trace overrides.
        let plan_workload = match &trace {
            Some(reqs) if !reqs.is_empty() => {
                let n = reqs.len();
                let mean_in = reqs.iter().map(|r| r.input_len).sum::<usize>() / n;
                let mean_out = reqs.iter().map(|r| r.output_len).sum::<usize>() / n;
                let mut w = WorkloadConfig::fixed_ratio(mean_in.max(1), mean_out.max(1), n);
                w.name = format!("trace≈{}", w.name);
                w
            }
            _ => workload.clone(),
        };
        let plan = plan_from(args, which, &chip_cfg, &model, &plan_workload)?;
        if n_chips > 1 {
            let router = RouterPolicy::parse(args.opt_or("router", "least"))?;
            let mut cluster_cfg = ClusterConfig::from_plan(chip_cfg, n_chips, &plan, router)?;
            if let Some(gap) = args.opt_parse::<usize>("migrate-gap")? {
                cluster_cfg.migrate_load_gap = gap;
            }
            cluster_cfg = apply_control_plane(args, cluster_cfg)?;
            let cm = match trace {
                Some(reqs) => simulate_cluster_requests(&cluster_cfg, &model, reqs)?,
                None => simulate_cluster(&cluster_cfg, &model, &workload)?,
            };
            print_cluster(
                &format!(
                    "plan {} × {n_chips} chips / {} router / {} / {}",
                    plan.name,
                    router.name(),
                    model.name,
                    workload.name
                ),
                &cm,
                cluster_cfg.slo_ttft_s,
                cluster_cfg.freq_mhz(),
            );
            return Ok(());
        }
        let sys = SchedulerConfig::from_plan(&plan)?;
        let mut chip = ChipSim::new(chip_cfg);
        let mut sched = sys.build();
        let metrics = match trace {
            Some(reqs) => scheduler::simulate_requests(&mut chip, &model, reqs, sched.as_mut())?,
            None => scheduler::simulate(&mut chip, &model, &workload, sched.as_mut())?,
        };
        print_metrics(
            &format!("plan {} / {} / {}", plan.name, model.name, workload.name),
            &metrics,
            &chip,
        );
        return Ok(());
    }

    if n_chips > 1 {
        let router = RouterPolicy::parse(args.opt_or("router", "least"))?;
        anyhow::ensure!(
            args.opt("fleet").is_none() || args.opt("roles").is_none(),
            "--fleet plans chip roles itself: pass either --fleet auto or --roles, not both"
        );
        let (label, fleet) = if let Some(which) = args.opt("fleet") {
            // The fleet planner owns each chip's scheduler layout, so the
            // single-chip layout flags would be silently ignored alongside
            // it (the same stance --plan takes).
            for legacy in [
                "mode",
                "tp",
                "stages",
                "chunk",
                "budget",
                "prefill-cores",
                "decode-cores",
                "window",
                "hysteresis",
                "min-dwell",
            ] {
                anyhow::ensure!(
                    args.opt(legacy).is_none(),
                    "--{legacy} conflicts with --fleet: the fleet planner decides each \
                     chip's layout"
                );
            }
            anyhow::ensure!(which == "auto", "unknown fleet mode {which:?} (auto)");
            let fp = plan::plan_fleet(
                &chip_cfg,
                &model,
                &workload,
                n_chips,
                &npusim::sim::interconnect::InterconnectConfig::default(),
            )?;
            println!("fleet plan: {}", fp.summary());
            (fp.name.clone(), FleetSpec::from_plan_fleet(&fp)?)
        } else if let Some(spec) = args.opt("roles") {
            (
                format!("{mode}+roles[{spec}]"),
                fleet_from_roles(spec, n_chips, chip_cfg, sched_cfg_from(args, mode)?)?,
            )
        } else {
            (
                mode.to_string(),
                FleetSpec::homogeneous(chip_cfg, n_chips, sched_cfg_from(args, mode)?),
            )
        };
        let mut cluster_cfg = ClusterConfig::builder(fleet).router(router).build();
        if let Some(gap) = args.opt_parse::<usize>("migrate-gap")? {
            cluster_cfg.migrate_load_gap = gap;
        }
        cluster_cfg = apply_control_plane(args, cluster_cfg)?;
        let cm = match trace {
            Some(reqs) => simulate_cluster_requests(&cluster_cfg, &model, reqs)?,
            None => simulate_cluster(&cluster_cfg, &model, &workload)?,
        };
        if cm.handoffs > 0 {
            println!("fleet handoffs: {} prefill→decode KV transfers", cm.handoffs);
        }
        print_cluster(
            &format!(
                "{label} × {n_chips} chips / {} router / {} / {}",
                router.name(),
                model.name,
                workload.name
            ),
            &cm,
            cluster_cfg.slo_ttft_s,
            cluster_cfg.freq_mhz(),
        );
        return Ok(());
    }

    let mut chip = ChipSim::new(chip_cfg);
    let metrics = match mode {
        "fusion" => {
            let cfg = fusion_cfg_from(args)?;
            match trace {
                Some(reqs) => npusim::serving::pd_fusion::simulate_fusion_requests(
                    &mut chip, &model, reqs, &cfg,
                )?,
                None => simulate_fusion(&mut chip, &model, &workload, &cfg)?,
            }
        }
        "disagg" => {
            let cfg = disagg_cfg_from(args)?;
            match trace {
                Some(reqs) => npusim::serving::pd_disagg::simulate_disagg_requests(
                    &mut chip, &model, reqs, &cfg,
                )?,
                None => simulate_disagg(&mut chip, &model, &workload, &cfg)?,
            }
        }
        "hybrid" => {
            let cfg = hybrid_cfg_from(args)?;
            let mut sched = HybridScheduler::new(cfg);
            let metrics = match trace {
                Some(reqs) => {
                    scheduler::simulate_requests(&mut chip, &model, reqs, &mut sched)?
                }
                None => scheduler::simulate(&mut chip, &model, &workload, &mut sched)?,
            };
            println!(
                "hybrid controller: {} dedicated prefill pipeline(s) at exit, {} re-partition(s)",
                sched.n_prefill_pipes(),
                sched.repartitions()
            );
            metrics
        }
        other => anyhow::bail!("unknown mode {other:?} (fusion|disagg|hybrid)"),
    };
    print_metrics(
        &format!("{mode} / {} / {}", model.name, workload.name),
        &metrics,
        &chip,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", npusim::runtime::ARTIFACT_DIR);
    let coord = Coordinator::start(dir)?;
    println!(
        "loaded TinyQwen artifacts: vocab={} hidden={} layers={} (decode batch {})",
        coord.meta.vocab, coord.meta.hidden, coord.meta.layers, coord.meta.decode_batch
    );
    let n = args.opt_parse_or::<usize>("n", 2)?;
    let max_new = args.opt_parse_or::<usize>("max-new-tokens", 16)?;
    let prompts: Vec<Vec<i32>> = match args.opt("prompt") {
        Some(p) => vec![p
            .split(',')
            .map(|t| t.trim().parse::<i32>().context("bad token id"))
            .collect::<Result<_>>()?],
        None => (0..n)
            .map(|i| (0..8).map(|j| (i * 31 + j * 7) as i32).collect())
            .collect(),
    };
    let reqs: Vec<GenRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| GenRequest {
            id: i as u64,
            prompt: p.clone(),
            max_new_tokens: max_new,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let responses = coord.generate(reqs)?;
    let dt = t0.elapsed().as_secs_f64();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    for r in &responses {
        println!("request {} -> {:?}", r.id, r.tokens);
    }
    println!(
        "{total_tokens} tokens in {dt:.3}s ({:.1} tok/s)",
        total_tokens as f64 / dt
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "model presets",
        &["name", "layers", "hidden", "heads/kv", "params (B)", "weights (GiB)"],
    );
    for m in ModelConfig::paper_models() {
        if let Some(filter) = args.opt("model") {
            if !m.name.contains(filter) {
                continue;
            }
        }
        t.row(&[
            m.name.clone(),
            m.layers.to_string(),
            m.hidden.to_string(),
            format!("{}/{}", m.heads, m.kv_heads),
            f3(m.n_params() as f64 / 1e9),
            f3(m.weight_bytes() as f64 / (1 << 30) as f64),
        ]);
    }
    t.print();
    let mut c = Table::new(
        "chip presets (Table 3)",
        &["name", "cores", "SA", "SRAM/core", "HBM bw/core", "NoC link"],
    );
    for chip in [
        ChipConfig::large_core(),
        ChipConfig::small_core(),
        ChipConfig::ascend910b_like(),
    ] {
        c.row(&[
            chip.name.clone(),
            chip.n_cores().to_string(),
            format!("{0}x{0}", chip.core.sa_dim),
            npusim::util::units::fmt_bytes(chip.core.sram_bytes),
            format!("{} GB/s", chip.core.hbm_bw_gbps),
            format!("{} GB/s", chip.noc.link_bw_gbps),
        ]);
    }
    c.print();
    Ok(())
}
