"""AOT export: lower the TinyQwen entry points to HLO **text** artifacts
the rust runtime loads via the `xla` crate.

HLO text — NOT `lowered.compile()` / serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: pathlib.Path, seed: int = 0) -> dict:
    c = model.CONFIG
    out_dir.mkdir(parents=True, exist_ok=True)
    prefill_fn, decode_fn = model.entry_points(seed)

    b, p = c["decode_batch"], c["prefill_len"]
    kv_shape = (c["layers"], 2, b, c["max_seq"], c["kv_heads"], c["head_dim"])

    tok_p = jax.ShapeDtypeStruct((b, p), jnp.int32)
    tok_d = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    kv = jax.ShapeDtypeStruct(kv_shape, jnp.float32)

    outputs = {}
    for name, lowered in [
        ("prefill", jax.jit(prefill_fn).lower(tok_p)),
        ("decode", jax.jit(decode_fn).lower(tok_d, pos, kv)),
    ]:
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        outputs[name] = path
        print(f"wrote {path} ({len(text)} chars)")

    meta = "".join(f"{k}={v}\n" for k, v in c.items())
    meta_path = out_dir / "model_meta.txt"
    meta_path.write_text(meta)
    outputs["meta"] = meta_path
    print(f"wrote {meta_path}")
    return outputs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", type=pathlib.Path)
    ap.add_argument("--seed", default=0, type=int)
    args = ap.parse_args()
    export(args.out_dir, args.seed)


if __name__ == "__main__":
    main()
