//! `spec_study` — end-to-end speculative decoding on the standard chip:
//! vanilla one-token-per-iteration decode vs `--spec` at
//! gamma ∈ {2, 4, 8} × acceptance ∈ {0.6, 0.8, 0.95}, all on one
//! chip-wide fused pipeline (Qwen3-4B, large-core-64).
//!
//! The win must come out of the modeled traffic, not a bolted-on scalar:
//! a verify round batches `d + 1` query tokens per request into ONE
//! iteration, so the per-iteration HBM weight stream (and the per-round
//! KV read) amortizes over `1 + E[accepted]` committed tokens — the
//! `tokens/weight-stream` column. The verify batch `M = batch·(γ+1)`
//! also crosses the cost-model-learned Fig. 9 threshold
//! ([`crate::parallel::plan::learned_m_threshold`]) where plain decode
//! stays below it, flipping the GEMM partition from the K-split to the
//! MN-split — the `verify M ≥ thresh` column counts those iterations.
//!
//! Every row must conserve tokens exactly: `completed == offered` and
//! the decode path must commit exactly `Σ (output_len − 1)` tokens
//! (the first token comes from prefill), whatever mix of acceptance,
//! rollback and preemption the row ran under. A dedicated
//! `+preempt` row parks requests mid-speculation (priority preemption
//! under a tiny batch cap) and must conserve identically.
//!
//! The acceptance properties (gated via `BENCH_serving.json`'s `"spec"`
//! section): gamma=4/accept=0.8 strictly beats vanilla on TBT p50 and
//! on goodput-under-SLO, and at least one spec row's verify batches
//! cross the learned threshold.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment spec_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::plan::{self, SpecConfig};
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Priority, Request};
use crate::serving::scheduler::{self, SchedulerConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// Concurrent requests of the main comparison — large enough that the
/// gamma=8 verify batch `M = n·9` crosses the learned Fig. 9 threshold
/// (≈ `Σ kₙnₙ / 2Σnₙ`, the analytic MN/K crossover of the layer GEMMs).
const N_REQUESTS: usize = 192;
/// Prompt length (kept short: the study is about decode).
const INPUT_LEN: usize = 32;

/// One measured decode-policy cell.
#[derive(Debug, Clone)]
pub struct SpecRun {
    pub label: String,
    /// Draft depth (0 = vanilla decode).
    pub gamma: u64,
    /// Configured per-token acceptance probability (0 for vanilla).
    pub acceptance: f64,
    pub offered: usize,
    pub completed: usize,
    /// Requests refused by admission (always 0 on the single-chip path —
    /// kept so the bench gate `completed + shed == offered` is uniform).
    pub shed: u64,
    /// `Σ (output_len − 1)` over the offered requests — what the decode
    /// path must commit exactly.
    pub expected_decode_tokens: u64,
    pub decode_tokens_committed: u64,
    pub tokens_exact: bool,
    pub drafted: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub acceptance_observed: f64,
    pub tbt_p50_ms: f64,
    pub tbt_p99_ms: f64,
    pub ttft_p99_s: f64,
    /// Output tokens/s over requests meeting the calibrated TTFT+TBT SLO.
    pub goodput_tok_s: f64,
    pub tok_s: f64,
    pub slo_ttft_s: f64,
    pub slo_tbt_s: f64,
    pub verify_steps: u64,
    pub verify_m_p50: u64,
    /// Verify iterations whose M crossed the learned threshold (ran the
    /// large-M MN partition instead of the decode K partition).
    pub verify_above_threshold: u64,
    pub m_threshold: u64,
    pub tokens_per_weight_stream: f64,
    pub preemptions: u64,
    pub resumes: u64,
}

/// One chip-wide fused pipeline (tp 64 × 1 stage on large-core-64) with
/// the Fig. 9 phase switch armed at the cost-model-learned threshold:
/// GEMMs below it run the decode K partition, above it the MN partition.
fn spec_cfg(spec: Option<SpecConfig>, m_threshold: u64, max_batch: usize) -> FusionConfig {
    FusionConfig {
        tp: 64,
        stages: 1,
        strategy: PartitionStrategy::OneDimMN,
        small_m_strategy: PartitionStrategy::OneDimK,
        m_threshold,
        chunk: 512,
        budget: 2048,
        max_batch,
        spec,
        ..FusionConfig::default()
    }
}

/// The learned MN/K crossover the study arms the phase switch with.
pub fn study_m_threshold(chip: &ChipConfig, model: &ModelConfig) -> u64 {
    plan::learned_m_threshold(
        chip,
        model,
        64,
        PartitionStrategy::OneDimMN,
        PartitionStrategy::OneDimK,
    )
}

/// The main trace: `n` identical decode-heavy requests offered at t=0, so
/// the decode batch reaches `n` and the verify M is `n·(γ+1)`.
pub fn batch_trace(n: usize, output: usize) -> Vec<Request> {
    let mut w = WorkloadConfig::fixed_ratio(INPUT_LEN, output, n).with_arrival(ArrivalProcess::Batch);
    w.name = "spec".into();
    request::generate(&w)
}

/// The preemption-under-speculation trace: low-priority long decodes
/// offered at t=0 fill the tiny batch cap, then high-priority arrivals
/// preempt them mid-speculation (park → KV spill → resume).
pub fn preempt_trace(cap: usize, low_output: usize, high_output: usize) -> Vec<Request> {
    let mut reqs = Vec::new();
    for i in 0..cap as u64 {
        let mut r = batch_trace(1, low_output).remove(0);
        r.id = i;
        r.priority = Priority::Low;
        reqs.push(r);
    }
    for i in 0..cap as u64 {
        let mut r = batch_trace(1, high_output).remove(0);
        r.id = cap as u64 + i;
        r.arrival_s = 1e-4;
        r.priority = Priority::High;
        reqs.push(r);
    }
    reqs
}

/// Run one decode policy over `reqs` and score it against the calibrated
/// SLO, enforcing exact token conservation.
fn run_policy(
    label: String,
    model: &ModelConfig,
    reqs: Vec<Request>,
    cfg: &FusionConfig,
    slo_ttft_s: f64,
    slo_tbt_s: f64,
) -> anyhow::Result<SpecRun> {
    let offered = reqs.len();
    let expected: u64 = reqs
        .iter()
        .map(|r| (r.output_len as u64).saturating_sub(1))
        .sum();
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let mut sched = SchedulerConfig::Fusion(*cfg).build();
    let m = scheduler::simulate_requests(&mut chip, model, reqs, sched.as_mut())?;
    anyhow::ensure!(
        m.n_requests() == offered,
        "{label}: {} completed != {offered} offered",
        m.n_requests()
    );
    anyhow::ensure!(
        m.spec.decode_tokens_committed == expected,
        "{label}: decode committed {} tokens, expected {expected}",
        m.spec.decode_tokens_committed
    );
    anyhow::ensure!(
        m.spec.drafted_tokens == m.spec.accepted_tokens + m.spec.rejected_tokens,
        "{label}: drafted {} != accepted {} + rejected {}",
        m.spec.drafted_tokens,
        m.spec.accepted_tokens,
        m.spec.rejected_tokens
    );
    let mut ttft = m.ttft_s();
    let mut tbt = m.tbt_s();
    Ok(SpecRun {
        label,
        gamma: cfg.spec.map_or(0, |sc| sc.gamma),
        acceptance: cfg.spec.map_or(0.0, |sc| sc.acceptance),
        offered,
        completed: m.n_requests(),
        shed: 0,
        expected_decode_tokens: expected,
        decode_tokens_committed: m.spec.decode_tokens_committed,
        tokens_exact: m.spec.decode_tokens_committed == expected,
        drafted: m.spec.drafted_tokens,
        accepted: m.spec.accepted_tokens,
        rejected: m.spec.rejected_tokens,
        acceptance_observed: m.spec.acceptance_rate(),
        tbt_p50_ms: tbt.median() * 1e3,
        tbt_p99_ms: tbt.p99() * 1e3,
        ttft_p99_s: ttft.p99(),
        goodput_tok_s: m.goodput_tokens_per_s(slo_ttft_s, slo_tbt_s),
        tok_s: m.tokens_per_s(),
        slo_ttft_s,
        slo_tbt_s,
        verify_steps: m.spec.verify_steps,
        verify_m_p50: m.spec.verify_m_p50(),
        verify_above_threshold: m.spec.verify_above_threshold,
        m_threshold: cfg.m_threshold,
        tokens_per_weight_stream: m.spec.tokens_per_weight_stream(),
        preemptions: m.control.preemptions,
        resumes: m.control.resumes,
    })
}

/// The comparison the bench's `"spec"` section reports: vanilla decode vs
/// the gamma × acceptance grid on the identical trace, plus the
/// preemption-under-speculation row. The SLO is calibrated off the
/// vanilla run (2× its TTFT p99, 1.5× its TBT p50), so goodput rewards
/// finishing the same work sooner rather than an arbitrary wall-clock
/// target.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<SpecRun>> {
    let model = ModelConfig::qwen3_4b();
    let chip = ChipConfig::large_core();
    let m_threshold = study_m_threshold(&chip, &model);
    let output = opts.pick(24, 12);
    let reqs = batch_trace(N_REQUESTS, output);

    // Calibrate the SLO off the vanilla run, then score every policy —
    // vanilla included — against it.
    let vanilla_cfg = spec_cfg(None, m_threshold, 256);
    let mut chip_sim = ChipSim::new(chip.clone());
    let mut sched = SchedulerConfig::Fusion(vanilla_cfg).build();
    let vm = scheduler::simulate_requests(&mut chip_sim, &model, reqs.clone(), sched.as_mut())?;
    let mut vttft = vm.ttft_s();
    let mut vtbt = vm.tbt_s();
    let slo_ttft_s = vttft.p99() * 2.0;
    let slo_tbt_s = vtbt.median() * 1.5;

    let mut rows = vec![run_policy(
        "vanilla".into(),
        &model,
        reqs.clone(),
        &vanilla_cfg,
        slo_ttft_s,
        slo_tbt_s,
    )?];
    let grid: Vec<(u64, f64)> = if opts.fast {
        vec![(4, 0.8), (8, 0.95)]
    } else {
        let mut g = Vec::new();
        for gamma in [2u64, 4, 8] {
            for accept in [0.6, 0.8, 0.95] {
                g.push((gamma, accept));
            }
        }
        g
    };
    for (gamma, accept) in grid {
        let cfg = spec_cfg(Some(SpecConfig::new(gamma, accept)), m_threshold, 256);
        rows.push(run_policy(
            format!("g{gamma}-a{accept:.2}"),
            &model,
            reqs.clone(),
            &cfg,
            slo_ttft_s,
            slo_tbt_s,
        )?);
    }

    // Preemption under speculation: 8 low-priority long decodes fill the
    // batch cap, 8 high-priority arrivals preempt them mid-round. The row
    // must conserve tokens exactly through park/rollback/resume.
    let cap = 8;
    let preempt_cfg = spec_cfg(Some(SpecConfig::new(4, 0.8)), m_threshold, cap);
    let preempt = run_policy(
        "g4-a0.80+preempt".into(),
        &model,
        preempt_trace(cap, opts.pick(48, 24), 8),
        &preempt_cfg,
        slo_ttft_s,
        slo_tbt_s,
    )?;
    anyhow::ensure!(
        preempt.preemptions > 0,
        "the preemption row never preempted — the scenario is inert"
    );
    rows.push(preempt);
    Ok(rows)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let rows = bench_rows(opts)?;
    let mut t = Table::new(
        "spec_study — speculative decoding vs vanilla (Qwen3-4B, large-core-64, one tp-64 pipeline)",
        &[
            "policy",
            "offered",
            "completed",
            "accept obs",
            "TBT p50 (ms)",
            "TBT p99 (ms)",
            "goodput tok/s (SLO)",
            "tok/s",
            "tok/weight-stream",
            "verify M p50",
            "verify M ≥ thresh",
            "preempt/resume",
        ],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            r.offered.to_string(),
            r.completed.to_string(),
            f3(r.acceptance_observed),
            f3(r.tbt_p50_ms),
            f3(r.tbt_p99_ms),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
            f3(r.tokens_per_weight_stream),
            r.verify_m_p50.to_string(),
            format!("{}/{}", r.verify_above_threshold, r.verify_steps),
            format!("{}/{}", r.preemptions, r.resumes),
        ]);
    }

    let vanilla = rows.iter().find(|r| r.label == "vanilla").unwrap();
    let headline = rows.iter().find(|r| r.label == "g4-a0.80").unwrap();
    println!(
        "spec_study: gamma=4 accept=0.8 — TBT p50 {:.3} ms vs vanilla {:.3} ms ({:.2}x), \
         goodput {:.1} vs {:.1} tok/s, {:.1} vs {:.1} tokens/weight-stream \
         (Fig. 9 threshold M≥{}: {}/{} verify batches crossed)",
        headline.tbt_p50_ms,
        vanilla.tbt_p50_ms,
        vanilla.tbt_p50_ms / headline.tbt_p50_ms.max(1e-12),
        headline.goodput_tok_s,
        vanilla.goodput_tok_s,
        headline.tokens_per_weight_stream,
        vanilla.tokens_per_weight_stream,
        headline.m_threshold,
        rows.iter().map(|r| r.verify_above_threshold).sum::<u64>(),
        rows.iter().map(|r| r.verify_steps).sum::<u64>(),
    );
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_beats_vanilla_and_conserves_tokens() {
        // The acceptance property at fast scale: the gamma=4/accept=0.8
        // row must strictly beat vanilla on TBT p50, goodput-under-SLO
        // and tokens-per-weight-stream; every row (the preemption one
        // included) conserves tokens exactly (checked inside run_policy,
        // re-asserted here); and the gamma=8 verify batches cross the
        // learned Fig. 9 threshold.
        let rows = bench_rows(&Opts::fast()).unwrap();
        let by = |l: &str| rows.iter().find(|r| r.label == l).unwrap();
        let (vanilla, spec) = (by("vanilla"), by("g4-a0.80"));
        assert_eq!(vanilla.drafted, 0, "vanilla must never draft");
        assert_eq!(vanilla.verify_steps, 0);
        assert!(spec.drafted > 0);
        assert!(
            spec.tbt_p50_ms < vanilla.tbt_p50_ms,
            "spec TBT p50 {} !< vanilla {}",
            spec.tbt_p50_ms,
            vanilla.tbt_p50_ms
        );
        assert!(
            spec.goodput_tok_s > vanilla.goodput_tok_s,
            "spec goodput {} !> vanilla {}",
            spec.goodput_tok_s,
            vanilla.goodput_tok_s
        );
        assert!(spec.tokens_per_weight_stream > vanilla.tokens_per_weight_stream);
        for r in &rows {
            assert!(r.tokens_exact, "{}: token conservation broken", r.label);
            assert_eq!(r.completed as u64 + r.shed, r.offered as u64);
        }
        // The modeled acceptance sampler tracks its configured rate.
        assert!(
            (spec.acceptance_observed - spec.acceptance).abs() < 0.15,
            "observed acceptance {} far from configured {}",
            spec.acceptance_observed,
            spec.acceptance
        );
        let deep = by("g8-a0.95");
        assert!(
            deep.verify_above_threshold > 0,
            "no verify batch crossed the learned threshold {}",
            deep.m_threshold
        );
        let preempt = by("g4-a0.80+preempt");
        assert!(preempt.preemptions > 0 && preempt.resumes > 0);
    }

    #[test]
    fn preempt_trace_is_arrival_sorted_and_two_class() {
        let reqs = preempt_trace(4, 16, 8);
        assert_eq!(reqs.len(), 8);
        assert!(reqs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(reqs.iter().filter(|r| r.priority == Priority::Low).count(), 4);
        assert_eq!(reqs.iter().filter(|r| r.priority == Priority::High).count(), 4);
        // Ids are unique (the KV cache keys chains by request id).
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
    }
}
