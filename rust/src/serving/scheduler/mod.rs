//! Unified serving-scheduler abstraction.
//!
//! Every serving policy — PD fusion (§4.3.2), PD disaggregation (§4.3.1),
//! and the FlexNPU-style adaptive [`hybrid`] — implements [`Scheduler`]:
//! admit requests, then repeatedly `step` [`crate::model::IterBatch`]es
//! against a [`ChipSim`] until every request retires. The shared
//! [`simulate`]/[`simulate_requests`] driver owns the outer loop, the
//! livelock guard, and the [`Metrics`] collection, so new policies
//! (priority, preemption, multi-tenant) plug in without another
//! copy-pasted simulate loop.
//!
//! Construction is data-driven through [`SchedulerConfig`], which maps the
//! CLI's `--mode fusion|disagg|hybrid` onto boxed scheduler instances.

pub mod disagg;
pub mod fusion;
pub mod hybrid;
pub(crate) mod pipe;

pub use disagg::DisaggScheduler;
pub use fusion::FusionScheduler;
pub use hybrid::{HybridConfig, HybridScheduler};

use crate::config::{ModelConfig, WorkloadConfig};
use crate::memmgr::prefix::{BlockKey, TierMatch};
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::DisaggConfig;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::sim::chip::ChipSim;
use crate::util::units::Cycle;

/// One request stranded inside a scheduler when its chip dies: the
/// original request plus how far it had progressed (the progress is lost —
/// its KV died with the chip — but recovery accounting reports it as
/// tokens to recompute).
#[derive(Debug, Clone)]
pub struct Incomplete {
    pub req: Request,
    /// Prompt tokens already prefilled on the dead chip.
    pub prefilled: u64,
    /// Output tokens already generated on the dead chip.
    pub generated: u64,
}

/// An iteration-level serving scheduler driving a [`ChipSim`].
///
/// Two lifecycles share the same implementation:
///
/// - **Batch (single chip):** [`Scheduler::init`] once with the full
///   (arrival-sorted) request trace, then [`Scheduler::step`] until the
///   driver has seen every request complete.
/// - **Streamed (cluster):** [`Scheduler::prepare`] once, then the
///   [cluster driver](crate::serving::cluster) interleaves
///   [`Scheduler::enqueue`] (releasing requests at their arrival times)
///   with [`Scheduler::step`], using [`Scheduler::next_action`] to order
///   chips against the arrival stream.
///
/// Schedulers own their placement, batching, and admission state; drivers
/// own time-keeping-free orchestration (the simulated clock lives in the
/// [`ChipSim`] cores). The probe methods ([`Scheduler::pending_work`],
/// [`Scheduler::kv_utilization`], [`Scheduler::probe_prefix`]) are the
/// read-only signals cluster routers steer by.
///
/// `Send` is a supertrait so the cluster driver can advance independent
/// chips on worker threads inside a conservative synchronization window
/// (`--sim-threads`); scheduler state is plain owned data, so every
/// implementation satisfies it automatically.
pub trait Scheduler: Send {
    /// Short policy name (used in tables and error messages).
    fn name(&self) -> &'static str;

    /// Build placement and per-worker state on `chip`, sized for requests
    /// of up to `max_tokens` prompt+output tokens.
    fn prepare(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        max_tokens: usize,
    ) -> anyhow::Result<()>;

    /// Hand one request to the scheduler's admission queues. Must be
    /// called in arrival order, after [`Scheduler::prepare`]. The chip is
    /// passed mutably because cache-affinity-aware policies may act on the
    /// hardware at admission time (e.g. the fusion/hybrid `cross_pipe`
    /// path streams a matched prefix between pipes over the NoC when the
    /// holding pipe is overloaded); policies without such behaviour simply
    /// ignore it.
    fn enqueue(&mut self, chip: &mut ChipSim, req: Request);

    /// Batch bootstrap: [`Scheduler::prepare`] sized for `reqs`, then
    /// [`Scheduler::enqueue`] each. `reqs` must be sorted by arrival time.
    fn init(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        reqs: Vec<Request>,
    ) -> anyhow::Result<()> {
        let max_tokens = reqs.iter().map(|r| r.total_tokens()).max().unwrap_or(1);
        self.prepare(chip, model, max_tokens)?;
        for r in reqs {
            self.enqueue(chip, r);
        }
        Ok(())
    }

    /// Run one scheduling step at the earliest actionable simulated time,
    /// recording completed requests into `metrics`. Returns the number of
    /// requests retired by this step.
    fn step(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        metrics: &mut Metrics,
    ) -> anyhow::Result<usize>;

    /// Earliest cycle at which [`Scheduler::step`] can do useful work, or
    /// `None` while fully idle (the cluster driver then waits for the next
    /// arrival). Calling `step` when this is `None` is an error.
    fn next_action(&self, chip: &ChipSim) -> Option<Cycle>;

    /// Requests enqueued but not yet retired (queued + in flight) — the
    /// router's queue-depth signal.
    fn pending_work(&self) -> usize;

    /// Mean occupancy of the admission-limiting KV tier in `[0, 1]` — the
    /// router's memory-pressure signal.
    fn kv_utilization(&self) -> f64 {
        0.0
    }

    /// Backpressure in `[0, 1]`: how close this chip is to refusing new
    /// work — the signal the cluster frontend's shed/defer admission
    /// throttles by (`1.0` = saturated). The default derives it from the
    /// queue-depth and memory-pressure probes; policies override it with
    /// their pipe-level saturation (the most-loaded pipe governs, since
    /// one saturated pipe stalls every request routed to it).
    fn backpressure(&self) -> f64 {
        let queued = (self.pending_work() as f64 / 16.0).min(1.0);
        queued.max(self.kv_utilization())
    }

    /// Longest cached-and-ready prompt prefix (tokens) an admission with
    /// `keys` could share at cycle `at`, capped at `limit` — the
    /// prefix-hit-aware router's read-only probe. Policies without a
    /// prefix cache report 0.
    fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        let _ = (keys, limit, at);
        0
    }

    /// Tier-split [`Scheduler::probe_prefix`]: how much of the best match
    /// is SRAM-resident versus demoted to the HBM tier (re-promotion
    /// priced). Routers use the split to rank two-tier hit quality.
    /// Policies without tiering report their whole match as fast-tier.
    fn probe_prefix_tiered(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> TierMatch {
        TierMatch {
            sram_tokens: self.probe_prefix(keys, limit, at),
            hbm_tokens: 0,
        }
    }

    /// Seed a migrated prefix copy (cluster KV transfer) into the
    /// scheduler's caches, matchable from cycle `ready_at` on by any
    /// later admission. Best-effort; policies without a prefix cache
    /// ignore it.
    fn import_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        let _ = (keys, ready_at);
    }

    /// Remove and return every request this scheduler still holds —
    /// queued, mid-prefill, decoding, parked, or awaiting handoff — in
    /// ascending request-id order. The cluster frontend calls this when
    /// the chip is declared dead so the stranded requests can be recovered
    /// on surviving chips; afterwards the scheduler holds no in-flight
    /// work. The default (for policies without internal queues) reports
    /// nothing.
    fn drain_incomplete(&mut self) -> Vec<Incomplete> {
        Vec::new()
    }

    /// Fold worker-level prefix-cache / memo counters (COW copies,
    /// evictions, memo hits) into `out`. The driver calls this once after
    /// the run; policies without such state keep the default no-op.
    fn collect_cache_stats(&self, out: &mut crate::serving::metrics::CacheStats) {
        let _ = out;
    }
}

/// Data-driven scheduler selection (CLI `--mode`, experiment sweeps).
#[derive(Debug, Clone, Copy)]
pub enum SchedulerConfig {
    Fusion(FusionConfig),
    Disagg(DisaggConfig),
    Hybrid(HybridConfig),
}

impl SchedulerConfig {
    /// Build the scheduler configuration a
    /// [`DeploymentPlan`](crate::parallel::plan::DeploymentPlan) describes
    /// — the one entry point the CLI's `--plan` and the planner
    /// experiments construct schedulers through.
    pub fn from_plan(plan: &crate::parallel::plan::DeploymentPlan) -> anyhow::Result<Self> {
        use crate::parallel::plan::PdMode;
        Ok(match plan.mode {
            PdMode::Fusion => SchedulerConfig::Fusion(FusionConfig::from_plan(plan)),
            PdMode::Hybrid => SchedulerConfig::Hybrid(HybridConfig::from_plan(plan)),
            PdMode::Disagg { .. } => SchedulerConfig::Disagg(DisaggConfig::from_plan(plan)?),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerConfig::Fusion(_) => "fusion",
            SchedulerConfig::Disagg(_) => "disagg",
            SchedulerConfig::Hybrid(_) => "hybrid",
        }
    }

    /// Instantiate the configured scheduler.
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerConfig::Fusion(c) => Box::new(FusionScheduler::new(*c)),
            SchedulerConfig::Disagg(c) => Box::new(DisaggScheduler::new(*c)),
            SchedulerConfig::Hybrid(c) => Box::new(HybridScheduler::new(*c)),
        }
    }
}

/// Simulate a synthetic workload under `sched`; returns serving metrics.
pub fn simulate(
    chip: &mut ChipSim,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    sched: &mut dyn Scheduler,
) -> anyhow::Result<Metrics> {
    simulate_requests(chip, model, request::generate(workload), sched)
}

/// Simulate an explicit (arrival-sorted) request list under `sched` —
/// trace replay uses this directly.
pub fn simulate_requests(
    chip: &mut ChipSim,
    model: &ModelConfig,
    reqs: Vec<Request>,
    sched: &mut dyn Scheduler,
) -> anyhow::Result<Metrics> {
    let freq = chip.cfg.freq_mhz;
    let total = reqs.len();
    sched.init(chip, model, reqs)?;
    let mut metrics = Metrics::new(freq);
    let mut done = 0usize;
    let mut guard = 0u64;
    while done < total {
        guard += 1;
        anyhow::ensure!(
            guard < 8_000_000,
            "{} scheduler livelock: {done}/{total} requests done",
            sched.name()
        );
        done += sched.step(chip, model, &mut metrics)?;
    }
    let mut hw = crate::serving::metrics::CacheStats::default();
    sched.collect_cache_stats(&mut hw);
    metrics.cache.merge(&hw);
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn every_mode_builds_and_serves() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(96, 8, 3);
        for cfg in [
            SchedulerConfig::Fusion(FusionConfig::default()),
            SchedulerConfig::Disagg(DisaggConfig::p42_d21()),
            SchedulerConfig::Hybrid(HybridConfig::default()),
        ] {
            let mut chip = ChipSim::new(ChipConfig::large_core());
            let mut sched = cfg.build();
            let m = simulate(&mut chip, &model, &w, sched.as_mut())
                .unwrap_or_else(|e| panic!("{} failed: {e:#}", cfg.name()));
            assert_eq!(m.n_requests(), 3, "{}", cfg.name());
            for r in m.records() {
                assert!(r.first_token >= r.arrival, "{}: {r:?}", cfg.name());
                assert!(r.finish >= r.first_token, "{}: {r:?}", cfg.name());
            }
        }
    }

    #[test]
    fn every_plan_preset_builds_a_scheduler_that_serves() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(96, 8, 3);
        for plan in crate::parallel::plan::DeploymentPlan::presets() {
            let cfg = SchedulerConfig::from_plan(&plan)
                .unwrap_or_else(|e| panic!("{}: {e:#}", plan.name));
            let mut chip = ChipSim::new(ChipConfig::large_core());
            let mut sched = cfg.build();
            let m = simulate(&mut chip, &model, &w, sched.as_mut())
                .unwrap_or_else(|e| panic!("{} failed: {e:#}", plan.name));
            assert_eq!(m.n_requests(), 3, "{}", plan.name);
        }
    }

    #[test]
    fn default_plan_projections_match_the_legacy_defaults() {
        // The `--plan` unset path must stay bit-identical: the fusion /
        // disagg / hybrid presets must project onto exactly the configs
        // the schedulers defaulted to before plans existed.
        use crate::parallel::plan::DeploymentPlan;
        let f = FusionConfig::from_plan(&DeploymentPlan::fusion_default());
        let fd = FusionConfig::default();
        assert_eq!(format!("{f:?}"), format!("{fd:?}"));
        let d = DisaggConfig::from_plan(&DeploymentPlan::disagg_default()).unwrap();
        assert_eq!(format!("{d:?}"), format!("{:?}", DisaggConfig::default()));
        let h = HybridConfig::from_plan(&DeploymentPlan::hybrid_default());
        assert_eq!(format!("{h:?}"), format!("{:?}", HybridConfig::default()));
    }

    #[test]
    fn empty_workload_is_a_noop() {
        let model = ModelConfig::qwen3_4b();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = FusionScheduler::new(FusionConfig::default());
        let m = simulate_requests(&mut chip, &model, Vec::new(), &mut sched).unwrap();
        assert_eq!(m.n_requests(), 0);
    }
}
