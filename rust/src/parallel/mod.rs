//! Tensor partition, core placement and collective communication — the
//! paper's §4.1 design space.
//!
//! - [`partition`]: the three GEMM partition strategies of Fig. 3 (1-D M/N
//!   via AllGather, 1-D K via AllReduce, 2-D hybrid) with the Table 2
//!   analytic cost model.
//! - [`placement`]: the core placement strategies of Fig. 4 (linear-seq,
//!   linear-interleave, ring, 2-D mesh) mapping logical TP ranks onto
//!   physical mesh coordinates, plus pipeline-stage region partitioning.
//! - [`collectives`]: ring AllGather / AllReduce schedules executed on the
//!   simulated mesh (contention-aware).
//! - [`pd_placement`]: DP-prioritized vs PP-prioritized core placement for
//!   PD disaggregation (Fig. 6).
//! - [`layout`]: mesh carving into pipeline-stage cells of TP groups.
//! - [`plan`]: the first-class [`plan::DeploymentPlan`] and the analytic
//!   auto-planner searching TP strategy × placement × PD mode over the
//!   Table-2 / placement / SRAM-planner cost models.

pub mod collectives;
pub mod layout;
pub mod partition;
pub mod pd_placement;
pub mod placement;
pub mod plan;

pub use partition::PartitionStrategy;
pub use placement::{Placement, Region, TpGroup};
pub use plan::{ChipRole, DeploymentPlan, FleetChipPlan, FleetPlan, PdMode};
