//! Serving workload configuration (§5.1 "Workloads").
//!
//! The paper references the ShareGPT and Mooncake industrial traces and
//! distils them into two workload classes: *prefill-dominated* and
//! *decode-dominated*. Since the raw traces are not redistributable, we
//! generate synthetic traces whose prompt/output length marginals and
//! arrival processes match the published characteristics (see DESIGN.md
//! "Substitutions").

/// Token-length distribution for prompts or outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LenDist {
    /// Every request has exactly this many tokens.
    Fixed(usize),
    /// Uniform in `[lo, hi]`.
    Uniform(usize, usize),
    /// Log-normal over the underlying normal's `mu`/`sigma`, clamped to
    /// `[min, max]`. ShareGPT-like prompt lengths: `mu≈5.2, sigma≈1.3`.
    LogNormal {
        mu: f64,
        sigma: f64,
        min: usize,
        max: usize,
    },
}

impl LenDist {
    pub fn sample(&self, rng: &mut crate::util::rng::Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => rng.range(lo, hi + 1),
            LenDist::LogNormal { mu, sigma, min, max } => {
                (rng.log_normal(mu, sigma).round() as usize).clamp(min, max)
            }
        }
    }

    /// Analytic-ish mean (used for capacity planning in the scheduler).
    pub fn mean(&self) -> f64 {
        match *self {
            LenDist::Fixed(n) => n as f64,
            LenDist::Uniform(lo, hi) => (lo + hi) as f64 / 2.0,
            LenDist::LogNormal { mu, sigma, min, max } => {
                (mu + sigma * sigma / 2.0).exp().clamp(min as f64, max as f64)
            }
        }
    }
}

/// Request arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// All requests available at t=0 (offline/batch evaluation).
    Batch,
    /// Poisson process at `rate` requests/second.
    Poisson { rate: f64 },
    /// Bursty: Poisson at `rate` with periodic bursts of `burst_size`
    /// back-to-back requests every `period_s` seconds (Mooncake-like).
    Bursty {
        rate: f64,
        burst_size: usize,
        period_s: f64,
    },
    /// Flash crowd: Poisson at `base_rate` until `spike_start_s`, then at
    /// `peak_rate` for `spike_len_s` seconds, then back to `base_rate`.
    /// The overload-study arrival process — `peak_rate` is picked past the
    /// sustainable service rate so admission control actually engages.
    FlashCrowd {
        base_rate: f64,
        peak_rate: f64,
        spike_start_s: f64,
        spike_len_s: f64,
    },
    /// Diurnal load: an inhomogeneous Poisson process whose rate follows a
    /// raised-cosine day/night cycle between `base_rate` (trough) and
    /// `peak_rate` (crest) with period `period_s` seconds — the
    /// adaptive-orchestration trace shape (long traces exhibit load
    /// structure instead of a flat average). The `scale_study` experiment
    /// replays this at both simulation levels.
    Diurnal {
        base_rate: f64,
        peak_rate: f64,
        period_s: f64,
    },
}

/// Shared-prefix / multi-turn structure of a conversational workload
/// (drives the prefix-caching study; `None` = independent requests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixSharing {
    /// Distinct system prompts; conversations round-robin across them.
    pub n_groups: usize,
    /// Tokens of the per-group shared system prompt, prepended to every
    /// conversation's first prompt (and part of all later contexts).
    pub shared_prefix_len: usize,
    /// Turns per conversation (1 = single-turn; each turn is a request
    /// whose prompt carries the whole accumulated context).
    pub turns: usize,
    /// Gap between consecutive turns of one conversation, seconds.
    pub think_time_s: f64,
}

impl Default for PrefixSharing {
    fn default() -> Self {
        PrefixSharing {
            n_groups: 4,
            shared_prefix_len: 1024,
            turns: 2,
            think_time_s: 5.0,
        }
    }
}

/// Priority-class mix of a workload: the probability that a generated
/// request is high- or low-priority (the remainder is normal). The
/// default mix is empty — every request is normal-priority and the
/// generator draws **no** extra random numbers, so pre-priority traces
/// stay bit-identical (pinned by `tests/golden_metrics.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PriorityMix {
    /// Fraction of requests sampled as high-priority, in `[0, 1]`.
    pub high: f64,
    /// Fraction of requests sampled as low-priority, in `[0, 1]`.
    pub low: f64,
}

impl PriorityMix {
    /// True when every request is normal-priority (the inert default).
    pub fn is_uniform(&self) -> bool {
        self.high <= 0.0 && self.low <= 0.0
    }

    /// Parse a `"HIGH:LOW"` fraction pair (e.g. `"0.2:0.5"`), as taken
    /// by the CLI's `--priority-mix` flag.
    pub fn parse(s: &str) -> anyhow::Result<PriorityMix> {
        let (h, l) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--priority-mix wants HIGH:LOW, e.g. 0.2:0.5"))?;
        let high: f64 = h.trim().parse()?;
        let low: f64 = l.trim().parse()?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&high) && (0.0..=1.0).contains(&low) && high + low <= 1.0,
            "priority mix fractions must be in [0, 1] and sum to at most 1, got {high}:{low}"
        );
        Ok(PriorityMix { high, low })
    }
}

/// A complete workload description.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub name: String,
    pub input_len: LenDist,
    pub output_len: LenDist,
    pub arrival: ArrivalProcess,
    pub n_requests: usize,
    pub seed: u64,
    /// Shared-prefix / multi-turn structure (`None` = independent
    /// requests; `input_len` then means the whole prompt, otherwise it
    /// means the fresh per-turn user tokens on top of the shared context).
    pub prefix: Option<PrefixSharing>,
    /// Priority-class mix (default: everything normal, no extra RNG draws).
    pub priority_mix: PriorityMix,
}

impl WorkloadConfig {
    /// Prefill-dominated workload: long prompts, short generations
    /// (retrieval / summarisation style; input:output ≈ 10:1).
    pub fn prefill_dominated(n_requests: usize) -> Self {
        WorkloadConfig {
            name: "prefill-dominated".into(),
            input_len: LenDist::LogNormal {
                mu: 7.3, // median ≈ 1480 tokens
                sigma: 0.6,
                min: 256,
                max: 8192,
            },
            output_len: LenDist::LogNormal {
                mu: 4.8, // median ≈ 120 tokens
                sigma: 0.5,
                min: 16,
                max: 512,
            },
            arrival: ArrivalProcess::Poisson { rate: 4.0 },
            n_requests,
            seed: 2025,
            prefix: None,
            priority_mix: PriorityMix::default(),
        }
    }

    /// Decode-dominated workload: short prompts, long generations
    /// (chatbot / reasoning style; input:output ≈ 1:8).
    pub fn decode_dominated(n_requests: usize) -> Self {
        WorkloadConfig {
            name: "decode-dominated".into(),
            input_len: LenDist::LogNormal {
                mu: 4.8,
                sigma: 0.7,
                min: 16,
                max: 1024,
            },
            output_len: LenDist::LogNormal {
                mu: 6.9, // median ≈ 990 tokens
                sigma: 0.5,
                min: 128,
                max: 4096,
            },
            arrival: ArrivalProcess::Poisson { rate: 4.0 },
            n_requests,
            seed: 2025,
            prefix: None,
            priority_mix: PriorityMix::default(),
        }
    }

    /// ShareGPT-like conversational trace (moderate both ways).
    pub fn sharegpt_like(n_requests: usize) -> Self {
        WorkloadConfig {
            name: "sharegpt-like".into(),
            input_len: LenDist::LogNormal {
                mu: 5.4,
                sigma: 1.1,
                min: 8,
                max: 4096,
            },
            output_len: LenDist::LogNormal {
                mu: 5.5,
                sigma: 0.9,
                min: 8,
                max: 2048,
            },
            arrival: ArrivalProcess::Poisson { rate: 6.0 },
            n_requests,
            seed: 2025,
            prefix: None,
            priority_mix: PriorityMix::default(),
        }
    }

    /// Mooncake-like trace: long, highly variable prompts with bursts.
    pub fn mooncake_like(n_requests: usize) -> Self {
        WorkloadConfig {
            name: "mooncake-like".into(),
            input_len: LenDist::LogNormal {
                mu: 7.8,
                sigma: 1.2,
                min: 64,
                max: 16384,
            },
            output_len: LenDist::LogNormal {
                mu: 5.0,
                sigma: 0.7,
                min: 16,
                max: 1024,
            },
            arrival: ArrivalProcess::Bursty {
                rate: 2.0,
                burst_size: 8,
                period_s: 10.0,
            },
            n_requests,
            seed: 2025,
            prefix: None,
            priority_mix: PriorityMix::default(),
        }
    }

    /// Fixed-shape workload `input:output` used by Figs. 11/14's ratio
    /// sweeps (e.g. `fixed_ratio(1000, 100, 64)` = the paper's "1000:100").
    pub fn fixed_ratio(input: usize, output: usize, n_requests: usize) -> Self {
        WorkloadConfig {
            name: format!("{input}:{output}"),
            input_len: LenDist::Fixed(input),
            output_len: LenDist::Fixed(output),
            arrival: ArrivalProcess::Batch,
            n_requests,
            seed: 2025,
            prefix: None,
            priority_mix: PriorityMix::default(),
        }
    }

    /// Shared-prefix conversational workload for the prefix-caching study:
    /// multi-turn chats opening with a 1k-token shared system prompt (4
    /// prompt groups), modest fresh user turns, chatbot-length outputs.
    /// Most prompt tokens are shareable — the regime where prefix caching
    /// pays (Mooncake reports >50% cache-able tokens in production).
    pub fn shared_prefix(n_requests: usize) -> Self {
        WorkloadConfig {
            name: "shared-prefix".into(),
            // Fresh user tokens per turn (on top of the shared context).
            input_len: LenDist::Uniform(48, 192),
            output_len: LenDist::Uniform(32, 128),
            arrival: ArrivalProcess::Poisson { rate: 4.0 },
            n_requests,
            seed: 2025,
            prefix: Some(PrefixSharing::default()),
            priority_mix: PriorityMix::default(),
        }
    }

    pub fn with_prefix(mut self, prefix: PrefixSharing) -> Self {
        self.prefix = Some(prefix);
        self
    }

    pub fn with_priority_mix(mut self, mix: PriorityMix) -> Self {
        self.priority_mix = mix;
        self
    }

    pub fn with_arrival(mut self, arrival: ArrivalProcess) -> Self {
        self.arrival = arrival;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fixed_dist_is_fixed() {
        let mut rng = Rng::new(1);
        let d = LenDist::Fixed(100);
        for _ in 0..10 {
            assert_eq!(d.sample(&mut rng), 100);
        }
        assert_eq!(d.mean(), 100.0);
    }

    #[test]
    fn lognormal_respects_clamps() {
        let mut rng = Rng::new(2);
        let d = LenDist::LogNormal {
            mu: 6.0,
            sigma: 2.0,
            min: 100,
            max: 500,
        };
        for _ in 0..1000 {
            let x = d.sample(&mut rng);
            assert!((100..=500).contains(&x));
        }
    }

    #[test]
    fn prefill_dominated_is_input_heavy() {
        let w = WorkloadConfig::prefill_dominated(10);
        assert!(w.input_len.mean() > 5.0 * w.output_len.mean());
    }

    #[test]
    fn decode_dominated_is_output_heavy() {
        let w = WorkloadConfig::decode_dominated(10);
        assert!(w.output_len.mean() > 3.0 * w.input_len.mean());
    }

    #[test]
    fn priority_mix_parses_and_validates() {
        let m = PriorityMix::parse("0.2:0.5").unwrap();
        assert_eq!(m, PriorityMix { high: 0.2, low: 0.5 });
        assert!(!m.is_uniform());
        assert!(PriorityMix::default().is_uniform());
        assert!(PriorityMix::parse("0.8:0.5").is_err(), "sum > 1");
        assert!(PriorityMix::parse("1.5:0.0").is_err(), "out of range");
        assert!(PriorityMix::parse("nonsense").is_err());
    }

    #[test]
    fn uniform_in_bounds() {
        let mut rng = Rng::new(3);
        let d = LenDist::Uniform(10, 20);
        for _ in 0..200 {
            let x = d.sample(&mut rng);
            assert!((10..=20).contains(&x));
        }
    }
}
