//! LLM operator graphs and their distributed execution on the simulated
//! chip.
//!
//! A serving iteration (one scheduler tick) is described by an
//! [`batch::IterBatch`] — which requests contribute how many query tokens
//! against how much KV context — and executed layer by layer on a placed
//! TP group by [`exec`]. Execution composes:
//!
//! - the **compute models** of [`crate::sim::compute`] for every GEMM /
//!   GEMV / vector operator,
//! - the **partition strategies** of [`crate::parallel::partition`] which
//!   decide what each core computes and what the group communicates,
//! - the **collectives** of [`crate::parallel::collectives`] running on the
//!   contention-aware NoC,
//! - the **KV residency** of [`crate::memmgr`] which decides how much of
//!   attention's KV streams from HBM, and
//! - the **SRAM plan** of [`crate::memmgr::planner`] which decides how much
//!   weight streams from HBM per layer.

pub mod batch;
pub mod exec;
pub mod memo;

pub use batch::{BatchItem, IterBatch, Phase};
pub use exec::{run_iteration, ExecConfig};
pub use memo::LatencyMemo;
