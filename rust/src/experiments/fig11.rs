//! Fig. 11 — PD-disaggregation core-ratio sweep: TTFT / TBT / e2e across
//! prefill:decode core splits (P49/D14 … P21/D42) and workload
//! input:output ratios, Qwen3-4B on the 64-core chip.

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

/// The paper's sweep points: (prefill cores, decode cores, prefill stages).
pub const RATIOS: [(usize, usize, usize); 4] =
    [(49, 14, 7), (42, 21, 6), (28, 28, 4), (21, 42, 3)];

pub fn run_ratio(
    model: &ModelConfig,
    w: &WorkloadConfig,
    p: usize,
    d: usize,
    stages: usize,
) -> anyhow::Result<Metrics> {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    // Per-group decode batches are SRAM-activation bound in practice; a
    // modest cap is what makes decode-core *count* matter under load (the
    // paper's "more scheduling resources under a high-request load").
    let cfg = DisaggConfig {
        max_decode_batch: 8,
        ..DisaggConfig::ratio_64(p, d, stages)
    };
    simulate_disagg(&mut chip, model, w, &cfg)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(16, 3);
    let workloads: Vec<WorkloadConfig> = if opts.fast {
        vec![WorkloadConfig::fixed_ratio(100, 20, n)]
    } else {
        vec![
            WorkloadConfig::fixed_ratio(1000, 100, n),
            WorkloadConfig::fixed_ratio(500, 250, n),
            WorkloadConfig::fixed_ratio(100, 100, n),
        ]
    };

    let mut tables = Vec::new();
    for w in &workloads {
        let mut t = Table::new(
            &format!("Fig 11 — PD core ratios, workload {} (Qwen3-4B, 64 cores)", w.name),
            &["cores", "TTFT (s)", "TBT (ms)", "e2e (s)", "tok/s"],
        );
        for (p, d, stages) in RATIOS {
            let m = run_ratio(&model, w, p, d, stages)?;
            t.row(&[
                format!("P{p}/D{d}"),
                f3(m.ttft_s().mean()),
                f3(m.tbt_s().mean() * 1e3),
                f3(m.e2e_s().mean()),
                f3(m.tokens_per_s()),
            ]);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_prefill_cores_reduce_ttft() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(500, 16, 6);
        let p49 = run_ratio(&model, &w, 49, 14, 7).unwrap();
        let p21 = run_ratio(&model, &w, 21, 42, 3).unwrap();
        assert!(
            p49.ttft_s().mean() <= p21.ttft_s().mean(),
            "P49 {} vs P21 {}",
            p49.ttft_s().mean(),
            p21.ttft_s().mean()
        );
    }

    #[test]
    fn more_decode_cores_reduce_e2e_on_decode_heavy() {
        // Paper: in the 100:100 task P21/D42 lowers e2e sharply vs P49/D14
        // — under enough load that decode capacity queues requests.
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(100, 100, 32);
        let p49 = run_ratio(&model, &w, 49, 14, 7).unwrap();
        let p21 = run_ratio(&model, &w, 21, 42, 3).unwrap();
        assert!(
            p21.e2e_s().mean() < p49.e2e_s().mean(),
            "P21 {} vs P49 {}",
            p21.e2e_s().mean(),
            p49.e2e_s().mean()
        );
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].n_rows(), 4);
    }
}
