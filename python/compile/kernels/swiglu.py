"""L1 Pallas kernel: fused SwiGLU activation — silu(gate) * up.

The FFN's elementwise hot-spot, fused so the gate/up intermediates never
round-trip to HBM: the grid walks row blocks, each step holding one
(BLOCK_R, intermediate) slab of both inputs in VMEM (the paper's vector
unit works the same way on its SRAM-resident activation slabs).

interpret=True: see matmul.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step (token positions).
BLOCK_R = 128


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...]
    u = u_ref[...]
    # silu(g) = g * sigmoid(g), computed stably in f32.
    o_ref[...] = (g * jax.nn.sigmoid(g)) * u


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """Fused `silu(gate) * up` over matching 2-D `[rows, inter]` arrays."""
    assert gate.shape == up.shape and gate.ndim == 2, (gate.shape, up.shape)
    rows, inter = gate.shape
    pad = (-rows) % BLOCK_R
    gp = jnp.pad(gate.astype(jnp.float32), ((0, pad), (0, 0)))
    upad = jnp.pad(up.astype(jnp.float32), ((0, pad), (0, 0)))
    rp = rows + pad

    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(rp // BLOCK_R,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, inter), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, inter), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, inter), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, inter), jnp.float32),
        interpret=True,
    )(gp, upad)
    return out[:rows]


def swiglu_batched(gate: jax.Array, up: jax.Array) -> jax.Array:
    """Collapse leading dims, apply the kernel, restore the shape."""
    lead = gate.shape[:-1]
    out = swiglu(gate.reshape(-1, gate.shape[-1]), up.reshape(-1, up.shape[-1]))
    return out.reshape(*lead, gate.shape[-1])
