"""L1 Pallas kernel: masked decode attention over the KV cache.

One grid step per (batch, query-head); the KV sequence is processed in
S-blocks with a running (flash-style) max/sum so the softmax never
materialises outside VMEM — the BlockSpec walk over the KV cache is the
HBM->VMEM streaming schedule the paper's section 4.2 KV management feeds.

interpret=True: see matmul.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# KV-sequence block (VMEM slab) per inner step.
BLOCK_S = 64


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, s_blocks: int, scale: float):
    """One (batch, head) pair: q [1, d], k/v [1, S, d], len [1, 1]."""
    q = q_ref[0]  # [d]
    kv_len = len_ref[0, 0]

    def body(s, carry):
        m_prev, l_prev, acc = carry
        ks = k_ref[0, pl.ds(s * BLOCK_S, BLOCK_S), :]  # [B_S, d]
        vs = v_ref[0, pl.ds(s * BLOCK_S, BLOCK_S), :]
        logits = (ks @ q) * scale  # [B_S]
        idx = s * BLOCK_S + jnp.arange(BLOCK_S)
        logits = jnp.where(idx < kv_len, logits, -jnp.inf)
        m_cur = jnp.maximum(m_prev, jnp.max(logits))
        # Rescale the running accumulator (flash-attention recurrence).
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(logits - m_cur)  # [B_S]
        l_cur = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + p @ vs  # [d]
        return m_cur, l_cur, acc

    d = q_ref.shape[-1]
    init = (jnp.float32(-jnp.inf), jnp.float32(0.0), jnp.zeros((d,), jnp.float32))
    _, l_fin, acc = jax.lax.fori_loop(0, s_blocks, body, init)
    o_ref[0, :] = acc / l_fin


def decode_attention(q, k, v, kv_len) -> jax.Array:
    """Single-token attention.

    q: [B, H, d]; k, v: [B, S, KH, d] (GQA: H a multiple of KH);
    kv_len: [B] valid prefix lengths. Returns [B, H, d].
    """
    b, h, d = q.shape
    _, s, kh, _ = k.shape
    assert h % kh == 0 and s % BLOCK_S == 0, (h, kh, s)
    groups = h // kh
    scale = 1.0 / (d**0.5)

    # Expand KV heads to query heads (GQA) and flatten (batch, head).
    k_full = jnp.repeat(k, groups, axis=2)  # [B, S, H, d]
    v_full = jnp.repeat(v, groups, axis=2)
    qf = q.reshape(b * h, d).astype(jnp.float32)
    kf = jnp.moveaxis(k_full, 2, 1).reshape(b * h, s, d).astype(jnp.float32)
    vf = jnp.moveaxis(v_full, 2, 1).reshape(b * h, s, d).astype(jnp.float32)
    lens = jnp.repeat(kv_len.astype(jnp.int32), h).reshape(b * h, 1)

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel, s_blocks=s // BLOCK_S, scale=scale
        ),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), jnp.float32),
        interpret=True,
    )(qf, kf, vf, lens)
    return out.reshape(b, h, d)
