//! Unit helpers: cycles ↔ seconds, byte quantities, and human formatting.
//!
//! The simulator's native time unit is the NPU core clock **cycle**; all
//! latency formulas operate in cycles and convert to wall time only at the
//! reporting boundary via the chip's core frequency.

/// Simulated time in cycles.
pub type Cycle = u64;

pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * KB;
pub const GB: u64 = 1024 * MB;

/// Convert a GB/s bandwidth into bytes/cycle at `freq_mhz`.
#[inline]
pub fn gbps_to_bytes_per_cycle(gb_per_s: f64, freq_mhz: f64) -> f64 {
    // bytes/s / cycles/s
    (gb_per_s * 1e9) / (freq_mhz * 1e6)
}

/// Convert cycles to seconds at `freq_mhz`.
#[inline]
pub fn cycles_to_secs(cycles: Cycle, freq_mhz: f64) -> f64 {
    cycles as f64 / (freq_mhz * 1e6)
}

/// Convert cycles to milliseconds at `freq_mhz`.
#[inline]
pub fn cycles_to_ms(cycles: Cycle, freq_mhz: f64) -> f64 {
    cycles_to_secs(cycles, freq_mhz) * 1e3
}

/// Convert seconds to cycles at `freq_mhz`.
#[inline]
pub fn secs_to_cycles(secs: f64, freq_mhz: f64) -> Cycle {
    (secs * freq_mhz * 1e6).round() as Cycle
}

/// Ceiling division for positive integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0);
    a.div_ceil(b)
}

/// Human-readable byte count.
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GB {
        format!("{:.2}GiB", bytes as f64 / GB as f64)
    } else if bytes >= MB {
        format!("{:.2}MiB", bytes as f64 / MB as f64)
    } else if bytes >= KB {
        format!("{:.1}KiB", bytes as f64 / KB as f64)
    } else {
        format!("{bytes}B")
    }
}

/// Human-readable cycle count as time at `freq_mhz`.
pub fn fmt_cycles(cycles: Cycle, freq_mhz: f64) -> String {
    let s = cycles_to_secs(cycles, freq_mhz);
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_conversion_round_trip() {
        // 500 MHz, 64 GB/s -> 128 bytes/cycle.
        let bpc = gbps_to_bytes_per_cycle(64.0, 500.0);
        assert!((bpc - 128.0).abs() < 1e-9);
    }

    #[test]
    fn cycle_time_round_trip() {
        let c = secs_to_cycles(0.002, 500.0);
        assert_eq!(c, 1_000_000);
        assert!((cycles_to_ms(c, 500.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 5), 2);
        assert_eq!(ceil_div(11, 5), 3);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn fmt_bytes_ranges() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2 * KB), "2.0KiB");
        assert!(fmt_bytes(3 * MB).starts_with("3.00MiB"));
        assert!(fmt_bytes(5 * GB).starts_with("5.00GiB"));
    }
}
