//! End-to-end driver: proves all three layers compose.
//!
//! 1. **Functional path** — load the AOT artifacts (JAX/Pallas → HLO text,
//!    `make artifacts`) through the PJRT runtime and serve batched
//!    requests with *real tokens* via the rust coordinator, reporting
//!    latency/throughput. Python is not involved at any point here.
//! 2. **Timing path** — run the same request trace through NpuSim's
//!    PD-fusion scheduler on the Table-3 large-core chip and report the
//!    simulated serving metrics.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`

use npusim::config::{ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use npusim::coordinator::{Coordinator, GenRequest};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::sim::chip::ChipSim;

fn main() -> anyhow::Result<()> {
    // ---------- 1. functional path: real tokens through PJRT ----------
    let coord = Coordinator::start("artifacts")?;
    let meta = coord.meta.clone();
    println!(
        "TinyQwen artifacts loaded: vocab={} hidden={} layers={} heads={}/{} (PJRT CPU)",
        meta.vocab, meta.hidden, meta.layers, meta.heads, meta.kv_heads
    );

    let n_requests = 8usize;
    let max_new = 24usize;
    let requests: Vec<GenRequest> = (0..n_requests)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: (0..meta.prefill_len).map(|j| ((i * 37 + j * 11) % meta.vocab) as i32).collect(),
            max_new_tokens: max_new,
        })
        .collect();

    let t0 = std::time::Instant::now();
    let responses = coord.generate(requests)?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    anyhow::ensure!(
        responses.iter().all(|r| !r.tokens.is_empty()),
        "empty generation"
    );
    // Greedy decoding of identical artifacts is deterministic.
    println!("first response tokens: {:?}", &responses[0].tokens);
    println!(
        "functional: {n_requests} requests, {tokens} tokens in {wall:.3}s -> {:.1} tok/s\n",
        tokens as f64 / wall
    );

    // ---------- 2. timing path: the same trace on the simulator ----------
    let model = ModelConfig::qwen3_4b();
    let mut workload = WorkloadConfig::fixed_ratio(meta.prefill_len, max_new, n_requests);
    workload.input_len = LenDist::Fixed(512); // paper-scale prompt lengths
    workload.output_len = LenDist::Fixed(max_new);
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let metrics = simulate_fusion(&mut chip, &model, &workload, &FusionConfig::default())?;

    println!("simulated (Qwen3-4B, 64-core large chip, PD fusion):");
    println!("  TTFT mean  : {:.1} ms", metrics.ttft_s().mean() * 1e3);
    println!("  TBT  mean  : {:.2} ms", metrics.tbt_s().mean() * 1e3);
    println!("  throughput : {:.1} tok/s", metrics.tokens_per_s());
    println!(
        "  simulated makespan: {:.3} s ({} cycles)",
        chip.cycles_to_secs(metrics.makespan()),
        metrics.makespan()
    );
    println!("\ne2e OK: functional tokens + simulated timing from one stack");
    Ok(())
}
