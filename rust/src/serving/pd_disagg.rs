//! PD disaggregation (§4.3.1): dedicated prefill pipelines and decode
//! groups, with KV-cache transfer between them over the NoC.
//!
//! Prefill cores run pipeline-parallel stages (prompts stream in without
//! waiting); decode cores run tensor-parallel groups over all layers
//! (autoregression tolerates no pipeline bubbles). The placement policy
//! (Fig. 6) decides where each lives — the paper's PP-prioritized layout
//! puts prefill at the chip edges and decode in the center to shorten and
//! de-contend the KV-transfer paths. Heterogeneous chips override the
//! decode cores' hardware (narrower systolic arrays, fatter HBM — §4.3.1).
//!
//! The policy is implemented by
//! [`DisaggScheduler`](crate::serving::scheduler::DisaggScheduler) behind
//! the unified [`Scheduler`](crate::serving::scheduler::Scheduler) trait;
//! the free functions here are convenience wrappers kept for the original
//! call sites.

use crate::config::{ModelConfig, WorkloadConfig};
use crate::parallel::partition::PartitionStrategy;
use crate::model::memo::SimLevel;
use crate::parallel::pd_placement::PdPlacementPolicy;
use crate::parallel::plan::{DeploymentPlan, PdMode, SpecConfig};
use crate::serving::metrics::Metrics;
use crate::serving::request::Request;
use crate::serving::scheduler::{self, DisaggScheduler};
use crate::sim::chip::ChipSim;

/// PD-disaggregation serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct DisaggConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// TP degree of each prefill pipeline stage.
    pub prefill_tp: usize,
    /// Pipeline stages per prefill pipeline.
    pub prefill_stages: usize,
    /// TP degree of each decode group (each group runs all layers).
    pub decode_tp: usize,
    pub policy: PdPlacementPolicy,
    /// Partition for the prefill GEMMs (long sequences → AllGather/2-D).
    pub prefill_strategy: PartitionStrategy,
    /// Partition for the decode GEMVs (M=batch is small → AllReduce).
    pub decode_strategy: PartitionStrategy,
    /// Fig. 9 phase switch on the prefill pipelines: prompts shorter than
    /// this run `decode_strategy` instead of `prefill_strategy` (the K
    /// partition wins while `M < hidden/2`). `0` = static.
    pub m_threshold: u64,
    /// Max concurrent decode requests per group.
    pub max_decode_batch: usize,
    pub kv_share: f64,
    /// Prefix-sharing KV caching on the prefill pipelines (off = legacy
    /// bit-exact behaviour).
    pub prefix_cache: bool,
    /// Two-tier prefix cache on the prefill pipelines: cold prefix blocks
    /// demote to a bounded HBM region and re-promote on a hit at charged
    /// HBM→SRAM cost (requires `prefix_cache`).
    pub hbm_tier: bool,
    /// Fraction of each prefill worker's post-weight HBM KV capacity
    /// carved for the demoted-prefix tier (only read with `hbm_tier`).
    pub hbm_tier_frac: f64,
    /// Cache-affinity prompt pull: a queued prompt is pulled by the
    /// prefill pipeline holding its longest cached-and-ready prefix
    /// (ties → earliest available) instead of by whichever pipeline frees
    /// first (requires `prefix_cache`).
    pub cross_pipe: bool,
    /// Operator-latency memoization (approximate fast path, off by
    /// default).
    pub memo: bool,
    /// Simulation fidelity (`--sim-level`): transaction-level (default)
    /// or the calibrated analytic surrogate — see
    /// [`crate::model::memo::Surrogate`].
    pub sim_level: SimLevel,
    /// Speculative decoding on the decode groups (`--spec`): `None` (the
    /// default) keeps vanilla one-token-per-step decode bit-identical.
    pub spec: Option<SpecConfig>,
}

impl DisaggConfig {
    /// Project a [`DeploymentPlan`] (whose mode must be
    /// [`PdMode::Disagg`]) onto the disaggregation knobs.
    pub fn from_plan(plan: &DeploymentPlan) -> anyhow::Result<Self> {
        let PdMode::Disagg {
            n_prefill,
            n_decode,
            prefill_stages,
            decode_tp,
        } = plan.mode
        else {
            anyhow::bail!("plan {} is not a disaggregation plan", plan.name);
        };
        // `plan.stages` mirrors the mode's prefill depth for reporting;
        // a disagreement means the plan was hand-built inconsistently and
        // some consumer would silently read the wrong half.
        anyhow::ensure!(
            plan.stages == prefill_stages,
            "plan {}: stages ({}) disagrees with its disagg prefill_stages ({})",
            plan.name,
            plan.stages,
            prefill_stages
        );
        Ok(DisaggConfig {
            n_prefill,
            n_decode,
            prefill_tp: plan.tp,
            prefill_stages,
            decode_tp,
            policy: PdPlacementPolicy::PpPrioritized,
            prefill_strategy: plan.prefill_strategy,
            decode_strategy: plan.decode_strategy,
            m_threshold: plan.m_threshold,
            max_decode_batch: plan.max_batch,
            kv_share: plan.kv_share,
            prefix_cache: plan.prefix_cache,
            hbm_tier: plan.hbm_tier,
            hbm_tier_frac: plan.hbm_tier_frac,
            cross_pipe: plan.cross_pipe,
            memo: plan.memo,
            sim_level: plan.sim_level,
            spec: plan.spec,
        })
    }

    /// The paper's balanced optimum on the 64-core chip: P42/D21 at TP 7
    /// (Fig. 11's "superior overall performance" configuration) —
    /// projected from [`DeploymentPlan::disagg_default`] so the preset and
    /// the config cannot drift.
    pub fn p42_d21() -> Self {
        Self::from_plan(&DeploymentPlan::disagg_default()).expect("static disagg preset")
    }

    /// A `P<p>/D<d>` ratio preset on the 64-core chip (Fig. 11 sweep).
    pub fn ratio_64(n_prefill: usize, n_decode: usize, prefill_stages: usize) -> Self {
        DisaggConfig {
            n_prefill,
            n_decode,
            prefill_stages,
            ..Self::p42_d21()
        }
    }
}

impl Default for DisaggConfig {
    fn default() -> Self {
        Self::p42_d21()
    }
}

/// Simulate a full workload under PD disaggregation.
pub fn simulate_disagg(
    chip: &mut ChipSim,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    cfg: &DisaggConfig,
) -> anyhow::Result<Metrics> {
    let mut sched = DisaggScheduler::new(*cfg);
    scheduler::simulate(chip, model, workload, &mut sched)
}

/// Like [`simulate_disagg`] but over an explicit request list (trace
/// replay — see [`crate::serving::trace`]). Requests must be sorted by
/// arrival time.
pub fn simulate_disagg_requests(
    chip: &mut ChipSim,
    model: &ModelConfig,
    reqs: Vec<Request>,
    cfg: &DisaggConfig,
) -> anyhow::Result<Metrics> {
    let mut sched = DisaggScheduler::new(*cfg);
    scheduler::simulate_requests(chip, model, reqs, &mut sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::sim::tracer::OpClass;

    fn run(workload: &WorkloadConfig, cfg: &DisaggConfig) -> Metrics {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_disagg(&mut chip, &model, workload, cfg).unwrap()
    }

    #[test]
    fn completes_all_requests() {
        let w = WorkloadConfig::fixed_ratio(256, 16, 8);
        let m = run(&w, &DisaggConfig::default());
        assert_eq!(m.n_requests(), 8);
    }

    #[test]
    fn p42_d21_pins_the_paper_preset_through_the_plan() {
        // `p42_d21` now projects from `DeploymentPlan::disagg_default()`;
        // pin the values the golden vectors were recorded with.
        let d = DisaggConfig::p42_d21();
        assert_eq!((d.n_prefill, d.n_decode), (42, 21));
        assert_eq!((d.prefill_tp, d.prefill_stages, d.decode_tp), (7, 3, 7));
        assert_eq!(d.policy, PdPlacementPolicy::PpPrioritized);
        assert_eq!(d.prefill_strategy, PartitionStrategy::OneDimMN);
        assert_eq!(d.decode_strategy, PartitionStrategy::OneDimK);
        assert_eq!(d.m_threshold, 0, "phase switch must default off");
        assert_eq!(d.max_decode_batch, 32);
        assert_eq!(d.kv_share, 0.6);
        assert!(d.spec.is_none(), "speculative decoding must default off");
        // A fusion plan cannot masquerade as a disagg config.
        assert!(DisaggConfig::from_plan(&DeploymentPlan::fusion_default()).is_err());
    }

    #[test]
    fn record_invariants_hold() {
        let w = WorkloadConfig::fixed_ratio(128, 32, 6);
        let m = run(&w, &DisaggConfig::default());
        for r in m.records() {
            assert!(r.first_token >= r.arrival);
            assert!(r.finish >= r.first_token);
            assert_eq!(r.output_tokens, 32);
        }
    }

    #[test]
    fn single_token_outputs_finish_at_prefill() {
        let w = WorkloadConfig::fixed_ratio(128, 1, 4);
        let m = run(&w, &DisaggConfig::default());
        for r in m.records() {
            assert_eq!(r.first_token, r.finish);
        }
    }

    #[test]
    fn more_prefill_cores_cut_ttft() {
        // Fig. 11: increasing prefill cores consistently reduces TTFT.
        let w = WorkloadConfig::fixed_ratio(1000, 16, 8);
        let p21 = run(&w, &DisaggConfig::ratio_64(21, 42, 3));
        let p49 = run(&w, &DisaggConfig::ratio_64(49, 14, 7));
        assert!(
            p49.ttft_s().mean() < p21.ttft_s().mean(),
            "P49 {} vs P21 {}",
            p49.ttft_s().mean(),
            p21.ttft_s().mean()
        );
    }

    #[test]
    fn kv_transfer_traffic_recorded() {
        let w = WorkloadConfig::fixed_ratio(512, 8, 2);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_disagg(&mut chip, &model, &w, &DisaggConfig::default()).unwrap();
        assert!(chip.aggregate_tracer().cycles(OpClass::KvTransfer) > 0);
    }

    #[test]
    fn heterogeneous_decode_cores_applied() {
        let mut decode = ChipConfig::large_core().core;
        decode.sa_dim = 32;
        decode.hbm_bw_gbps = 480.0;
        let mut chip = ChipSim::new(ChipConfig::large_core().with_decode_core(decode));
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(128, 8, 2);
        simulate_disagg(&mut chip, &model, &w, &DisaggConfig::default()).unwrap();
        // Center (decode) cores must carry the override.
        let any_decode = chip.core(crate::sim::noc::Coord::new(0, 3));
        assert_eq!(any_decode.cfg.sa_dim, 32);
    }
}
