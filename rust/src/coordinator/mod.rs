//! Serving coordinator: router + dynamic batcher + PJRT worker.
//!
//! This is the *functional* half of the stack: real tokens through the
//! AOT-compiled TinyQwen artifacts (the *timing* half is [`crate::serving`]
//! on the simulator; `examples/serve_e2e.rs` composes both). Python never
//! runs here — the worker executes the HLO artifacts via
//! [`crate::runtime`].
//!
//! Threading model (std::thread + mpsc, no async runtime needed at this
//! scale): callers submit [`GenRequest`]s to the router; the batcher
//! groups them into model-sized batches (the lowered decode entry point
//! has a fixed batch dimension); one worker thread owns the PJRT client
//! and runs prefill + greedy decode, threading the KV cache between steps.

use crate::runtime::{argmax, literal_f32, literal_i32, ModelMeta, Runtime};
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::thread::JoinHandle;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// Prompt token ids (clamped to the model's vocab by the worker).
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone, PartialEq)]
pub struct GenResponse {
    pub id: u64,
    /// Generated token ids (prompt not included).
    pub tokens: Vec<i32>,
}

enum Msg {
    Submit(GenRequest, mpsc::Sender<GenResponse>),
    Shutdown,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<()>>,
    pub meta: ModelMeta,
}

impl Coordinator {
    /// Spawn the worker thread and load the artifacts inside it (the PJRT
    /// client is not `Send`, so the worker owns it end to end).
    pub fn start(artifact_dir: impl AsRef<std::path::Path>) -> Result<Coordinator> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (meta_tx, meta_rx) = mpsc::channel::<Result<ModelMeta>>();
        let worker = std::thread::spawn(move || {
            let runtime = match Runtime::load(&dir) {
                Ok(r) => {
                    let _ = meta_tx.send(Ok(r.meta.clone()));
                    r
                }
                Err(e) => {
                    let _ = meta_tx.send(Err(e));
                    return;
                }
            };
            let meta = runtime.meta.clone();
            worker_loop(runtime, meta, rx);
        });
        let meta = meta_rx
            .recv()
            .context("worker thread died during startup")??;
        Ok(Coordinator {
            tx,
            worker: Some(worker),
            meta,
        })
    }

    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self, req: GenRequest) -> Result<mpsc::Receiver<GenResponse>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(req, rtx))
            .map_err(|_| anyhow::anyhow!("coordinator worker gone"))?;
        Ok(rrx)
    }

    /// Convenience: batched blocking generation.
    pub fn generate(&self, requests: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let receivers: Vec<_> = requests
            .into_iter()
            .map(|r| self.submit(r))
            .collect::<Result<_>>()?;
        receivers
            .into_iter()
            .map(|rx| rx.recv().context("worker dropped response"))
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The worker: dynamic batching + prefill/decode over PJRT.
fn worker_loop(runtime: Runtime, meta: ModelMeta, rx: mpsc::Receiver<Msg>) {
    let batch = meta.decode_batch;
    let mut queue: Vec<(GenRequest, mpsc::Sender<GenResponse>)> = Vec::new();
    loop {
        // Block for the first request, then drain whatever else is queued
        // (dynamic batching: take what arrived, don't wait for a full batch
        // longer than the drain window).
        if queue.is_empty() {
            match rx.recv() {
                Ok(Msg::Submit(r, tx)) => queue.push((r, tx)),
                Ok(Msg::Shutdown) | Err(_) => return,
            }
        }
        let window = std::time::Duration::from_millis(2);
        while queue.len() < batch {
            match rx.recv_timeout(window) {
                Ok(Msg::Submit(r, tx)) => queue.push((r, tx)),
                Ok(Msg::Shutdown) => return,
                Err(_) => break,
            }
        }
        let take = queue.len().min(batch);
        let group: Vec<_> = queue.drain(..take).collect();
        match run_batch(&runtime, &meta, &group) {
            Ok(responses) => {
                for ((_, tx), resp) in group.iter().zip(responses) {
                    let _ = tx.send(resp);
                }
            }
            Err(e) => {
                crate::log_warn!("batch failed: {e:#}");
                for (req, tx) in &group {
                    let _ = tx.send(GenResponse {
                        id: req.id,
                        tokens: Vec::new(),
                    });
                }
            }
        }
    }
}

/// Run one model-sized batch: fixed-length prefill + greedy decode.
fn run_batch(
    runtime: &Runtime,
    meta: &ModelMeta,
    group: &[(GenRequest, mpsc::Sender<GenResponse>)],
) -> Result<Vec<GenResponse>> {
    let b = meta.decode_batch;
    let p = meta.prefill_len;
    // Right-align prompts into the fixed prefill window (pad id 0).
    let mut tokens = vec![0i32; b * p];
    for (i, (req, _)) in group.iter().enumerate() {
        let prompt: Vec<i32> = req
            .prompt
            .iter()
            .map(|&t| t.rem_euclid(meta.vocab as i32))
            .collect();
        let take = prompt.len().min(p);
        let src = &prompt[prompt.len() - take..];
        tokens[i * p + (p - take)..(i + 1) * p].copy_from_slice(src);
    }
    let tok_lit = literal_i32(&tokens, &[b as i64, p as i64])?;
    let out = runtime.execute(&runtime.prefill, &[tok_lit])?;
    let (logits, mut kv) = (out[0].clone(), out[1].clone());

    // Last-position logits per sequence -> first generated token.
    let vocab = meta.vocab;
    let mut current: Vec<i32> = (0..b)
        .map(|i| {
            let row = &logits[(i * p + p - 1) * vocab..(i * p + p) * vocab];
            argmax(row) as i32
        })
        .collect();
    let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
    for (i, t) in current.iter().enumerate() {
        generated[i].push(*t);
    }

    let max_new = group
        .iter()
        .map(|(r, _)| r.max_new_tokens)
        .max()
        .unwrap_or(1)
        .min(meta.max_seq - p);
    let kv_shape: Vec<i64> = vec![
        meta.layers as i64,
        2,
        b as i64,
        meta.max_seq as i64,
        meta.kv_heads as i64,
        meta.head_dim as i64,
    ];
    for step in 1..max_new {
        let pos = (p + step - 1) as i32;
        let tok_lit = literal_i32(&current, &[b as i64])?;
        let pos_lit = xla::Literal::scalar(pos);
        let kv_lit = literal_f32(&kv, &kv_shape)?;
        let out = runtime.execute(&runtime.decode, &[tok_lit, pos_lit, kv_lit])?;
        kv = out[1].clone();
        for i in 0..b {
            current[i] = argmax(&out[0][i * vocab..(i + 1) * vocab]) as i32;
            generated[i].push(current[i]);
        }
    }

    Ok(group
        .iter()
        .enumerate()
        .map(|(i, (req, _))| GenResponse {
            id: req.id,
            tokens: generated[i][..req.max_new_tokens.min(generated[i].len())].to_vec(),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_e2e.rs (need
    // `make artifacts`); here we only test the pure helpers.

    #[test]
    fn prompt_clamping_is_modulo_vocab() {
        assert_eq!((300i32).rem_euclid(256), 44);
        assert_eq!((-1i32).rem_euclid(256), 255);
    }
}
