//! Deterministic radix/trie index over token-block hashes — the lookup
//! structure behind prefix-sharing KV caching (vLLM/SGLang-style automatic
//! prefix caching, adapted to the paper's fine-grained SRAM blocks).
//!
//! Each node stands for one SRAM block holding one block's worth of prefix
//! tokens; its key is the content hash of that token block, and its parent
//! is the preceding block of the prefix — so a path from the root spells a
//! token prefix, and the longest matching path is exactly the longest
//! cached prefix of an incoming request. Nodes hold the *terminal* token
//! count too, so a partially filled final block of a shared prefix (e.g. a
//! system prompt that is not block-aligned) is matchable; divergence past
//! it is handled by the [`super::kv::KvCache`]'s copy-on-write.
//!
//! Eviction is ref-count-aware LRU: only leaf nodes whose block has no
//! owner besides the index itself are candidates, ordered by last use then
//! node id — fully deterministic (no HashMap iteration order leaks into
//! behaviour; the map is only ever *probed* by key).
//!
//! With the **HBM tier** enabled (see [`super::kv::KvCache`]), eviction
//! becomes *demotion*: a cold node keeps its place in the trie but drops
//! its SRAM block and moves to [`Tier::Hbm`] ([`PrefixIndex::demote_lru`]).
//! Demoted nodes still match lookups — at a charged HBM→SRAM promotion
//! cost ([`PrefixIndex::promote`]) instead of a full prefill recompute —
//! and only leave the trie when the HBM tier itself overflows
//! ([`PrefixIndex::drop_lru_hbm`]).
//!
//! Matching is **in-flight aware**: a node registered at admission time is
//! [`PENDING`] until the producing prefill actually completes
//! ([`PrefixIndex::mark_ready`]), and [`PrefixIndex::lookup`]/
//! [`PrefixIndex::peek`] only match nodes whose `ready_at` is at or before
//! the probing cycle — so a just-registered block never counts as a hit
//! (and never skips prefill work) before its KV physically exists.

use std::collections::HashMap;

/// Sentinel parent for root-level nodes.
pub const NO_NODE: u32 = u32::MAX;

/// `ready_at` sentinel for blocks whose producing prefill is in flight.
pub const PENDING: u64 = u64::MAX;

/// Which memory tier a cached prefix block currently lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Fast tier: the node owns an SRAM block and matches for free.
    Sram,
    /// Capacity tier: the KV bytes were demoted to HBM; a match must first
    /// re-promote them into a fresh SRAM block at charged transfer cost.
    Hbm,
}

/// A tier-split prefix match: how many matched tokens are SRAM-resident
/// versus demoted to HBM (promotion-priced). Routing and pipe selection
/// score the two tiers differently — both beat a recompute.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierMatch {
    /// Matched tokens whose blocks are SRAM-resident (free to share).
    pub sram_tokens: u64,
    /// Matched tokens whose blocks are HBM-demoted (promotion-priced).
    pub hbm_tokens: u64,
}

impl TierMatch {
    /// Total matched tokens across both tiers.
    pub fn total(&self) -> u64 {
        self.sram_tokens + self.hbm_tokens
    }

    /// Deterministic integer affinity score: a fast-tier token counts
    /// double an HBM-tier token (both replace recompute; only one pays a
    /// promotion transfer).
    pub fn score(&self) -> u64 {
        2 * self.sram_tokens + self.hbm_tokens
    }
}

/// The `keys` prefix covering exactly the first `tokens` matched tokens
/// (block-aligned truncation helper shared by the cluster router's KV
/// migration and the cross-pipe NoC import).
pub fn keys_prefix(keys: &[BlockKey], tokens: u64) -> Vec<BlockKey> {
    let mut out = Vec::new();
    let mut cum = 0u64;
    for &k in keys {
        if cum + k.tokens > tokens {
            break;
        }
        cum += k.tokens;
        out.push(k);
    }
    out
}

/// One token block of a shareable prefix: the content hash of the block
/// and how many tokens it holds (full blocks hold `block_tokens`; the
/// terminal block of a prefix may hold fewer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockKey {
    pub hash: u64,
    pub tokens: u64,
}

#[derive(Debug, Clone, Copy)]
struct Node {
    parent: u32,
    hash: u64,
    block: u32,
    tokens: u64,
    last_use: u64,
    n_children: u32,
    /// Live children still on the SRAM tier. Demotion proceeds
    /// leaf-upward (only nodes with `n_sram_children == 0` qualify), so a
    /// demoted subtree is always drainable by [`PrefixIndex::drop_lru_hbm`]
    /// leaf by leaf — the HBM tier's capacity bound stays enforceable.
    n_sram_children: u32,
    live: bool,
    /// Cycle at which the block's KV is materialised ([`PENDING`] while
    /// the producing prefill is still in flight).
    ready_at: u64,
    /// Residency tier. `block` is only meaningful while [`Tier::Sram`];
    /// demotion frees the SRAM block and promotion assigns a fresh one.
    tier: Tier,
}

/// A matched or registered prefix block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixBlock {
    /// Index node backing this block.
    pub node: u32,
    /// SRAM block id (stale while `tier` is [`Tier::Hbm`] — the caller
    /// must promote first and use the fresh block).
    pub block: u32,
    /// Tokens this block contributes to the matched prefix.
    pub tokens: u64,
    /// Residency tier at lookup time.
    pub tier: Tier,
}

/// The trie of cached prefix blocks for one [`super::kv::KvCache`].
#[derive(Debug, Default)]
pub struct PrefixIndex {
    nodes: Vec<Node>,
    /// `(parent node | NO_NODE, block hash) -> node` — probed by key only.
    children: HashMap<(u32, u64), u32>,
    free_slots: Vec<u32>,
    tick: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live (cached) prefix blocks.
    pub fn n_cached(&self) -> usize {
        self.nodes.iter().filter(|n| n.live).count()
    }

    /// Advance the LRU clock (once per lookup).
    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Is `key` already cached as a child of `parent`? (Used to stop
    /// registration when a capped match left cached continuations.)
    pub fn child_of(&self, parent: u32, key: BlockKey) -> Option<u32> {
        self.child(parent, key)
    }

    /// Child of `parent` matching `key` exactly (hash *and* token count).
    fn child(&self, parent: u32, key: BlockKey) -> Option<u32> {
        let &ix = self.children.get(&(parent, key.hash))?;
        let n = &self.nodes[ix as usize];
        (n.live && n.tokens == key.tokens).then_some(ix)
    }

    /// Longest cached-and-ready prefix of `keys`, capped at `max_tokens`:
    /// only nodes whose producing prefill completed at or before cycle
    /// `at` match (registered-but-in-flight blocks are invisible). Touches
    /// every matched node's LRU stamp. Read-only peek via `peek`.
    pub fn lookup(&mut self, keys: &[BlockKey], max_tokens: u64, at: u64) -> Vec<PrefixBlock> {
        let now = self.bump();
        let mut out = Vec::new();
        let mut parent = NO_NODE;
        let mut tokens = 0u64;
        for &key in keys {
            let Some(ix) = self.child(parent, key) else { break };
            if self.nodes[ix as usize].ready_at > at {
                break;
            }
            if tokens + key.tokens > max_tokens {
                break;
            }
            tokens += key.tokens;
            self.nodes[ix as usize].last_use = now;
            out.push(PrefixBlock {
                node: ix,
                block: self.nodes[ix as usize].block,
                tokens: key.tokens,
                tier: self.nodes[ix as usize].tier,
            });
            parent = ix;
        }
        out
    }

    /// Matched ready token count for `keys` at cycle `at` without mutating
    /// LRU state (used to agree on a common match length across pipeline
    /// stages, and by the cluster router's read-only probe). Counts both
    /// tiers — a demoted block still replaces a recompute.
    pub fn peek(&self, keys: &[BlockKey], max_tokens: u64, at: u64) -> u64 {
        self.peek_tiered(keys, max_tokens, at).total()
    }

    /// Like [`PrefixIndex::peek`] but split by residency tier, so callers
    /// can price SRAM hits and promotion-priced HBM hits differently.
    pub fn peek_tiered(&self, keys: &[BlockKey], max_tokens: u64, at: u64) -> TierMatch {
        let mut parent = NO_NODE;
        let mut m = TierMatch::default();
        for &key in keys {
            let Some(ix) = self.child(parent, key) else { break };
            if self.nodes[ix as usize].ready_at > at {
                break;
            }
            if m.total() + key.tokens > max_tokens {
                break;
            }
            match self.nodes[ix as usize].tier {
                Tier::Sram => m.sram_tokens += key.tokens,
                Tier::Hbm => m.hbm_tokens += key.tokens,
            }
            parent = ix;
        }
        m
    }

    /// Register `block` as the child of `parent` for `key`, usable by
    /// matches from cycle `ready_at` on (pass [`PENDING`] at admission
    /// time and [`PrefixIndex::mark_ready`] it when the producing prefill
    /// completes). Returns the new node (the caller must hold one
    /// reference on `block` for the index). `parent` is `NO_NODE` for the
    /// first block of a prefix.
    pub fn insert(&mut self, parent: u32, key: BlockKey, block: u32, ready_at: u64) -> u32 {
        debug_assert!(
            self.child(parent, key).is_none(),
            "duplicate prefix insert"
        );
        let now = self.bump();
        let node = Node {
            parent,
            hash: key.hash,
            block,
            tokens: key.tokens,
            last_use: now,
            n_children: 0,
            n_sram_children: 0,
            live: true,
            ready_at,
            tier: Tier::Sram,
        };
        let ix = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        self.children.insert((parent, key.hash), ix);
        if parent != NO_NODE {
            self.nodes[parent as usize].n_children += 1;
            self.nodes[parent as usize].n_sram_children += 1;
        }
        ix
    }

    /// Record that `node`'s KV exists from cycle `now` on (the producing
    /// prefill completed, or a migrated copy landed). Keeps the earliest
    /// readiness if called twice.
    pub fn mark_ready(&mut self, node: u32, now: u64) {
        let n = &mut self.nodes[node as usize];
        if n.live && now < n.ready_at {
            n.ready_at = now;
        }
    }

    /// Evict the least-recently-used SRAM-resident leaf whose block
    /// `can_evict` (i.e. is referenced by nobody but the index). Returns
    /// the evicted block so the caller can drop the index's reference.
    /// Deterministic: ties on `last_use` break on node id.
    pub fn evict_lru(&mut self, can_evict: impl Fn(u32) -> bool) -> Option<u32> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                n.live && n.tier == Tier::Sram && n.n_children == 0 && can_evict(n.block)
            })
            .min_by_key(|(ix, n)| (n.last_use, *ix))
            .map(|(ix, _)| ix as u32)?;
        Some(self.remove(victim))
    }

    /// Demote the least-recently-used SRAM-resident node whose block
    /// `can_evict` to the HBM tier: the node stays in the trie (and stays
    /// matchable, at promotion cost) but releases its SRAM block, which is
    /// returned as `(node, block)` for the caller to free. Demotion
    /// proceeds leaf-upward: only nodes with no SRAM-resident children
    /// qualify (a node whose children are all demoted counts), so demoted
    /// subtrees are always Hbm-closed downward and the overflow drop loop
    /// can drain them leaf by leaf. Interior nodes still become demotable
    /// once their subtree has demoted — demotion never deadlocks SRAM
    /// reclamation and never breaks the trie structure.
    pub fn demote_lru(&mut self, can_evict: impl Fn(u32) -> bool) -> Option<(u32, u32)> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| {
                n.live && n.tier == Tier::Sram && n.n_sram_children == 0 && can_evict(n.block)
            })
            .min_by_key(|(ix, n)| (n.last_use, *ix))
            .map(|(ix, _)| ix as u32)?;
        let block = self.nodes[victim as usize].block;
        let parent = self.nodes[victim as usize].parent;
        self.nodes[victim as usize].tier = Tier::Hbm;
        if parent != NO_NODE {
            self.nodes[parent as usize].n_sram_children -= 1;
        }
        Some((victim, block))
    }

    /// Re-materialise a demoted node in SRAM: assign it the freshly
    /// allocated `block` (whose single reference now belongs to the index)
    /// and move it back to the fast tier.
    pub fn promote(&mut self, node: u32, block: u32) {
        let parent = self.nodes[node as usize].parent;
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.live && n.tier == Tier::Hbm, "promote of node {node}");
        n.block = block;
        n.tier = Tier::Sram;
        if parent != NO_NODE {
            self.nodes[parent as usize].n_sram_children += 1;
        }
    }

    /// Drop the least-recently-used HBM-tier leaf from the trie entirely
    /// (true eviction — the HBM tier overflowed). Returns the dropped
    /// node's token count for capacity accounting.
    pub fn drop_lru_hbm(&mut self) -> Option<u64> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.live && n.tier == Tier::Hbm && n.n_children == 0)
            .min_by_key(|(ix, n)| (n.last_use, *ix))
            .map(|(ix, _)| ix as u32)?;
        let tokens = self.nodes[victim as usize].tokens;
        self.remove(victim);
        Some(tokens)
    }

    /// Is `node` still a live trie entry? (A concurrent demotion chain can
    /// drop an HBM leaf between a lookup and its promotion.)
    pub fn is_live(&self, node: u32) -> bool {
        self.nodes[node as usize].live
    }

    /// Current residency tier of a live node.
    pub fn tier_of(&self, node: u32) -> Tier {
        self.nodes[node as usize].tier
    }

    /// SRAM block of a live [`Tier::Sram`] node.
    pub fn block_of(&self, node: u32) -> u32 {
        self.nodes[node as usize].block
    }

    /// Token count of a live node.
    pub fn tokens_of(&self, node: u32) -> u64 {
        self.nodes[node as usize].tokens
    }

    /// Remove one leaf node, returning its block.
    fn remove(&mut self, ix: u32) -> u32 {
        let n = self.nodes[ix as usize];
        debug_assert!(n.live && n.n_children == 0, "removing non-leaf {ix}");
        self.children.remove(&(n.parent, n.hash));
        if n.parent != NO_NODE {
            self.nodes[n.parent as usize].n_children -= 1;
            if n.tier == Tier::Sram {
                self.nodes[n.parent as usize].n_sram_children -= 1;
            }
        }
        self.nodes[ix as usize].live = false;
        self.free_slots.push(ix);
        n.block
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(hash: u64) -> BlockKey {
        BlockKey { hash, tokens: 16 }
    }

    #[test]
    fn empty_index_matches_nothing() {
        let mut ix = PrefixIndex::new();
        assert!(ix.lookup(&[key(1), key(2)], u64::MAX, 0).is_empty());
        assert_eq!(ix.peek(&[key(1)], u64::MAX, 0), 0);
    }

    #[test]
    fn longest_prefix_match_walks_the_trie() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        let b = ix.insert(a, key(2), 11, 0);
        ix.insert(b, key(3), 12, 0);
        let m = ix.lookup(&[key(1), key(2), key(9)], u64::MAX, 0);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].block, 10);
        assert_eq!(m[1].block, 11);
        // Full path matches all three.
        assert_eq!(ix.peek(&[key(1), key(2), key(3)], u64::MAX, 0), 48);
        // A different first block matches nothing.
        assert!(ix.lookup(&[key(7)], u64::MAX, 0).is_empty());
    }

    #[test]
    fn partial_terminal_block_requires_exact_token_count() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, BlockKey { hash: 2, tokens: 5 }, 11, 0);
        // Same hash, different fill: no match past the first block.
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 0), 16);
        assert_eq!(
            ix.peek(&[key(1), BlockKey { hash: 2, tokens: 5 }], u64::MAX, 0),
            21
        );
    }

    #[test]
    fn max_tokens_caps_the_match() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        let m = ix.lookup(&[key(1), key(2)], 16, 0);
        assert_eq!(m.len(), 1);
        assert_eq!(ix.peek(&[key(1), key(2)], 20, 0), 16);
    }

    #[test]
    fn pending_blocks_are_invisible_until_marked_ready() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, PENDING);
        let b = ix.insert(a, key(2), 11, PENDING);
        // In flight: nothing matches at any finite cycle.
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 1_000_000), 0);
        assert!(ix.lookup(&[key(1), key(2)], u64::MAX, 1_000_000).is_empty());
        // First block's prefill completes at cycle 500: it matches from
        // then on, but the still-pending continuation does not.
        ix.mark_ready(a, 500);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 499), 0);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 500), 16);
        ix.mark_ready(b, 800);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 800), 32);
        // mark_ready keeps the earliest readiness.
        ix.mark_ready(b, 900);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 800), 32);
    }

    #[test]
    fn lru_eviction_prefers_cold_leaves_and_respects_refcounts() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        ix.insert(NO_NODE, key(5), 12, 0);
        // Touch the second root so block 12 is no longer the coldest leaf…
        ix.lookup(&[key(5)], u64::MAX, 0);
        // …leaving block 11 (leaf of the first path) as the LRU victim.
        assert_eq!(ix.evict_lru(|_| true), Some(11));
        // Now block 10 is a leaf again; a refcount guard can protect it.
        assert_eq!(ix.evict_lru(|b| b != 10), Some(12));
        assert_eq!(ix.evict_lru(|b| b != 10), None);
        assert_eq!(ix.evict_lru(|_| true), Some(10));
        assert_eq!(ix.n_cached(), 0);
    }

    #[test]
    fn interior_nodes_are_never_evicted() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        // Block 10 backs an interior node: only 11 is evictable.
        assert_eq!(ix.evict_lru(|_| true), Some(11));
    }

    #[test]
    fn demotion_keeps_the_node_matchable_and_frees_its_block() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        let b = ix.insert(a, key(2), 11, 0);
        // Leaf-upward: the leaf demotes first even though the root is
        // colder (an interior node with SRAM children never demotes, so
        // demoted subtrees stay drainable).
        assert_eq!(ix.demote_lru(|_| true), Some((b, 11)));
        assert_eq!(ix.tier_of(b), Tier::Hbm);
        // Still matches — but split reports the HBM-tier portion.
        let m = ix.peek_tiered(&[key(1), key(2)], u64::MAX, 0);
        assert_eq!(m.sram_tokens, 16);
        assert_eq!(m.hbm_tokens, 16);
        assert_eq!(m.total(), 32);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 0), 32);
        // With its subtree demoted, the root becomes demotable too.
        assert_eq!(ix.demote_lru(|_| true), Some((a, 10)));
        assert_eq!(
            ix.peek_tiered(&[key(1), key(2)], u64::MAX, 0).hbm_tokens,
            32
        );
        // Promotion restores the fast tier with fresh blocks (path order,
        // as admission promotes).
        ix.promote(a, 42);
        ix.promote(b, 43);
        assert_eq!(ix.tier_of(a), Tier::Sram);
        assert_eq!(ix.block_of(a), 42);
        assert_eq!(ix.block_of(b), 43);
        assert_eq!(
            ix.peek_tiered(&[key(1), key(2)], u64::MAX, 0).hbm_tokens,
            0
        );
    }

    #[test]
    fn demoted_nodes_are_invisible_to_sram_eviction() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        assert_eq!(ix.demote_lru(|_| true), Some((a, 10)));
        // evict_lru must not return the stale block of an HBM node.
        assert_eq!(ix.evict_lru(|_| true), None);
        assert_eq!(ix.demote_lru(|_| true), None);
        // The HBM drop path reclaims it instead.
        assert_eq!(ix.drop_lru_hbm(), Some(16));
        assert_eq!(ix.n_cached(), 0);
        assert!(!ix.is_live(a));
    }

    #[test]
    fn hbm_drop_respects_leaves_and_lru_order() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        let b = ix.insert(a, key(2), 11, 0);
        ix.demote_lru(|_| true); // b (leaf-upward)
        ix.demote_lru(|_| true); // a
        // a is interior: only the leaf b may drop first.
        assert_eq!(ix.drop_lru_hbm(), Some(16));
        assert!(!ix.is_live(b));
        assert!(ix.is_live(a));
        assert_eq!(ix.drop_lru_hbm(), Some(16));
        assert_eq!(ix.drop_lru_hbm(), None);
    }

    #[test]
    fn keys_prefix_truncates_on_block_boundaries() {
        let ks = [key(1), key(2), BlockKey { hash: 3, tokens: 5 }];
        assert_eq!(keys_prefix(&ks, 37).len(), 3);
        assert_eq!(keys_prefix(&ks, 36).len(), 2);
        assert_eq!(keys_prefix(&ks, 31).len(), 1);
        assert_eq!(keys_prefix(&ks, 0).len(), 0);
    }

    #[test]
    fn slots_are_recycled_after_eviction() {
        let mut ix = PrefixIndex::new();
        ix.insert(NO_NODE, key(1), 10, 0);
        assert_eq!(ix.evict_lru(|_| true), Some(10));
        let again = ix.insert(NO_NODE, key(3), 20, 0);
        assert_eq!(again, 0, "freed slot reused");
        assert_eq!(ix.peek(&[key(3)], u64::MAX, 0), 16);
        assert_eq!(ix.n_cached(), 1);
    }
}
