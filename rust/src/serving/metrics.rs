//! Serving metrics: TTFT, TBT, end-to-end latency, throughput, SLO
//! attainment — the quantities every figure in §5.5 reports.

use crate::serving::request::Priority;
use crate::util::stats::Summary;
use crate::util::units::{cycles_to_secs, Cycle};

/// Lifecycle timestamps of one completed request (in simulated cycles).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: Cycle,
    /// First output token produced (end of prefill).
    pub first_token: Cycle,
    /// Last output token produced.
    pub finish: Cycle,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// Scheduling class the request ran under.
    pub priority: Priority,
}

impl RequestRecord {
    /// Time To First Token, cycles.
    pub fn ttft(&self) -> Cycle {
        self.first_token.saturating_sub(self.arrival)
    }

    /// Mean Time Between Tokens, cycles (0 for single-token outputs).
    pub fn tbt(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish - self.first_token) as f64 / (self.output_tokens - 1) as f64
    }

    /// End-to-end latency, cycles.
    pub fn e2e(&self) -> Cycle {
        self.finish.saturating_sub(self.arrival)
    }

    /// Mean Time Between Tokens in seconds at `freq_mhz` (0 for
    /// single-token outputs) — the one conversion shared by reporting and
    /// SLO checks.
    pub fn tbt_secs(&self, freq_mhz: f64) -> f64 {
        self.tbt() / (freq_mhz * 1e6)
    }
}

/// Prefix-cache and memoization counters of one serving run (all zero
/// when both features are off).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Admissions that consulted the prefix cache with a non-empty set of
    /// shareable-prefix keys — the hit-rate denominator. Requests with
    /// nothing shareable (and admissions on cache-disabled workers, e.g.
    /// the prefix-off chips of a mixed cluster) are excluded, so the rate
    /// measures how often a consultable prompt actually hit.
    pub prefix_lookups: u64,
    /// Admissions that matched a non-empty cached prefix.
    pub prefix_hits: u64,
    /// Prompt tokens admitted (hit-rate denominator for skip fraction).
    pub prefill_tokens_total: u64,
    /// Prompt tokens whose prefill was skipped via cached prefixes.
    pub prefill_tokens_skipped: u64,
    /// Whole-model KV bytes deduplicated by sharing.
    pub kv_bytes_deduped: u64,
    /// Copy-on-write block copies on divergence (summed over workers).
    pub cow_copies: u64,
    /// Cached prefix blocks reclaimed by LRU eviction (summed).
    pub prefix_evictions: u64,
    /// Cold prefix blocks demoted SRAM→HBM instead of dropped (summed).
    pub tier_demotions: u64,
    /// Demoted prefix blocks re-promoted to SRAM on a hit (summed).
    pub tier_promotions: u64,
    /// Demoted blocks dropped for real when the HBM tier overflowed.
    pub tier_dropped: u64,
    /// Cross-pipe prefix imports streamed over the on-chip NoC.
    pub noc_prefix_imports: u64,
    /// Prompt tokens whose cached KV was imported from a sibling pipe.
    pub noc_prefix_tokens: u64,
    /// Operator-latency memo hits (summed over workers).
    pub memo_hits: u64,
    /// Operator-latency memo misses (summed over workers).
    pub memo_misses: u64,
}

impl CacheStats {
    /// Fraction of prefix lookups that hit.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / self.prefix_lookups as f64
    }

    /// Fraction of admitted prompt tokens skipped by prefix caching.
    pub fn token_skip_rate(&self) -> f64 {
        if self.prefill_tokens_total == 0 {
            return 0.0;
        }
        self.prefill_tokens_skipped as f64 / self.prefill_tokens_total as f64
    }

    pub fn memo_hit_rate(&self) -> f64 {
        let total = self.memo_hits + self.memo_misses;
        if total == 0 {
            return 0.0;
        }
        self.memo_hits as f64 / total as f64
    }

    /// Fold another run's counters into this one (cluster rollups, worker
    /// sweeps).
    pub fn merge(&mut self, o: &CacheStats) {
        self.prefix_lookups += o.prefix_lookups;
        self.prefix_hits += o.prefix_hits;
        self.prefill_tokens_total += o.prefill_tokens_total;
        self.prefill_tokens_skipped += o.prefill_tokens_skipped;
        self.kv_bytes_deduped += o.kv_bytes_deduped;
        self.cow_copies += o.cow_copies;
        self.prefix_evictions += o.prefix_evictions;
        self.tier_demotions += o.tier_demotions;
        self.tier_promotions += o.tier_promotions;
        self.tier_dropped += o.tier_dropped;
        self.noc_prefix_imports += o.noc_prefix_imports;
        self.noc_prefix_tokens += o.noc_prefix_tokens;
        self.memo_hits += o.memo_hits;
        self.memo_misses += o.memo_misses;
    }
}

/// Control-plane counters of one serving run: overload shedding and
/// deferral at the cluster frontend, plus preemption/resume activity
/// inside the chips. All zero with uniform priorities and no shed policy
/// — the golden vectors pin that.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ControlStats {
    /// Requests refused admission by the overload policy. A shed request
    /// never produces a [`RequestRecord`] — shed and completed are
    /// disjoint by construction.
    pub shed_requests: u64,
    /// Shed requests split by class (indexed by [`Priority::index`]).
    pub shed_by_class: [u64; 3],
    /// Admissions postponed by the `defer` policy (each retry counts).
    pub deferrals: u64,
    /// In-flight decodes parked so higher-priority work could run.
    pub preemptions: u64,
    /// Parked requests re-admitted from their parked KV (no recompute).
    pub resumes: u64,
    /// Total cycles resumed requests spent parked (resume latency sum).
    pub resume_wait_cycles: u64,
}

impl ControlStats {
    /// Mean park→resume latency in cycles (0 when nothing resumed).
    pub fn mean_resume_wait(&self) -> f64 {
        if self.resumes == 0 {
            return 0.0;
        }
        self.resume_wait_cycles as f64 / self.resumes as f64
    }

    /// Fold another run's counters into this one (cluster rollups).
    pub fn merge(&mut self, o: &ControlStats) {
        self.shed_requests += o.shed_requests;
        for (a, b) in self.shed_by_class.iter_mut().zip(o.shed_by_class) {
            *a += b;
        }
        self.deferrals += o.deferrals;
        self.preemptions += o.preemptions;
        self.resumes += o.resumes;
        self.resume_wait_cycles += o.resume_wait_cycles;
    }
}

/// Speculative-decoding counters of one serving run (all zero when
/// `--spec` is off — the golden vectors pin that).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens proposed across all verify rounds.
    pub drafted_tokens: u64,
    /// Draft tokens accepted by verification.
    pub accepted_tokens: u64,
    /// Draft tokens rejected and rolled back from the paged KV.
    pub rejected_tokens: u64,
    /// Batched verify iterations issued.
    pub verify_steps: u64,
    /// Histogram of the verify GEMM M (total q_tokens per verify batch),
    /// bucketed by power of two: bucket `i` counts batches with
    /// `M in [2^i, 2^(i+1))`.
    pub verify_m_hist: [u64; 16],
    /// Verify batches whose M crossed the exec's phase-switch threshold,
    /// i.e. ran the large-M (prefill) partition strategy instead of the
    /// decode K-partition — the Fig. 9 flip evidence the bucketed
    /// histogram cannot express exactly.
    pub verify_above_threshold: u64,
    /// Decode iterations that streamed the layer weights from HBM
    /// (vanilla decode steps + spec verify steps) — the denominator of
    /// tokens-per-weight-stream.
    pub decode_weight_streams: u64,
    /// Output tokens committed by decode iterations (vanilla + spec).
    pub decode_tokens_committed: u64,
}

impl SpecStats {
    /// Record one verify batch of GEMM size `m` against the exec's
    /// phase-switch threshold (`0` = no switch configured).
    pub fn observe_verify_m(&mut self, m: u64, threshold: u64) {
        let bucket = (63 - m.max(1).leading_zeros() as usize).min(self.verify_m_hist.len() - 1);
        self.verify_m_hist[bucket] += 1;
        self.verify_steps += 1;
        if threshold > 0 && m >= threshold {
            self.verify_above_threshold += 1;
        }
    }

    /// Median verify-batch M, reconstructed from the histogram's bucket
    /// lower bounds (0 when no verify step ran).
    pub fn verify_m_p50(&self) -> u64 {
        let total: u64 = self.verify_m_hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let mut seen = 0u64;
        for (i, &n) in self.verify_m_hist.iter().enumerate() {
            seen += n;
            if seen * 2 >= total {
                return 1 << i;
            }
        }
        0
    }

    /// Fraction of drafted tokens accepted (0 when nothing drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            return 0.0;
        }
        self.accepted_tokens as f64 / self.drafted_tokens as f64
    }

    /// Output tokens committed per weight stream from HBM — the
    /// amortization headline: vanilla decode commits one token per request
    /// per stream, spec verification lifts that toward
    /// `1 + gamma * acceptance` per request.
    pub fn tokens_per_weight_stream(&self) -> f64 {
        if self.decode_weight_streams == 0 {
            return 0.0;
        }
        self.decode_tokens_committed as f64 / self.decode_weight_streams as f64
    }

    /// Fold another run's counters into this one (cluster rollups).
    pub fn merge(&mut self, o: &SpecStats) {
        self.drafted_tokens += o.drafted_tokens;
        self.accepted_tokens += o.accepted_tokens;
        self.rejected_tokens += o.rejected_tokens;
        self.verify_steps += o.verify_steps;
        for (a, b) in self.verify_m_hist.iter_mut().zip(o.verify_m_hist) {
            *a += b;
        }
        self.verify_above_threshold += o.verify_above_threshold;
        self.decode_weight_streams += o.decode_weight_streams;
        self.decode_tokens_committed += o.decode_tokens_committed;
    }
}

/// Aggregated metrics over a serving run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    records: Vec<RequestRecord>,
    freq_mhz: f64,
    /// Prefix-cache / memo counters (filled by the schedulers).
    pub cache: CacheStats,
    /// Control-plane counters (filled by the schedulers and the cluster
    /// admission frontend).
    pub control: ControlStats,
    /// Speculative-decoding counters (filled by the schedulers).
    pub spec: SpecStats,
}

impl Metrics {
    pub fn new(freq_mhz: f64) -> Self {
        Metrics {
            records: Vec::new(),
            freq_mhz,
            cache: CacheStats::default(),
            control: ControlStats::default(),
            spec: SpecStats::default(),
        }
    }

    pub fn record(&mut self, r: RequestRecord) {
        debug_assert!(r.first_token >= r.arrival && r.finish >= r.first_token, "{r:?}");
        self.records.push(r);
    }

    /// Rewrite one record's arrival to an earlier cycle, returning whether
    /// the record exists yet. The cluster driver (and the cross-pipe NoC
    /// import) admit a migrated request at its KV-landing instant but its
    /// TTFT must count from the true frontend arrival — this restores it
    /// after completion (keeps the earlier of the two, preserving the
    /// `first_token >= arrival` invariant).
    pub fn rebase_arrival(&mut self, id: u64, arrival: Cycle) -> bool {
        match self.records.iter_mut().find(|r| r.id == id) {
            Some(r) => {
                r.arrival = r.arrival.min(arrival);
                true
            }
            None => false,
        }
    }

    /// Remove and return every record matching `pred` (order preserved).
    /// The cluster's fleet handoff uses this to pull completed prefill-leg
    /// records out of the per-chip rollups before merging them into their
    /// decode legs.
    pub fn drain_records(&mut self, mut pred: impl FnMut(&RequestRecord) -> bool) -> Vec<RequestRecord> {
        let mut out = Vec::new();
        self.records.retain(|r| {
            if pred(r) {
                out.push(*r);
                false
            } else {
                true
            }
        });
        out
    }

    /// Fold a completed prefill-leg record into the decode-leg record with
    /// `id` (fleet handoff): the merged record keeps the decode finish,
    /// takes the prefill leg's first token and the earlier arrival, and
    /// sums the output tokens — so TTFT counts from the true frontend
    /// arrival to the token the prefill chip emitted, and TBT absorbs the
    /// cross-chip KV-transfer gap. Returns whether `id` was found.
    pub fn merge_handoff(&mut self, id: u64, prefill: &RequestRecord) -> bool {
        match self.records.iter_mut().find(|r| r.id == id) {
            Some(r) => {
                r.arrival = r.arrival.min(prefill.arrival);
                r.first_token = r.first_token.min(prefill.first_token);
                debug_assert!(r.first_token >= r.arrival && r.finish >= r.first_token, "{r:?}");
                r.input_tokens = prefill.input_tokens;
                r.output_tokens += prefill.output_tokens;
                true
            }
            None => false,
        }
    }

    /// Fold another run's records and cache counters into this rollup
    /// (cluster aggregation; both sides must share one clock frequency).
    pub fn absorb(&mut self, other: &Metrics) {
        debug_assert!(
            self.freq_mhz == other.freq_mhz || other.records.is_empty(),
            "absorbing metrics across clock domains"
        );
        self.records.extend_from_slice(&other.records);
        self.cache.merge(&other.cache);
        self.control.merge(&other.control);
        self.spec.merge(&other.spec);
    }

    pub fn n_requests(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Makespan: last finish cycle.
    pub fn makespan(&self) -> Cycle {
        self.records.iter().map(|r| r.finish).max().unwrap_or(0)
    }

    /// TTFT distribution in seconds.
    pub fn ttft_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .map(|r| cycles_to_secs(r.ttft(), self.freq_mhz)),
        )
    }

    /// TBT distribution in seconds.
    pub fn tbt_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .filter(|r| r.output_tokens > 1)
                .map(|r| r.tbt_secs(self.freq_mhz)),
        )
    }

    /// End-to-end latency distribution in seconds.
    pub fn e2e_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .map(|r| cycles_to_secs(r.e2e(), self.freq_mhz)),
        )
    }

    /// Output-token throughput over the makespan, tokens/s.
    pub fn tokens_per_s(&self) -> f64 {
        let tokens: u64 = self.records.iter().map(|r| r.output_tokens).sum();
        let span = cycles_to_secs(self.makespan(), self.freq_mhz);
        if span <= 0.0 {
            return 0.0;
        }
        tokens as f64 / span
    }

    /// Completed requests per second.
    pub fn requests_per_s(&self) -> f64 {
        let span = cycles_to_secs(self.makespan(), self.freq_mhz);
        if span <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / span
    }

    /// Fraction of requests meeting both SLO targets (seconds).
    pub fn slo_attainment(&self, ttft_target_s: f64, tbt_target_s: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self
            .records
            .iter()
            .filter(|r| self.meets_slo(r, ttft_target_s, tbt_target_s))
            .count();
        ok as f64 / self.records.len() as f64
    }

    fn meets_slo(&self, r: &RequestRecord, ttft_target_s: f64, tbt_target_s: f64) -> bool {
        cycles_to_secs(r.ttft(), self.freq_mhz) <= ttft_target_s
            && r.tbt_secs(self.freq_mhz) <= tbt_target_s
    }

    /// **Goodput under SLO**: output tokens/s counting only requests that
    /// met both latency targets — the overload-study headline. Shed or
    /// SLO-violating requests contribute to the makespan but not to the
    /// numerator, so an overloaded FIFO frontend scores low even at full
    /// raw throughput.
    pub fn goodput_tokens_per_s(&self, ttft_target_s: f64, tbt_target_s: f64) -> f64 {
        let span = cycles_to_secs(self.makespan(), self.freq_mhz);
        if span <= 0.0 {
            return 0.0;
        }
        let tokens: u64 = self
            .records
            .iter()
            .filter(|r| self.meets_slo(r, ttft_target_s, tbt_target_s))
            .map(|r| r.output_tokens)
            .sum();
        tokens as f64 / span
    }

    /// Fraction of offered requests the admission policy shed.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.control.shed_requests + self.records.len() as u64;
        if offered == 0 {
            return 0.0;
        }
        self.control.shed_requests as f64 / offered as f64
    }

    /// TTFT distribution in seconds restricted to one priority class.
    pub fn ttft_s_of(&self, class: Priority) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .filter(|r| r.priority == class)
                .map(|r| cycles_to_secs(r.ttft(), self.freq_mhz)),
        )
    }

    /// Completed-request count of one priority class.
    pub fn n_requests_of(&self, class: Priority) -> usize {
        self.records.iter().filter(|r| r.priority == class).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, arrival: Cycle, first: Cycle, finish: Cycle, out: u64) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            first_token: first,
            finish,
            input_tokens: 100,
            output_tokens: out,
            priority: Priority::Normal,
        }
    }

    #[test]
    fn per_request_derivations() {
        let r = rec(1, 1000, 3000, 13_000, 11);
        assert_eq!(r.ttft(), 2000);
        assert_eq!(r.e2e(), 12_000);
        assert!((r.tbt() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_tbt_is_zero() {
        assert_eq!(rec(1, 0, 10, 10, 1).tbt(), 0.0);
    }

    #[test]
    fn aggregate_conversions() {
        let mut m = Metrics::new(500.0); // 5e8 cycles/s
        m.record(rec(1, 0, 5_000_000, 255_000_000, 51)); // ttft 10ms, tbt 10ms
        m.record(rec(2, 0, 10_000_000, 260_000_000, 51));
        assert_eq!(m.n_requests(), 2);
        assert!((m.ttft_s().mean() - 0.015).abs() < 1e-9);
        assert!((m.tbt_s().mean() - 0.01).abs() < 1e-9);
        // 102 tokens over 0.52 s.
        assert!((m.tokens_per_s() - 102.0 / 0.52).abs() < 1e-6);
    }

    #[test]
    fn slo_attainment_counts() {
        let mut m = Metrics::new(500.0);
        m.record(rec(1, 0, 5_000_000, 255_000_000, 51)); // ttft 10ms tbt 10ms
        m.record(rec(2, 0, 500_000_000, 600_000_000, 2)); // ttft 1s
        assert!((m.slo_attainment(0.1, 0.5) - 0.5).abs() < 1e-9);
        assert!((m.slo_attainment(2.0, 0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn drain_records_removes_matches_in_order() {
        let mut m = Metrics::new(500.0);
        m.record(rec(1, 0, 10, 20, 1));
        m.record(rec(1 << 63 | 2, 0, 10, 20, 1));
        m.record(rec(3, 0, 10, 20, 1));
        m.record(rec(1 << 63 | 4, 0, 10, 20, 1));
        let legs = m.drain_records(|r| r.id & (1 << 63) != 0);
        assert_eq!(legs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1 << 63 | 2, 1 << 63 | 4]);
        assert_eq!(m.records().iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn merge_handoff_folds_prefill_leg_into_decode_leg() {
        let mut m = Metrics::new(500.0);
        // Decode leg: admitted at KV landing (5000), 7 tokens generated.
        m.record(rec(9, 5000, 6000, 13_000, 7));
        // Prefill leg: true arrival 0, first token at 3000, 1 token.
        let p = rec(1 << 63 | 9, 0, 3000, 3500, 1);
        assert!(m.merge_handoff(9, &p));
        let r = m.records()[0];
        assert_eq!(r.arrival, 0);
        assert_eq!(r.first_token, 3000);
        assert_eq!(r.finish, 13_000);
        assert_eq!(r.output_tokens, 8);
        assert!(!m.merge_handoff(42, &p));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new(500.0);
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.slo_attainment(1.0, 1.0), 0.0);
        assert_eq!(m.makespan(), 0);
        assert_eq!(m.cache, CacheStats::default());
        assert_eq!(m.cache.prefix_hit_rate(), 0.0);
        assert_eq!(m.cache.memo_hit_rate(), 0.0);
        assert_eq!(m.spec, SpecStats::default());
        assert_eq!(m.spec.verify_m_p50(), 0);
        assert_eq!(m.spec.tokens_per_weight_stream(), 0.0);
    }

    #[test]
    fn spec_stats_histogram_median_and_merge() {
        let mut s = SpecStats::default();
        // Three batches at M=40 (bucket 5), one at M=200 (bucket 7),
        // against a phase-switch threshold of 100: only M=200 crosses.
        for _ in 0..3 {
            s.observe_verify_m(40, 100);
        }
        s.observe_verify_m(200, 100);
        assert_eq!(s.verify_steps, 4);
        assert_eq!(s.verify_m_hist[5], 3);
        assert_eq!(s.verify_m_hist[7], 1);
        assert_eq!(s.verify_above_threshold, 1);
        // Median falls in the M=40 bucket → its lower bound 32.
        assert_eq!(s.verify_m_p50(), 32);
        s.drafted_tokens = 10;
        s.accepted_tokens = 8;
        s.rejected_tokens = 2;
        s.decode_weight_streams = 4;
        s.decode_tokens_committed = 12;
        assert!((s.acceptance_rate() - 0.8).abs() < 1e-9);
        assert!((s.tokens_per_weight_stream() - 3.0).abs() < 1e-9);
        let b = s;
        s.merge(&b);
        assert_eq!(s.verify_steps, 8);
        assert_eq!(s.drafted_tokens, 20);
        assert_eq!(s.verify_m_hist[5], 6);
        assert_eq!(s.verify_above_threshold, 2);
        // Rates are scale-invariant under self-merge.
        assert!((s.acceptance_rate() - 0.8).abs() < 1e-9);
        // Huge M clamps into the last bucket instead of overflowing.
        let mut t = SpecStats::default();
        t.observe_verify_m(u64::MAX, 0);
        assert_eq!(t.verify_m_hist[15], 1);
        t.observe_verify_m(0, 0); // degenerate M clamps to bucket 0
        assert_eq!(t.verify_m_hist[0], 1);
        // Threshold 0 = no phase switch: nothing counts as crossing.
        assert_eq!(t.verify_above_threshold, 0);
    }

    #[test]
    fn cache_stats_rates_and_merge() {
        let mut a = CacheStats {
            prefix_lookups: 8,
            prefix_hits: 6,
            prefill_tokens_total: 1000,
            prefill_tokens_skipped: 400,
            kv_bytes_deduped: 4096,
            cow_copies: 2,
            prefix_evictions: 1,
            tier_demotions: 5,
            tier_promotions: 3,
            tier_dropped: 1,
            noc_prefix_imports: 2,
            noc_prefix_tokens: 256,
            memo_hits: 30,
            memo_misses: 10,
        };
        assert!((a.prefix_hit_rate() - 0.75).abs() < 1e-9);
        assert!((a.token_skip_rate() - 0.4).abs() < 1e-9);
        assert!((a.memo_hit_rate() - 0.75).abs() < 1e-9);
        let b = a;
        a.merge(&b);
        assert_eq!(a.prefix_lookups, 16);
        assert_eq!(a.kv_bytes_deduped, 8192);
        assert_eq!(a.memo_hits, 60);
        assert_eq!(a.tier_demotions, 10);
        assert_eq!(a.tier_promotions, 6);
        assert_eq!(a.noc_prefix_tokens, 512);
        // Rates are scale-invariant under self-merge.
        assert!((a.prefix_hit_rate() - 0.75).abs() < 1e-9);
    }
}
