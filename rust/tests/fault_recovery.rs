//! Fault-tolerance end-to-end: crash a chip mid-run and check the
//! recovery contract — every offered request is either completed exactly
//! once or shed exactly once (no duplicates, no stranded work), recovered
//! requests reproduce their original token counts bit-for-bit, seeded
//! chaos schedules replay deterministically, and the load-adaptive defer
//! backoff still terminates under sustained overload.

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::cluster::{self, ClusterConfig, RouterPolicy, ShedPolicy, ShedScope};
use npusim::serving::faults::{FaultSchedule, RecoveryPolicy};
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request::{self, Prefix, Priority, Request};
use npusim::serving::scheduler::SchedulerConfig;

fn fleet(n_chips: usize) -> ClusterConfig {
    ClusterConfig::new(
        ChipConfig::large_core(),
        n_chips,
        SchedulerConfig::Fusion(FusionConfig::default()),
        RouterPolicy::LeastLoaded,
    )
}

fn burst(n: u64, input_len: usize, output_len: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0005 * i as f64,
            input_len,
            output_len,
            prefix: Prefix::default(),
            priority: Priority::Normal,
        })
        .collect()
}

/// Recovered requests must re-run to their exact original shape: the
/// completion record of a request that died with a chip is
/// indistinguishable (tokens-wise) from an undisturbed run's.
#[test]
fn recovered_requests_reproduce_exact_token_counts() {
    let model = ModelConfig::qwen3_4b();
    let reqs = burst(10, 1536, 12);
    let offered = reqs.len();
    let cfg = fleet(2).with_faults(
        FaultSchedule::parse("crash:0@0.004")
            .unwrap()
            .with_retries(8, 0.002),
    );
    let cm = cluster::simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
    assert_eq!(cm.faults.crashes, 1);
    assert!(cm.faults.recovered > 0, "{:?}", cm.faults);
    assert!(cm.conserves(offered));
    assert_eq!(cm.shed_requests(), 0, "retry budget 8 must absorb one crash");
    let agg = cm.aggregate();
    assert_eq!(agg.n_requests(), offered);
    for rec in agg.records() {
        let orig = &reqs[rec.id as usize];
        assert_eq!(rec.input_tokens, orig.input_len as u64, "{rec:?}");
        assert_eq!(rec.output_tokens, orig.output_len as u64, "{rec:?}");
        assert!(rec.first_token >= rec.arrival && rec.finish >= rec.first_token, "{rec:?}");
    }
    // Recovery accounting is consistent: every retry recomputed at least
    // the tokens the prefix cache could not restore.
    for r in &cm.recovery {
        assert!(r.tokens_recomputed > 0, "{r:?}");
    }
}

/// Exactly-once partition under a harsher schedule: two crashes (one with
/// a restart), a tiny retry budget, and an overload-sized burst. Completed
/// and shed must tile the offered set with no overlap and no leftovers —
/// the run terminating at all also exercises the driver's event guard.
#[test]
fn completions_and_sheds_partition_offered_work_exactly_once() {
    let model = ModelConfig::qwen3_4b();
    let reqs = burst(16, 1024, 8);
    let offered = reqs.len();
    for (policy, tag) in [
        (RecoveryPolicy::Recover, "recover"),
        (RecoveryPolicy::Resubmit { client_timeout_s: 0.01 }, "resubmit"),
    ] {
        let cfg = fleet(2).with_faults(
            FaultSchedule::parse("crash:0@0.003:0.08;crash:1@0.25")
                .unwrap()
                .with_retries(2, 0.002)
                .with_recovery(policy),
        );
        let cm = cluster::simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
        assert!(
            cm.conserves(offered),
            "{tag}: completed {} + shed {} != offered {offered}",
            cm.n_requests(),
            cm.shed_requests()
        );
        // No record id appears twice (exactly-once, not at-least-once).
        let agg = cm.aggregate();
        let mut ids: Vec<u64> = agg.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let before = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), before, "{tag}: duplicated completion");
        assert!(cm.faults.crashes >= 1, "{tag}");
    }
}

/// Seeded chaos is replayable: the same seed yields the same schedule, and
/// the same schedule yields bit-identical metrics, fault stats, and
/// recovery logs across runs.
#[test]
fn seeded_chaos_runs_are_bit_identical() {
    let model = ModelConfig::qwen3_4b();
    let w = WorkloadConfig::sharegpt_like(24).with_seed(5);
    let reqs = request::generate(&w);
    let s1 = FaultSchedule::seeded(42, 3, 2.0, 1.5).with_retries(4, 0.002);
    let s2 = FaultSchedule::seeded(42, 3, 2.0, 1.5).with_retries(4, 0.002);
    assert_eq!(s1, s2, "seeded schedule must be a pure function of the seed");
    assert_ne!(
        FaultSchedule::seeded(43, 3, 2.0, 1.5),
        FaultSchedule::seeded(42, 3, 2.0, 1.5),
        "different seeds should draw different fault histories"
    );
    let run = |s: FaultSchedule| {
        cluster::simulate_cluster_requests(&fleet(3).with_faults(s), &model, reqs.clone()).unwrap()
    };
    let a = run(s1);
    let b = run(s2);
    assert_eq!(a.aggregate().records(), b.aggregate().records());
    assert_eq!(a.control, b.control);
    assert_eq!(a.faults, b.faults);
    assert_eq!(a.recovery, b.recovery);
    assert!(a.conserves(reqs.len()));
}

/// Satellite: the load-adaptive defer backoff must terminate under
/// sustained overload — every offered request resolves to completed or
/// shed within the bounded re-timing chain, per shed scope.
#[test]
fn adaptive_defer_terminates_under_sustained_overload() {
    let model = ModelConfig::qwen3_4b();
    let reqs = burst(24, 2048, 8);
    let offered = reqs.len();
    for scope in [ShedScope::Global, ShedScope::PerChip] {
        let cfg = fleet(2)
            .with_shed(ShedPolicy::Defer, 2)
            .with_shed_scope(scope);
        // Terminating at all is the property: a non-decaying retry chain
        // would trip the driver's event-budget guard and error out.
        let cm = cluster::simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
        assert!(
            cm.conserves(offered),
            "{}: completed {} + shed {} != {offered}",
            scope.name(),
            cm.n_requests(),
            cm.shed_requests()
        );
        assert!(cm.control.deferrals > 0, "{}: overload must defer", scope.name());
    }
}
