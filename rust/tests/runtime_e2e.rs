//! Integration: the PJRT runtime + coordinator over the real AOT
//! artifacts. Requires `make artifacts` (skips with a notice otherwise —
//! `make test` always builds them first).

use npusim::coordinator::{Coordinator, GenRequest};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("model_meta.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn coordinator_generates_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(&dir).expect("load artifacts");
    let reqs = vec![
        GenRequest {
            id: 0,
            prompt: vec![1, 2, 3, 4, 5],
            max_new_tokens: 8,
        },
        GenRequest {
            id: 1,
            prompt: vec![9, 8, 7],
            max_new_tokens: 8,
        },
    ];
    let out = coord.generate(reqs).expect("generate");
    assert_eq!(out.len(), 2);
    for r in &out {
        assert_eq!(r.tokens.len(), 8, "request {}: {:?}", r.id, r.tokens);
        assert!(r.tokens.iter().all(|&t| (0..coord.meta.vocab as i32).contains(&t)));
    }
}

#[test]
fn generation_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(&dir).expect("load artifacts");
    let req = || {
        vec![GenRequest {
            id: 0,
            prompt: vec![42, 17, 99],
            max_new_tokens: 12,
        }]
    };
    let a = coord.generate(req()).unwrap();
    let b = coord.generate(req()).unwrap();
    assert_eq!(a[0].tokens, b[0].tokens, "greedy decode must be deterministic");
}

#[test]
fn oversized_batch_splits_across_model_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(&dir).expect("load artifacts");
    let n = coord.meta.decode_batch * 2 + 1;
    let reqs: Vec<GenRequest> = (0..n)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: vec![i as i32; 4],
            max_new_tokens: 4,
        })
        .collect();
    let out = coord.generate(reqs).expect("generate");
    assert_eq!(out.len(), n);
    assert!(out.iter().all(|r| r.tokens.len() == 4));
}

#[test]
fn long_prompts_are_window_clamped() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::start(&dir).expect("load artifacts");
    let long: Vec<i32> = (0..200).collect(); // prefill window is 16
    let out = coord
        .generate(vec![GenRequest {
            id: 7,
            prompt: long,
            max_new_tokens: 4,
        }])
        .unwrap();
    assert_eq!(out[0].tokens.len(), 4);
}
