//! Fine-grained SRAM block allocator (Fig. 5, left), now **ref-counted**
//! so blocks can be shared between requests (prefix caching) and by the
//! [`super::prefix::PrefixIndex`].
//!
//! The KV region of SRAM is carved into fixed-size blocks. Each request
//! owns a [`Chain`] (ordered block table) — blocks from different requests
//! interleave freely, exactly as in the paper's example where requests 2
//! and 3 arrive while request 1 is mid-generation. Every block carries a
//! reference count: a freshly allocated block has one owner; sharing a
//! block (`retain`) bumps the count, and a block only returns to the free
//! list once every owner has released it — so a prefix block referenced by
//! three requests plus the prefix index survives until all four drop it.

/// A request's handle on its ordered block table.
///
/// Historically this was a linked list threaded through the allocator;
/// prefix sharing requires blocks to appear in *multiple* tables with
/// different successors, so each chain now owns its own ordering.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Chain {
    blocks: Vec<u32>,
}

impl Chain {
    pub fn empty() -> Self {
        Chain { blocks: Vec::new() }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// The block ids of this chain, in order.
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }

    /// Last block of the chain, if any.
    pub fn last(&self) -> Option<u32> {
        self.blocks.last().copied()
    }

    /// Append an externally allocated/retained block to the table.
    pub fn push(&mut self, block: u32) {
        self.blocks.push(block);
    }

    /// Replace the last block (copy-on-write divergence).
    pub fn replace_last(&mut self, block: u32) {
        *self.blocks.last_mut().expect("replace_last on empty chain") = block;
    }

    /// Remove and return the last block (speculative-decode rollback).
    /// The caller owns releasing the block's reference.
    pub fn pop(&mut self) -> Option<u32> {
        self.blocks.pop()
    }
}

/// Fixed-size, ref-counted block allocator over a byte capacity.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    block_bytes: u64,
    /// `refcount[i] == 0` means block `i` is free.
    refcount: Vec<u32>,
    /// LIFO free stack, initialised reversed so ids allocate 0, 1, 2, …
    free: Vec<u32>,
}

impl BlockAllocator {
    /// Carve `capacity_bytes` into blocks of `block_bytes`.
    pub fn new(capacity_bytes: u64, block_bytes: u64) -> Self {
        assert!(block_bytes > 0, "zero block size");
        let n = (capacity_bytes / block_bytes) as usize;
        let n = n.min(u32::MAX as usize - 1);
        BlockAllocator {
            block_bytes,
            refcount: vec![0; n],
            free: (0..n as u32).rev().collect(),
        }
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    pub fn n_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn bytes_free(&self) -> u64 {
        self.free.len() as u64 * self.block_bytes
    }

    /// Current reference count of a block (0 = free).
    pub fn refcount(&self, block: u32) -> u32 {
        self.refcount[block as usize]
    }

    /// Allocate one block with a single owner; `None` when SRAM is
    /// exhausted (the caller spills to HBM or evicts cached prefixes).
    pub fn alloc(&mut self) -> Option<u32> {
        let blk = self.free.pop()?;
        debug_assert_eq!(self.refcount[blk as usize], 0, "free block with refs");
        self.refcount[blk as usize] = 1;
        Some(blk)
    }

    /// Add an owner to a live block (prefix sharing).
    pub fn retain(&mut self, block: u32) {
        let rc = &mut self.refcount[block as usize];
        assert!(*rc > 0, "retain of free block {block}");
        *rc += 1;
    }

    /// Drop one owner; returns `true` when this freed the block.
    pub fn release_block(&mut self, block: u32) -> bool {
        let rc = &mut self.refcount[block as usize];
        assert!(*rc > 0, "double free of block {block}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(block);
            true
        } else {
            false
        }
    }

    /// Append one freshly allocated block to `chain`. Returns `false`
    /// (chain unchanged) when SRAM is exhausted.
    pub fn append(&mut self, chain: &mut Chain) -> bool {
        match self.alloc() {
            Some(blk) => {
                chain.push(blk);
                true
            }
            None => false,
        }
    }

    /// Release one owner of every block of a chain (request completed).
    /// Shared blocks survive until their other owners release them.
    pub fn release(&mut self, chain: &mut Chain) {
        for blk in std::mem::take(&mut chain.blocks) {
            self.release_block(blk);
        }
    }

    /// Walk a chain's block IDs (diagnostics / tests).
    pub fn chain_blocks(&self, chain: &Chain) -> Vec<u32> {
        chain.blocks.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn carves_capacity_into_blocks() {
        let a = BlockAllocator::new(1024, 128);
        assert_eq!(a.n_blocks(), 8);
        assert_eq!(a.n_free(), 8);
        assert_eq!(a.bytes_free(), 1024);
    }

    #[test]
    fn append_until_exhausted() {
        let mut a = BlockAllocator::new(512, 128);
        let mut c = Chain::empty();
        for _ in 0..4 {
            assert!(a.append(&mut c));
        }
        assert!(!a.append(&mut c), "5th block must fail");
        assert_eq!(c.n_blocks(), 4);
        assert_eq!(a.n_free(), 0);
    }

    #[test]
    fn chains_interleave_like_fig5() {
        // Request 1 grows alone, then 2 and 3 arrive: block IDs interleave.
        let mut a = BlockAllocator::new(8 * 64, 64);
        let mut r1 = Chain::empty();
        let mut r2 = Chain::empty();
        let mut r3 = Chain::empty();
        a.append(&mut r1);
        a.append(&mut r1);
        a.append(&mut r2);
        a.append(&mut r3);
        a.append(&mut r1); // r1's third block is *after* r2/r3's first
        assert_eq!(a.chain_blocks(&r1), vec![0, 1, 4]);
        assert_eq!(a.chain_blocks(&r2), vec![2]);
        assert_eq!(a.chain_blocks(&r3), vec![3]);
    }

    #[test]
    fn release_recycles_blocks() {
        let mut a = BlockAllocator::new(4 * 64, 64);
        let mut r1 = Chain::empty();
        let mut r2 = Chain::empty();
        for _ in 0..2 {
            a.append(&mut r1);
            a.append(&mut r2);
        }
        assert_eq!(a.n_free(), 0);
        a.release(&mut r1);
        assert_eq!(a.n_free(), 2);
        assert!(r1.is_empty());
        // Freed blocks are reusable by a new request.
        let mut r3 = Chain::empty();
        assert!(a.append(&mut r3));
        assert!(a.append(&mut r3));
        assert!(!a.append(&mut r3));
        // r2 is untouched.
        assert_eq!(r2.n_blocks(), 2);
    }

    #[test]
    fn zero_capacity_always_fails() {
        let mut a = BlockAllocator::new(63, 64); // less than one block
        let mut c = Chain::empty();
        assert!(!a.append(&mut c));
    }

    #[test]
    fn release_empty_chain_is_noop() {
        let mut a = BlockAllocator::new(256, 64);
        let mut c = Chain::empty();
        a.release(&mut c);
        assert_eq!(a.n_free(), 4);
    }

    #[test]
    fn shared_block_survives_until_last_owner_releases() {
        let mut a = BlockAllocator::new(4 * 64, 64);
        let blk = a.alloc().unwrap();
        a.retain(blk); // second owner (e.g. the prefix index)
        a.retain(blk); // third owner
        assert_eq!(a.refcount(blk), 3);
        assert!(!a.release_block(blk));
        assert!(!a.release_block(blk));
        assert_eq!(a.n_free(), 3);
        assert!(a.release_block(blk), "last release frees");
        assert_eq!(a.n_free(), 4);
        assert_eq!(a.refcount(blk), 0);
    }

    #[test]
    fn chains_can_share_prefix_blocks() {
        let mut a = BlockAllocator::new(4 * 64, 64);
        let mut r1 = Chain::empty();
        a.append(&mut r1);
        a.append(&mut r1);
        // r2 shares r1's first block, then grows its own.
        let shared = r1.blocks()[0];
        a.retain(shared);
        let mut r2 = Chain::empty();
        r2.push(shared);
        a.append(&mut r2);
        assert_eq!(a.n_free(), 1);
        a.release(&mut r1);
        // The shared block is still live (r2 holds it); r1's private one freed.
        assert_eq!(a.refcount(shared), 1);
        assert_eq!(a.n_free(), 2);
        a.release(&mut r2);
        assert_eq!(a.n_free(), 4);
    }

    #[test]
    fn prop_no_block_shared_between_chains() {
        check("block exclusivity", 128, |rng| {
            let n_blocks = rng.range(1, 32);
            let mut a = BlockAllocator::new(n_blocks as u64 * 64, 64);
            let mut chains = vec![Chain::empty(); rng.range(1, 6)];
            // Random interleaving of appends and releases.
            for _ in 0..rng.range(1, 64) {
                let i = rng.range(0, chains.len());
                if rng.chance(0.8) {
                    a.append(&mut chains[i]);
                } else {
                    a.release(&mut chains[i]);
                }
            }
            // Invariant: all live blocks distinct, accounting consistent.
            let mut seen = std::collections::HashSet::new();
            let mut live = 0;
            for c in &chains {
                for b in a.chain_blocks(c) {
                    assert!(seen.insert(b), "block {b} in two chains");
                    live += 1;
                }
            }
            assert_eq!(live + a.n_free(), a.n_blocks());
        });
    }

    #[test]
    fn prop_refcounts_conserve_blocks_under_sharing() {
        // Random share/release interleavings: the allocator must never
        // double-free, and (sum of refcounts == total owner references)
        // with `free + live == n_blocks` at every step.
        check("refcount conservation", 128, |rng| {
            let n_blocks = rng.range(1, 24);
            let mut a = BlockAllocator::new(n_blocks as u64 * 64, 64);
            // owners[b] tracks how many references we believe block b has.
            let mut owners: Vec<u32> = vec![0; n_blocks];
            for _ in 0..rng.range(1, 128) {
                let live: Vec<u32> = (0..n_blocks as u32).filter(|&b| owners[b as usize] > 0).collect();
                let roll = rng.f64();
                if roll < 0.4 {
                    if let Some(b) = a.alloc() {
                        assert_eq!(owners[b as usize], 0, "alloc returned live block");
                        owners[b as usize] = 1;
                    }
                } else if roll < 0.7 && !live.is_empty() {
                    let b = *rng.choose(&live);
                    a.retain(b);
                    owners[b as usize] += 1;
                } else if !live.is_empty() {
                    let b = *rng.choose(&live);
                    let freed = a.release_block(b);
                    owners[b as usize] -= 1;
                    assert_eq!(freed, owners[b as usize] == 0);
                }
                let live_now = owners.iter().filter(|&&o| o > 0).count();
                assert_eq!(live_now + a.n_free(), a.n_blocks());
                for (b, &o) in owners.iter().enumerate() {
                    assert_eq!(a.refcount(b as u32), o, "block {b}");
                }
            }
        });
    }
}
