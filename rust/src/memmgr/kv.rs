//! The combined multi-grained KV cache (Fig. 5): fine-grained SRAM blocks
//! with spill into coarse-grained per-request HBM ring buffers, plus
//! opt-in **prefix sharing** over the SRAM blocks.
//!
//! One `KvCache` instance manages the KV memory of one worker group (all
//! cores of a TP group share the same residency statistics since the KV is
//! head-sharded uniformly across them).
//!
//! With [`KvCache::enable_prefix_cache`] the cache keeps a
//! [`PrefixIndex`] — a trie over token-block hashes. Admission walks the
//! trie: the longest cached prefix is *shared* (blocks are ref-counted and
//! charged physically once, with the request's residency still covering
//! them for attention timing), and the request's own shareable prefix
//! blocks are registered for future arrivals. A shared terminal block that
//! is only partially filled is *frozen*: the first append past it triggers
//! a copy-on-write into a private block, so divergence never corrupts a
//! cached prefix. Released requests leave their registered blocks cached
//! (the index holds a reference); when SRAM runs dry, ref-count-aware LRU
//! eviction reclaims cold leaves — blocks referenced by live requests are
//! never evicted. With the cache disabled, every code path is the
//! pre-prefix-sharing one and simulations reproduce bit-for-bit.
//!
//! With [`KvCache::enable_hbm_tier`] the cache becomes **two-tier**: SRAM
//! pressure *demotes* cold prefix blocks to a bounded HBM region instead
//! of dropping them (their node stays in the trie, marked
//! [`Tier::Hbm`]), and a later hit *re-promotes* them into fresh SRAM
//! blocks. Both directions are bandwidth-priced: the cache accumulates the
//! moved bytes and the owning worker drains them
//! ([`KvCache::drain_tier_traffic`]) into charged HBM accesses on its
//! cores, so a promotion costs an HBM→SRAM stream — far cheaper than the
//! prefill recompute it replaces, but never free. The HBM tier itself is
//! capacity-bounded: when it overflows, the coldest demoted leaves are
//! dropped for real. With the tier disabled (the default), demotion never
//! happens and behaviour is bit-identical to the single-tier cache.

use super::blocks::{BlockAllocator, Chain};
use super::prefix::{BlockKey, PrefixBlock, PrefixIndex, Tier, TierMatch, NO_NODE, PENDING};
use super::ring::{RingAlloc, RingBuffer};
use std::collections::HashMap;

/// Where a request's KV bytes currently live. The attention operator
/// charges HBM streaming time for the `hbm_bytes` portion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvResidency {
    pub sram_bytes: u64,
    pub hbm_bytes: u64,
}

impl KvResidency {
    pub fn total(&self) -> u64 {
        self.sram_bytes + self.hbm_bytes
    }
}

#[derive(Debug)]
struct Entry {
    chain: Chain,
    /// Appendable SRAM byte capacity over `chain` (a shared/frozen block
    /// contributes only its fill, a private block its full size).
    cap_bytes: u64,
    /// `Some(fill)` when the chain's last block is shared and only `fill`
    /// bytes of it belong to this request's prefix: appending past it
    /// requires a copy-on-write into a private block.
    frozen_tail_fill: Option<u64>,
    /// Prefix-index nodes this request registered at admission, still
    /// [`PENDING`] until the producing prefill reaches them: `(node,
    /// prefix-token end)`, end counted from the start of the prompt.
    registered: Vec<(u32, u64)>,
    hbm: Option<RingAlloc>,
    res: KvResidency,
}

impl Entry {
    fn new(hbm: Option<RingAlloc>) -> Self {
        Entry {
            chain: Chain::empty(),
            cap_bytes: 0,
            frozen_tail_fill: None,
            registered: Vec::new(),
            hbm,
            res: KvResidency::default(),
        }
    }
}

/// Outcome of appending tokens: how many new bytes landed where (the
/// `hbm_bytes` part is what the executor charges as spill writeback).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Appended {
    pub sram_bytes: u64,
    pub hbm_bytes: u64,
}

/// Prefix-cache / sharing counters of one `KvCache` (all zero while the
/// prefix cache is disabled). These are *per-cache* physical diagnostics;
/// the request-level rates a serving run reports live in
/// `serving::metrics::CacheStats` (recorded once per admission, not once
/// per stage), which consumes only `cow_copies` / `prefix_evictions` from
/// here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct KvStats {
    /// Prefixed admissions that consulted the index.
    pub prefix_lookups: u64,
    /// Prefixed admissions that matched at least one block.
    pub prefix_hits: u64,
    /// Tokens served from cached prefix blocks.
    pub matched_tokens: u64,
    /// Bytes *not* stored again thanks to sharing (matched tokens × B/tok).
    pub deduped_bytes: u64,
    /// Blocks registered into the prefix index.
    pub inserted_blocks: u64,
    /// Copy-on-write block copies on divergence from a shared prefix.
    pub cow_copies: u64,
    /// Cached blocks reclaimed by ref-count-aware LRU eviction.
    pub prefix_evictions: u64,
    /// Cold prefix blocks demoted SRAM→HBM instead of dropped.
    pub tier_demotions: u64,
    /// Demoted prefix blocks re-promoted to SRAM on a hit.
    pub tier_promotions: u64,
    /// Demoted blocks dropped for real when the HBM tier overflowed.
    pub tier_dropped: u64,
    /// Bytes streamed SRAM→HBM by demotions (charged as HBM writes).
    pub demoted_bytes: u64,
    /// Bytes streamed HBM→SRAM by promotions (charged as HBM reads).
    pub promoted_bytes: u64,
    /// Bytes removed by speculative-decode rollback ([`KvCache::truncate`]).
    pub rollback_bytes: u64,
    /// SRAM blocks freed by speculative-decode rollback.
    pub rollback_blocks: u64,
}

/// The bounded HBM region holding demoted prefix blocks, plus the
/// not-yet-charged transfer bytes the owning worker drains into HBM
/// accesses.
#[derive(Debug, Default)]
struct HbmTier {
    capacity_bytes: u64,
    used_bytes: u64,
    pending_promote_bytes: u64,
    pending_demote_bytes: u64,
}

/// Multi-grained KV cache for one worker group.
#[derive(Debug)]
pub struct KvCache {
    sram: BlockAllocator,
    hbm: RingBuffer,
    /// Tokens per SRAM block (fine granularity).
    block_tokens: u64,
    /// Bytes of K+V per token (for this group's layer/head shard).
    bytes_per_token: u64,
    /// HBM buffer size reserved per admitted request (max token length).
    max_request_bytes: u64,
    entries: HashMap<u64, Entry>,
    /// Bytes that could not be stored anywhere (admission bug if > 0).
    overflow_bytes: u64,
    /// `Some` once prefix sharing is enabled.
    prefix: Option<PrefixIndex>,
    /// `Some` once the HBM prefix tier is enabled (requires `prefix`).
    hbm_tier: Option<HbmTier>,
    stats: KvStats,
}

impl KvCache {
    /// * `sram_kv_bytes`: the planner's SRAM KV budget for this group.
    /// * `block_tokens`: tokens per SRAM block (fine granularity).
    /// * `hbm_bytes`: HBM ring capacity for spilled KV.
    /// * `bytes_per_token`: K+V bytes per token for this group's shard.
    /// * `max_tokens`: maximum request length (sizes the HBM buffers).
    pub fn new(
        sram_kv_bytes: u64,
        block_tokens: u64,
        hbm_bytes: u64,
        bytes_per_token: u64,
        max_tokens: u64,
    ) -> Self {
        let block_bytes = (block_tokens.max(1) * bytes_per_token).max(1);
        KvCache {
            sram: BlockAllocator::new(sram_kv_bytes, block_bytes),
            hbm: RingBuffer::new(hbm_bytes),
            block_tokens: block_tokens.max(1),
            bytes_per_token,
            max_request_bytes: max_tokens * bytes_per_token,
            entries: HashMap::new(),
            overflow_bytes: 0,
            prefix: None,
            hbm_tier: None,
            stats: KvStats::default(),
        }
    }

    /// Turn on prefix sharing (off by default; with it off, behaviour is
    /// bit-identical to the pre-prefix-cache implementation).
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixIndex::new());
        }
    }

    /// Is prefix sharing enabled on this cache?
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Turn on the HBM prefix tier: SRAM pressure demotes cold prefix
    /// blocks into a `capacity_bytes`-bounded HBM region instead of
    /// dropping them, and hits on demoted blocks re-promote at charged
    /// HBM→SRAM cost. The region is **carved out of the HBM ring** (it
    /// must be called before any admission), so demoted bytes occupy
    /// real, admission-visible capacity — modeled HBM occupancy can never
    /// exceed the physical part.
    ///
    /// The carve is bound-validated: the region must leave the spill ring
    /// able to hold at least one per-request reservation, otherwise
    /// enabling the tier would make every admission fail. Out-of-bound
    /// requests (SRAM-only chips, a region bigger than the ring, or one
    /// that would starve admission) refuse the tier and leave the ring
    /// untouched; returns whether the tier was enabled.
    pub fn enable_hbm_tier(&mut self, capacity_bytes: u64) -> bool {
        if self.prefix.is_none() || capacity_bytes == 0 || self.hbm_tier.is_some() {
            return false;
        }
        debug_assert!(self.entries.is_empty(), "enable_hbm_tier after admission");
        let cap = self.hbm.capacity();
        if cap < capacity_bytes || cap - capacity_bytes < self.max_request_bytes {
            return false;
        }
        self.hbm = RingBuffer::new(cap - capacity_bytes);
        self.hbm_tier = Some(HbmTier {
            capacity_bytes,
            ..HbmTier::default()
        });
        true
    }

    /// Is the HBM prefix tier enabled on this cache?
    pub fn hbm_tier_enabled(&self) -> bool {
        self.hbm_tier.is_some()
    }

    /// Bytes currently held by demoted prefix blocks in the HBM tier.
    pub fn hbm_tier_used_bytes(&self) -> u64 {
        self.hbm_tier.as_ref().map(|t| t.used_bytes).unwrap_or(0)
    }

    /// Take the HBM bytes moved by tier promotions/demotions since the
    /// last drain, as `(promoted HBM→SRAM reads, demoted SRAM→HBM
    /// writes)`. The owning worker charges them on its cores so the tier
    /// is bandwidth-priced, not free.
    pub fn drain_tier_traffic(&mut self) -> (u64, u64) {
        match self.hbm_tier.as_mut() {
            Some(t) => (
                std::mem::take(&mut t.pending_promote_bytes),
                std::mem::take(&mut t.pending_demote_bytes),
            ),
            None => (0, 0),
        }
    }

    /// Sharing / eviction counters.
    pub fn stats(&self) -> KvStats {
        self.stats
    }

    /// Can another request be admitted? True when HBM can hold a whole
    /// max-length buffer (SRAM is best-effort and never blocks admission),
    /// or when there is no HBM at all (SRAM-only chips admit and may
    /// overflow — the WaferLLM regime, where overflow KV is remote SRAM).
    pub fn can_admit(&self) -> bool {
        self.hbm.capacity() == 0 || self.hbm.bytes_free() >= self.max_request_bytes
    }

    /// Reserve the coarse-grained HBM buffer for one admission.
    fn reserve_hbm(&mut self) -> Result<Option<RingAlloc>, ()> {
        if self.hbm.capacity() > 0 {
            match self.hbm.alloc(self.max_request_bytes) {
                Some(a) => Ok(Some(a)),
                None => Err(()),
            }
        } else {
            Ok(None)
        }
    }

    /// Admit a request: reserve its coarse-grained HBM buffer.
    pub fn admit(&mut self, id: u64) -> bool {
        if self.entries.contains_key(&id) {
            return true;
        }
        let Ok(hbm) = self.reserve_hbm() else {
            return false;
        };
        self.entries.insert(id, Entry::new(hbm));
        true
    }

    /// Longest cached-and-ready prefix (in tokens) for `keys` at cycle
    /// `at`, capped at `max_tokens`, without admitting or touching LRU
    /// state. Pipeline stages use this to agree on a common match length
    /// before committing; the cluster router probes it read-only.
    pub fn peek_prefix(&self, keys: &[BlockKey], max_tokens: u64, at: u64) -> u64 {
        self.prefix
            .as_ref()
            .map(|ix| ix.peek(keys, max_tokens, at))
            .unwrap_or(0)
    }

    /// Like [`KvCache::peek_prefix`] but split by residency tier: how much
    /// of the match is SRAM-resident (free) versus HBM-demoted
    /// (promotion-priced). Routers and pipe selection score the two
    /// differently.
    pub fn peek_prefix_tiered(&self, keys: &[BlockKey], max_tokens: u64, at: u64) -> TierMatch {
        self.prefix
            .as_ref()
            .map(|ix| ix.peek_tiered(keys, max_tokens, at))
            .unwrap_or_default()
    }

    /// Admit a request with prefix sharing at cycle `at`: match the
    /// longest cached prefix of `keys` (at most `max_match_tokens` tokens)
    /// whose producing prefills have completed by `at`, share those
    /// blocks, and register the request's remaining shareable prefix
    /// blocks for future arrivals (as [`PENDING`] — they only become
    /// matchable once [`KvCache::note_prefilled`] reports the producing
    /// prefill reached them). Returns the matched token count, or `None`
    /// when HBM admission fails. Falls back to a plain [`admit`]
    /// (matching nothing) while the prefix cache is disabled.
    ///
    /// Matched tokens are already KV-resident: the scheduler skips their
    /// prefill chunks entirely, and the entry's residency covers them so
    /// attention streams the right amount — but physically the bytes are
    /// charged once across all sharers.
    ///
    /// [`admit`]: KvCache::admit
    pub fn admit_prefixed(
        &mut self,
        id: u64,
        keys: &[BlockKey],
        max_match_tokens: u64,
        at: u64,
    ) -> Option<u64> {
        if self.entries.contains_key(&id) {
            return Some(0);
        }
        let Ok(hbm) = self.reserve_hbm() else {
            return None;
        };
        let mut entry = Entry::new(hbm);
        if self.prefix.is_none() || keys.is_empty() {
            self.entries.insert(id, entry);
            return Some(0);
        }

        // 1. Share the longest cached-and-ready prefix. Demoted blocks are
        //    re-promoted into fresh SRAM blocks first (charged HBM→SRAM);
        //    when SRAM cannot host a promotion even after demoting colder
        //    blocks, the match stops there. Tier state is re-read per node
        //    — a promotion's own demotion chain may have moved (or, on an
        //    overflowing HBM tier, dropped) a later matched node.
        self.stats.prefix_lookups += 1;
        let matched: Vec<PrefixBlock> = self
            .prefix
            .as_mut()
            .expect("prefix enabled")
            .lookup(keys, max_match_tokens, at);
        let mut matched_tokens = 0u64;
        let mut parent = NO_NODE;
        let mut kept = 0usize;
        for m in &matched {
            let ix = self.prefix.as_ref().expect("prefix enabled");
            if !ix.is_live(m.node) {
                break;
            }
            let block = match ix.tier_of(m.node) {
                Tier::Sram => ix.block_of(m.node),
                Tier::Hbm => match self.promote_node(m.node) {
                    Some(b) => b,
                    None => break,
                },
            };
            self.sram.retain(block);
            entry.chain.push(block);
            matched_tokens += m.tokens;
            let fill = m.tokens * self.bytes_per_token;
            entry.cap_bytes += fill;
            entry.frozen_tail_fill = (m.tokens < self.block_tokens).then_some(fill);
            parent = m.node;
            kept += 1;
        }
        entry.res.sram_bytes = matched_tokens * self.bytes_per_token;
        if matched_tokens > 0 {
            self.stats.prefix_hits += 1;
            self.stats.matched_tokens += matched_tokens;
            self.stats.deduped_bytes += matched_tokens * self.bytes_per_token;
        }

        // 2. Register the request's remaining shareable prefix blocks as
        //    PENDING (the owner's prefill fills them; they become
        //    matchable chunk by chunk as `note_prefilled` reports the
        //    prefill reaching them — never before the KV exists).
        let mut prefix_end = matched_tokens;
        for &key in keys.iter().skip(kept) {
            // A capped or readiness-bounded match can leave already-cached
            // continuations: never re-register them (that would orphan the
            // cached node).
            if self
                .prefix
                .as_ref()
                .expect("prefix enabled")
                .child_of(parent, key)
                .is_some()
            {
                break;
            }
            let Some(blk) = self.alloc_block() else {
                break; // SRAM exhausted: the rest of the prefix spills unshared
            };
            self.sram.retain(blk); // the index's own reference
            let node = self
                .prefix
                .as_mut()
                .expect("prefix enabled")
                .insert(parent, key, blk, PENDING);
            entry.chain.push(blk);
            let fill = key.tokens * self.bytes_per_token;
            entry.cap_bytes += fill;
            entry.frozen_tail_fill = (key.tokens < self.block_tokens).then_some(fill);
            prefix_end += key.tokens;
            entry.registered.push((node, prefix_end));
            self.stats.inserted_blocks += 1;
            parent = node;
        }

        self.entries.insert(id, entry);
        Some(matched_tokens)
    }

    /// Report that request `id`'s prefill has materialised the first
    /// `upto_tokens` prompt tokens by cycle `now`: every prefix block this
    /// request registered that lies entirely inside that range becomes
    /// matchable from `now` on. Schedulers call this once per completed
    /// prefill chunk; it is a no-op without registered blocks.
    pub fn note_prefilled(&mut self, id: u64, upto_tokens: u64, now: u64) {
        let Some(entry) = self.entries.get_mut(&id) else {
            return;
        };
        if entry.registered.is_empty() {
            return;
        }
        let ix = self.prefix.as_mut().expect("registered implies enabled");
        entry.registered.retain(|&(node, end)| {
            if end <= upto_tokens {
                ix.mark_ready(node, now);
                false
            } else {
                true
            }
        });
    }

    /// Seed the cache with an externally produced copy of a prefix
    /// (cluster KV migration, cross-pipe NoC import): registers blocks
    /// for `keys`, ready from cycle `ready_at` (when the transfer lands).
    /// Blocks already cached just have their readiness advanced.
    /// Best-effort under SRAM pressure; returns the token length of the
    /// seeded path. A seed never extends *past* an HBM-demoted node: a
    /// fresh SRAM child under an HBM parent would pin the parent's bytes
    /// in the tier (the overflow drop loop only removes leaves), making
    /// the tier's capacity bound unenforceable — the walk stops there and
    /// the remainder of the copy is dropped.
    pub fn seed_prefix(&mut self, keys: &[BlockKey], ready_at: u64) -> u64 {
        if self.prefix.is_none() {
            return 0;
        }
        let mut parent = NO_NODE;
        let mut tokens = 0u64;
        for &key in keys {
            let (existing, parent_demoted) = {
                let ix = self.prefix.as_ref().expect("prefix enabled");
                (
                    ix.child_of(parent, key),
                    parent != NO_NODE && ix.tier_of(parent) == Tier::Hbm,
                )
            };
            if let Some(node) = existing {
                self.prefix
                    .as_mut()
                    .expect("prefix enabled")
                    .mark_ready(node, ready_at);
                tokens += key.tokens;
                parent = node;
                continue;
            }
            if parent_demoted {
                break; // never create an SRAM child under a demoted parent
            }
            let Some(blk) = self.alloc_block() else {
                break;
            };
            // The freshly allocated block's single reference belongs to
            // the index (there is no owning request to share with yet).
            let node = self
                .prefix
                .as_mut()
                .expect("prefix enabled")
                .insert(parent, key, blk, ready_at);
            self.stats.inserted_blocks += 1;
            tokens += key.tokens;
            parent = node;
        }
        tokens
    }

    /// Allocate one SRAM block, reclaiming cold cached prefix blocks when
    /// the free list is empty. With the HBM tier enabled the coldest
    /// evictable block is *demoted* (its bytes move to the HBM tier and
    /// the node stays matchable); without it — or when nothing is
    /// demotable — the coldest evictable leaf is dropped as before. Only
    /// blocks referenced by nobody but the index qualify either way.
    fn alloc_block(&mut self) -> Option<u32> {
        if let Some(b) = self.sram.alloc() {
            return Some(b);
        }
        let bpt = self.bytes_per_token;
        let KvCache {
            prefix,
            hbm_tier,
            sram,
            stats,
            ..
        } = self;
        let ix = prefix.as_mut()?;
        if let Some(tier) = hbm_tier.as_mut() {
            if let Some((node, block)) = ix.demote_lru(|b| sram.refcount(b) == 1) {
                let fill = ix.tokens_of(node) * bpt;
                sram.release_block(block);
                tier.used_bytes += fill;
                tier.pending_demote_bytes += fill;
                stats.tier_demotions += 1;
                stats.demoted_bytes += fill;
                // Bound the HBM tier: drop the coldest demoted leaves
                // until the region fits again.
                while tier.used_bytes > tier.capacity_bytes {
                    let Some(tokens) = ix.drop_lru_hbm() else { break };
                    tier.used_bytes = tier.used_bytes.saturating_sub(tokens * bpt);
                    stats.tier_dropped += 1;
                }
                return sram.alloc();
            }
            // Nothing demotable (every SRAM node is shared with a live
            // request): fall through to the plain drop path, which will
            // find nothing either — kept for symmetry with tier-off.
        }
        let victim = ix.evict_lru(|b| sram.refcount(b) == 1)?;
        sram.release_block(victim);
        stats.prefix_evictions += 1;
        sram.alloc()
    }

    /// Re-promote a demoted prefix node into a fresh SRAM block (the
    /// index's reference), charging the HBM→SRAM stream. Returns the new
    /// block, or `None` when SRAM cannot host it — or when the allocation
    /// attempt's own demotion chain dropped the node from an overflowing
    /// HBM tier in the meantime.
    fn promote_node(&mut self, node: u32) -> Option<u32> {
        let blk = self.alloc_block()?;
        let ix = self.prefix.as_ref().expect("promote implies prefix");
        if !ix.is_live(node) || ix.tier_of(node) != Tier::Hbm {
            // Dropped (or already re-promoted) while making room: return
            // the block and report no promotion.
            self.sram.release_block(blk);
            return None;
        }
        let fill = ix.tokens_of(node) * self.bytes_per_token;
        let KvCache {
            prefix,
            hbm_tier,
            stats,
            ..
        } = self;
        prefix.as_mut().expect("promote implies prefix").promote(node, blk);
        if let Some(tier) = hbm_tier.as_mut() {
            tier.used_bytes = tier.used_bytes.saturating_sub(fill);
            tier.pending_promote_bytes += fill;
        }
        stats.tier_promotions += 1;
        stats.promoted_bytes += fill;
        Some(blk)
    }

    /// Append `n_tokens` of KV for request `id`. New tokens fill SRAM
    /// blocks while any remain, then spill to the request's HBM buffer.
    /// Appending past a shared partial block first copy-on-writes it.
    pub fn append(&mut self, id: u64, n_tokens: u64) -> Appended {
        let bytes = n_tokens * self.bytes_per_token;
        let block_bytes = self.sram.block_bytes();
        let mut out = Appended::default();
        // Fill the tail of the chain's appendable capacity first.
        let (tail_room, has_frozen_tail) = {
            let e = self.entries.get(&id).expect("append before admit");
            (
                e.cap_bytes.saturating_sub(e.res.sram_bytes),
                e.frozen_tail_fill.is_some(),
            )
        };
        let into_tail = bytes.min(tail_room);
        out.sram_bytes += into_tail;
        let mut remaining = bytes - into_tail;
        // Diverging past a shared partial block: copy-on-write it into a
        // private block. The cached fill stays valid for the other sharers;
        // the SRAM-to-SRAM copy itself is not charged (it is tiny next to
        // the prefill work the sharing skipped).
        if remaining > 0 && has_frozen_tail {
            if let Some(nb) = self.alloc_block() {
                let entry = self.entries.get_mut(&id).expect("append before admit");
                let fill = entry.frozen_tail_fill.take().expect("checked above");
                let old = entry.chain.last().expect("frozen tail without block");
                entry.chain.replace_last(nb);
                entry.cap_bytes += block_bytes - fill;
                self.sram.release_block(old);
                self.stats.cow_copies += 1;
                let take = remaining.min(block_bytes - fill);
                out.sram_bytes += take;
                remaining -= take;
            }
        }
        // Grab new blocks while SRAM has them.
        while remaining > 0 {
            let Some(blk) = self.alloc_block() else { break };
            let entry = self.entries.get_mut(&id).expect("append before admit");
            entry.chain.push(blk);
            entry.cap_bytes += block_bytes;
            let take = remaining.min(block_bytes);
            out.sram_bytes += take;
            remaining -= take;
        }
        // Spill the rest to the HBM buffer.
        let entry = self.entries.get_mut(&id).expect("append before admit");
        if remaining > 0 {
            match &entry.hbm {
                Some(a) => {
                    let room = a.bytes.saturating_sub(entry.res.hbm_bytes);
                    let take = remaining.min(room);
                    out.hbm_bytes += take;
                    self.overflow_bytes += remaining - take;
                }
                None => {
                    // SRAM-only chip: "spill" is remote/overflow, tracked so
                    // the executor can charge NoC offload (WaferLLM style).
                    out.hbm_bytes += remaining;
                }
            }
        }
        entry.res.sram_bytes += out.sram_bytes;
        entry.res.hbm_bytes += out.hbm_bytes;
        out
    }

    /// Roll back the most recent `n_tokens` of request `id`'s KV
    /// (speculative-decode reject path). Unwinds the append order exactly:
    /// spilled HBM bytes first (they are the newest), then SRAM tail
    /// bytes, popping tail blocks that become empty. Only *private*
    /// blocks (refcount 1) are popped — a block shared with the prefix
    /// index or another request is never reclaimed, and a frozen shared
    /// tail clamps the walk (speculative tokens never land in either, so
    /// the clamp is a safety bound, not a lossy path). `n_tokens` must not
    /// exceed the tokens appended since the last committed token. Returns
    /// the bytes removed; the caller charges them as KV-spill-class HBM
    /// traffic.
    pub fn truncate(&mut self, id: u64, n_tokens: u64) -> u64 {
        let mut remaining = n_tokens * self.bytes_per_token;
        let block_bytes = self.sram.block_bytes();
        let Some(entry) = self.entries.get_mut(&id) else {
            return 0;
        };
        let mut removed = 0u64;
        // Newest bytes live in the HBM spill buffer: unwind those first.
        let take = remaining.min(entry.res.hbm_bytes);
        entry.res.hbm_bytes -= take;
        removed += take;
        remaining -= take;
        // Then unwind the SRAM tail.
        while remaining > 0 && entry.res.sram_bytes > 0 {
            let tail = entry.chain.last().expect("sram bytes without blocks");
            if entry.frozen_tail_fill.is_some() || self.sram.refcount(tail) > 1 {
                // The tail (and everything below it) is shared prefix
                // content: clamp — rollback never reclaims shared bytes.
                break;
            }
            // Earlier blocks are always full (appends fill tail room before
            // allocating), so the tail's fill is the residency overhang.
            let tail_fill = entry.res.sram_bytes - (entry.cap_bytes - block_bytes);
            let take = remaining.min(tail_fill);
            entry.res.sram_bytes -= take;
            removed += take;
            remaining -= take;
            if take < tail_fill {
                break; // partial unwind: the tail block stays
            }
            entry.chain.pop();
            self.sram.release_block(tail);
            entry.cap_bytes -= block_bytes;
            self.stats.rollback_blocks += 1;
        }
        self.stats.rollback_bytes += removed;
        removed
    }

    /// Current residency of a request's KV.
    pub fn residency(&self, id: u64) -> KvResidency {
        self.entries.get(&id).map(|e| e.res).unwrap_or_default()
    }

    /// Release all memory of a completed request. Blocks registered in the
    /// prefix index stay cached (the index holds a reference) until LRU
    /// eviction reclaims them.
    pub fn release(&mut self, id: u64) {
        if let Some(mut e) = self.entries.remove(&id) {
            self.sram.release(&mut e.chain);
            if let Some(a) = e.hbm {
                self.hbm.free(a.id);
            }
        }
    }

    pub fn n_active(&self) -> usize {
        self.entries.len()
    }

    /// Aggregate *logical* SRAM KV occupancy across requests (shared bytes
    /// count once per sharer — the attention-timing view).
    pub fn sram_used_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.res.sram_bytes).sum()
    }

    /// *Physical* SRAM block bytes in use (shared blocks count once).
    pub fn sram_physical_bytes(&self) -> u64 {
        (self.sram.n_blocks() - self.sram.n_free()) as u64 * self.sram.block_bytes()
    }

    pub fn sram_free_bytes(&self) -> u64 {
        self.sram.bytes_free()
    }

    pub fn hbm_free_bytes(&self) -> u64 {
        self.hbm.bytes_free()
    }

    /// Bytes lost to exhausted HBM buffers (must stay 0 when admission
    /// control sizes buffers by `max_tokens`).
    pub fn overflow_bytes(&self) -> u64 {
        self.overflow_bytes
    }

    /// Occupancy of the admission-limiting KV tier in `[0, 1]`: the HBM
    /// ring when this worker has HBM (its buffer reservations gate
    /// [`KvCache::can_admit`]), otherwise the SRAM block pool. The cluster
    /// router's least-loaded signal.
    pub fn utilization(&self) -> f64 {
        let cap = self.hbm.capacity();
        if cap > 0 {
            return 1.0 - self.hbm.bytes_free() as f64 / cap as f64;
        }
        let total = self.sram.n_blocks();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.sram.n_free() as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn cache() -> KvCache {
        // 4 blocks of 16 tokens × 8 B/token; HBM fits 4 requests of 256 tok.
        KvCache::new(4 * 16 * 8, 16, 4 * 256 * 8, 8, 256)
    }

    /// Content keys for a `tokens`-long prefix tagged by `scope`.
    fn keys(scope: u64, tokens: u64) -> Vec<BlockKey> {
        let mut out = Vec::new();
        let mut pos = 0;
        let mut i = 0u64;
        while pos < tokens {
            let t = (tokens - pos).min(16);
            out.push(BlockKey {
                hash: scope.wrapping_mul(1_000_003) ^ (i << 8) ^ t,
                tokens: t,
            });
            pos += t;
            i += 1;
        }
        out
    }

    #[test]
    fn fills_sram_then_spills() {
        let mut kv = cache();
        assert!(kv.admit(1));
        // 64 tokens exactly fill SRAM (4 blocks × 16 tokens).
        let a = kv.append(1, 64);
        assert_eq!(a.sram_bytes, 64 * 8);
        assert_eq!(a.hbm_bytes, 0);
        // The next token spills.
        let a = kv.append(1, 10);
        assert_eq!(a.sram_bytes, 0);
        assert_eq!(a.hbm_bytes, 80);
        let r = kv.residency(1);
        assert_eq!(r.sram_bytes, 512);
        assert_eq!(r.hbm_bytes, 80);
    }

    #[test]
    fn partial_block_tail_is_reused() {
        let mut kv = cache();
        kv.admit(1);
        kv.append(1, 10); // block 0: 10/16 tokens used
        let a = kv.append(1, 4); // fits in block 0's tail
        assert_eq!(a.sram_bytes, 32);
        assert_eq!(kv.sram_free_bytes(), 3 * 16 * 8);
    }

    #[test]
    fn admission_bounded_by_hbm() {
        let mut kv = cache();
        for id in 0..4 {
            assert!(kv.can_admit(), "id={id}");
            assert!(kv.admit(id));
        }
        assert!(!kv.can_admit());
        assert!(!kv.admit(99));
        // Releasing one admits another.
        kv.release(0);
        assert!(kv.admit(99));
    }

    #[test]
    fn release_frees_both_tiers() {
        let mut kv = cache();
        kv.admit(1);
        kv.append(1, 100); // 64 SRAM + 36 spilled
        kv.admit(2);
        kv.append(2, 16); // all spilled (SRAM full)
        assert_eq!(kv.residency(2).sram_bytes, 0);
        kv.release(1);
        // New request can now use SRAM again.
        kv.admit(3);
        let a = kv.append(3, 16);
        assert_eq!(a.sram_bytes, 128);
    }

    #[test]
    fn sram_only_chip_tracks_remote_overflow() {
        let mut kv = KvCache::new(2 * 16 * 8, 16, 0, 8, 256);
        assert!(kv.can_admit());
        kv.admit(1);
        let a = kv.append(1, 48); // 32 tokens fit, 16 overflow "remote"
        assert_eq!(a.sram_bytes, 256);
        assert_eq!(a.hbm_bytes, 128);
        assert_eq!(kv.overflow_bytes(), 0);
    }

    #[test]
    fn prefix_sharing_dedups_blocks_and_skips_storage() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(7, 32); // two full blocks of shared prefix
        // First request: miss; registers its prefix blocks while admitting.
        assert_eq!(kv.admit_prefixed(1, &ks, u64::MAX, 0), Some(0));
        kv.append(1, 40); // 32 prefix + 8 unique tokens
        kv.note_prefilled(1, 40, 100); // prefill completes at cycle 100
        // Second request: hits both prefix blocks.
        assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 100), Some(32));
        assert_eq!(kv.residency(2).sram_bytes, 32 * 8);
        // Physically the two prefix blocks exist once: 1 used 3 blocks
        // (2 prefix + 1 private), request 2 added none.
        assert_eq!(kv.sram_physical_bytes(), 3 * 16 * 8);
        let s = kv.stats();
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.matched_tokens, 32);
        assert_eq!(s.deduped_bytes, 32 * 8);
        assert_eq!(s.inserted_blocks, 2);
    }

    #[test]
    fn in_flight_blocks_do_not_match_until_prefilled() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(7, 32);
        assert_eq!(kv.admit_prefixed(1, &ks, u64::MAX, 0), Some(0));
        // Request 1's prefill is still in flight: a co-arriving request
        // must not count its registered blocks as hits (the historical
        // admission-time optimism this fix removes).
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 0), 0);
        assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 0), Some(0));
        let s = kv.stats();
        assert_eq!(s.prefix_hits, 0);
        assert_eq!(s.matched_tokens, 0);
        // Chunked completion: the first block becomes matchable once the
        // prefill passes it, the second only at full coverage.
        kv.note_prefilled(1, 16, 700);
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 700), 16);
        kv.note_prefilled(1, 32, 900);
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 899), 16);
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 900), 32);
    }

    #[test]
    fn cached_prefix_survives_release_and_is_rematched() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(3, 32);
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.append(1, 33);
        kv.note_prefilled(1, 33, 50);
        kv.release(1);
        // Blocks stay cached: a later request still matches.
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 50), 32);
        assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 50), Some(32));
    }

    #[test]
    fn cow_on_divergence_past_a_shared_partial_block() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(9, 24); // one full block + one partial (8 tokens)
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.append(1, 24); // owner fills exactly the registered prefix
        kv.note_prefilled(1, 24, 10);
        // Request 2 shares both blocks (incl. the partial terminal)…
        assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 10), Some(24));
        let before = kv.stats().cow_copies;
        // …and diverges: the partial block must be COWed, not mutated.
        let a = kv.append(2, 4);
        assert_eq!(a.sram_bytes, 4 * 8);
        assert_eq!(kv.stats().cow_copies, before + 1);
        // A third request still matches the *original* cached prefix.
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 10), 24);
        // Owner appending past its own registered partial also COWs.
        kv.append(1, 2);
        assert_eq!(kv.stats().cow_copies, before + 2);
    }

    #[test]
    fn lru_eviction_reclaims_cold_prefixes_under_pressure() {
        let mut kv = cache(); // 4 SRAM blocks
        kv.enable_prefix_cache();
        kv.admit_prefixed(1, &keys(1, 32), u64::MAX, 0);
        kv.append(1, 32);
        kv.release(1); // 2 cached blocks, refcount 1 (index only)
        // A new unshared request needs 3 blocks: eviction must free them.
        kv.admit(2);
        let a = kv.append(2, 48);
        assert_eq!(a.sram_bytes, 48 * 8, "eviction should free SRAM");
        assert!(kv.stats().prefix_evictions >= 1);
    }

    #[test]
    fn live_shared_blocks_are_never_evicted() {
        let mut kv = cache(); // 4 SRAM blocks
        kv.enable_prefix_cache();
        let ks = keys(5, 32);
        kv.admit_prefixed(1, &ks, u64::MAX, 0); // 2 registered blocks, live
        kv.append(1, 32);
        kv.note_prefilled(1, 32, 0);
        // Fill the remaining 2 blocks with an unshared request, then ask
        // for more: the live prefix blocks must not be reclaimed.
        kv.admit(2);
        let a = kv.append(2, 48); // 32 fit, 16 spill
        assert_eq!(a.sram_bytes, 32 * 8);
        assert_eq!(a.hbm_bytes, 16 * 8);
        assert_eq!(kv.stats().prefix_evictions, 0);
        // Request 1 still matches its prefix for a sharer.
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 0), 32);
    }

    #[test]
    fn match_cap_limits_sharing() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(2, 48);
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.note_prefilled(1, 48, 0);
        // Cap below the cached 48 tokens: match stops at a block boundary.
        assert_eq!(kv.admit_prefixed(2, &ks, 40, 0), Some(32));
    }

    #[test]
    fn seeded_prefixes_match_from_their_landing_cycle() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(4, 32);
        // A migrated copy lands at cycle 2000.
        assert_eq!(kv.seed_prefix(&ks, 2000), 32);
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 1999), 0);
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 2000), 32);
        assert_eq!(kv.admit_prefixed(9, &ks, u64::MAX, 2500), Some(32));
        // Seeded blocks are index-owned and evictable once cold: an
        // unshared request needing 3 of the 4 blocks forces at least one
        // eviction of the seeded pair.
        kv.release(9);
        kv.admit(10);
        let a = kv.append(10, 48);
        assert_eq!(a.sram_bytes, 48 * 8);
        assert!(kv.stats().prefix_evictions >= 1);
    }

    #[test]
    fn hbm_tier_demotes_instead_of_dropping_and_repromotes_on_hit() {
        let mut kv = cache(); // 4 SRAM blocks
        kv.enable_prefix_cache();
        kv.enable_hbm_tier(1024); // carved out of the test ring (8 KiB)
        assert!(kv.hbm_tier_enabled());
        let ks = keys(1, 32);
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.append(1, 32);
        kv.note_prefilled(1, 32, 10);
        kv.release(1); // 2 cached blocks, refcount 1 (index only)
        // Pressure: an unshared request needs 3 blocks; with the tier on,
        // the coldest prefix block is demoted, not dropped.
        kv.admit(2);
        let a = kv.append(2, 48);
        assert_eq!(a.sram_bytes, 48 * 8);
        let s = kv.stats();
        assert_eq!(s.prefix_evictions, 0, "tier must demote, not drop");
        assert_eq!(s.tier_demotions, 1);
        assert_eq!(s.demoted_bytes, 16 * 8);
        assert_eq!(kv.hbm_tier_used_bytes(), 16 * 8);
        // The demoted block still matches — split across tiers.
        let m = kv.peek_prefix_tiered(&ks, u64::MAX, 10);
        assert_eq!(m.total(), 32);
        assert_eq!(m.hbm_tokens, 16);
        assert_eq!(m.sram_tokens, 16);
        // Free the pressure; a re-admission promotes the demoted block
        // back into SRAM at charged HBM→SRAM cost.
        kv.release(2);
        assert_eq!(kv.admit_prefixed(3, &ks, u64::MAX, 10), Some(32));
        let s = kv.stats();
        assert_eq!(s.tier_promotions, 1);
        assert_eq!(s.promoted_bytes, 16 * 8);
        assert_eq!(kv.hbm_tier_used_bytes(), 0);
        assert_eq!(kv.residency(3).sram_bytes, 32 * 8);
        // Both directions drain exactly once as chargeable traffic.
        assert_eq!(kv.drain_tier_traffic(), (16 * 8, 16 * 8));
        assert_eq!(kv.drain_tier_traffic(), (0, 0));
        // Demote→promote conserved the cached path: the sharer releases
        // and the whole prefix still matches from the fast tier.
        kv.release(3);
        let m = kv.peek_prefix_tiered(&ks, u64::MAX, 10);
        assert_eq!(m.sram_tokens, 32);
        assert_eq!(m.hbm_tokens, 0);
    }

    #[test]
    fn hbm_tier_capacity_bounds_demotions_with_lru_drops() {
        let mut kv = cache(); // 4 SRAM blocks
        kv.enable_prefix_cache();
        kv.enable_hbm_tier(16 * 8); // exactly one demoted block fits
        let ks = keys(2, 32);
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.append(1, 32);
        kv.note_prefilled(1, 32, 0);
        kv.release(1);
        // 4 blocks of pressure: both cached blocks demote; the second
        // demotion overflows the tier and drops the colder leaf for real.
        kv.admit(2);
        let a = kv.append(2, 64);
        assert_eq!(a.sram_bytes, 64 * 8);
        let s = kv.stats();
        assert_eq!(s.tier_demotions, 2);
        assert_eq!(s.tier_dropped, 1);
        assert_eq!(kv.hbm_tier_used_bytes(), 16 * 8);
    }

    #[test]
    fn hbm_tier_region_is_carved_out_of_the_ring() {
        // 4 max-length buffers fit the plain ring; carving the tier's
        // region leaves room for 3 — demoted bytes occupy real,
        // admission-visible HBM capacity, never phantom space.
        let mut kv = cache();
        kv.enable_prefix_cache();
        kv.enable_hbm_tier(2048); // one whole request buffer's worth
        assert!(kv.hbm_tier_enabled());
        for id in 0..3 {
            assert!(kv.admit(id), "id={id}");
        }
        assert!(!kv.can_admit(), "tier bytes must be admission-visible");
        // A tier larger than the ring is refused (SRAM-only regime).
        let mut tiny = KvCache::new(2 * 16 * 8, 16, 0, 8, 256);
        tiny.enable_prefix_cache();
        assert!(!tiny.enable_hbm_tier(1 << 20));
        assert!(!tiny.hbm_tier_enabled());
    }

    #[test]
    fn hbm_tier_carve_must_leave_room_for_one_request() {
        // Bound validation: a carve that would starve admission (the
        // remaining ring cannot hold even one per-request reservation) is
        // refused and leaves the ring untouched; the largest valid carve
        // is accepted.
        let mut kv = cache(); // ring 8192 B, 2048 B per request
        kv.enable_prefix_cache();
        assert!(!kv.enable_hbm_tier(8192 - 2048 + 1));
        assert!(!kv.hbm_tier_enabled());
        assert_eq!(kv.hbm_free_bytes(), 8192);
        assert!(kv.enable_hbm_tier(8192 - 2048));
        assert!(kv.hbm_tier_enabled());
        assert!(kv.admit(1), "one admission must still fit");
        assert!(!kv.can_admit());
    }

    #[test]
    fn hbm_tier_without_pressure_is_inert() {
        // Same op sequence on tier-on and tier-off caches, never exceeding
        // SRAM: stats and residency must agree exactly (the tier only
        // changes behaviour at the eviction point).
        let mut on = cache();
        on.enable_prefix_cache();
        on.enable_hbm_tier(1024);
        assert!(on.hbm_tier_enabled());
        let mut off = cache();
        off.enable_prefix_cache();
        let ks = keys(4, 32);
        for kv in [&mut on, &mut off] {
            assert_eq!(kv.admit_prefixed(1, &ks, u64::MAX, 0), Some(0));
            kv.append(1, 33);
            kv.note_prefilled(1, 33, 5);
            assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 5), Some(32));
            kv.release(1);
            kv.release(2);
        }
        assert_eq!(on.stats(), off.stats());
        assert_eq!(on.hbm_tier_used_bytes(), 0);
        assert_eq!(on.drain_tier_traffic(), (0, 0));
    }

    #[test]
    fn prop_demote_promote_conserves_bytes_and_refcounts() {
        // Random admit/append/release mixes on a tiny SRAM pool with the
        // HBM tier enabled: per-request residency must equal matched +
        // appended tokens (promotions included), physical SRAM never
        // exceeds capacity, the HBM tier never exceeds its own bound, and
        // draining everything reclaims every block exactly once (the
        // allocator panics on double frees — demote/promote must not leak
        // or double-count a block).
        check("kv tier conservation", 48, |rng| {
            let n_blocks = rng.range_u64(2, 10);
            let tier_cap = rng.range_u64(1, 6) * 16 * 8;
            let mut kv = KvCache::new(n_blocks * 16 * 8, 16, 1 << 20, 8, 2048);
            kv.enable_prefix_cache();
            kv.enable_hbm_tier(tier_cap);
            let mut tokens: HashMap<u64, u64> = HashMap::new();
            let mut next_id = 0u64;
            let mut live: Vec<u64> = Vec::new();
            let mut now = 0u64;
            for _ in 0..rng.range(1, 60) {
                now += 1;
                let roll = rng.f64();
                if roll < 0.4 {
                    let scope = rng.range_u64(1, 4);
                    let prefix_tokens = rng.range_u64(1, 64);
                    let id = next_id;
                    next_id += 1;
                    let ks = keys(scope, prefix_tokens);
                    if let Some(matched) = kv.admit_prefixed(id, &ks, u64::MAX, now) {
                        assert!(matched <= prefix_tokens);
                        kv.note_prefilled(id, prefix_tokens, now);
                        tokens.insert(id, matched);
                        live.push(id);
                    }
                } else if roll < 0.8 && !live.is_empty() {
                    let id = *rng.choose(&live);
                    let n = rng.range_u64(1, 48);
                    let t = tokens.get_mut(&id).unwrap();
                    if *t + n <= 2048 {
                        kv.append(id, n);
                        *t += n;
                    }
                } else if !live.is_empty() {
                    let i = rng.range(0, live.len());
                    let id = live.swap_remove(i);
                    kv.release(id);
                    tokens.remove(&id);
                }
                for (&id, &t) in &tokens {
                    assert_eq!(kv.residency(id).total(), t * 8, "id={id}");
                }
                assert!(kv.sram_physical_bytes() <= n_blocks * 16 * 8);
                assert!(kv.hbm_tier_used_bytes() <= tier_cap, "tier overflow");
                assert_eq!(kv.overflow_bytes(), 0);
            }
            // Byte conservation across the tier: everything demoted either
            // came back (promoted), was dropped, or still sits in HBM.
            let s = kv.stats();
            assert!(s.promoted_bytes + kv.hbm_tier_used_bytes() <= s.demoted_bytes);
            // Drain: evicting until dry must reclaim every block exactly
            // once, demotions included.
            for id in live {
                kv.release(id);
            }
            while kv.alloc_block().is_some() {}
            assert_eq!(kv.sram_free_bytes(), 0);
        });
    }

    #[test]
    fn truncate_unwinds_appends_hbm_first_and_pops_empty_blocks() {
        let mut kv = cache(); // 4 SRAM blocks × 16 tokens
        kv.admit(1);
        kv.append(1, 70); // 64 SRAM + 6 spilled
        assert_eq!(kv.residency(1).hbm_bytes, 6 * 8);
        // Rolling back 10 tokens removes the 6 spilled first, then 4 from
        // the SRAM tail — the tail block empties and is reclaimed.
        assert_eq!(kv.truncate(1, 10), 10 * 8);
        let r = kv.residency(1);
        assert_eq!(r.hbm_bytes, 0);
        assert_eq!(r.sram_bytes, 60 * 8);
        assert_eq!(kv.sram_free_bytes(), 0, "60/64 tokens keep 4 blocks");
        assert_eq!(kv.truncate(1, 12), 12 * 8); // 48 left: block 4 frees
        assert_eq!(kv.sram_free_bytes(), 16 * 8);
        let s = kv.stats();
        assert_eq!(s.rollback_bytes, 22 * 8);
        assert_eq!(s.rollback_blocks, 1);
        // Re-appending after rollback lands exactly where it would have.
        let a = kv.append(1, 16);
        assert_eq!(a.sram_bytes, 16 * 8);
        assert_eq!(kv.residency(1).total(), 64 * 8);
    }

    #[test]
    fn truncate_never_reclaims_shared_prefix_blocks() {
        let mut kv = cache();
        kv.enable_prefix_cache();
        let ks = keys(11, 32);
        kv.admit_prefixed(1, &ks, u64::MAX, 0);
        kv.append(1, 32);
        kv.note_prefilled(1, 32, 5);
        // Request 2 shares both prefix blocks, then speculates 4 tokens
        // into a fresh private block.
        assert_eq!(kv.admit_prefixed(2, &ks, u64::MAX, 5), Some(32));
        kv.append(2, 4);
        let phys = kv.sram_physical_bytes();
        // Rolling the 4 speculative tokens back frees only the private
        // block; asking for more clamps at the shared prefix.
        assert_eq!(kv.truncate(2, 4), 4 * 8);
        assert_eq!(kv.truncate(2, 100), 0, "shared prefix is clamped");
        assert_eq!(kv.residency(2).sram_bytes, 32 * 8);
        assert_eq!(kv.sram_physical_bytes(), phys - 16 * 8);
        // The cached prefix is intact for a third request.
        assert_eq!(kv.peek_prefix(&ks, u64::MAX, 5), 32);
        kv.release(1);
        kv.release(2);
        assert_eq!(kv.admit_prefixed(3, &ks, u64::MAX, 5), Some(32));
    }

    #[test]
    fn prop_append_truncate_roundtrip_conserves_residency_and_blocks() {
        // Random append/truncate interleavings (truncate never exceeding
        // the tokens appended so far, the spec-decode contract): residency
        // must track the net token count exactly and a full unwind must
        // return the allocator to its starting state.
        check("kv truncate conservation", 64, |rng| {
            let n_blocks = rng.range_u64(2, 10);
            let mut kv = KvCache::new(n_blocks * 16 * 8, 16, 1 << 20, 8, 2048);
            kv.admit(1);
            let free0 = kv.sram_free_bytes();
            let mut tokens = 0u64;
            for _ in 0..rng.range(1, 50) {
                if rng.chance(0.6) {
                    let n = rng.range_u64(1, 24).min(2048 - tokens);
                    kv.append(1, n);
                    tokens += n;
                } else if tokens > 0 {
                    let n = rng.range_u64(1, tokens + 1);
                    assert_eq!(kv.truncate(1, n), n * 8);
                    tokens -= n;
                }
                assert_eq!(kv.residency(1).total(), tokens * 8);
                assert_eq!(kv.overflow_bytes(), 0);
            }
            kv.truncate(1, tokens);
            assert_eq!(kv.residency(1).total(), 0);
            assert_eq!(kv.sram_free_bytes(), free0, "full unwind frees all");
        });
    }

    #[test]
    fn prop_residency_equals_appended_tokens() {
        check("kv residency conservation", 64, |rng| {
            let mut kv = KvCache::new(
                rng.range_u64(0, 4096),
                rng.range_u64(1, 32),
                1 << 20,
                8,
                1024,
            );
            let mut expect: HashMap<u64, u64> = HashMap::new();
            for _ in 0..rng.range(1, 40) {
                let id = rng.range_u64(0, 4);
                if !kv.admit(id) {
                    continue;
                }
                let n = rng.range_u64(1, 64);
                let already = expect.entry(id).or_insert(0);
                if *already + n <= 1024 {
                    kv.append(id, n);
                    *already += n;
                }
            }
            for (id, tokens) in expect {
                assert_eq!(kv.residency(id).total(), tokens * 8, "id={id}");
            }
            assert_eq!(kv.overflow_bytes(), 0);
        });
    }

    #[test]
    fn prop_sharing_conserves_bytes_and_never_double_frees() {
        // Random mixes of prefixed admissions (drawn from a few prefix
        // scopes), appends, and releases: per-request residency must equal
        // matched + appended tokens, physical blocks must never exceed
        // capacity, and draining everything must leave only index-held
        // blocks (which eviction can then fully reclaim).
        check("kv sharing conservation", 48, |rng| {
            let n_blocks = rng.range_u64(2, 12);
            let mut kv = KvCache::new(n_blocks * 16 * 8, 16, 1 << 20, 8, 2048);
            kv.enable_prefix_cache();
            let mut tokens: HashMap<u64, u64> = HashMap::new();
            let mut next_id = 0u64;
            let mut live: Vec<u64> = Vec::new();
            let mut now = 0u64;
            for _ in 0..rng.range(1, 60) {
                now += 1;
                let roll = rng.f64();
                if roll < 0.4 {
                    let scope = rng.range_u64(1, 4);
                    let prefix_tokens = rng.range_u64(1, 64);
                    let id = next_id;
                    next_id += 1;
                    let ks = keys(scope, prefix_tokens);
                    if let Some(matched) = kv.admit_prefixed(id, &ks, u64::MAX, now) {
                        assert!(matched <= prefix_tokens);
                        // Emulate the producing prefill completing at once
                        // so later admissions keep exercising sharing.
                        kv.note_prefilled(id, prefix_tokens, now);
                        tokens.insert(id, matched);
                        live.push(id);
                    }
                } else if roll < 0.8 && !live.is_empty() {
                    let id = *rng.choose(&live);
                    let n = rng.range_u64(1, 48);
                    let t = tokens.get_mut(&id).unwrap();
                    if *t + n <= 2048 {
                        kv.append(id, n);
                        *t += n;
                    }
                } else if !live.is_empty() {
                    let i = rng.range(0, live.len());
                    let id = live.swap_remove(i);
                    kv.release(id);
                    tokens.remove(&id);
                }
                // Residency conservation for every live request.
                for (&id, &t) in &tokens {
                    assert_eq!(kv.residency(id).total(), t * 8, "id={id}");
                }
                assert!(kv.sram_physical_bytes() <= n_blocks * 16 * 8);
                assert_eq!(kv.overflow_bytes(), 0);
            }
            // Drain: all remaining blocks belong to the index; evicting
            // until dry must reclaim every block exactly once (the
            // allocator panics on double frees).
            for id in live {
                kv.release(id);
            }
            while kv.alloc_block().is_some() {}
            assert_eq!(kv.sram_free_bytes(), 0);
        });
    }
}
