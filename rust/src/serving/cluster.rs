//! Multi-chip serving cluster: N independent [`ChipSim`]s behind a
//! streamed admission frontend and a pluggable [`Router`].
//!
//! The single-chip drivers pre-load a whole trace into one scheduler; the
//! cluster driver instead *streams* — requests are released into a
//! cluster-level queue at their arrival times and routed to a chip based
//! on the chips' state **at that moment** (queue depth, KV occupancy,
//! prefix-cache contents). Three routing policies ship:
//!
//! - [`RouterPolicy::RoundRobin`] — static, state-blind baseline.
//! - [`RouterPolicy::LeastLoaded`] — minimises `(pending requests, KV
//!   occupancy)` at admission.
//! - [`RouterPolicy::PrefixAware`] — probes every chip's prefix index
//!   (read-only, in-flight-aware, **tier-split**: an SRAM-resident hit
//!   outranks an equal-length HBM-demoted one, which pays a re-promotion
//!   stream) and routes to the chip holding the best cached-and-ready
//!   prefix of the prompt; falls back to
//!   least-loaded on a miss. When the holder chip is overloaded (pending
//!   work exceeds the lightest chip's by the configured migration gap,
//!   `ClusterConfig::migrate_load_gap`), it routes to the lightest chip and
//!   *migrates* the matched prefix KV over the inter-chip fabric
//!   ([`crate::sim::interconnect`]) — charging the transfer's latency and
//!   bandwidth rather than recomputing the prefill.
//!
//! Every chip runs its own [`Scheduler`] (fusion, disagg, or hybrid —
//! mixes are allowed via [`simulate_cluster_mixed`]); the driver
//! interleaves chips deterministically by their earliest actionable cycle
//! and rolls per-chip [`Metrics`] up into a cluster aggregate.

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::memmgr::prefix::{keys_prefix, BlockKey, TierMatch};
use crate::memmgr::KV_BLOCK_TOKENS;
use crate::serving::metrics::{CacheStats, ControlStats, Metrics};
use crate::serving::request::{self, Priority, Request};
use crate::serving::scheduler::{Scheduler, SchedulerConfig};
use crate::sim::chip::ChipSim;
use crate::sim::interconnect::{Interconnect, InterconnectConfig, InterconnectStats};
use crate::util::units::{cycles_to_secs, secs_to_cycles, Cycle};
use std::collections::{HashMap, VecDeque};

/// Frontend overload response (CLI `--shed-policy`). With
/// [`ShedPolicy::None`] (the default) the admission path is bit-identical
/// to the pre-control-plane driver: every arrival routes immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Admit everything (legacy behaviour; the queue is unbounded).
    #[default]
    None,
    /// Reject overload arrivals outright: a shed request never runs and
    /// is counted in [`ControlStats::shed_requests`] by class.
    Drop,
    /// Re-time overload arrivals to the cluster's next actionable cycle
    /// (bounded retries); sustained overload degrades to a shed.
    Defer,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "none" | "off" => ShedPolicy::None,
            "drop" | "shed" => ShedPolicy::Drop,
            "defer" => ShedPolicy::Defer,
            other => anyhow::bail!("unknown shed policy {other:?} (none|drop|defer)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShedPolicy::None => "none",
            ShedPolicy::Drop => "drop",
            ShedPolicy::Defer => "defer",
        }
    }
}

/// Deferral retry bound: after this many re-timings one request degrades
/// to a shed (sustained overload must not recycle arrivals forever).
const MAX_DEFERRALS: u32 = 8;

/// Minimum re-timing step of one deferral, in seconds — keeps a deferred
/// arrival strictly later than the admission that bounced it even when
/// the cycle→seconds round-trip rounds down.
const DEFER_BACKOFF_S: f64 = 1e-4;

/// Routing policy selector (CLI `--router`, experiment sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastLoaded,
    PrefixAware,
}

impl RouterPolicy {
    /// All policies, in sweep order.
    pub const ALL: [RouterPolicy; 3] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::PrefixAware,
    ];

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "rr" | "round-robin" | "roundrobin" => RouterPolicy::RoundRobin,
            "least" | "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "prefix" | "prefix-aware" | "hit-aware" => RouterPolicy::PrefixAware,
            other => anyhow::bail!("unknown router {other:?} (rr|least|prefix)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "rr",
            RouterPolicy::LeastLoaded => "least",
            RouterPolicy::PrefixAware => "prefix",
        }
    }

    /// Instantiate the policy. `migrate_load_gap` only affects
    /// [`RouterPolicy::PrefixAware`].
    pub fn build(&self, migrate_load_gap: usize) -> Box<dyn Router> {
        match self {
            RouterPolicy::RoundRobin => Box::new(RoundRobinRouter { next: 0 }),
            RouterPolicy::LeastLoaded => Box::new(LeastLoadedRouter),
            RouterPolicy::PrefixAware => Box::new(PrefixAwareRouter {
                load_gap: migrate_load_gap,
            }),
        }
    }
}

/// One chip's routing-relevant state at an admission instant.
#[derive(Debug, Clone, Copy)]
pub struct ChipView {
    /// Requests enqueued on the chip but not yet retired.
    pub pending_work: usize,
    /// KV occupancy of the admission-limiting tier, in per-mille
    /// (integer so routing comparisons are exact and deterministic).
    pub kv_occupancy_milli: u64,
    /// Longest cached-and-ready prefix (tokens) the chip could share with
    /// this request, across both cache tiers (0 when the prompt has no
    /// shareable prefix, the chip holds none of it, or its prefill is
    /// still in flight).
    pub prefix_match: u64,
    /// The SRAM-resident portion of `prefix_match` — the two-tier hit
    /// quality signal: a fast-tier match shares for free, an HBM-demoted
    /// one pays a re-promotion stream first.
    pub prefix_sram: u64,
}

impl ChipView {
    fn load_key(&self) -> (usize, u64) {
        (self.pending_work, self.kv_occupancy_milli)
    }

    /// Tier-weighted match score, the prefix router's ranking key —
    /// delegated to [`TierMatch::score`] so the weighting cannot drift
    /// from the in-chip pipe-affinity scoring.
    fn match_score(&self) -> u64 {
        TierMatch {
            sram_tokens: self.prefix_sram,
            hbm_tokens: self.prefix_match.saturating_sub(self.prefix_sram),
        }
        .score()
    }
}

/// Where a request goes, and whether its prefix KV migrates first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub chip: usize,
    /// `Some(holder)`: stream the matched prefix from `holder`'s cache to
    /// `chip` over the interconnect before admission (charged, not free).
    pub migrate_from: Option<usize>,
}

/// A cluster admission router: one decision per arriving request, based on
/// read-only per-chip state snapshots.
pub trait Router {
    fn name(&self) -> &'static str;

    /// Does this policy read [`ChipView::prefix_match`]? The driver skips
    /// the per-arrival trie probes (every stage of every pipe of every
    /// chip) for policies that never look at them.
    fn wants_prefix(&self) -> bool {
        false
    }

    fn route(&mut self, req: &Request, views: &[ChipView]) -> RouteDecision;
}

/// Chip with the least `(pending work, KV occupancy)`, ties on index.
fn least_loaded(views: &[ChipView]) -> usize {
    views
        .iter()
        .enumerate()
        .min_by_key(|(i, v)| (v.load_key(), *i))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Static round-robin (the state-blind baseline).
struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        let chip = self.next % views.len().max(1);
        self.next = (self.next + 1) % views.len().max(1);
        RouteDecision {
            chip,
            migrate_from: None,
        }
    }
}

/// Least `(queue depth, KV occupancy)` at each admission.
struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        RouteDecision {
            chip: least_loaded(views),
            migrate_from: None,
        }
    }
}

/// Longest-ready-prefix-first, least-loaded fallback, migration under
/// holder overload.
struct PrefixAwareRouter {
    load_gap: usize,
}

impl Router for PrefixAwareRouter {
    fn name(&self) -> &'static str {
        "prefix"
    }

    fn wants_prefix(&self) -> bool {
        true
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        let lightest = least_loaded(views);
        // Best tier-weighted match wins (an SRAM-resident hit outranks an
        // equal-length HBM-demoted one); ties go to the less loaded
        // holder, then to the lower chip index (deterministic).
        let holder = views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.prefix_match > 0)
            .min_by_key(|(i, v)| (std::cmp::Reverse(v.match_score()), v.load_key(), *i))
            .map(|(i, _)| i);
        match holder {
            None => RouteDecision {
                chip: lightest,
                migrate_from: None,
            },
            Some(h) => {
                let overloaded = views[h].pending_work
                    > views[lightest].pending_work.saturating_add(self.load_gap);
                if overloaded && h != lightest {
                    // Queueing on the holder would cost more than moving
                    // the KV: migrate the prefix to the lightest chip.
                    RouteDecision {
                        chip: lightest,
                        migrate_from: Some(h),
                    }
                } else {
                    RouteDecision {
                        chip: h,
                        migrate_from: None,
                    }
                }
            }
        }
    }
}

/// Cluster topology + policy configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-chip hardware (the cluster is homogeneous; heterogeneous chips
    /// are a ROADMAP follow-up).
    pub chip: ChipConfig,
    pub n_chips: usize,
    /// Scheduler every chip runs ([`simulate_cluster_mixed`] overrides).
    pub sched: SchedulerConfig,
    pub router: RouterPolicy,
    pub interconnect: InterconnectConfig,
    /// Pending-work excess over the lightest chip above which the prefix
    /// router migrates the matched KV instead of queueing on the holder.
    pub migrate_load_gap: usize,
    /// Frontend overload response ([`ShedPolicy::None`] = legacy
    /// unbounded admission, bit-identical to the pre-control-plane path).
    pub shed: ShedPolicy,
    /// Per-chip pending-work bound for Low-class arrivals while shedding
    /// is on; Normal tolerates twice this, High is never shed. Ignored
    /// under [`ShedPolicy::None`].
    pub queue_cap: usize,
    /// TTFT target the frontend's goodput accounting reports against
    /// (does not gate admission — queue depth and scheduler backpressure
    /// do; this is the SLO the shed policy is protecting).
    pub slo_ttft_s: f64,
}

impl ClusterConfig {
    pub fn new(
        chip: ChipConfig,
        n_chips: usize,
        sched: SchedulerConfig,
        router: RouterPolicy,
    ) -> Self {
        ClusterConfig {
            chip,
            n_chips: n_chips.max(1),
            sched,
            router,
            interconnect: InterconnectConfig::default(),
            migrate_load_gap: 8,
            shed: ShedPolicy::default(),
            queue_cap: 32,
            slo_ttft_s: 2.0,
        }
    }

    /// Enable SLO-aware overload control (builder style).
    pub fn with_shed(mut self, shed: ShedPolicy, queue_cap: usize) -> Self {
        self.shed = shed;
        self.queue_cap = queue_cap.max(1);
        self
    }

    /// Build a cluster where every chip runs the deployment a
    /// [`crate::parallel::plan::DeploymentPlan`] describes.
    pub fn from_plan(
        chip: ChipConfig,
        n_chips: usize,
        plan: &crate::parallel::plan::DeploymentPlan,
        router: RouterPolicy,
    ) -> anyhow::Result<Self> {
        Ok(Self::new(
            chip,
            n_chips,
            SchedulerConfig::from_plan(plan)?,
            router,
        ))
    }
}

/// Per-chip metrics plus the cluster-level rollup inputs.
#[derive(Debug)]
pub struct ClusterMetrics {
    pub per_chip: Vec<Metrics>,
    /// Requests admitted per chip (the routing histogram).
    pub routed: Vec<usize>,
    /// Prefix migrations the router performed.
    pub migrations: u64,
    /// Frontend control-plane counters (sheds and deferrals happen before
    /// any chip sees the request, so they live here rather than on a
    /// chip's [`Metrics`]; preemption/resume counters live per chip).
    pub control: ControlStats,
    pub interconnect: InterconnectStats,
    freq_mhz: f64,
}

impl ClusterMetrics {
    /// Total completed requests across chips.
    pub fn n_requests(&self) -> usize {
        self.per_chip.iter().map(|m| m.n_requests()).sum()
    }

    /// Requests the frontend shed (never admitted to any chip).
    pub fn shed_requests(&self) -> u64 {
        self.control.shed_requests
    }

    /// Merge every chip's records and cache counters into one [`Metrics`]
    /// (cluster-level TTFT/TBT distributions, throughput over the global
    /// makespan, aggregate cache rates), folding the frontend's shed and
    /// deferral counters in with the chips' preemption counters.
    pub fn aggregate(&self) -> Metrics {
        let mut out = Metrics::new(self.freq_mhz);
        for m in &self.per_chip {
            out.absorb(m);
        }
        out.control.merge(&self.control);
        out
    }
}

/// A migrated request waiting for its KV to land on the target chip.
struct Transit {
    landing: Cycle,
    dst: usize,
    req: Request,
    keys: Vec<BlockKey>,
}

/// Simulate a synthetic workload on the cluster.
pub fn simulate_cluster(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
) -> anyhow::Result<ClusterMetrics> {
    simulate_cluster_requests(cfg, model, request::generate(workload))
}

/// Simulate an explicit (arrival-sorted) request list on the cluster,
/// every chip running `cfg.sched`.
pub fn simulate_cluster_requests(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    reqs: Vec<Request>,
) -> anyhow::Result<ClusterMetrics> {
    let scheds: Vec<Box<dyn Scheduler>> = (0..cfg.n_chips.max(1))
        .map(|_| cfg.sched.build())
        .collect();
    simulate_cluster_mixed(cfg, model, reqs, scheds)
}

/// Simulate with an explicit per-chip scheduler list (mixed policies:
/// e.g. chip 0 fused, chip 1 disaggregated). `scheds.len()` must equal
/// `cfg.n_chips`; requests must be sorted by arrival time.
pub fn simulate_cluster_mixed(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    reqs: Vec<Request>,
    mut scheds: Vec<Box<dyn Scheduler>>,
) -> anyhow::Result<ClusterMetrics> {
    let n = cfg.n_chips.max(1);
    anyhow::ensure!(
        scheds.len() == n,
        "cluster has {n} chips but {} schedulers",
        scheds.len()
    );
    let freq = cfg.chip.freq_mhz;
    let mut chips: Vec<ChipSim> = (0..n).map(|_| ChipSim::new(cfg.chip.clone())).collect();
    let max_tokens = reqs.iter().map(|r| r.total_tokens()).max().unwrap_or(1);
    for (i, s) in scheds.iter_mut().enumerate() {
        s.prepare(&mut chips[i], model, max_tokens)?;
    }
    let mut icn = Interconnect::new(cfg.interconnect, n, freq);
    let mut router = cfg.router.build(cfg.migrate_load_gap);

    let total = reqs.len();
    let mut stream: VecDeque<Request> = reqs.into();
    let mut transit: Vec<Transit> = Vec::new();
    // `(request id, true arrival cycle, destination chip)` of every
    // migration — used to rebase recorded arrivals after the run.
    let mut migrated_log: Vec<(u64, Cycle, usize)> = Vec::new();
    let mut per_chip: Vec<Metrics> = (0..n).map(|_| Metrics::new(freq)).collect();
    let mut routed = vec![0usize; n];
    let mut migrations = 0u64;
    let mut control = ControlStats::default();
    // Deferral retries by request id (Defer policy only).
    let mut deferred: HashMap<u64, u32> = HashMap::new();
    let mut done = 0usize;
    let mut guard = 0u64;

    while done < total {
        guard += 1;
        anyhow::ensure!(
            guard < 64_000_000,
            "cluster livelock: {done}/{total} requests done"
        );
        // Three event sources: the arrival stream, in-flight migrations,
        // and the chips themselves. Process the earliest; ties prefer
        // admissions (arrival, then transit) so routing always sees every
        // request released up to the chips' next actionable cycle.
        let arr_t = stream
            .front()
            .map(|r| secs_to_cycles(r.arrival_s, freq))
            .unwrap_or(Cycle::MAX);
        let tra = transit
            .iter()
            .enumerate()
            .min_by_key(|(k, t)| (t.landing, *k))
            .map(|(k, t)| (k, t.landing));
        let tra_t = tra.map(|(_, c)| c).unwrap_or(Cycle::MAX);
        let act = (0..n)
            .filter_map(|i| scheds[i].next_action(&chips[i]).map(|t| (t, i)))
            .min();
        let act_t = act.map(|(t, _)| t).unwrap_or(Cycle::MAX);
        anyhow::ensure!(
            arr_t != Cycle::MAX || tra_t != Cycle::MAX || act_t != Cycle::MAX,
            "cluster deadlock: {done}/{total} requests done, nothing actionable"
        );

        if arr_t <= tra_t && arr_t <= act_t {
            // Release one arrival and route it on current chip state.
            let req = stream.pop_front().expect("arr_t finite");
            let now = secs_to_cycles(req.arrival_s, freq);
            // In-flight migrations count toward their destination's load,
            // so a transfer window cannot look like an idle chip (which
            // would flood it with duplicate migrations).
            let mut transit_load = vec![0usize; n];
            for t in &transit {
                transit_load[t.dst] += 1;
            }
            // SLO-aware admission control: when every chip is saturated
            // for this arrival's class — its queue depth (including KV in
            // transit toward it) exceeds the class cap, or the chip
            // reports hard backpressure — the frontend sheds or defers
            // instead of queueing behind work the SLO cannot survive.
            // Low tolerates `queue_cap`, Normal twice that, High is never
            // shed; `ShedPolicy::None` skips the check entirely.
            if cfg.shed != ShedPolicy::None && req.priority != Priority::High {
                let cap = match req.priority {
                    Priority::Low => cfg.queue_cap,
                    _ => cfg.queue_cap.saturating_mul(2),
                };
                let overloaded = (0..n).all(|i| {
                    scheds[i].pending_work() + transit_load[i] >= cap
                        || scheds[i].backpressure() >= 0.999
                });
                if overloaded {
                    let retries = deferred.get(&req.id).copied().unwrap_or(0);
                    if cfg.shed == ShedPolicy::Defer && retries < MAX_DEFERRALS {
                        // Re-time the arrival past the chips' next action
                        // and slot it back into the (sorted) stream.
                        deferred.insert(req.id, retries + 1);
                        control.deferrals += 1;
                        let mut req = req;
                        req.arrival_s = (cycles_to_secs(act_t.min(tra_t), freq)
                            .max(req.arrival_s))
                            + DEFER_BACKOFF_S;
                        let at = stream
                            .iter()
                            .position(|r| r.arrival_s > req.arrival_s)
                            .unwrap_or(stream.len());
                        stream.insert(at, req);
                    } else {
                        control.shed_requests += 1;
                        control.shed_by_class[req.priority.index()] += 1;
                        done += 1;
                    }
                    continue;
                }
            }
            let keys = req.block_keys(KV_BLOCK_TOKENS);
            let limit = (req.input_len as u64).saturating_sub(1);
            let probe = router.wants_prefix() && !keys.is_empty();
            let views: Vec<ChipView> = scheds
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let hit = if probe {
                        s.probe_prefix_tiered(&keys, limit, now)
                    } else {
                        TierMatch::default()
                    };
                    ChipView {
                        pending_work: s.pending_work() + transit_load[i],
                        kv_occupancy_milli: (s.kv_utilization() * 1000.0).round() as u64,
                        prefix_match: hit.total(),
                        prefix_sram: hit.sram_tokens,
                    }
                })
                .collect();
            let d = router.route(&req, &views);
            anyhow::ensure!(d.chip < n, "router returned chip {} of {n}", d.chip);
            match d.migrate_from {
                Some(src) if src != d.chip && views[src].prefix_match > 0 => {
                    // A migration of this prefix may already be in flight
                    // (co-arriving turns of one conversation while the
                    // holder stays overloaded): piggyback on it instead of
                    // paying a duplicate transfer of the same bytes.
                    let dup = transit
                        .iter()
                        .find(|t| !t.keys.is_empty() && t.keys.first() == keys.first())
                        .map(|t| (t.dst, t.landing));
                    // Piggybacked requests carry no seed keys (the
                    // original transit seeds the cache for both).
                    let (dst, landing, transit_keys) = match dup {
                        Some((dst, landing)) => (dst, landing, Vec::new()),
                        None => {
                            // Stream the matched prefix KV across the
                            // fabric; the request (and its seeded blocks)
                            // reach the target chip when the last byte
                            // lands.
                            let matched = views[src].prefix_match;
                            let bytes = matched * model.kv_bytes_per_token();
                            let landing = icn.transfer(src, d.chip, bytes, now);
                            migrations += 1;
                            (d.chip, landing, keys_prefix(&keys, matched))
                        }
                    };
                    // Admission is deferred to the landing instant so the
                    // request actually matches the migrated copy; the
                    // recorded arrival is rebased afterwards so TTFT
                    // charges the wait.
                    routed[dst] += 1;
                    migrated_log.push((req.id, now, dst));
                    let mut req = req;
                    req.arrival_s = req.arrival_s.max(cycles_to_secs(landing, freq));
                    transit.push(Transit {
                        landing,
                        dst,
                        req,
                        keys: transit_keys,
                    });
                }
                _ => {
                    routed[d.chip] += 1;
                    scheds[d.chip].enqueue(&mut chips[d.chip], req);
                }
            }
        } else if tra_t <= act_t {
            // A migrated prefix landed: seed the target chip's cache and
            // release the request there. Readiness is derived from the
            // request's (seconds-rounded) arrival so the float round-trip
            // can never land the admission one cycle before the seed.
            let (k, _) = tra.expect("tra_t finite");
            let t = transit.swap_remove(k);
            let ready = secs_to_cycles(t.req.arrival_s, freq).min(t.landing);
            scheds[t.dst].import_prefix(&t.keys, ready);
            scheds[t.dst].enqueue(&mut chips[t.dst], t.req);
        } else {
            let (_, i) = act.expect("act_t finite");
            done += scheds[i].step(&mut chips[i], model, &mut per_chip[i])?;
        }
    }

    // Migrated requests were admitted at their KV-landing instant;
    // restore their true frontend arrivals so TTFT includes the transfer
    // wait instead of hiding it.
    for &(id, arrival, dst) in &migrated_log {
        per_chip[dst].rebase_arrival(id, arrival);
    }
    for (i, s) in scheds.iter().enumerate() {
        let mut hw = CacheStats::default();
        s.collect_cache_stats(&mut hw);
        per_chip[i].cache.merge(&hw);
    }
    Ok(ClusterMetrics {
        per_chip,
        routed,
        migrations,
        control,
        interconnect: icn.stats(),
        freq_mhz: freq,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefixSharing;
    use crate::serving::pd_fusion::FusionConfig;

    fn views(loads: &[usize]) -> Vec<ChipView> {
        loads
            .iter()
            .map(|&pending_work| ChipView {
                pending_work,
                kv_occupancy_milli: 0,
                prefix_match: 0,
                prefix_sram: 0,
            })
            .collect()
    }

    fn req() -> Request {
        Request {
            id: 0,
            arrival_s: 0.0,
            input_len: 128,
            output_len: 8,
            prefix: crate::serving::request::Prefix::default(),
            priority: Priority::Normal,
        }
    }

    #[test]
    fn router_policy_parses_and_names() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(
            RouterPolicy::parse("least-loaded").unwrap(),
            RouterPolicy::LeastLoaded
        );
        assert_eq!(
            RouterPolicy::parse("prefix").unwrap(),
            RouterPolicy::PrefixAware
        );
        assert!(RouterPolicy::parse("magic").is_err());
        for p in RouterPolicy::ALL {
            assert_eq!(p.build(0).name(), p.name());
        }
    }

    #[test]
    fn round_robin_cycles_chips() {
        let mut r = RouterPolicy::RoundRobin.build(0);
        let v = views(&[5, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &v).chip).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_breaks_ties_on_kv_then_index() {
        let mut r = RouterPolicy::LeastLoaded.build(0);
        assert_eq!(r.route(&req(), &views(&[3, 1, 2])).chip, 1);
        let mut v = views(&[2, 2, 2]);
        v[1].kv_occupancy_milli = 500;
        assert_eq!(r.route(&req(), &v).chip, 0);
    }

    #[test]
    fn prefix_router_follows_the_longest_ready_match() {
        let mut r = RouterPolicy::PrefixAware.build(8);
        let mut v = views(&[0, 3, 3]);
        v[1].prefix_match = 512;
        v[2].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 2);
        assert_eq!(d.migrate_from, None);
        // No match anywhere: least-loaded fallback.
        assert_eq!(r.route(&req(), &views(&[4, 1, 2])).chip, 1);
    }

    #[test]
    fn prefix_router_prefers_fast_tier_matches_at_equal_length() {
        // Two chips hold the same-length match, but chip 2's is entirely
        // SRAM-resident while chip 1's is HBM-demoted: the router must
        // pick the hit that shares for free over the one that pays a
        // promotion stream.
        let mut r = RouterPolicy::PrefixAware.build(8);
        let mut v = views(&[1, 1, 1]);
        v[1].prefix_match = 512; // all demoted (prefix_sram 0)
        v[2].prefix_match = 512;
        v[2].prefix_sram = 512;
        assert_eq!(r.route(&req(), &v).chip, 2);
        // Length still dominates tier quality.
        v[1].prefix_match = 2048;
        assert_eq!(r.route(&req(), &v).chip, 1);
    }

    #[test]
    fn prefix_router_migrates_off_an_overloaded_holder() {
        let mut r = RouterPolicy::PrefixAware.build(4);
        let mut v = views(&[20, 0, 1]);
        v[0].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 1);
        assert_eq!(d.migrate_from, Some(0));
        // Within the gap: stay on the holder.
        let mut v = views(&[3, 0, 1]);
        v[0].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 0);
        assert_eq!(d.migrate_from, None);
    }

    #[test]
    fn cluster_serves_a_small_workload_on_every_router() {
        let model = ModelConfig::qwen3_4b();
        let mut w = WorkloadConfig::shared_prefix(8);
        w.prefix = Some(PrefixSharing {
            n_groups: 2,
            shared_prefix_len: 256,
            turns: 2,
            think_time_s: 1.0,
        });
        for router in RouterPolicy::ALL {
            let cfg = ClusterConfig::new(
                ChipConfig::large_core(),
                2,
                SchedulerConfig::Fusion(FusionConfig {
                    prefix_cache: true,
                    ..FusionConfig::default()
                }),
                router,
            );
            let cm = simulate_cluster(&cfg, &model, &w)
                .unwrap_or_else(|e| panic!("{} failed: {e:#}", router.name()));
            assert_eq!(cm.n_requests(), 8, "{}", router.name());
            assert_eq!(cm.routed.iter().sum::<usize>(), 8, "{}", router.name());
            let agg = cm.aggregate();
            assert_eq!(agg.n_requests(), 8);
            for r in agg.records() {
                assert!(r.first_token >= r.arrival, "{}: {r:?}", router.name());
                assert!(r.finish >= r.first_token, "{}: {r:?}", router.name());
            }
        }
    }

    #[test]
    fn single_chip_cluster_matches_the_batch_driver() {
        // With one chip and any router, streamed admission must reproduce
        // the single-chip simulate_requests timeline record for record
        // (same scheduler, same arrival order, same pipe assignment).
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6).with_seed(3);
        let reqs = request::generate(&w);
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let cm = simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = crate::serving::scheduler::FusionScheduler::new(FusionConfig::default());
        let m = crate::serving::scheduler::simulate_requests(&mut chip, &model, reqs, &mut sched)
            .unwrap();
        let mut a = cm.aggregate().records().to_vec();
        let mut b = m.records().to_vec();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        assert_eq!(a, b);
    }

    #[test]
    fn shed_policy_parses_and_names() {
        assert_eq!(ShedPolicy::parse("none").unwrap(), ShedPolicy::None);
        assert_eq!(ShedPolicy::parse("drop").unwrap(), ShedPolicy::Drop);
        assert_eq!(ShedPolicy::parse("defer").unwrap(), ShedPolicy::Defer);
        assert!(ShedPolicy::parse("maybe").is_err());
        for p in [ShedPolicy::None, ShedPolicy::Drop, ShedPolicy::Defer] {
            assert_eq!(ShedPolicy::parse(p.name()).unwrap(), p);
        }
    }

    /// A burst of co-arriving requests with mixed classes against a tiny
    /// queue cap: the frontend must shed, sheds must hit the lower classes
    /// only, and completions + sheds must cover every request exactly once.
    #[test]
    fn drop_policy_sheds_low_classes_and_conserves_requests() {
        let model = ModelConfig::qwen3_4b();
        let mut reqs: Vec<Request> = (0..12)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len: 2048,
                output_len: 8,
                prefix: crate::serving::request::Prefix::default(),
                priority: match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Low,
                    _ => Priority::Normal,
                },
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Drop, 1);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        let shed = cm.shed_requests() as usize;
        assert!(shed > 0, "cap 1 under a 12-request burst must shed");
        assert_eq!(cm.n_requests() + shed, 12);
        // High is never shed; every High request completes.
        assert_eq!(cm.control.shed_by_class[Priority::High.index()], 0);
        let agg = cm.aggregate();
        assert_eq!(agg.n_requests_of(Priority::High), 4);
        assert_eq!(agg.control.shed_requests, cm.control.shed_requests);
    }

    /// Defer re-times arrivals instead of dropping them outright; under a
    /// transient burst everything still completes (possibly after
    /// deferrals), and sustained overload degrades to sheds rather than
    /// recycling arrivals forever.
    #[test]
    fn defer_policy_retries_then_completes_or_sheds() {
        let model = ModelConfig::qwen3_4b();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len: 2048,
                output_len: 8,
                prefix: crate::serving::request::Prefix::default(),
                priority: if i % 2 == 0 {
                    Priority::Normal
                } else {
                    Priority::Low
                },
            })
            .collect();
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Defer, 2);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert!(cm.control.deferrals > 0, "cap 2 burst must defer");
        assert_eq!(cm.n_requests() + cm.shed_requests() as usize, 8);
    }

    /// `ShedPolicy::None` leaves the run bit-identical to a driver build
    /// that never had admission control (the golden suite pins the default
    /// byte-stream; this pins it at the config level).
    #[test]
    fn shed_none_matches_the_legacy_admission_path() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6).with_seed(11);
        let reqs = request::generate(&w);
        let base = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let a = simulate_cluster_requests(&base, &model, reqs.clone()).unwrap();
        // Same config built through the builder with shedding explicitly
        // off must agree record for record.
        let b_cfg = base.clone().with_shed(ShedPolicy::None, 1);
        let b = simulate_cluster_requests(&b_cfg, &model, reqs).unwrap();
        assert_eq!(a.aggregate().records(), b.aggregate().records());
        assert_eq!(a.control, b.control);
        assert_eq!(a.control.shed_requests, 0);
    }

    #[test]
    fn mixed_scheduler_cluster_requires_matching_lengths() {
        let model = ModelConfig::qwen3_4b();
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::RoundRobin,
        );
        let err = simulate_cluster_mixed(&cfg, &model, Vec::new(), Vec::new());
        assert!(err.is_err());
    }
}
