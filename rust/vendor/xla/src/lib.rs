//! API-compatible **stub** of the `xla` crate (PJRT bindings).
//!
//! The offline build environment has no XLA/PJRT shared libraries, so this
//! stub provides the exact type/function surface `npusim::runtime` and
//! `npusim::coordinator` use. Every execution entry point returns an
//! "unavailable" error at run time; pure-metadata helpers ([`Literal`]
//! shape bookkeeping) behave faithfully so shape-validation code and its
//! tests work. Swap this path dependency for the real `xla` crate to run
//! the AOT artifacts.

use std::fmt;

/// Stub error type (mirrors `xla::Error` closely enough for `?`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime unavailable — this build uses the in-tree \
         stub (rust/vendor/xla); vendor the real `xla` crate to enable it"
    )))
}

/// Element types (only the ones the repository converts to).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// A host-side literal. The stub tracks only the element count so shape
/// checks (`vec1(..).reshape(..)`) behave like the real crate.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
}

impl Literal {
    /// Rank-1 literal from a data slice.
    pub fn vec1<T>(data: &[T]) -> Literal {
        Literal { elems: data.len() }
    }

    /// Rank-0 literal.
    pub fn scalar<T>(_value: T) -> Literal {
        Literal { elems: 1 }
    }

    /// Reshape; errors when the element counts disagree (as the real crate
    /// does).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n >= 0 && n as usize == self.elems {
            Ok(self.clone())
        } else {
            Err(Error(format!(
                "cannot reshape a literal of {} elements to {dims:?}",
                self.elems
            )))
        }
    }

    /// Split a tuple literal into its elements (unavailable in the stub).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    /// Convert to another element type (unavailable in the stub).
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable("Literal::convert")
    }

    /// Copy out as a typed vector (unavailable in the stub).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (never constructible through the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("HloModuleProto::from_text_file({path:?})"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-side buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A PJRT client. `cpu()` fails in the stub, so nothing downstream ever
/// holds a live client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3]).is_err());
        assert!(Literal::scalar(7i32).reshape(&[1]).is_ok());
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::vec1(&[0i32]).to_vec::<i32>().unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }
}
