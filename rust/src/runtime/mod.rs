//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO **text**,
//! see `python/compile/aot.py`) and execute them from rust — Python never
//! runs on the request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per model entry point (prefill, decode step).

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory (built by `make artifacts`).
pub const ARTIFACT_DIR: &str = "artifacts";

/// Shape metadata for the tiny AOT model, parsed from the sidecar
/// `model_meta.txt` the exporter writes next to the HLO.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub max_seq: usize,
    /// Fixed prefill length the prefill entry point was lowered for.
    pub prefill_len: usize,
    /// Fixed batch the decode entry point was lowered for.
    pub decode_batch: usize,
}

impl ModelMeta {
    /// Parse `key=value` lines.
    pub fn parse(text: &str) -> Result<ModelMeta> {
        let get = |k: &str| -> Result<usize> {
            text.lines()
                .find_map(|l| l.strip_prefix(&format!("{k}=")))
                .with_context(|| format!("missing key {k} in model_meta"))?
                .trim()
                .parse()
                .with_context(|| format!("bad value for {k}"))
        };
        Ok(ModelMeta {
            vocab: get("vocab")?,
            hidden: get("hidden")?,
            layers: get("layers")?,
            heads: get("heads")?,
            kv_heads: get("kv_heads")?,
            head_dim: get("head_dim")?,
            intermediate: get("intermediate")?,
            max_seq: get("max_seq")?,
            prefill_len: get("prefill_len")?,
            decode_batch: get("decode_batch")?,
        })
    }

    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("model_meta.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        Self::parse(&text)
    }

    /// KV cache elements per layer:
    /// `2 (k/v) × batch × max_seq × kv_heads × head_dim`.
    pub fn kv_elems(&self) -> usize {
        2 * self.decode_batch * self.max_seq * self.kv_heads * self.head_dim
    }
}

/// A compiled model entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The PJRT runtime: client + compiled entry points.
pub struct Runtime {
    client: xla::PjRtClient,
    pub meta: ModelMeta,
    pub prefill: Executable,
    pub decode: Executable,
}

impl Runtime {
    /// Load + compile every artifact under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let meta = ModelMeta::load(dir)?;
        let prefill = Self::compile_one(&client, &dir.join("prefill.hlo.txt"))?;
        let decode = Self::compile_one(&client, &dir.join("decode.hlo.txt"))?;
        crate::log_info!(
            "runtime: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            meta,
            prefill,
            decode,
        })
    }

    fn compile_one(client: &xla::PjRtClient, path: &PathBuf) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("model")
                .to_string(),
        })
    }

    /// Execute an entry point, returning every output buffer flattened to
    /// `Vec<f32>`. The lowered computations return a tuple
    /// `(logits, kv_cache)` — see `aot.py`.
    pub fn execute(&self, exe: &Executable, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = exe
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", exe.name))?;
        let mut literal = result[0][0]
            .to_literal_sync()
            .context("fetching result buffer")?;
        let tuple = literal.decompose_tuple().context("decomposing tuple")?;
        tuple
            .into_iter()
            .map(|l| {
                let l = l
                    .convert(xla::PrimitiveType::F32)
                    .context("converting output to f32")?;
                l.to_vec::<f32>().context("reading output buffer")
            })
            .collect()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

/// Build an f32 literal of `shape` from data.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

/// Build an i32 literal of `shape` from data.
pub fn literal_i32(data: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

/// Greedy argmax over a logits row.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses_and_derives() {
        let text = "vocab=256\nhidden=64\nlayers=2\nheads=4\nkv_heads=2\nhead_dim=16\nintermediate=128\nmax_seq=64\nprefill_len=16\ndecode_batch=2\n";
        let m = ModelMeta::parse(text).unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.kv_elems(), 2 * 2 * 64 * 2 * 16);
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(ModelMeta::parse("vocab=256\n").is_err());
    }

    #[test]
    fn argmax_picks_peak() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 2.9]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    // Runtime::load is exercised by `rust/tests/runtime_e2e.rs` (needs
    // `make artifacts`).
}
