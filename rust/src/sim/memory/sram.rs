//! SRAM scratchpad port model.
//!
//! Compute operators account for their own SRAM traffic analytically inside
//! the roofline of [`crate::sim::compute`]; this port models the *shared*
//! traffic that competes with compute — DMA spills to HBM and NoC
//! send/receive staging — as a bandwidth timeline.

use crate::config::{ChipConfig, CoreConfig};
use crate::sim::engine::Timeline;
use crate::util::units::Cycle;

/// One core's SRAM port for DMA/NoC staging traffic.
#[derive(Debug)]
pub struct SramPort {
    timeline: Timeline,
    bytes_per_cycle: f64,
    capacity: u64,
}

impl SramPort {
    pub fn new(chip: &ChipConfig, core: &CoreConfig) -> Self {
        SramPort {
            timeline: Timeline::new(),
            bytes_per_cycle: core.sram_bytes_per_cycle(chip.freq_mhz),
            capacity: core.sram_bytes,
        }
    }

    /// Move `bytes` through the port starting no earlier than `earliest`;
    /// returns completion cycle.
    pub fn transfer(&mut self, earliest: Cycle, bytes: u64) -> Cycle {
        if bytes == 0 {
            return earliest;
        }
        let cycles = (bytes as f64 / self.bytes_per_cycle).ceil().max(1.0) as Cycle;
        let start = self.timeline.reserve(earliest, cycles);
        start + cycles
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn busy_cycles(&self) -> Cycle {
        self.timeline.busy_cycles()
    }

    pub fn reset(&mut self) {
        self.timeline.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    #[test]
    fn transfer_time_matches_bandwidth() {
        let chip = ChipConfig::large_core();
        let mut p = SramPort::new(&chip, &chip.core);
        // 256 GB/s @ 500 MHz = 512 B/cycle; 5120 bytes -> 10 cycles.
        assert_eq!(p.transfer(0, 5120), 10);
    }

    #[test]
    fn transfers_serialize() {
        let chip = ChipConfig::large_core();
        let mut p = SramPort::new(&chip, &chip.core);
        let t1 = p.transfer(0, 5120);
        let t2 = p.transfer(0, 5120);
        assert_eq!(t2, t1 + 10);
    }

    #[test]
    fn zero_bytes_noop() {
        let chip = ChipConfig::large_core();
        let mut p = SramPort::new(&chip, &chip.core);
        assert_eq!(p.transfer(7, 0), 7);
        assert_eq!(p.busy_cycles(), 0);
    }
}
