//! Randomized cross-stack invariants: whatever the workload, chip shape
//! and scheduler configuration, the serving engines must preserve these.

use npusim::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, PriorityMix, WorkloadConfig};
use npusim::serving::cluster::{self, ClusterConfig, RouterPolicy, ShedPolicy};
use npusim::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::serving::request;
use npusim::serving::scheduler::{self, HybridConfig, HybridScheduler, SchedulerConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::prop::check;

fn random_workload(rng: &mut npusim::util::rng::Rng) -> WorkloadConfig {
    let n = rng.range(1, 5);
    let mut w = WorkloadConfig::fixed_ratio(rng.range(8, 200), rng.range(1, 24), n);
    if rng.chance(0.5) {
        w.input_len = LenDist::Uniform(8, 256);
        w.output_len = LenDist::Uniform(1, 16);
    }
    if rng.chance(0.5) {
        w = w.with_arrival(ArrivalProcess::Poisson {
            rate: rng.range_f64(0.5, 8.0),
        });
    }
    w.with_seed(rng.next_u64())
}

#[test]
fn fusion_invariants_hold_for_random_workloads() {
    check("fusion invariants", 12, |rng| {
        let w = random_workload(rng);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let cfg = FusionConfig {
            tp: *rng.choose(&[4usize, 8, 16]),
            stages: *rng.choose(&[1usize, 2, 4]),
            chunk: *rng.choose(&[64usize, 256]),
            budget: 288,
            ..FusionConfig::default()
        };
        let m = simulate_fusion(&mut chip, &ModelConfig::qwen3_4b(), &w, &cfg)
            .expect("fusion run failed");
        // 1. Every request completes exactly once.
        assert_eq!(m.n_requests(), w.n_requests);
        let mut ids: Vec<u64> = m.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.n_requests);
        // 2. Causality per request.
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
            assert!(r.output_tokens >= 1);
        }
        // 3. The chip did work and clocks are consistent.
        assert!(chip.makespan() >= m.makespan());
    });
}

#[test]
fn disagg_invariants_hold_for_random_workloads() {
    check("disagg invariants", 10, |rng| {
        let w = random_workload(rng);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let (p, d, stages) = *rng.choose(&[(49, 14, 7), (42, 21, 6), (28, 28, 4), (21, 42, 3)]);
        let cfg = DisaggConfig {
            max_decode_batch: rng.range(2, 32),
            ..DisaggConfig::ratio_64(p, d, stages)
        };
        let m = simulate_disagg(&mut chip, &ModelConfig::qwen3_4b(), &w, &cfg)
            .expect("disagg run failed");
        assert_eq!(m.n_requests(), w.n_requests);
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
    });
}

#[test]
fn schedulers_agree_on_total_output_tokens() {
    check("token conservation", 8, |rng| {
        let w = random_workload(rng);
        let expect: u64 = npusim::serving::request::generate(&w)
            .iter()
            .map(|r| r.output_len as u64)
            .sum();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mf = simulate_fusion(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            &w,
            &FusionConfig::default(),
        )
        .unwrap();
        let got: u64 = mf.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(got, expect, "fusion lost/invented tokens");
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let md = simulate_disagg(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            &w,
            &DisaggConfig::p42_d21(),
        )
        .unwrap();
        let got: u64 = md.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(got, expect, "disagg lost/invented tokens");
    });
}

/// Staggered arrivals + mixed priorities + a tiny batch: the shape that
/// makes high-priority prefills land while low-priority decodes hold the
/// slots, so the preemption/park/resume path actually runs.
fn contended_priority_workload(rng: &mut npusim::util::rng::Rng) -> WorkloadConfig {
    let n = rng.range(6, 16);
    let mut w = WorkloadConfig::fixed_ratio(rng.range(16, 96), rng.range(4, 24), n);
    w.input_len = LenDist::Uniform(16, 128);
    w.output_len = LenDist::Uniform(4, 32);
    w.with_arrival(ArrivalProcess::Poisson {
        rate: rng.range_f64(20.0, 200.0),
    })
    .with_priority_mix(PriorityMix {
        high: rng.range_f64(0.2, 0.4),
        low: rng.range_f64(0.2, 0.4),
    })
    .with_seed(rng.next_u64())
}

#[test]
fn preemption_preserves_token_counts_and_exactly_once_completion() {
    use std::sync::atomic::{AtomicU64, Ordering};
    // Accumulated across cases so we can prove the machinery engaged at
    // least once without demanding it per random case.
    let preemptions = AtomicU64::new(0);
    let resumes = AtomicU64::new(0);
    check("preempt/resume conservation", 10, |rng| {
        let w = contended_priority_workload(rng);
        let reqs = request::generate(&w);
        let expect: Vec<(u64, u64)> = reqs.iter().map(|r| (r.id, r.output_len as u64)).collect();
        let cfg = FusionConfig {
            tp: 16,
            stages: *rng.choose(&[1usize, 2]),
            max_batch: *rng.choose(&[1usize, 2]),
            ..FusionConfig::default()
        };
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let m = if rng.chance(0.5) {
            simulate_fusion(&mut chip, &ModelConfig::qwen3_4b(), &w, &cfg).unwrap()
        } else {
            let mut sched = HybridScheduler::new(HybridConfig {
                fusion: cfg,
                ..HybridConfig::default()
            });
            scheduler::simulate(&mut chip, &ModelConfig::qwen3_4b(), &w, &mut sched).unwrap()
        };
        // Exactly-once completion, and a preempted-then-resumed request
        // emits exactly its original token count.
        assert_eq!(m.n_requests(), w.n_requests);
        let mut got: Vec<(u64, u64)> = m.records().iter().map(|r| (r.id, r.output_tokens)).collect();
        got.sort_unstable();
        assert_eq!(got, expect, "token counts changed under preemption");
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
        // Every park has a matching un-park: nothing ends stranded.
        assert_eq!(m.control.preemptions, m.control.resumes, "parked KV leaked");
        preemptions.fetch_add(m.control.preemptions, Ordering::Relaxed);
        resumes.fetch_add(m.control.resumes, Ordering::Relaxed);
    });
    assert!(
        preemptions.into_inner() > 0 && resumes.into_inner() > 0,
        "no case ever preempted: the property never exercised the machinery"
    );
}

#[test]
fn shed_requests_never_complete_and_counts_conserve() {
    use std::sync::atomic::{AtomicU64, Ordering};
    let total_shed = AtomicU64::new(0);
    check("shed conservation", 8, |rng| {
        let mut w = contended_priority_workload(rng);
        // Longer prompts so a 2-chip cluster with a unit queue cap is
        // decisively saturated by the arrival burst.
        w.input_len = LenDist::Uniform(256, 1024);
        let reqs = request::generate(&w);
        let offered: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let shed_policy = *rng.choose(&[ShedPolicy::Drop, ShedPolicy::Defer]);
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig {
                tp: 16,
                stages: 2,
                ..FusionConfig::default()
            }),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(shed_policy, rng.range(1, 3));
        let cm = cluster::simulate_cluster_requests(&cfg, &ModelConfig::qwen3_4b(), reqs).unwrap();
        let agg = cm.aggregate();
        // Shed and completed partition the offered set: every completion
        // is an offered id, completed exactly once, and the counts add up.
        let mut ids: Vec<u64> = agg.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let mut deduped = ids.clone();
        deduped.dedup();
        assert_eq!(ids.len(), deduped.len(), "a request completed twice");
        assert!(ids.iter().all(|id| offered.contains(id)));
        assert_eq!(
            ids.len() as u64 + agg.control.shed_requests,
            offered.len() as u64,
            "completed + shed != offered"
        );
        // High-priority work is never shed, whatever the policy.
        assert_eq!(agg.control.shed_by_class[2], 0, "shed a high-priority request");
        assert_eq!(
            agg.control.shed_by_class.iter().sum::<u64>(),
            agg.control.shed_requests
        );
        total_shed.fetch_add(agg.control.shed_requests, Ordering::Relaxed);
    });
    assert!(
        total_shed.into_inner() > 0,
        "no case ever shed: the property never exercised the admission check"
    );
}

#[test]
fn fleet_disaggregation_preserves_per_request_token_counts() {
    use npusim::parallel::plan::ChipRole;
    use npusim::serving::fleet::{ChipSpec, FleetSpec};
    use std::sync::atomic::{AtomicU64, Ordering};
    let total_handoffs = AtomicU64::new(0);
    check("fleet handoff conservation", 8, |rng| {
        // Mixed prompt/output lengths (including single-token outputs,
        // which must stay whole on the prefill side) over a random
        // prefill/decode staffing split.
        let n = rng.range(4, 12);
        let mut w = WorkloadConfig::fixed_ratio(256, 8, n);
        w.input_len = LenDist::Uniform(64, 512);
        w.output_len = LenDist::Uniform(1, 32);
        let w = w
            .with_arrival(ArrivalProcess::Poisson {
                rate: rng.range_f64(2.0, 40.0),
            })
            .with_seed(rng.next_u64());
        let reqs = request::generate(&w);
        let mut expect: Vec<(u64, u64, u64)> = reqs
            .iter()
            .map(|r| (r.id, r.input_len as u64, r.output_len as u64))
            .collect();
        expect.sort_unstable();
        let sched = SchedulerConfig::Fusion(FusionConfig {
            tp: 16,
            stages: 2,
            prefix_cache: true,
            ..FusionConfig::default()
        });
        let (n_prefill, n_decode) = *rng.choose(&[(1usize, 1usize), (2, 1), (1, 2)]);
        let mut chips = Vec::new();
        for _ in 0..n_prefill {
            chips.push(
                ChipSpec::new(ChipConfig::prefill_optimized(), sched).with_role(ChipRole::Prefill),
            );
        }
        for _ in 0..n_decode {
            chips.push(
                ChipSpec::new(ChipConfig::decode_optimized(), sched).with_role(ChipRole::Decode),
            );
        }
        let cfg = ClusterConfig::builder(FleetSpec::new(chips))
            .router(RouterPolicy::LeastLoaded)
            .build();
        let cm =
            cluster::simulate_cluster_requests(&cfg, &ModelConfig::qwen3_4b(), reqs).unwrap();
        // No shed policy is armed, so exactly-once means every offered
        // request completes...
        assert!(cm.conserves(expect.len()));
        assert_eq!(cm.shed_requests(), 0);
        // ...and each merged record carries exactly its offered token
        // counts: the prefill→decode split neither loses, duplicates,
        // nor re-attributes a single token.
        let agg = cm.aggregate();
        let mut got: Vec<(u64, u64, u64)> = agg
            .records()
            .iter()
            .map(|r| (r.id, r.input_tokens, r.output_tokens))
            .collect();
        got.sort_unstable();
        assert_eq!(got, expect, "token counts drifted across the fleet handoff");
        for r in agg.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
        total_handoffs.fetch_add(cm.handoffs, Ordering::Relaxed);
    });
    assert!(
        total_handoffs.into_inner() > 0,
        "no case ever handed off: the property never exercised the fleet split"
    );
}

#[test]
fn parallel_stepping_is_byte_identical_across_thread_counts() {
    use npusim::serving::faults::{FaultEvent, FaultKind, FaultSchedule, RecoveryPolicy};
    use npusim::serving::fleet::FleetSpec;
    // The conservative-window parallel scheduler must reproduce the
    // sequential schedule byte-for-byte at every worker thread count —
    // across routers (the PR-3 golden-vector scenarios) and under a
    // seeded mid-trace chip crash with recovery.
    let model = ModelConfig::qwen3_4b();
    let sched = SchedulerConfig::Fusion(FusionConfig {
        tp: 16,
        stages: 2,
        prefix_cache: true,
        ..FusionConfig::default()
    });
    let run = |router: RouterPolicy, faults: Option<FaultSchedule>, threads: usize| {
        let mut b = ClusterConfig::builder(FleetSpec::homogeneous(
            ChipConfig::large_core(),
            4,
            sched,
        ))
        .router(router)
        .sim_threads(threads);
        if let Some(f) = faults {
            b = b.faults(f);
        }
        let w = WorkloadConfig::sharegpt_like(12).with_seed(2025);
        let cm = cluster::simulate_cluster(&b.build(), &model, &w).unwrap();
        format!("{cm:?}")
    };
    let crash = || {
        Some(
            FaultSchedule::new(vec![FaultEvent {
                at_s: 0.05,
                chip: 1,
                kind: FaultKind::ChipCrash {
                    restart_after_s: Some(0.2),
                },
            }])
            .with_retries(6, 0.002)
            .with_recovery(RecoveryPolicy::Recover),
        )
    };
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::PrefixAware,
    ] {
        let seq = run(router, None, 1);
        for threads in [2usize, 8] {
            assert_eq!(
                seq,
                run(router, None, threads),
                "{router:?} diverged at {threads} sim threads"
            );
        }
    }
    let seq = run(RouterPolicy::LeastLoaded, crash(), 1);
    for threads in [2usize, 8] {
        assert_eq!(
            seq,
            run(RouterPolicy::LeastLoaded, crash(), threads),
            "seeded-fault scenario diverged at {threads} sim threads"
        );
    }
}

#[test]
fn simulated_time_is_monotone_in_workload_size() {
    check("monotone makespan", 6, |rng| {
        let base_n = rng.range(1, 3);
        let mk = |n: usize, seed: u64| {
            let w = WorkloadConfig::fixed_ratio(64, 8, n).with_seed(seed);
            let mut chip = ChipSim::new(ChipConfig::large_core());
            simulate_fusion(
                &mut chip,
                &ModelConfig::qwen3_4b(),
                &w,
                &FusionConfig::default(),
            )
            .unwrap()
            .makespan()
        };
        let seed = rng.next_u64();
        assert!(mk(base_n, seed) <= mk(base_n * 4, seed));
    });
}
