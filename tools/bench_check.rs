//! CI bench gate: compare `BENCH_serving.json` against the committed
//! `BENCH_baseline.json` and fail on regression.
//!
//! ```text
//! bench_check <current.json> <baseline.json> [--tolerance 0.15]
//! ```
//!
//! Three layers of gating, all simulated (machine-independent) metrics —
//! wall-clock fields are deliberately ignored:
//!
//! 1. **Structure**: the current file must contain the full prefix-cache
//!    grid (3 schedulers × cache on/off), the full cluster grid
//!    (shared-prefix + poisson workloads × fusion/disagg/hybrid ×
//!    rr/least/prefix routers on ≥ 2 chips), the tier ablation
//!    (sram-only / hbm-tier / two-tier+noc), the deployment-plan
//!    study (one auto row plus the named presets), the overload
//!    control-plane study (fifo / drop / defer admission policies), the
//!    fault study (none / crash_recover / crash_resubmit / degrade
//!    scenarios on a ≥ 4-chip fleet), the fleet-specialization study
//!    (homog-fused / fleet-planned / fleet-planned-crash at one equal
//!    chip count), the two-speed simulation study (txn / txn-par8 /
//!    fast rows on a ≥ 16-chip fleet), and the speculative-decoding
//!    study (vanilla / g4-a0.80 / g8-a0.95 / g4-a0.80+preempt rows).
//! 2. **Invariants**: on the shared-prefix workload the prefix-hit-aware
//!    router must beat round-robin on TTFT p50 for the fusion system (the
//!    cluster acceptance property), cache-on must not lose TTFT, the
//!    two-tier configuration must skip strictly more prefill tokens than
//!    SRAM-only caching (cross-pipe/HBM hits replace recomputation), the
//!    auto plan's simulated wall-clock must not exceed the worst
//!    enumerated preset's (the planner may not pick a known-bad
//!    deployment), and under the 2x flash crowd the priority+shed
//!    control plane must strictly beat the FIFO/no-shed baseline on
//!    goodput-under-SLO while conserving requests (completed + shed =
//!    offered, FIFO shedding nothing). The fault study adds exactly-once
//!    under faults (completed + shed = offered in every scenario, with a
//!    crash actually injected), frontend recovery strictly beating
//!    client-timeout resubmission on goodput-under-SLO, and the bounded
//!    single-chip-crash degradation (crash_recover goodput ≥ healthy ×
//!    (1 − 2/chips − 0.35)). The fleet study adds the specialization
//!    property — the planned heterogeneous fleet is disaggregated,
//!    performs cross-chip KV handoffs, and strictly beats the
//!    homogeneous fused fleet on goodput-under-SLO at equal chip
//!    count — and exactly-once across the prefill→decode handoff
//!    (completed + shed = offered with exact per-request token counts
//!    in every fleet scenario, including under a decode-chip crash).
//!    The scale study adds the two-speed tolerance gate: the calibrated
//!    analytic fast path must be strictly faster than the
//!    transaction-level reference (`speedup` > 1) while landing its
//!    TTFT, TBT and goodput-under-SLO within ±10% of it, the parallel
//!    txn-par8 row must report metrics identical to sequential txn
//!    (conservative-window stepping is bit-exact by construction), and
//!    every level must conserve requests (completed + shed = offered).
//!    The spec study adds the speculative-decoding properties: every
//!    row — the preemption-under-speculation one included — conserves
//!    requests (completed + shed = offered) and commits exactly the
//!    expected decode tokens (`tokens_exact`), gamma=4/accept=0.8 must
//!    strictly beat vanilla decode on TBT p50, goodput-under-SLO and
//!    tokens-per-weight-stream (the modeled HBM amortization win), at
//!    least one row's verify batches must cross the learned Fig. 9
//!    M-threshold (the K→MN partition flip), and the `+preempt` row
//!    must actually preempt mid-speculation.
//! 3. **Numbers**: `tokens_per_s` must not drop, and `ttft_p99_s` must
//!    not rise, by more than the tolerance against the matching baseline
//!    row. A baseline marked `"provisional": true` skips this layer (the
//!    numeric baseline is then bootstrapped by the next refresh:
//!    `cargo run --release -p npusim -- experiment bench --fast &&
//!    cp BENCH_serving.json BENCH_baseline.json`).

use npusim::util::minijson::{self, Json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => println!("bench_check: OK"),
        Err(e) => {
            eprintln!("bench_check: FAIL\n{e:#}");
            std::process::exit(1);
        }
    }
}

fn load(path: &str) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    minijson::parse(&text).map_err(|e| e.context(format!("parsing {path}")))
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let mut positional: Vec<&String> = Vec::new();
    let mut tolerance = 0.15f64;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tolerance" {
            tolerance = args
                .get(i + 1)
                .and_then(|v| v.parse::<f64>().ok())
                .ok_or_else(|| anyhow::anyhow!("--tolerance needs a number"))?;
            i += 2;
        } else if args[i].starts_with("--") {
            anyhow::bail!("unknown flag {}", args[i]);
        } else {
            positional.push(&args[i]);
            i += 1;
        }
    }
    anyhow::ensure!(
        positional.len() == 2,
        "usage: bench_check <current.json> <baseline.json> [--tolerance 0.15]"
    );
    let current = load(positional[0])?;
    let baseline = load(positional[1])?;

    let mut violations: Vec<String> = Vec::new();
    check_structure(&current, &mut violations);
    check_invariants(&current, &mut violations);
    if baseline
        .get("provisional")
        .and_then(|v| v.as_bool())
        .unwrap_or(false)
    {
        println!(
            "bench_check: baseline is provisional — structural + invariant gates only; \
             refresh it with `experiment bench --fast` and commit to arm the numeric gate"
        );
    } else {
        check_numbers(&current, &baseline, tolerance, &mut violations);
    }

    anyhow::ensure!(
        violations.is_empty(),
        "{} violation(s):\n  - {}",
        violations.len(),
        violations.join("\n  - ")
    );
    Ok(())
}

fn rows<'a>(j: &'a Json, key: &str) -> Vec<&'a Json> {
    j.get(key)
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().collect())
        .unwrap_or_default()
}

/// Find the cluster row for `(workload, sched, router)` at the smallest
/// chip count.
fn cluster_row<'a>(
    cluster: &[&'a Json],
    workload: &str,
    sched: &str,
    router: &str,
) -> Option<&'a Json> {
    cluster
        .iter()
        .filter(|r| {
            r.str("workload") == Some(workload)
                && r.str("sched") == Some(sched)
                && r.str("router") == Some(router)
        })
        .min_by_key(|r| r.num("chips").unwrap_or(f64::MAX) as u64)
        .copied()
}

fn check_structure(current: &Json, violations: &mut Vec<String>) {
    let prefix = rows(current, "prefix_cache");
    for system in ["fusion", "disagg", "hybrid"] {
        for cache_on in [false, true] {
            let found = prefix.iter().any(|r| {
                r.str("system") == Some(system)
                    && r.get("prefix_cache").and_then(|v| v.as_bool()) == Some(cache_on)
            });
            if !found {
                violations.push(format!(
                    "prefix_cache row missing: system={system} cache_on={cache_on}"
                ));
            }
        }
    }
    let cluster = rows(current, "cluster");
    for workload in ["shared-prefix", "poisson"] {
        for sched in ["fusion", "disagg", "hybrid"] {
            for router in ["rr", "least", "prefix"] {
                match cluster_row(&cluster, workload, sched, router) {
                    None => {
                        violations.push(format!("cluster row missing: {workload}/{sched}/{router}"))
                    }
                    Some(r) => {
                        if r.num("chips").unwrap_or(0.0) < 2.0 {
                            violations.push(format!(
                                "cluster row {workload}/{sched}/{router} runs on < 2 chips"
                            ));
                        }
                    }
                }
            }
        }
    }
    let tier = rows(current, "tier");
    for config in ["sram-only", "hbm-tier", "two-tier+noc"] {
        if !tier.iter().any(|r| r.str("config") == Some(config)) {
            violations.push(format!("tier row missing: {config}"));
        }
    }
    let plan = rows(current, "plan");
    if !plan
        .iter()
        .any(|r| r.get("auto").and_then(|v| v.as_bool()) == Some(true))
    {
        violations.push("plan section has no auto row".into());
    }
    for preset in ["fusion", "fusion-mn", "disagg"] {
        if !plan.iter().any(|r| r.str("plan") == Some(preset)) {
            violations.push(format!("plan row missing: {preset}"));
        }
    }
    let slo = rows(current, "slo");
    for policy in ["fifo", "drop", "defer"] {
        if !slo.iter().any(|r| r.str("policy") == Some(policy)) {
            violations.push(format!("slo row missing: {policy}"));
        }
    }
    let fault = rows(current, "fault");
    for scenario in ["none", "crash_recover", "crash_resubmit", "degrade"] {
        match fault_row(&fault, scenario) {
            None => violations.push(format!("fault row missing: {scenario}")),
            Some(r) => {
                if r.num("chips").unwrap_or(0.0) < 4.0 {
                    violations.push(format!("fault row {scenario} runs on < 4 chips"));
                }
            }
        }
    }
    let fleet = rows(current, "fleet");
    let mut fleet_chips: Option<u64> = None;
    for name in ["homog-fused", "fleet-planned", "fleet-planned-crash"] {
        match fleet_row(&fleet, name) {
            None => violations.push(format!("fleet row missing: {name}")),
            Some(r) => {
                let chips = r.num("chips").unwrap_or(0.0) as u64;
                if chips < 2 {
                    violations.push(format!("fleet row {name} runs on < 2 chips"));
                }
                // Specialization must be compared at equal chip count.
                match fleet_chips {
                    None => fleet_chips = Some(chips),
                    Some(c) if c != chips => violations.push(format!(
                        "fleet row {name} runs on {chips} chips, others on {c}"
                    )),
                    Some(_) => {}
                }
            }
        }
    }
    let scale = rows(current, "scale");
    for level in ["txn", "txn-par8", "fast"] {
        match scale_row(&scale, level) {
            None => violations.push(format!("scale row missing: {level}")),
            Some(r) => {
                if r.num("chips").unwrap_or(0.0) < 16.0 {
                    violations.push(format!("scale row {level} runs on < 16 chips"));
                }
            }
        }
    }
    let spec = rows(current, "spec");
    for policy in ["vanilla", "g4-a0.80", "g8-a0.95", "g4-a0.80+preempt"] {
        if spec_row(&spec, policy).is_none() {
            violations.push(format!("spec row missing: {policy}"));
        }
    }
}

/// The slo-section row of one admission policy.
fn slo_row<'a>(slo: &[&'a Json], policy: &str) -> Option<&'a Json> {
    slo.iter().find(|r| r.str("policy") == Some(policy)).copied()
}

/// The fault-section row of one scenario.
fn fault_row<'a>(fault: &[&'a Json], scenario: &str) -> Option<&'a Json> {
    fault
        .iter()
        .find(|r| r.str("scenario") == Some(scenario))
        .copied()
}

/// The fleet-section row of one fleet configuration.
fn fleet_row<'a>(fleet: &[&'a Json], name: &str) -> Option<&'a Json> {
    fleet.iter().find(|r| r.str("fleet") == Some(name)).copied()
}

/// The scale-section row of one simulation level.
fn scale_row<'a>(scale: &[&'a Json], level: &str) -> Option<&'a Json> {
    scale.iter().find(|r| r.str("level") == Some(level)).copied()
}

/// The spec-section row of one decode policy.
fn spec_row<'a>(spec: &[&'a Json], policy: &str) -> Option<&'a Json> {
    spec.iter().find(|r| r.str("policy") == Some(policy)).copied()
}

/// `prefill_tokens_skipped` of one tier-ablation row.
fn tier_skipped(tier: &[&Json], config: &str) -> Option<f64> {
    tier.iter()
        .find(|r| r.str("config") == Some(config))
        .and_then(|r| r.num("prefill_tokens_skipped"))
}

fn check_invariants(current: &Json, violations: &mut Vec<String>) {
    // The cluster acceptance property: hit-aware routing beats static
    // round-robin on median TTFT when there is something to hit.
    let cluster = rows(current, "cluster");
    let rr = cluster_row(&cluster, "shared-prefix", "fusion", "rr")
        .and_then(|r| r.num("ttft_p50_s"));
    let prefix = cluster_row(&cluster, "shared-prefix", "fusion", "prefix")
        .and_then(|r| r.num("ttft_p50_s"));
    match (rr, prefix) {
        (Some(rr), Some(prefix)) => {
            if prefix >= rr {
                violations.push(format!(
                    "prefix-aware router does not beat round-robin on shared-prefix \
                     fusion TTFT p50 ({prefix} vs {rr})"
                ));
            }
        }
        _ => violations.push("cannot evaluate prefix-vs-rr TTFT p50 invariant".into()),
    }
    // Prefix caching must not hurt mean TTFT on any scheduler.
    for system in ["fusion", "disagg", "hybrid"] {
        if let Some(cut) = current
            .get("ttft_reduction_pct")
            .and_then(|o| o.num(system))
        {
            if cut < 0.0 {
                violations.push(format!(
                    "prefix cache regressed {system} mean TTFT by {:.1}%",
                    -cut
                ));
            }
        }
    }
    // The tier acceptance property: two-tier + cross-pipe sharing must
    // replace recomputation that SRAM-only caching performs.
    let tier = rows(current, "tier");
    match (
        tier_skipped(&tier, "sram-only"),
        tier_skipped(&tier, "two-tier+noc"),
    ) {
        (Some(base), Some(two)) => {
            if two <= base {
                violations.push(format!(
                    "two-tier+noc does not skip more prefill than sram-only ({two} vs {base})"
                ));
            }
        }
        _ => violations.push("cannot evaluate two-tier-vs-sram-only skip invariant".into()),
    }
    // The planner acceptance property: the auto plan's simulated
    // wall-clock must not exceed the worst enumerated preset's.
    let plan = rows(current, "plan");
    let auto = plan
        .iter()
        .find(|r| r.get("auto").and_then(|v| v.as_bool()) == Some(true))
        .and_then(|r| r.num("sim_makespan_s"));
    let worst_preset = plan
        .iter()
        .filter(|r| r.get("auto").and_then(|v| v.as_bool()) == Some(false))
        .filter_map(|r| r.num("sim_makespan_s"))
        .fold(f64::NEG_INFINITY, f64::max);
    match auto {
        Some(auto) if worst_preset.is_finite() => {
            if auto > worst_preset {
                violations.push(format!(
                    "auto plan's simulated makespan {auto} exceeds the worst preset's \
                     {worst_preset}"
                ));
            }
        }
        _ => violations.push("cannot evaluate auto-plan-vs-worst-preset invariant".into()),
    }
    // The control-plane acceptance property: at 2x load, shedding +
    // priorities must strictly beat the FIFO/no-shed baseline on
    // goodput-under-SLO, and every policy must conserve requests.
    let slo = rows(current, "slo");
    match (
        slo_row(&slo, "fifo").and_then(|r| r.num("goodput_tok_s")),
        slo_row(&slo, "drop").and_then(|r| r.num("goodput_tok_s")),
    ) {
        (Some(fifo), Some(drop)) => {
            if drop <= fifo {
                violations.push(format!(
                    "shed/priority control plane does not beat FIFO on goodput-under-SLO \
                     ({drop} vs {fifo})"
                ));
            }
        }
        _ => violations.push("cannot evaluate shed-vs-fifo goodput invariant".into()),
    }
    for policy in ["fifo", "drop", "defer"] {
        let Some(r) = slo_row(&slo, policy) else { continue };
        let (offered, completed, shed) = (
            r.num("offered").unwrap_or(-1.0),
            r.num("completed").unwrap_or(-1.0),
            r.num("shed").unwrap_or(-1.0),
        );
        if completed + shed != offered {
            violations.push(format!(
                "slo {policy}: completed {completed} + shed {shed} != offered {offered}"
            ));
        }
        if policy == "fifo" && shed != 0.0 {
            violations.push(format!("slo fifo shed {shed} requests; must shed none"));
        }
    }
    // The fault-tolerance acceptance properties.
    let fault = rows(current, "fault");
    for scenario in ["none", "crash_recover", "crash_resubmit", "degrade"] {
        let Some(r) = fault_row(&fault, scenario) else { continue };
        // Exactly-once: a crash must strand nothing and duplicate nothing.
        let (offered, completed, shed) = (
            r.num("offered").unwrap_or(-1.0),
            r.num("completed").unwrap_or(-1.0),
            r.num("shed").unwrap_or(-1.0),
        );
        if completed + shed != offered {
            violations.push(format!(
                "fault {scenario}: completed {completed} + shed {shed} != offered {offered}"
            ));
        }
    }
    match (
        fault_row(&fault, "none"),
        fault_row(&fault, "crash_recover"),
        fault_row(&fault, "crash_resubmit"),
    ) {
        (Some(none), Some(rec), Some(res)) => {
            if rec.num("crashes").unwrap_or(0.0) < 1.0 {
                violations.push("fault crash_recover injected no crash".into());
            }
            if rec.num("recovered").unwrap_or(0.0) <= 0.0 {
                violations.push("fault crash_recover recovered no stranded requests".into());
            }
            let (g_none, g_rec, g_res) = (
                none.num("goodput_tok_s").unwrap_or(0.0),
                rec.num("goodput_tok_s").unwrap_or(0.0),
                res.num("goodput_tok_s").unwrap_or(0.0),
            );
            // Frontend recovery must strictly beat waiting out a client
            // timeout and resubmitting from scratch.
            if g_rec <= g_res {
                violations.push(format!(
                    "fault recovery does not beat drop-and-resubmit on goodput-under-SLO \
                     ({g_rec} vs {g_res})"
                ));
            }
            // Losing 1 of N chips costs at most its capacity share (~2/N,
            // accounting for queue shuffle) plus recovery overhead.
            let chips = rec.num("chips").unwrap_or(4.0).max(1.0);
            let floor = (1.0 - 2.0 / chips - 0.35).max(0.0);
            if g_rec < g_none * floor {
                violations.push(format!(
                    "single-chip crash degrades goodput below the bound: {g_rec} < \
                     {g_none} x {floor:.3}"
                ));
            }
        }
        _ => violations.push("cannot evaluate fault-recovery invariants".into()),
    }
    // The fleet-specialization acceptance properties.
    let fleet = rows(current, "fleet");
    for name in ["homog-fused", "fleet-planned", "fleet-planned-crash"] {
        let Some(r) = fleet_row(&fleet, name) else { continue };
        // Exactly-once across the prefill→decode handoff: splitting a
        // request into legs must neither lose nor duplicate it...
        let (offered, completed, shed) = (
            r.num("offered").unwrap_or(-1.0),
            r.num("completed").unwrap_or(-1.0),
            r.num("shed").unwrap_or(-1.0),
        );
        if completed + shed != offered {
            violations.push(format!(
                "fleet {name}: completed {completed} + shed {shed} != offered {offered}"
            ));
        }
        // ...nor drift a single token of any completed request.
        if r.get("tokens_exact").and_then(|v| v.as_bool()) != Some(true) {
            violations.push(format!(
                "fleet {name}: per-request token counts drifted across the handoff"
            ));
        }
    }
    match (
        fleet_row(&fleet, "homog-fused"),
        fleet_row(&fleet, "fleet-planned"),
        fleet_row(&fleet, "fleet-planned-crash"),
    ) {
        (Some(homog), Some(planned), Some(crash)) => {
            if homog.num("handoffs").unwrap_or(-1.0) != 0.0 {
                violations.push("fleet homog-fused performed cross-chip handoffs".into());
            }
            if planned.get("disaggregated").and_then(|v| v.as_bool()) != Some(true) {
                violations
                    .push("fleet planner did not specialize on the prefill-heavy mix".into());
            }
            if planned.num("handoffs").unwrap_or(0.0) < 1.0 {
                violations.push("fleet fleet-planned performed no cross-chip handoffs".into());
            }
            // Specialization must pay: the planned heterogeneous fleet
            // strictly beats the homogeneous fused fleet on
            // goodput-under-SLO at equal chip count.
            let (g_homog, g_planned) = (
                homog.num("goodput_tok_s").unwrap_or(0.0),
                planned.num("goodput_tok_s").unwrap_or(0.0),
            );
            if g_planned <= g_homog {
                violations.push(format!(
                    "planned fleet does not beat homogeneous fused on goodput-under-SLO \
                     ({g_planned} vs {g_homog})"
                ));
            }
            if crash.num("crashes").unwrap_or(0.0) < 1.0 {
                violations.push("fleet fleet-planned-crash injected no crash".into());
            }
        }
        _ => violations.push("cannot evaluate fleet-specialization invariants".into()),
    }
    // The two-speed simulation acceptance properties.
    let scale = rows(current, "scale");
    for level in ["txn", "txn-par8", "fast"] {
        let Some(r) = scale_row(&scale, level) else { continue };
        // Every simulation level must conserve requests exactly.
        let (offered, completed, shed) = (
            r.num("offered").unwrap_or(-1.0),
            r.num("completed").unwrap_or(-1.0),
            r.num("shed").unwrap_or(-1.0),
        );
        if completed + shed != offered {
            violations.push(format!(
                "scale {level}: completed {completed} + shed {shed} != offered {offered}"
            ));
        }
    }
    match (
        scale_row(&scale, "txn"),
        scale_row(&scale, "txn-par8"),
        scale_row(&scale, "fast"),
    ) {
        (Some(txn), Some(par), Some(fast)) => {
            // Parallel stepping must be bit-exact, not merely close: the
            // simulated metrics of the 8-thread run equal the sequential
            // run's to the last printed digit.
            for metric in ["events", "ttft_ms", "tbt_ms", "goodput_tok_s"] {
                let (p, t) = (par.num(metric), txn.num(metric));
                if p != t {
                    violations.push(format!(
                        "scale txn-par8 {metric} {p:?} != sequential txn {t:?} \
                         (parallel stepping must be bit-identical)"
                    ));
                }
            }
            // The calibrated surrogate must actually be faster...
            let speedup = fast.num("speedup").unwrap_or(0.0);
            if speedup <= 1.0 {
                violations.push(format!(
                    "scale fast path is not faster than transaction-level (speedup {speedup})"
                ));
            }
            // ...while staying inside the ±10% error band on every
            // user-visible metric.
            for metric in ["ttft_err", "tbt_err", "goodput_err"] {
                let err = fast.num(metric).unwrap_or(f64::INFINITY);
                if err > 0.10 {
                    violations.push(format!(
                        "scale fast-vs-txn {metric} {err} exceeds the 10% tolerance band"
                    ));
                }
            }
        }
        _ => violations.push("cannot evaluate two-speed simulation invariants".into()),
    }
    // The speculative-decoding acceptance properties.
    let spec = rows(current, "spec");
    for r in &spec {
        let policy = r.str("policy").unwrap_or("?");
        // Exact conservation in every row: speculation may neither lose
        // nor duplicate a request, and rollback may not drift a token.
        let (offered, completed, shed) = (
            r.num("offered").unwrap_or(-1.0),
            r.num("completed").unwrap_or(-1.0),
            r.num("shed").unwrap_or(-1.0),
        );
        if completed + shed != offered {
            violations.push(format!(
                "spec {policy}: completed {completed} + shed {shed} != offered {offered}"
            ));
        }
        if r.get("tokens_exact").and_then(|v| v.as_bool()) != Some(true) {
            violations.push(format!(
                "spec {policy}: decode did not commit exactly the expected tokens"
            ));
        }
    }
    match (spec_row(&spec, "vanilla"), spec_row(&spec, "g4-a0.80")) {
        (Some(vanilla), Some(g4)) => {
            if vanilla.num("verify_steps").unwrap_or(-1.0) != 0.0 {
                violations.push("spec vanilla ran verify iterations".into());
            }
            // The headline win must come from the modeled traffic:
            // strictly better TBT p50, goodput-under-SLO and
            // tokens-per-weight-stream than vanilla decode.
            let (v_tbt, s_tbt) = (
                vanilla.num("tbt_p50_ms").unwrap_or(0.0),
                g4.num("tbt_p50_ms").unwrap_or(f64::INFINITY),
            );
            if s_tbt >= v_tbt {
                violations.push(format!(
                    "spec g4-a0.80 does not beat vanilla on TBT p50 ({s_tbt} vs {v_tbt})"
                ));
            }
            let (v_good, s_good) = (
                vanilla.num("goodput_tok_s").unwrap_or(f64::INFINITY),
                g4.num("goodput_tok_s").unwrap_or(0.0),
            );
            if s_good <= v_good {
                violations.push(format!(
                    "spec g4-a0.80 does not beat vanilla on goodput-under-SLO \
                     ({s_good} vs {v_good})"
                ));
            }
            let (v_tws, s_tws) = (
                vanilla.num("tokens_per_weight_stream").unwrap_or(f64::INFINITY),
                g4.num("tokens_per_weight_stream").unwrap_or(0.0),
            );
            if s_tws <= v_tws {
                violations.push(format!(
                    "spec g4-a0.80 does not amortize the weight stream over vanilla \
                     ({s_tws} vs {v_tws} tokens/stream)"
                ));
            }
        }
        _ => violations.push("cannot evaluate spec-vs-vanilla invariants".into()),
    }
    // The Fig. 9 phase flip must actually fire: somewhere, a verify batch
    // crossed the learned M-threshold into the large-M MN partition.
    if !spec.is_empty()
        && !spec
            .iter()
            .any(|r| r.num("verify_above_threshold").unwrap_or(0.0) > 0.0)
    {
        violations.push(
            "no spec verify batch crossed the learned Fig. 9 M-threshold".into(),
        );
    }
    if let Some(preempt) = spec_row(&spec, "g4-a0.80+preempt") {
        if preempt.num("preemptions").unwrap_or(0.0) < 1.0 {
            violations.push("spec g4-a0.80+preempt never preempted mid-speculation".into());
        }
    }
}

/// One directional comparison: `cur` must not be worse than `base` by more
/// than `tol` (relative). `higher_is_better` picks the bad direction.
fn check_metric(
    what: &str,
    cur: Option<f64>,
    base: Option<f64>,
    tol: f64,
    higher_is_better: bool,
    violations: &mut Vec<String>,
) {
    let (Some(cur), Some(base)) = (cur, base) else {
        violations.push(format!("{what}: metric missing"));
        return;
    };
    // Both effectively zero: nothing to compare.
    if base.abs() < 1e-9 && cur.abs() < 1e-9 {
        return;
    }
    let denom = base.abs().max(1e-9);
    let drift = (cur - base) / denom;
    let bad = if higher_is_better { -drift } else { drift };
    if bad > tol {
        violations.push(format!(
            "{what}: {cur:.6} vs baseline {base:.6} ({:+.1}% drift exceeds {:.0}% tolerance)",
            drift * 100.0,
            tol * 100.0
        ));
    } else if bad < -tol {
        println!(
            "bench_check: note — {what} improved beyond tolerance \
             ({cur:.6} vs {base:.6}); consider refreshing the baseline"
        );
    }
}

fn check_numbers(current: &Json, baseline: &Json, tol: f64, violations: &mut Vec<String>) {
    // Prefix-cache grid: match rows on (system, cache flag).
    let cur_rows = rows(current, "prefix_cache");
    let base_rows = rows(baseline, "prefix_cache");
    for b in &base_rows {
        let (system, cache_on) = (
            b.str("system").unwrap_or(""),
            b.get("prefix_cache").and_then(|v| v.as_bool()),
        );
        let Some(c) = cur_rows.iter().find(|r| {
            r.str("system") == Some(system)
                && r.get("prefix_cache").and_then(|v| v.as_bool()) == cache_on
        }) else {
            violations.push(format!(
                "prefix_cache row disappeared: {system}/{cache_on:?}"
            ));
            continue;
        };
        let tag = format!("prefix_cache {system}/cache={}", cache_on.unwrap_or(false));
        check_metric(
            &format!("{tag} tokens_per_s"),
            c.num("tokens_per_s"),
            b.num("tokens_per_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("{tag} ttft_p99_s"),
            c.num("ttft_p99_s"),
            b.num("ttft_p99_s"),
            tol,
            false,
            violations,
        );
    }
    // Cluster grid: match rows on (workload, sched, router, chips).
    let cur_cluster = rows(current, "cluster");
    let base_cluster = rows(baseline, "cluster");
    for b in &base_cluster {
        let key = (
            b.str("workload").unwrap_or(""),
            b.str("sched").unwrap_or(""),
            b.str("router").unwrap_or(""),
            b.num("chips").unwrap_or(0.0) as u64,
        );
        let Some(c) = cur_cluster.iter().find(|r| {
            (
                r.str("workload").unwrap_or(""),
                r.str("sched").unwrap_or(""),
                r.str("router").unwrap_or(""),
                r.num("chips").unwrap_or(0.0) as u64,
            ) == key
        }) else {
            violations.push(format!("cluster row disappeared: {key:?}"));
            continue;
        };
        let tag = format!("cluster {}/{}/{}/{}", key.0, key.1, key.2, key.3);
        check_metric(
            &format!("{tag} tokens_per_s"),
            c.num("tokens_per_s"),
            b.num("tokens_per_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("{tag} ttft_p99_s"),
            c.num("ttft_p99_s"),
            b.num("ttft_p99_s"),
            tol,
            false,
            violations,
        );
    }
    // Plan study: match rows on the plan label.
    let cur_plan = rows(current, "plan");
    let base_plan = rows(baseline, "plan");
    for b in &base_plan {
        let label = b.str("plan").unwrap_or("");
        let Some(c) = cur_plan.iter().find(|r| r.str("plan") == Some(label)) else {
            violations.push(format!("plan row disappeared: {label}"));
            continue;
        };
        check_metric(
            &format!("plan {label} tokens_per_s"),
            c.num("tokens_per_s"),
            b.num("tokens_per_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("plan {label} sim_makespan_s"),
            c.num("sim_makespan_s"),
            b.num("sim_makespan_s"),
            tol,
            false,
            violations,
        );
    }
    // Tier ablation: match rows on config label.
    let cur_tier = rows(current, "tier");
    let base_tier = rows(baseline, "tier");
    for b in &base_tier {
        let config = b.str("config").unwrap_or("");
        let Some(c) = cur_tier.iter().find(|r| r.str("config") == Some(config)) else {
            violations.push(format!("tier row disappeared: {config}"));
            continue;
        };
        check_metric(
            &format!("tier {config} tokens_per_s"),
            c.num("tokens_per_s"),
            b.num("tokens_per_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("tier {config} ttft_p99_s"),
            c.num("ttft_p99_s"),
            b.num("ttft_p99_s"),
            tol,
            false,
            violations,
        );
    }
    // Overload control plane: match rows on the policy label.
    let cur_slo = rows(current, "slo");
    let base_slo = rows(baseline, "slo");
    for b in &base_slo {
        let policy = b.str("policy").unwrap_or("");
        let Some(c) = cur_slo.iter().find(|r| r.str("policy") == Some(policy)) else {
            violations.push(format!("slo row disappeared: {policy}"));
            continue;
        };
        check_metric(
            &format!("slo {policy} goodput_tok_s"),
            c.num("goodput_tok_s"),
            b.num("goodput_tok_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("slo {policy} ttft_p99_high_s"),
            c.num("ttft_p99_high_s"),
            b.num("ttft_p99_high_s"),
            tol,
            false,
            violations,
        );
    }
    // Fault study: match rows on the scenario label.
    let cur_fault = rows(current, "fault");
    let base_fault = rows(baseline, "fault");
    for b in &base_fault {
        let scenario = b.str("scenario").unwrap_or("");
        let Some(c) = cur_fault
            .iter()
            .find(|r| r.str("scenario") == Some(scenario))
        else {
            violations.push(format!("fault row disappeared: {scenario}"));
            continue;
        };
        check_metric(
            &format!("fault {scenario} goodput_tok_s"),
            c.num("goodput_tok_s"),
            b.num("goodput_tok_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("fault {scenario} mean_detect_s"),
            c.num("mean_detect_s"),
            b.num("mean_detect_s"),
            tol,
            false,
            violations,
        );
    }
    // Fleet study: match rows on the fleet label.
    let cur_fleet = rows(current, "fleet");
    let base_fleet = rows(baseline, "fleet");
    for b in &base_fleet {
        let name = b.str("fleet").unwrap_or("");
        let Some(c) = cur_fleet.iter().find(|r| r.str("fleet") == Some(name)) else {
            violations.push(format!("fleet row disappeared: {name}"));
            continue;
        };
        check_metric(
            &format!("fleet {name} goodput_tok_s"),
            c.num("goodput_tok_s"),
            b.num("goodput_tok_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("fleet {name} tokens_per_s"),
            c.num("tokens_per_s"),
            b.num("tokens_per_s"),
            tol,
            true,
            violations,
        );
    }
    // Scale study: match rows on the level label. Only the simulated
    // metrics are gated — wall_s / events_per_s / speedup are wall-clock
    // and machine-dependent (speedup's > 1 floor lives in the invariant
    // layer instead).
    let cur_scale = rows(current, "scale");
    let base_scale = rows(baseline, "scale");
    for b in &base_scale {
        let level = b.str("level").unwrap_or("");
        let Some(c) = cur_scale.iter().find(|r| r.str("level") == Some(level)) else {
            violations.push(format!("scale row disappeared: {level}"));
            continue;
        };
        check_metric(
            &format!("scale {level} goodput_tok_s"),
            c.num("goodput_tok_s"),
            b.num("goodput_tok_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("scale {level} ttft_ms"),
            c.num("ttft_ms"),
            b.num("ttft_ms"),
            tol,
            false,
            violations,
        );
    }
    // Spec study: match rows on the policy label.
    let cur_spec = rows(current, "spec");
    let base_spec = rows(baseline, "spec");
    for b in &base_spec {
        let policy = b.str("policy").unwrap_or("");
        let Some(c) = cur_spec.iter().find(|r| r.str("policy") == Some(policy)) else {
            violations.push(format!("spec row disappeared: {policy}"));
            continue;
        };
        check_metric(
            &format!("spec {policy} goodput_tok_s"),
            c.num("goodput_tok_s"),
            b.num("goodput_tok_s"),
            tol,
            true,
            violations,
        );
        check_metric(
            &format!("spec {policy} tbt_p50_ms"),
            c.num("tbt_p50_ms"),
            b.num("tbt_p50_ms"),
            tol,
            false,
            violations,
        );
    }
}
