//! Fig. 10 — core placement strategies (linear-seq / linear-interleave /
//! ring / 2-D mesh) at TP=4 (64-core chip) and TP=16 (256-core chip):
//! single-request latency.
//!
//! Placement quality manifests through the NoC channel-locking model: a
//! 2-hop logical neighbour holds two links per transfer, halving ring
//! bandwidth — which is why linear-interleave (optimal on Cerebras) loses
//! to ring/mesh here, matching the paper's §5.4 discussion.

use crate::config::{ChipConfig, ModelConfig};
use crate::experiments::Opts;
use crate::memmgr::planner::{plan, PlanRequest};
use crate::memmgr::KvCache;
use crate::model::exec::{run_iteration, ExecConfig};
use crate::model::{BatchItem, IterBatch};
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::placement::{Placement, Region, TpGroup};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};
use crate::util::units::cycles_to_ms;

/// One full-model pass (prefill + a few decode steps) with the TP group
/// arranged by `placement`.
pub fn request_latency_ms(
    chip_cfg: &ChipConfig,
    model: &ModelConfig,
    tp: usize,
    placement: Placement,
    seq: u64,
    decode_steps: u64,
) -> f64 {
    let mut chip = ChipSim::new(chip_cfg.clone());
    // The placement decides the region *shape* (Fig. 4): linear strategies
    // arrange the TP group along a line (pipe-shaped), ring/mesh fold the
    // same cores into a rectangle.
    let (r, c) = match placement {
        Placement::LinearSeq | Placement::LinearInterleave => (1, tp),
        Placement::Ring | Placement::Mesh2D => {
            crate::serving::layout::tp_rect(tp, chip_cfg.rows, chip_cfg.cols)
        }
    };
    let group = TpGroup::place(Region::new(0, 0, r, c), placement);
    // AllGather GEMMs stress the ring the hardest (weights rotate through
    // every rank) — the regime T10/WaferLLM designed these placements for.
    let strategy = if placement == Placement::Mesh2D && tp >= 4 {
        let rows = (1..=tp).rev().find(|x| tp % x == 0 && x * x <= tp).unwrap_or(1);
        PartitionStrategy::TwoDim { rows, cols: tp / rows }
    } else {
        PartitionStrategy::OneDimMN
    };
    let mut p = plan(
        &chip_cfg.core,
        model,
        &PlanRequest {
            layers: model.layers,
            tp,
            iter_tokens: seq as usize,
            kv_share: 0.5,
        },
    );
    // Placement study semantics (the T10/WaferLLM regime): weights are
    // SRAM-resident and *rotate over the NoC* — no HBM streaming, so the
    // figure isolates what placement controls. (With per-core HBM the
    // streaming time drowns the NoC entirely; Fig. 8 covers that axis.)
    p.weight_sram_bytes = p.shard_weight_bytes;
    p.weight_hbm_bytes = 0;
    let bpt = (model.kv_bytes_per_token_layer() * model.layers as u64 / tp as u64).max(1);
    let mut kv = KvCache::new(
        p.kv_bytes,
        16,
        chip_cfg.core.hbm_bytes,
        bpt,
        model.max_context as u64,
    );
    kv.admit(1);
    let exec = ExecConfig::new(strategy, model.layers, true);
    let mut t = run_iteration(
        &mut chip,
        &group,
        model,
        &p,
        &exec,
        &IterBatch::new(vec![BatchItem::prefill(1, seq, seq)]),
        &mut kv,
    );
    for s in 0..decode_steps {
        t = run_iteration(
            &mut chip,
            &group,
            model,
            &p,
            &exec,
            &IterBatch::new(vec![BatchItem::decode(1, seq + s + 1)]),
            &mut kv,
        );
    }
    cycles_to_ms(t, chip_cfg.freq_mhz)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let seq = opts.pick(2048, 512);
    let decode = opts.pick(8, 2);
    let cases: Vec<(&str, ChipConfig, usize)> = if opts.fast {
        vec![("TP=4 (64 cores)", ChipConfig::large_core(), 4)]
    } else {
        vec![
            ("TP=4 (64 cores)", ChipConfig::large_core(), 4),
            ("TP=16 (256 cores)", ChipConfig::small_core(), 16),
        ]
    };

    let mut tables = Vec::new();
    for (name, chip, tp) in cases {
        let mut t = Table::new(
            &format!("Fig 10 — {} single-request latency (ms) by placement", name),
            &["placement", "latency", "speedup vs linear-interleave"],
        );
        let base = request_latency_ms(&chip, &model, tp, Placement::LinearInterleave, seq, decode);
        for p in Placement::all() {
            let l = request_latency_ms(&chip, &model, tp, p, seq, decode);
            t.row(&[p.name().to_string(), f3(l), f3(base / l)]);
        }
        tables.push(t);
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_beats_linear_seq() {
        let chip = ChipConfig::large_core();
        let m = ModelConfig::qwen3_4b();
        let ring = request_latency_ms(&chip, &m, 4, Placement::Ring, 512, 0);
        let lin = request_latency_ms(&chip, &m, 4, Placement::LinearSeq, 512, 0);
        assert!(ring <= lin, "ring {ring} vs linear-seq {lin}");
    }

    #[test]
    fn ring_beats_interleave_under_channel_locking() {
        // The paper's §5.4 observation: with channel locking, interleaved
        // 2-hop transfers hold two links, so ring wins on this platform.
        let chip = ChipConfig::large_core();
        let m = ModelConfig::qwen3_4b();
        let ring = request_latency_ms(&chip, &m, 4, Placement::Ring, 2048, 0);
        let inter = request_latency_ms(&chip, &m, 4, Placement::LinearInterleave, 2048, 0);
        assert!(ring <= inter * 1.02, "ring {ring} vs interleave {inter}");
    }

    #[test]
    fn table_shape() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables[0].n_rows(), 4);
    }
}
