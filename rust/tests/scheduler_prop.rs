//! Randomized invariants for the unified scheduler layer, with emphasis on
//! the adaptive hybrid: whatever the workload, controller aggressiveness,
//! and pipeline shape, token conservation and per-request causality must
//! hold, and the scheduler-trait driver must agree with the legacy
//! wrapper entry points.

use npusim::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::serving::request;
use npusim::serving::scheduler::{self, HybridConfig, HybridScheduler};
use npusim::sim::chip::ChipSim;
use npusim::util::prop::check;

fn random_workload(rng: &mut npusim::util::rng::Rng) -> WorkloadConfig {
    let n = rng.range(1, 5);
    let mut w = WorkloadConfig::fixed_ratio(rng.range(8, 300), rng.range(1, 24), n);
    if rng.chance(0.5) {
        w.input_len = LenDist::Uniform(8, 512);
        w.output_len = LenDist::Uniform(1, 16);
    }
    if rng.chance(0.5) {
        w = w.with_arrival(ArrivalProcess::Poisson {
            rate: rng.range_f64(0.5, 8.0),
        });
    }
    w.with_seed(rng.next_u64())
}

fn random_hybrid_cfg(rng: &mut npusim::util::rng::Rng) -> HybridConfig {
    HybridConfig {
        fusion: FusionConfig {
            tp: *rng.choose(&[4usize, 8]),
            stages: *rng.choose(&[1usize, 2, 4]),
            chunk: *rng.choose(&[64usize, 256]),
            ..FusionConfig::default()
        },
        window: *rng.choose(&[2usize, 8, 32]),
        hysteresis: rng.range(1, 4),
        min_dwell: *rng.choose(&[0usize, 16, 128]),
        ..HybridConfig::default()
    }
}

#[test]
fn hybrid_conserves_tokens_under_random_workloads() {
    check("hybrid token conservation", 10, |rng| {
        let w = random_workload(rng);
        let expect: u64 = request::generate(&w)
            .iter()
            .map(|r| r.output_len as u64)
            .sum();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(random_hybrid_cfg(rng));
        let m = scheduler::simulate(&mut chip, &ModelConfig::qwen3_4b(), &w, &mut sched)
            .expect("hybrid run failed");
        // Every request completes exactly once; no token lost or invented
        // across prefill handoffs.
        assert_eq!(m.n_requests(), w.n_requests);
        let mut ids: Vec<u64> = m.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.n_requests);
        let got: u64 = m.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(got, expect, "hybrid lost/invented tokens");
    });
}

#[test]
fn hybrid_causality_holds_under_random_workloads() {
    check("hybrid causality", 10, |rng| {
        let w = random_workload(rng);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(random_hybrid_cfg(rng));
        let m = scheduler::simulate(&mut chip, &ModelConfig::qwen3_4b(), &w, &mut sched)
            .expect("hybrid run failed");
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
            assert!(r.output_tokens >= 1, "{r:?}");
        }
        // The chip's clocks must cover every recorded completion.
        assert!(chip.makespan() >= m.makespan());
    });
}

#[test]
fn trait_driver_agrees_with_legacy_fusion_wrapper() {
    check("trait vs wrapper", 6, |rng| {
        let w = random_workload(rng);
        let cfg = FusionConfig::default();
        let mut c1 = ChipSim::new(ChipConfig::large_core());
        let via_wrapper = simulate_fusion(&mut c1, &ModelConfig::qwen3_4b(), &w, &cfg).unwrap();
        let mut c2 = ChipSim::new(ChipConfig::large_core());
        let mut sched = scheduler::FusionScheduler::new(cfg);
        let via_trait =
            scheduler::simulate(&mut c2, &ModelConfig::qwen3_4b(), &w, &mut sched).unwrap();
        assert_eq!(via_wrapper.records(), via_trait.records());
        assert_eq!(c1.makespan(), c2.makespan());
    });
}

#[test]
fn hybrid_handles_burst_arrivals() {
    check("hybrid bursty arrivals", 6, |rng| {
        let n = rng.range(2, 8);
        // Trim tails so property cases stay quick.
        let mut w = WorkloadConfig::mooncake_like(n).with_seed(rng.next_u64());
        w.input_len = LenDist::Uniform(64, 1536);
        w.output_len = LenDist::Uniform(1, 32);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(random_hybrid_cfg(rng));
        let m = scheduler::simulate(&mut chip, &ModelConfig::qwen3_4b(), &w, &mut sched)
            .expect("hybrid bursty run failed");
        assert_eq!(m.n_requests(), n);
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
        }
    });
}
