//! First-class deployment planning: the [`DeploymentPlan`] every serving
//! constructor consumes, plus the **analytic auto-planner** that searches
//! the (TP strategy × placement × pipeline depth × PD mode) space for a
//! `(ChipConfig, ModelConfig, WorkloadConfig)` triple instead of
//! hardcoding the choices.
//!
//! The paper's headline speedups come from *selecting* the right tensor
//! partition, core placement, memory split, and PD organisation per
//! scenario (§4, §5.6) — the planner turns that selection into a search
//! problem over the analytic machinery that already exists in the tree:
//!
//! - **Collective cost** per GEMM from Table 2
//!   ([`crate::parallel::partition::partition_cost`]), scaled by the
//!   placement's physical hop count
//!   ([`crate::parallel::placement::TpGroup::max_ring_hop`]).
//! - **KV-transfer distance** for disaggregated candidates from
//!   [`crate::parallel::pd_placement::PdAssignment::mean_kv_distance`].
//! - **SRAM feasibility** (buffers fit, KV blocks exist, weight residency)
//!   from [`crate::memmgr::planner::plan`].
//!
//! Candidates are ranked by an estimated workload makespan in cycles
//! (prefill + decode service time plus, for disaggregation, the KV
//! transfer tax). The estimate is deliberately coarse — its job is
//! *ordering*, validated against transaction-level simulation by the
//! `plan_study` experiment (the top analytic pick must land in the
//! simulated top-2).

use crate::config::{ChipConfig, CoreConfig, ModelConfig, WorkloadConfig};
use crate::memmgr::planner::{plan as sram_plan, PlanRequest, SramPlan};
use crate::model::memo::SimLevel;
use crate::parallel::layout::PipelineLayout;
use crate::parallel::partition::{partition_cost, PartitionStrategy};
use crate::parallel::pd_placement::{assign, fleet_split, PdPlacementPolicy};
use crate::parallel::placement::Placement;
use crate::sim::interconnect::InterconnectConfig;
use crate::util::cli::CliEnum;

/// Default fraction of a worker's post-weight HBM KV capacity carved out
/// for the demoted-prefix tier (the former fixed 1/8 share, now a plan
/// knob — see `StageWorker::with_hbm_tier`).
pub const DEFAULT_HBM_TIER_FRAC: f64 = 0.125;

/// Modeled decode batch for the analytic cost estimate: steady-state
/// decode iterations amortise the per-iteration weight stream and
/// collectives over roughly this many requests. A fixed, documented
/// constant keeps the planner deterministic and workload-shape-agnostic.
const MODELED_DECODE_BATCH: u64 = 8;

/// Speculative decoding (CLI `--spec gamma=K,accept=P[,draft=F]`).
///
/// Decode is memory-bound: every vanilla step streams the full weight
/// shard to emit one token per request. With speculation a cheap draft
/// proposes `gamma` tokens per request and the target model scores them
/// in **one** verify iteration of `gamma+1` tokens per request — the
/// verify GEMM's row count is `batch * (gamma+1)`, which amortises the
/// weight stream over every proposed token and pushes decode GEMMs
/// across the Fig. 9 partition crossover (`m_threshold`), so the win
/// shows up in modeled collective/HBM traffic rather than a scalar.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecConfig {
    /// Draft tokens proposed per request per speculation round (≥ 1).
    pub gamma: u64,
    /// Per-token acceptance probability of the modeled draft (i.i.d.;
    /// the first rejection discards the rest of the round's draft).
    pub acceptance: f64,
    /// Draft-pass cost as a fraction of the target model's per-step
    /// weight stream (a ~10×-smaller draft model ≈ 0.1).
    pub draft_cost_frac: f64,
}

impl SpecConfig {
    pub fn new(gamma: u64, acceptance: f64) -> Self {
        SpecConfig {
            gamma,
            acceptance,
            draft_cost_frac: 0.1,
        }
    }

    /// Parse the CLI form `gamma=K,accept=P[,draft=F]`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut spec = SpecConfig::new(4, 0.8);
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, val)) = part.split_once('=') else {
                anyhow::bail!("--spec expects key=value pairs, got {part:?}");
            };
            let val = val.trim();
            match key.trim() {
                "gamma" => {
                    spec.gamma = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--spec gamma={val:?} is not an integer"))?
                }
                "accept" | "acceptance" => {
                    spec.acceptance = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--spec accept={val:?} is not a number"))?
                }
                "draft" | "draft_cost_frac" => {
                    spec.draft_cost_frac = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--spec draft={val:?} is not a number"))?
                }
                other => anyhow::bail!("unknown --spec key {other:?} (gamma|accept|draft)"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=64).contains(&self.gamma),
            "--spec gamma must be in 1..=64, got {}",
            self.gamma
        );
        anyhow::ensure!(
            self.acceptance > 0.0 && self.acceptance <= 1.0,
            "--spec accept must be in (0, 1], got {}",
            self.acceptance
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.draft_cost_frac),
            "--spec draft must be in [0, 1), got {}",
            self.draft_cost_frac
        );
        Ok(())
    }
}

/// PD organisation of a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PdMode {
    /// Every pipeline co-locates chunked prefill and decode (§4.3.2).
    Fusion,
    /// Fusion layout with the adaptive re-partitioning controller on top.
    Hybrid,
    /// Dedicated prefill pipelines and decode groups (§4.3.1).
    Disagg {
        n_prefill: usize,
        n_decode: usize,
        prefill_stages: usize,
        decode_tp: usize,
    },
}

impl PdMode {
    pub fn name(&self) -> &'static str {
        match self {
            PdMode::Fusion => "fusion",
            PdMode::Hybrid => "hybrid",
            PdMode::Disagg { .. } => "disagg",
        }
    }
}

/// A complete deployment decision: everything the serving constructors
/// need to lay out and drive a chip. The scheduler configs
/// (`FusionConfig` / `DisaggConfig` / `HybridConfig`) are thin projections
/// of this — see their `from_plan` constructors.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentPlan {
    /// Preset name or `"auto"` (reporting only).
    pub name: String,
    pub mode: PdMode,
    /// TP degree of each pipeline stage (fusion/hybrid) or prefill stage
    /// (disagg).
    pub tp: usize,
    /// Pipeline stages (fusion/hybrid layout depth). For disagg plans
    /// this must mirror the mode's `prefill_stages` — enforced by
    /// `DisaggConfig::from_plan`, which rejects a disagreement.
    pub stages: usize,
    pub placement: Placement,
    /// Partition for large-M GEMMs (long prefill; §5.6 guidance).
    pub prefill_strategy: PartitionStrategy,
    /// Partition for small-M GEMMs (decode, short chunks).
    pub decode_strategy: PartitionStrategy,
    /// Fig. 9 phase switch: GEMMs with `M < m_threshold` run
    /// `decode_strategy`, the rest `prefill_strategy`. `0` = static (every
    /// GEMM uses the phase's configured strategy — the pre-plan
    /// behaviour).
    pub m_threshold: u64,
    /// Chunked-prefill chunk size in tokens.
    pub chunk: usize,
    /// Per-iteration token budget (fusion/hybrid).
    pub budget: usize,
    /// Max concurrent requests per pipeline / decode group.
    pub max_batch: usize,
    /// SRAM remainder split between KV and weights.
    pub kv_share: f64,
    pub prefix_cache: bool,
    pub hbm_tier: bool,
    /// Fraction of the worker's post-weight HBM KV capacity reserved for
    /// the demoted-prefix tier (only read with `hbm_tier`).
    pub hbm_tier_frac: f64,
    pub cross_pipe: bool,
    pub affinity_gap: usize,
    pub memo: bool,
    /// Simulation fidelity: transaction-level (default) or the calibrated
    /// analytic surrogate (`--sim-level fast`).
    pub sim_level: SimLevel,
    /// Speculative decoding (`--spec`); `None` keeps vanilla
    /// one-token-per-step decode bit-identically.
    pub spec: Option<SpecConfig>,
}

impl DeploymentPlan {
    /// The PD-fusion default — field-for-field the layout the serving
    /// stack hardcoded before plans existed (`FusionConfig::default`
    /// projects from this, so the two can never drift).
    pub fn fusion_default() -> Self {
        DeploymentPlan {
            name: "fusion".into(),
            mode: PdMode::Fusion,
            tp: 4,
            stages: 4,
            placement: Placement::Ring,
            prefill_strategy: PartitionStrategy::OneDimK,
            decode_strategy: PartitionStrategy::OneDimK,
            m_threshold: 0,
            chunk: 256,
            budget: 288,
            max_batch: 32,
            kv_share: 0.6,
            prefix_cache: false,
            hbm_tier: false,
            hbm_tier_frac: DEFAULT_HBM_TIER_FRAC,
            cross_pipe: false,
            affinity_gap: 4,
            memo: false,
            sim_level: SimLevel::Txn,
            spec: None,
        }
    }

    /// The paper's balanced disaggregation optimum (P42/D21 at TP 7 on
    /// the 64-core chip — Fig. 11).
    pub fn disagg_default() -> Self {
        DeploymentPlan {
            name: "disagg".into(),
            mode: PdMode::Disagg {
                n_prefill: 42,
                n_decode: 21,
                prefill_stages: 3,
                decode_tp: 7,
            },
            tp: 7,
            stages: 3,
            placement: Placement::LinearInterleave,
            prefill_strategy: PartitionStrategy::OneDimMN,
            decode_strategy: PartitionStrategy::OneDimK,
            m_threshold: 0,
            chunk: 256,
            budget: 288,
            max_batch: 32,
            kv_share: 0.6,
            prefix_cache: false,
            hbm_tier: false,
            hbm_tier_frac: DEFAULT_HBM_TIER_FRAC,
            cross_pipe: false,
            affinity_gap: 4,
            memo: false,
            sim_level: SimLevel::Txn,
            spec: None,
        }
    }

    /// The adaptive-hybrid default: the fusion layout with the controller
    /// on top.
    pub fn hybrid_default() -> Self {
        DeploymentPlan {
            name: "hybrid".into(),
            mode: PdMode::Hybrid,
            ..Self::fusion_default()
        }
    }

    /// Named plan presets for the CLI (`--plan <preset>`) and the
    /// `plan_study` experiment. `"auto"` is handled by the caller (it
    /// needs the chip/model/workload triple to search).
    pub fn preset(name: &str) -> anyhow::Result<Self> {
        Ok(match name {
            "fusion" | "fusion-k" => Self::fusion_default(),
            "fusion-mn" => DeploymentPlan {
                name: "fusion-mn".into(),
                prefill_strategy: PartitionStrategy::OneDimMN,
                decode_strategy: PartitionStrategy::OneDimMN,
                ..Self::fusion_default()
            },
            "fusion-2d" => DeploymentPlan {
                name: "fusion-2d".into(),
                prefill_strategy: PartitionStrategy::TwoDim { rows: 2, cols: 2 },
                decode_strategy: PartitionStrategy::TwoDim { rows: 2, cols: 2 },
                ..Self::fusion_default()
            },
            // Per-GEMM phase awareness (Fig. 9): big prefill chunks run the
            // AllGather partition, decode steps (and the sub-threshold tail
            // chunk) the AllReduce one — selected per `dist_gemm` call.
            "fusion-phase" => DeploymentPlan {
                name: "fusion-phase".into(),
                prefill_strategy: PartitionStrategy::OneDimMN,
                decode_strategy: PartitionStrategy::OneDimK,
                m_threshold: 512,
                chunk: 1024,
                budget: 1056,
                ..Self::fusion_default()
            },
            "disagg" => Self::disagg_default(),
            "hybrid" => Self::hybrid_default(),
            other => anyhow::bail!(
                "unknown plan preset {other:?} \
                 (auto|fusion|fusion-mn|fusion-2d|fusion-phase|disagg|hybrid)"
            ),
        })
    }

    /// All named presets, in `plan_study` presentation order.
    pub fn presets() -> Vec<DeploymentPlan> {
        ["fusion", "fusion-mn", "fusion-2d", "fusion-phase", "disagg", "hybrid"]
            .iter()
            .map(|n| Self::preset(n).expect("static preset"))
            .collect()
    }

    /// One-line human summary for CLI/report output.
    pub fn summary(&self) -> String {
        let mode = match self.mode {
            PdMode::Disagg {
                n_prefill,
                n_decode,
                ..
            } => format!("disagg P{n_prefill}/D{n_decode}"),
            m => m.name().to_string(),
        };
        let phase = if self.m_threshold > 0 {
            format!(
                " | phase-aware: M<{} -> {}",
                self.m_threshold,
                self.decode_strategy.name()
            )
        } else {
            String::new()
        };
        let spec = match self.spec {
            Some(sc) => format!(" | spec: gamma {} accept {:.2}", sc.gamma, sc.acceptance),
            None => String::new(),
        };
        format!(
            "plan {} [{mode} | tp {} x {} stages | {} | prefill {} / decode {}{phase}{spec}]",
            self.name,
            self.tp,
            self.stages,
            self.placement.name(),
            self.prefill_strategy.name(),
            self.decode_strategy.name(),
        )
    }
}

/// Analytic score of one candidate (lower `total_cycles` is better).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanScore {
    /// Estimated chip-level cycles to serve one prefill token.
    pub prefill_cycles_per_token: f64,
    /// Estimated chip-level cycles to serve one decode token.
    pub decode_cycles_per_token: f64,
    /// Fraction of the weight shard SRAM-resident under the plan's split.
    pub weight_resident_frac: f64,
    /// Mean prefill→decode KV hop distance (disagg candidates; 0 for
    /// fused ones).
    pub kv_distance: f64,
    /// Workload-weighted makespan estimate in cycles — the ranking key.
    pub total_cycles: f64,
}

/// A scored candidate of the search.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    pub plan: DeploymentPlan,
    pub score: PlanScore,
}

/// The per-layer GEMM shapes `(K, N)` the analytic model sums over — the
/// four projections of a dense layer, with the FFN width swapped for the
/// routed-expert equivalent on MoE models.
fn layer_gemms(model: &ModelConfig) -> [(u64, u64); 4] {
    let h = model.hidden as u64;
    let qd = model.q_dim() as u64;
    let kvd = model.kv_dim() as u64;
    let inter = match model.moe {
        Some(moe) => moe.expert_intermediate as u64 * moe.top_k as u64,
        None => model.intermediate as u64,
    };
    [(h, qd + 2 * kvd), (qd, h), (h, 2 * inter), (inter, h)]
}

/// Estimated cycles of one distributed GEMM `[m,k]×[k,n]` on a TP group:
/// per-core compute at the systolic peak plus Table-2 collective bytes over
/// the NoC links, each logical hop traversing `alpha` physical links.
fn gemm_cycles(
    chip: &ChipConfig,
    strategy: PartitionStrategy,
    tp: usize,
    m: u64,
    k: u64,
    n: u64,
    alpha: u64,
) -> f64 {
    let macs = chip.core.peak_macs_per_cycle().max(1) as f64;
    let link = chip.noc.link_bytes_per_cycle(chip.freq_mhz).max(1e-9);
    let compute = (m as f64 * k as f64 * n as f64) / (tp.max(1) as f64 * macs);
    let cost = partition_cost(strategy, tp, m, k, n, alpha);
    let comm = cost.total_comm * chip.dtype_bytes as f64 * cost.max_hop.max(1) as f64 / link;
    compute + comm
}

/// Learn the Fig. 9 phase-switch threshold for a strategy pair: the
/// smallest GEMM row count `m` at which the large-M (prefill) strategy's
/// analytic cycle estimate, summed over the model's per-layer GEMMs,
/// stops losing to the small-M (decode) strategy. This replaces the old
/// `hidden/2` heuristic with the actual cost-model crossover: Table 2
/// makes the MN collective volume m-independent (`(p-1)/p·K·N`) while the
/// K-partition's AllReduce grows linearly in m (`2(p-1)/p·M·N`), so with
/// equal compute a unique crossover exists whenever the strategies
/// differ. Falls back to `hidden/2` when no crossover appears in the
/// searched range (e.g. identical strategies).
pub fn learned_m_threshold(
    chip: &ChipConfig,
    model: &ModelConfig,
    tp: usize,
    prefill_strategy: PartitionStrategy,
    decode_strategy: PartitionStrategy,
) -> u64 {
    let fallback = model.hidden as u64 / 2;
    if prefill_strategy == decode_strategy {
        return fallback;
    }
    let cost = |strategy: PartitionStrategy, m: u64| -> f64 {
        layer_gemms(model)
            .iter()
            .map(|&(k, n)| gemm_cycles(chip, strategy, tp, m, k, n, 1))
            .sum()
    };
    let wins = |m: u64| cost(prefill_strategy, m) <= cost(decode_strategy, m);
    let cap = (8 * model.hidden as u64).max(16);
    if !wins(cap) {
        return fallback; // no crossover in range: keep the heuristic
    }
    // Binary search the smallest winning m (`wins` is monotone in m: the
    // decode strategy's collective volume grows linearly in m while the
    // prefill strategy's m-dependence is strictly weaker).
    let (mut lo, mut hi) = (1u64, cap);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if wins(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// The partition strategy the phase-aware executor would run a GEMM of
/// `m` rows with under this plan (mirrors `ExecConfig::strategy_for`).
fn strategy_for(plan: &DeploymentPlan, m: u64) -> PartitionStrategy {
    if plan.m_threshold > 0 && m < plan.m_threshold {
        plan.decode_strategy
    } else {
        plan.prefill_strategy
    }
}

/// Estimated cycles of one full-model iteration of `m` tokens on a
/// TP-`tp` group with `alpha`-hop ring neighbours, including the
/// **per-layer** HBM weight stream (`weight_hbm_per_layer` — the caller
/// divides its stage shard by the stage's layer count so the full-model
/// pass streams every layer exactly once) and a coarse attention term
/// over a mean context of `ctx` tokens.
#[allow(clippy::too_many_arguments)]
fn iteration_cycles(
    chip: &ChipConfig,
    model: &ModelConfig,
    strategy: PartitionStrategy,
    tp: usize,
    m: u64,
    ctx: u64,
    alpha: u64,
    weight_hbm_per_layer: u64,
) -> f64 {
    let macs = chip.core.peak_macs_per_cycle().max(1) as f64;
    let layers = model.layers as f64;
    let mut per_layer = 0.0;
    for (k, n) in layer_gemms(model) {
        per_layer += gemm_cycles(chip, strategy, tp, m, k, n, alpha);
    }
    // Attention: O(m · ctx · head_dim · heads / tp) MACs, heads sharded.
    per_layer += (m as f64 * ctx as f64 * model.q_dim() as f64) / (tp.max(1) as f64 * macs);
    if weight_hbm_per_layer > 0 {
        let bpc = chip.core.hbm_bytes_per_cycle(chip.freq_mhz).max(1e-9);
        per_layer += weight_hbm_per_layer as f64 / bpc;
    }
    layers * per_layer
}

/// Workload token totals `(prefill, decode, mean_input, mean_output)` the
/// score weights by.
fn workload_tokens(workload: &WorkloadConfig) -> (f64, f64, u64, u64) {
    let shared = workload
        .prefix
        .map(|p| p.shared_prefix_len as f64 / p.turns.max(1) as f64)
        .unwrap_or(0.0);
    let mean_in = (workload.input_len.mean() + shared).max(1.0);
    let mean_out = workload.output_len.mean().max(1.0);
    let n = workload.n_requests.max(1) as f64;
    (
        n * mean_in,
        n * mean_out,
        mean_in.round() as u64,
        mean_out.round() as u64,
    )
}

/// SRAM feasibility gate: the fixed buffers must fit, some KV blocks must
/// exist, and weights that miss SRAM need an HBM big enough to hold them.
fn sram_feasible(core: &CoreConfig, p: &SramPlan) -> bool {
    p.total() <= core.sram_bytes
        && p.kv_bytes > 0
        && (p.weight_hbm_bytes == 0 || (core.has_hbm() && p.weight_hbm_bytes < core.hbm_bytes))
}

/// Score one plan analytically; `None` = infeasible on this triple
/// (layout does not fit, SRAM budget collapses, placement fails).
pub fn score_plan(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    plan: &DeploymentPlan,
) -> Option<PlanScore> {
    let (prefill_tokens, decode_tokens, mean_in, mean_out) = workload_tokens(workload);
    match plan.mode {
        PdMode::Fusion | PdMode::Hybrid => {
            let layout =
                PipelineLayout::build(chip.rows, chip.cols, plan.tp, plan.stages, plan.placement)
                    .ok()?;
            let n_pipes = layout.n_pipelines() as f64;
            let alpha = layout.pipelines[0][0].max_ring_hop().max(1) as u64;
            let max_layers = *layout.layers_per_stage(model.layers).iter().max()?;
            let p = sram_plan(
                &chip.core,
                model,
                &PlanRequest {
                    layers: max_layers.max(1),
                    tp: plan.tp,
                    iter_tokens: plan.budget.max(plan.chunk),
                    kv_share: plan.kv_share,
                },
            );
            if !sram_feasible(&chip.core, &p) {
                return None;
            }
            let hbm_per_layer = p.weight_hbm_bytes / max_layers.max(1) as u64;
            let m_pre = (plan.chunk as u64).min(mean_in).max(1);
            let pre_strat = strategy_for(plan, m_pre);
            let pre_iter = iteration_cycles(
                chip,
                model,
                pre_strat,
                plan.tp,
                m_pre,
                mean_in / 2,
                alpha,
                hbm_per_layer,
            );
            // Chunks pipeline through the stages: steady-state, one chunk
            // retires per stage-time per pipe.
            let prefill_per_token = pre_iter / (m_pre as f64 * plan.stages as f64 * n_pipes);
            let m_dec = MODELED_DECODE_BATCH.min(plan.max_batch as u64).max(1);
            let dec_strat = strategy_for(plan, m_dec);
            let mut dec_iter = iteration_cycles(
                chip,
                model,
                dec_strat,
                plan.tp,
                m_dec,
                mean_in + mean_out / 2,
                alpha,
                hbm_per_layer,
            );
            // Decode is autoregressive: the step traverses every stage
            // before the next may start, so depth adds handoffs instead of
            // throughput (§4.3.1's TP-over-PP point).
            let link = chip.noc.link_bytes_per_cycle(chip.freq_mhz).max(1e-9);
            dec_iter += (plan.stages.saturating_sub(1)) as f64
                * (m_dec * model.hidden as u64 * model.dtype_bytes) as f64
                / link;
            let decode_per_token = dec_iter / (m_dec as f64 * n_pipes);
            let mut total = prefill_tokens * prefill_per_token + decode_tokens * decode_per_token;
            if plan.mode == PdMode::Hybrid {
                // Controller overhead: role flips drain in place and the
                // quiescent path equals fusion, so the tax is small but
                // real.
                total *= 1.005;
            }
            Some(PlanScore {
                prefill_cycles_per_token: prefill_per_token,
                decode_cycles_per_token: decode_per_token,
                weight_resident_frac: p.weight_resident_fraction(),
                kv_distance: 0.0,
                total_cycles: total,
            })
        }
        PdMode::Disagg {
            n_prefill,
            n_decode,
            prefill_stages,
            decode_tp,
        } => {
            let a = assign(
                chip.rows,
                chip.cols,
                n_prefill,
                n_decode,
                plan.tp,
                prefill_stages,
                decode_tp,
                PdPlacementPolicy::PpPrioritized,
            )
            .ok()?;
            let n_pipes = a.prefill_pipelines.len() as f64;
            let n_groups = a.decode_groups.len() as f64;
            let alpha_pre = a.prefill_pipelines[0][0].max_ring_hop().max(1) as u64;
            let alpha_dec = a.decode_groups[0].max_ring_hop().max(1) as u64;
            let pre_layers = model.layers.div_ceil(prefill_stages).max(1);
            let p_pre = sram_plan(
                &chip.core,
                model,
                &PlanRequest {
                    layers: pre_layers,
                    tp: plan.tp,
                    iter_tokens: mean_in as usize,
                    kv_share: plan.kv_share,
                },
            );
            let decode_core = chip.decode_core();
            let p_dec = sram_plan(
                &decode_core,
                model,
                &PlanRequest {
                    layers: model.layers,
                    tp: decode_tp,
                    iter_tokens: plan.max_batch,
                    kv_share: plan.kv_share,
                },
            );
            if !sram_feasible(&chip.core, &p_pre) || !sram_feasible(&decode_core, &p_dec) {
                return None;
            }
            // Whole prompts stream through the prefill pipelines.
            let pre_strat = strategy_for(plan, mean_in);
            let pre_iter = iteration_cycles(
                chip,
                model,
                pre_strat,
                plan.tp,
                mean_in,
                mean_in / 2,
                alpha_pre,
                p_pre.weight_hbm_bytes / pre_layers as u64,
            );
            let prefill_per_token = pre_iter / (mean_in as f64 * prefill_stages as f64 * n_pipes);
            let m_dec = MODELED_DECODE_BATCH.min(plan.max_batch as u64).max(1);
            let dec_iter = iteration_cycles(
                chip,
                model,
                plan.decode_strategy,
                decode_tp,
                m_dec,
                mean_in + mean_out / 2,
                alpha_dec,
                p_dec.weight_hbm_bytes / model.layers.max(1) as u64,
            );
            let decode_per_token = dec_iter / (m_dec as f64 * n_groups);
            // The KV-transfer tax every request pays between the phases:
            // whole-prompt KV across `mean_kv_distance` mesh hops, the
            // stage shards streaming in parallel over the tp lanes.
            let link = chip.noc.link_bytes_per_cycle(chip.freq_mhz).max(1e-9);
            let kv_dist = a.mean_kv_distance();
            let kv_bytes = mean_in as f64 * model.kv_bytes_per_token() as f64;
            let transfer = kv_bytes * kv_dist.max(1.0) / (link * plan.tp.max(1) as f64);
            let n = workload.n_requests.max(1) as f64;
            let total = prefill_tokens * prefill_per_token
                + decode_tokens * decode_per_token
                + n * transfer;
            Some(PlanScore {
                prefill_cycles_per_token: prefill_per_token,
                decode_cycles_per_token: decode_per_token,
                weight_resident_frac: p_pre.weight_resident_fraction(),
                kv_distance: kv_dist,
                total_cycles: total,
            })
        }
    }
}

/// Enumerate the feasible plan space for the triple: fusion/hybrid layouts
/// over TP × stages × placement × partition strategy (with a phase-aware
/// variant whenever the strategies differ), plus PP-prioritized
/// disaggregation ratios. Every returned plan scores `Some` under
/// [`score_plan`].
pub fn enumerate_plans(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
) -> Vec<PlanCandidate> {
    let mut out: Vec<PlanCandidate> = Vec::new();
    let mut push = |plan: DeploymentPlan| {
        if let Some(score) = score_plan(chip, model, workload, &plan) {
            out.push(PlanCandidate { plan, score });
        }
    };

    let base = DeploymentPlan::fusion_default();
    for tp in [2usize, 4, 8, 16] {
        if tp > chip.n_cores() {
            continue;
        }
        for stages in [2usize, 4, 8] {
            for placement in [Placement::Ring, Placement::LinearInterleave, Placement::LinearSeq] {
                let mut strategies = vec![PartitionStrategy::OneDimK, PartitionStrategy::OneDimMN];
                if let Ok(s @ PartitionStrategy::TwoDim { .. }) =
                    PartitionStrategy::parse("2d", tp)
                {
                    strategies.push(s);
                }
                for strategy in strategies {
                    let name = format!(
                        "fusion-tp{tp}s{stages}-{}-{}",
                        placement.name(),
                        strategy.name()
                    );
                    let plan = DeploymentPlan {
                        name,
                        tp,
                        stages,
                        placement,
                        prefill_strategy: strategy,
                        decode_strategy: PartitionStrategy::OneDimK,
                        ..base.clone()
                    };
                    if strategy != PartitionStrategy::OneDimK {
                        // Phase-aware variant: long-chunk prefill runs
                        // `strategy`, while GEMMs below the threshold
                        // (decode steps, short tail chunks) fall back to
                        // AllReduce. The threshold is learned from the
                        // Table-2 cost crossover for this strategy pair,
                        // and the chunk must reach it or the variant would
                        // never exercise its large-M strategy and
                        // degenerate into a duplicate of the K candidate.
                        let m_threshold = learned_m_threshold(
                            chip,
                            model,
                            tp,
                            strategy,
                            PartitionStrategy::OneDimK,
                        );
                        let chunk = (m_threshold as usize).max(plan.chunk);
                        push(DeploymentPlan {
                            name: format!("{}+phase", plan.name),
                            m_threshold,
                            chunk,
                            budget: chunk + plan.budget.saturating_sub(plan.chunk),
                            ..plan.clone()
                        });
                    }
                    push(plan);
                }
            }
        }
    }

    // Disaggregation ratios (PP-prioritized edges-out placement), TP sized
    // to a mesh column minus one so decode groups stay column-compact.
    let cores = chip.n_cores();
    let tp = chip.rows.saturating_sub(1).max(1);
    let mut seen_ratios = std::collections::BTreeSet::new();
    for (frac, stages) in [(0.75, 3usize), (0.66, 3), (0.5, 2), (0.33, 2)] {
        let n_prefill = (((cores as f64 * frac) as usize) / tp).max(1) * tp;
        if n_prefill >= cores || !seen_ratios.insert((n_prefill, stages)) {
            continue;
        }
        let n_decode = cores - n_prefill;
        push(DeploymentPlan {
            name: format!("disagg-p{n_prefill}d{n_decode}"),
            mode: PdMode::Disagg {
                n_prefill,
                n_decode,
                prefill_stages: stages,
                decode_tp: tp,
            },
            tp,
            stages,
            placement: Placement::LinearInterleave,
            prefill_strategy: PartitionStrategy::OneDimMN,
            decode_strategy: PartitionStrategy::OneDimK,
            m_threshold: learned_m_threshold(
                chip,
                model,
                tp,
                PartitionStrategy::OneDimMN,
                PartitionStrategy::OneDimK,
            ),
            ..base.clone()
        });
    }

    // Hybrid variants of the two strongest fused shapes.
    for (tp, stages) in [(4usize, 4usize), (8, 2)] {
        push(DeploymentPlan {
            name: format!("hybrid-tp{tp}s{stages}"),
            mode: PdMode::Hybrid,
            tp,
            stages,
            ..base.clone()
        });
    }

    out
}

/// Search the plan space and rank it: candidates sorted by ascending
/// analytic makespan estimate (ties broken on name for determinism).
/// Errors when nothing in the space is feasible for the triple.
pub fn auto_plan(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
) -> anyhow::Result<Vec<PlanCandidate>> {
    let mut cands = enumerate_plans(chip, model, workload);
    anyhow::ensure!(
        !cands.is_empty(),
        "auto-planner found no feasible deployment for {} on {} ({}x{})",
        model.name,
        chip.name,
        chip.rows,
        chip.cols
    );
    cands.sort_by(|a, b| {
        a.score
            .total_cycles
            .total_cmp(&b.score.total_cycles)
            .then_with(|| a.plan.name.cmp(&b.plan.name))
    });
    // Confidence hysteresis: the analytic model orders the space but its
    // absolute resolution is coarse, so an exotic top pick must predict a
    // clear (>10%) win before the planner abandons the battle-tested
    // canonical fused shape — deployment churn for a sub-noise delta is a
    // cost the estimate cannot see.
    let canon = DeploymentPlan::fusion_default();
    if let Some(pos) = cands.iter().position(|c| {
        c.plan.mode == canon.mode
            && c.plan.tp == canon.tp
            && c.plan.stages == canon.stages
            && c.plan.placement == canon.placement
            && c.plan.prefill_strategy == canon.prefill_strategy
            && c.plan.m_threshold == canon.m_threshold
    }) {
        if pos > 0 && cands[pos].score.total_cycles <= cands[0].score.total_cycles * 1.10 {
            let c = cands.remove(pos);
            cands.insert(0, c);
        }
    }
    for c in &mut cands {
        c.plan.name = format!("auto:{}", c.plan.name);
    }
    Ok(cands)
}

/// Role of one chip in a fleet: cluster-level PD disaggregation assigns
/// prompt processing and token generation to different chips, connected by
/// the inter-chip fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChipRole {
    /// Runs prompt processing only; streams finished KV to a decode chip.
    Prefill,
    /// Runs decode legs handed off (with their KV) by prefill chips.
    Decode,
    /// Serves whole requests end to end (homogeneous fleets).
    #[default]
    General,
}

impl CliEnum for ChipRole {
    const WHAT: &'static str = "chip role";
    const TABLE: &'static [(&'static str, &'static [&'static str], ChipRole)] = &[
        ("prefill", &["p"], ChipRole::Prefill),
        ("decode", &["d"], ChipRole::Decode),
        ("general", &["g", "any"], ChipRole::General),
    ];
}

impl ChipRole {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Self::parse_cli(s)
    }

    pub fn name(self) -> &'static str {
        self.cli_name()
    }
}

/// One chip of a planned fleet: its hardware variant, the deployment plan
/// it runs, and its serving role.
#[derive(Debug, Clone)]
pub struct FleetChipPlan {
    pub hw: ChipConfig,
    pub plan: DeploymentPlan,
    pub role: ChipRole,
}

/// A fleet-level deployment decision from [`plan_fleet`]: either a
/// role-specialized heterogeneous fleet (compute-heavy prefill chips +
/// HBM-heavy decode chips) or the best homogeneous fused fleet.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub name: String,
    /// Per-chip assignment, prefill chips first (deterministic order).
    pub chips: Vec<FleetChipPlan>,
    /// Whether the fleet splits prefill and decode across chips.
    pub disaggregated: bool,
    /// Analytic fleet makespan estimate in cycles — the decision key.
    pub est_cycles: f64,
}

impl FleetPlan {
    pub fn n_prefill(&self) -> usize {
        self.chips.iter().filter(|c| c.role == ChipRole::Prefill).count()
    }

    pub fn n_decode(&self) -> usize {
        self.chips.iter().filter(|c| c.role == ChipRole::Decode).count()
    }

    /// One-line human summary for CLI output and experiment tables.
    pub fn summary(&self) -> String {
        let roles: Vec<String> = self
            .chips
            .iter()
            .map(|c| format!("{}:{}", c.role.name(), c.hw.name))
            .collect();
        format!(
            "{} ({} chips: {}) est {:.3e} cycles",
            self.name,
            self.chips.len(),
            roles.join(", "),
            self.est_cycles
        )
    }
}

/// The best homogeneous fused fleet: every chip a clone of `chip` running
/// the top fused plan of [`auto_plan`] over its 1/n share of the workload.
pub fn plan_fleet_fused(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    n_chips: usize,
) -> anyhow::Result<FleetPlan> {
    let n_chips = n_chips.max(1);
    let mut wl_chip = workload.clone();
    wl_chip.n_requests = workload.n_requests.div_ceil(n_chips).max(1);
    let cands = auto_plan(chip, model, &wl_chip)?;
    let fused = cands
        .iter()
        .find(|c| matches!(c.plan.mode, PdMode::Fusion | PdMode::Hybrid))
        .ok_or_else(|| anyhow::anyhow!("no feasible fused plan for {}", chip.name))?;
    Ok(FleetPlan {
        name: format!("fleet-fused-x{n_chips}"),
        chips: vec![
            FleetChipPlan {
                hw: chip.clone(),
                plan: fused.plan.clone(),
                role: ChipRole::General,
            };
            n_chips
        ],
        disaggregated: false,
        est_cycles: fused.score.total_cycles,
    })
}

/// The heterogeneous role-split fleet for `n_chips` (≥ 2): compute-heavy
/// [`ChipConfig::prefill_optimized`] chips paired with HBM-heavy
/// [`ChipConfig::decode_optimized`] chips, each running the fused shape
/// that best serves its phase, with the chip count split by
/// [`fleet_split`] and every request's prompt-KV handoff charged at the
/// fabric's egress cost. Errors if no fused shape fits either variant.
pub fn plan_fleet_disagg(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    n_chips: usize,
    icn: &InterconnectConfig,
) -> anyhow::Result<FleetPlan> {
    anyhow::ensure!(n_chips >= 2, "a disaggregated fleet needs >= 2 chips");
    let pre_hw = ChipConfig::prefill_optimized();
    let dec_hw = ChipConfig::decode_optimized();
    // Best fused shape on each specialized variant, rated by the phase it
    // will actually run.
    let best_by = |hw: &ChipConfig, key: fn(&PlanScore) -> f64| -> Option<PlanCandidate> {
        enumerate_plans(hw, model, workload)
            .into_iter()
            .filter(|c| c.plan.mode == PdMode::Fusion)
            .min_by(|a, b| {
                key(&a.score)
                    .total_cmp(&key(&b.score))
                    .then_with(|| a.plan.name.cmp(&b.plan.name))
            })
    };
    let pre_cand = best_by(&pre_hw, |s| s.prefill_cycles_per_token)
        .ok_or_else(|| anyhow::anyhow!("no feasible fused plan for {}", pre_hw.name))?;
    let dec_cand = best_by(&dec_hw, |s| s.decode_cycles_per_token)
        .ok_or_else(|| anyhow::anyhow!("no feasible fused plan for {}", dec_hw.name))?;

    let (prefill_tokens, decode_tokens, mean_in, _) = workload_tokens(workload);
    let prefill_work = prefill_tokens * pre_cand.score.prefill_cycles_per_token;
    let decode_work = decode_tokens * dec_cand.score.decode_cycles_per_token;
    let (n_p, n_d) = fleet_split(prefill_work, decode_work, n_chips);

    // Each request ships its whole prompt KV (plus the first generated
    // token's) across the fabric once; transfers out of the same prefill
    // chip serialise on its egress port.
    let n_reqs = workload.n_requests.max(1) as f64;
    let handoff_bytes = (mean_in + 1) * model.kv_bytes_per_token();
    let handoff_cycles = icn.transfer_s(handoff_bytes) * chip.freq_mhz * 1e6;
    let egress_per_chip = (n_reqs / n_p as f64) * handoff_cycles;
    let est_disagg =
        (prefill_work / n_p as f64 + egress_per_chip).max(decode_work / n_d as f64);

    let mut pre_plan = pre_cand.plan.clone();
    pre_plan.name = format!("fleet-prefill:{}", pre_plan.name);
    let mut dec_plan = dec_cand.plan.clone();
    // Decode chips must honour the seeded handoff prefix or they would
    // recompute the whole prompt the prefill chip already processed.
    dec_plan.prefix_cache = true;
    dec_plan.name = format!("fleet-decode:{}", dec_plan.name);
    let mut chips = Vec::with_capacity(n_chips);
    for _ in 0..n_p {
        chips.push(FleetChipPlan {
            hw: pre_hw.clone(),
            plan: pre_plan.clone(),
            role: ChipRole::Prefill,
        });
    }
    for _ in 0..n_d {
        chips.push(FleetChipPlan {
            hw: dec_hw.clone(),
            plan: dec_plan.clone(),
            role: ChipRole::Decode,
        });
    }
    Ok(FleetPlan {
        name: format!("fleet-disagg-p{n_p}d{n_d}"),
        chips,
        disaggregated: true,
        est_cycles: est_disagg,
    })
}

/// Extend [`auto_plan`] to a fleet of `n_chips`: evaluate the best
/// homogeneous fused fleet ([`plan_fleet_fused`]) against the
/// role-specialized heterogeneous fleet ([`plan_fleet_disagg`]) and pick
/// whichever the analytic makespan estimate favours for this workload.
pub fn plan_fleet(
    chip: &ChipConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    n_chips: usize,
    icn: &InterconnectConfig,
) -> anyhow::Result<FleetPlan> {
    let homogeneous = plan_fleet_fused(chip, model, workload, n_chips)?;
    if n_chips < 2 {
        return Ok(homogeneous);
    }
    match plan_fleet_disagg(chip, model, workload, n_chips, icn) {
        Ok(disagg) if disagg.est_cycles < homogeneous.est_cycles => Ok(disagg),
        _ => Ok(homogeneous),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triple() -> (ChipConfig, ModelConfig, WorkloadConfig) {
        (
            ChipConfig::small_core(),
            ModelConfig::qwen3_4b(),
            WorkloadConfig::sharegpt_like(16),
        )
    }

    #[test]
    fn enumerates_a_rich_feasible_space_on_the_16x16_chip() {
        // The acceptance floor: ≥ 12 feasible candidates for the default
        // 16×16 chip + dense model.
        let (chip, model, w) = triple();
        let cands = enumerate_plans(&chip, &model, &w);
        assert!(cands.len() >= 12, "only {} candidates", cands.len());
        // The space must actually span modes and strategies.
        assert!(cands.iter().any(|c| matches!(c.plan.mode, PdMode::Disagg { .. })));
        assert!(cands.iter().any(|c| c.plan.mode == PdMode::Hybrid));
        assert!(cands
            .iter()
            .any(|c| c.plan.prefill_strategy == PartitionStrategy::OneDimMN));
        assert!(cands.iter().any(|c| c.plan.m_threshold > 0));
    }

    #[test]
    fn auto_plan_is_deterministic_for_the_seed_configs() {
        // Golden pin: same triple, same ranked list — byte for byte on the
        // names and bit-equal on the scores.
        for (chip, model, w) in [
            (
                ChipConfig::large_core(),
                ModelConfig::qwen3_4b(),
                WorkloadConfig::sharegpt_like(16),
            ),
            triple(),
        ] {
            let a = auto_plan(&chip, &model, &w).unwrap();
            let b = auto_plan(&chip, &model, &w).unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.plan, y.plan);
                assert_eq!(x.score.total_cycles, y.score.total_cycles);
            }
        }
    }

    #[test]
    fn ranking_follows_the_paper_guidance() {
        // Decode-leaning sharegpt traffic on the 64-core chip: the K
        // partition must outrank MN at the same layout (chunked prefill
        // keeps M small — §5.6), and ring placement must outrank
        // linear-seq at the same strategy (alpha 1 vs alpha ~ region
        // perimeter).
        let chip = ChipConfig::large_core();
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(16);
        let score = |name: &str| {
            let ranked = auto_plan(&chip, &model, &w).unwrap();
            ranked
                .iter()
                .find(|c| c.plan.name == format!("auto:{name}"))
                .unwrap_or_else(|| panic!("{name} missing"))
                .score
                .total_cycles
        };
        let k_ring = score("fusion-tp4s4-ring-1d-k(allreduce)");
        assert!(k_ring < score("fusion-tp4s4-ring-1d-mn(allgather)"));
        assert!(k_ring < score("fusion-tp4s4-linear-seq-1d-k(allreduce)"));
    }

    #[test]
    fn spec_config_parses_and_validates() {
        let s = SpecConfig::parse("gamma=4,accept=0.8").unwrap();
        assert_eq!(s.gamma, 4);
        assert_eq!(s.acceptance, 0.8);
        assert_eq!(s.draft_cost_frac, 0.1);
        let s = SpecConfig::parse("gamma=2,accept=0.6,draft=0.05").unwrap();
        assert_eq!(s.gamma, 2);
        assert_eq!(s.draft_cost_frac, 0.05);
        assert!(SpecConfig::parse("gamma=0,accept=0.8").is_err());
        assert!(SpecConfig::parse("gamma=4,accept=1.5").is_err());
        assert!(SpecConfig::parse("gamma=4,accept=0.8,draft=1.0").is_err());
        assert!(SpecConfig::parse("turbo=9").is_err());
        assert!(SpecConfig::parse("gamma").is_err());
    }

    #[test]
    fn learned_threshold_sits_at_the_analytic_crossover() {
        // With equal compute and alpha-1 hops the Table-2 crossover of
        // AllGather (comm (p-1)/p·K·N, m-independent) against AllReduce
        // (2(p-1)/p·M·N) is m* = Σkn / (2Σn) — the learned threshold must
        // hit it exactly, for any tp (the (p-1)/p factors cancel).
        let chip = ChipConfig::large_core();
        let model = ModelConfig::qwen3_4b();
        let gemms = layer_gemms(&model);
        let kn: f64 = gemms.iter().map(|&(k, n)| (k * n) as f64).sum();
        let n_sum: f64 = gemms.iter().map(|&(_, n)| n as f64).sum();
        let expect = (kn / (2.0 * n_sum)).ceil() as u64;
        for tp in [2usize, 4, 8] {
            let t = learned_m_threshold(
                &chip,
                &model,
                tp,
                PartitionStrategy::OneDimMN,
                PartitionStrategy::OneDimK,
            );
            assert_eq!(t, expect, "tp={tp}");
        }
        // The learned value genuinely replaces the heuristic…
        assert_ne!(expect, model.hidden as u64 / 2);
        // …and identical strategies (no crossover) keep the fallback.
        let same = learned_m_threshold(
            &chip,
            &model,
            4,
            PartitionStrategy::OneDimK,
            PartitionStrategy::OneDimK,
        );
        assert_eq!(same, model.hidden as u64 / 2);
        // Every phase-aware candidate the enumerator emits carries the
        // learned threshold, not the heuristic.
        let w = WorkloadConfig::sharegpt_like(16);
        for c in enumerate_plans(&chip, &model, &w) {
            if c.plan.name.ends_with("+phase")
                && c.plan.prefill_strategy == PartitionStrategy::OneDimMN
            {
                assert_eq!(c.plan.m_threshold, expect, "{}", c.plan.name);
                assert!(c.plan.chunk as u64 >= expect);
            }
        }
    }

    #[test]
    fn presets_cover_the_cli_names_and_reject_garbage() {
        for name in ["fusion", "fusion-mn", "fusion-2d", "fusion-phase", "disagg", "hybrid"] {
            let p = DeploymentPlan::preset(name).unwrap();
            assert_eq!(p.name, name);
            assert!(!p.summary().is_empty());
        }
        assert!(DeploymentPlan::preset("warp-drive").is_err());
        assert_eq!(DeploymentPlan::presets().len(), 6);
    }

    #[test]
    fn chip_role_parses_uniformly() {
        assert_eq!(ChipRole::parse("prefill").unwrap(), ChipRole::Prefill);
        assert_eq!(ChipRole::parse("p").unwrap(), ChipRole::Prefill);
        assert_eq!(ChipRole::parse("d").unwrap(), ChipRole::Decode);
        assert_eq!(ChipRole::parse("any").unwrap(), ChipRole::General);
        assert_eq!(ChipRole::Decode.name(), "decode");
        let err = ChipRole::parse("oracle").unwrap_err().to_string();
        assert_eq!(err, "unknown chip role \"oracle\" (prefill|decode|general)");
    }

    #[test]
    fn fleet_planner_is_deterministic_and_well_formed() {
        let chip = ChipConfig::large_core();
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(16);
        let icn = InterconnectConfig::default();
        let a = plan_fleet(&chip, &model, &w, 4, &icn).unwrap();
        let b = plan_fleet(&chip, &model, &w, 4, &icn).unwrap();
        assert_eq!(a.name, b.name);
        assert_eq!(a.est_cycles, b.est_cycles);
        assert_eq!(a.chips.len(), 4);
        assert!(a.est_cycles.is_finite() && a.est_cycles > 0.0);
        assert!(!a.summary().is_empty());
        if a.disaggregated {
            assert!(a.n_prefill() >= 1 && a.n_decode() >= 1);
            assert_eq!(a.n_prefill() + a.n_decode(), 4);
        } else {
            assert!(a.chips.iter().all(|c| c.role == ChipRole::General));
        }
        // A single chip can never disaggregate.
        let solo = plan_fleet(&chip, &model, &w, 1, &icn).unwrap();
        assert!(!solo.disaggregated);
        assert_eq!(solo.chips.len(), 1);
    }

    #[test]
    fn forced_disagg_fleet_staffs_both_roles_with_specialized_silicon() {
        let chip = ChipConfig::large_core();
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(16);
        let icn = InterconnectConfig::default();
        let f = plan_fleet_disagg(&chip, &model, &w, 4, &icn).unwrap();
        assert!(f.disaggregated);
        assert_eq!(f.n_prefill() + f.n_decode(), 4);
        for c in &f.chips {
            match c.role {
                ChipRole::Prefill => assert_eq!(c.hw.name, "prefill-opt-64"),
                ChipRole::Decode => {
                    assert_eq!(c.hw.name, "decode-opt-64");
                    // Decode chips must honour handoff prefix seeds.
                    assert!(c.plan.prefix_cache);
                }
                ChipRole::General => panic!("disagg fleet has no general chips"),
            }
        }
        // Prefill chips come first, so role order is deterministic.
        assert_eq!(f.chips[0].role, ChipRole::Prefill);
        assert!(plan_fleet_disagg(&chip, &model, &w, 1, &icn).is_err());
    }

    #[test]
    fn infeasible_layouts_are_filtered() {
        // A 2×2 chip cannot host tp 16 or a 42/21 disagg split: those
        // candidates must be dropped, not scored.
        let mut chip = ChipConfig::large_core();
        chip.rows = 2;
        chip.cols = 2;
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(4);
        for c in enumerate_plans(&chip, &model, &w) {
            assert!(c.plan.tp <= 4, "{}", c.plan.name);
        }
        assert!(score_plan(&chip, &model, &w, &DeploymentPlan::disagg_default()).is_none());
    }
}
