//! Randomized cross-stack invariants: whatever the workload, chip shape
//! and scheduler configuration, the serving engines must preserve these.

use npusim::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use npusim::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::prop::check;

fn random_workload(rng: &mut npusim::util::rng::Rng) -> WorkloadConfig {
    let n = rng.range(1, 5);
    let mut w = WorkloadConfig::fixed_ratio(rng.range(8, 200), rng.range(1, 24), n);
    if rng.chance(0.5) {
        w.input_len = LenDist::Uniform(8, 256);
        w.output_len = LenDist::Uniform(1, 16);
    }
    if rng.chance(0.5) {
        w = w.with_arrival(ArrivalProcess::Poisson {
            rate: rng.range_f64(0.5, 8.0),
        });
    }
    w.with_seed(rng.next_u64())
}

#[test]
fn fusion_invariants_hold_for_random_workloads() {
    check("fusion invariants", 12, |rng| {
        let w = random_workload(rng);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let cfg = FusionConfig {
            tp: *rng.choose(&[4usize, 8, 16]),
            stages: *rng.choose(&[1usize, 2, 4]),
            chunk: *rng.choose(&[64usize, 256]),
            budget: 288,
            ..FusionConfig::default()
        };
        let m = simulate_fusion(&mut chip, &ModelConfig::qwen3_4b(), &w, &cfg)
            .expect("fusion run failed");
        // 1. Every request completes exactly once.
        assert_eq!(m.n_requests(), w.n_requests);
        let mut ids: Vec<u64> = m.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), w.n_requests);
        // 2. Causality per request.
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
            assert!(r.output_tokens >= 1);
        }
        // 3. The chip did work and clocks are consistent.
        assert!(chip.makespan() >= m.makespan());
    });
}

#[test]
fn disagg_invariants_hold_for_random_workloads() {
    check("disagg invariants", 10, |rng| {
        let w = random_workload(rng);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let (p, d, stages) = *rng.choose(&[(49, 14, 7), (42, 21, 6), (28, 28, 4), (21, 42, 3)]);
        let cfg = DisaggConfig {
            max_decode_batch: rng.range(2, 32),
            ..DisaggConfig::ratio_64(p, d, stages)
        };
        let m = simulate_disagg(&mut chip, &ModelConfig::qwen3_4b(), &w, &cfg)
            .expect("disagg run failed");
        assert_eq!(m.n_requests(), w.n_requests);
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
    });
}

#[test]
fn schedulers_agree_on_total_output_tokens() {
    check("token conservation", 8, |rng| {
        let w = random_workload(rng);
        let expect: u64 = npusim::serving::request::generate(&w)
            .iter()
            .map(|r| r.output_len as u64)
            .sum();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mf = simulate_fusion(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            &w,
            &FusionConfig::default(),
        )
        .unwrap();
        let got: u64 = mf.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(got, expect, "fusion lost/invented tokens");
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let md = simulate_disagg(
            &mut chip,
            &ModelConfig::qwen3_4b(),
            &w,
            &DisaggConfig::p42_d21(),
        )
        .unwrap();
        let got: u64 = md.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(got, expect, "disagg lost/invented tokens");
    });
}

#[test]
fn simulated_time_is_monotone_in_workload_size() {
    check("monotone makespan", 6, |rng| {
        let base_n = rng.range(1, 3);
        let mk = |n: usize, seed: u64| {
            let w = WorkloadConfig::fixed_ratio(64, 8, n).with_seed(seed);
            let mut chip = ChipSim::new(ChipConfig::large_core());
            simulate_fusion(
                &mut chip,
                &ModelConfig::qwen3_4b(),
                &w,
                &FusionConfig::default(),
            )
            .unwrap()
            .makespan()
        };
        let seed = rng.next_u64();
        assert!(mk(base_n, seed) <= mk(base_n * 4, seed));
    });
}
