//! Back-compat shim: the pipeline-layout geometry moved to
//! [`crate::parallel::layout`] so the auto-planner
//! ([`crate::parallel::plan`]) can use it as its fusion feasibility test
//! without the parallel layer reaching up into serving. Existing serving
//! call sites keep importing from here.

pub use crate::parallel::layout::{carve_stage_cells, tp_rect, PipelineLayout};
