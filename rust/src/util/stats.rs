//! Summary statistics used by the metrics layer and the bench harness:
//! mean / std / percentiles / histograms over latency samples.

/// Online + batch summary over a set of f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for x in samples {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var =
            self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.samples.len() as f64;
        var.sqrt()
    }

    /// Percentile with linear interpolation, `p` in `[0, 100]`.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
        let n = self.samples.len();
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.samples[lo]
        } else {
            let frac = rank - lo as f64;
            self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket histogram (used by the tracer for utilization reports).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo) * self.buckets.len() as f64) as usize;
            let last = self.buckets.len() - 1;
            self.buckets[idx.min(last)] += 1;
        }
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_samples([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::from_samples((1..=100).map(|x| x as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!(s.p99() > 98.0 && s.p99() < 100.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.buckets(), &[1u64; 10][..]);
        assert_eq!(h.total(), 12);
    }
}
