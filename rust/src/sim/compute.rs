//! Operator performance models (§3.1 "computing system").
//!
//! Compute latency on a systolic array is deterministic given shapes, so it
//! is modeled analytically: for a GEMM the weight matrix is tiled into
//! `sa_dim × sa_dim` tiles (last tiles padded) and
//!
//! ```text
//! T_comp = N_tiles × T_cycles + T_inject
//! ```
//!
//! where `T_cycles = M + sa_dim` (stream M activation rows through the
//! array + pipeline drain) and `T_inject = sa_dim` (initial weight
//! injection; subsequent tiles double-buffer their injection behind the
//! previous tile's streaming). The result is lower-bounded by the SRAM
//! bandwidth roofline. Vector operators (norms, softmax, RoPE, residuals)
//! run on the `lanes × 64` ALU vector unit.

use crate::config::{ChipConfig, CoreConfig};
use crate::util::units::{ceil_div, Cycle};

/// Where the GEMM weights stream from (affects the roofline only; HBM
/// prefetch latency is simulated by the core executor via the TLM channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightSource {
    Sram,
    Hbm,
}

/// GEMM/GEMV latency for `[m,k] × [k,n]`.
///
/// The operator is dispatched to the better-suited unit — the systolic
/// array (tile pipeline: `N_tiles × (M + sa) + sa`) or the vector unit
/// (`2·M·K·N / peak_ops`; a skinny GEMV cannot amortise systolic weight
/// injection, so real NPU cores run it on the vector lanes — this is the
/// premise of §4.3.1's heterogeneous decode cores, whose systolic arrays
/// shrink "with minimal impact" on decode). The result is lower-bounded
/// by the SRAM-bandwidth roofline.
pub fn matmul_cycles(chip: &ChipConfig, core: &CoreConfig, m: u64, k: u64, n: u64) -> Cycle {
    if m == 0 || k == 0 || n == 0 {
        return 0;
    }
    let sa = core.sa_dim;
    let n_tiles = ceil_div(k, sa) * ceil_div(n, sa);
    let t_cycles = m + sa; // stream M rows + drain
    let t_inject = sa;
    let systolic = n_tiles * t_cycles + t_inject;

    // Vector-unit path (MAC = 2 ALU ops).
    let vector = ceil_div(2 * m * k * n, core.peak_vector_ops_per_cycle()).max(1);

    // SRAM roofline: weights + activations read, outputs written.
    let dtype = chip.dtype_bytes;
    let bytes = (m * k + k * n + m * n) * dtype;
    let sram = (bytes as f64 / core.sram_bytes_per_cycle(chip.freq_mhz)).ceil() as Cycle;

    systolic.min(vector).max(sram)
}

/// GEMV (`m = 1`) — decode-stage projections. On a systolic array a GEMV
/// cannot amortise weight injection across rows, which is exactly why the
/// paper provisions decode cores with narrower arrays + more memory
/// bandwidth (§4.3.1 heterogeneous core design).
pub fn gemv_cycles(chip: &ChipConfig, core: &CoreConfig, k: u64, n: u64) -> Cycle {
    matmul_cycles(chip, core, 1, k, n)
}

/// Elementwise vector op over `elems` elements, `passes` read-modify-write
/// passes (e.g. residual add = 1, RMSNorm ≈ 2: reduce + scale).
pub fn vector_cycles(core: &CoreConfig, elems: u64, passes: u64) -> Cycle {
    if elems == 0 {
        return 0;
    }
    ceil_div(elems * passes, core.peak_vector_ops_per_cycle()).max(1)
}

/// Softmax over `rows` rows of `cols` elements: max-reduce, exp+sum, scale
/// ≈ 3 passes (exp costed as ~4 ALU ops).
pub fn softmax_cycles(core: &CoreConfig, rows: u64, cols: u64) -> Cycle {
    vector_cycles(core, rows * cols, 6)
}

/// RMSNorm over `tokens` rows of `hidden`: square+sum, rsqrt, scale.
pub fn rmsnorm_cycles(core: &CoreConfig, tokens: u64, hidden: u64) -> Cycle {
    vector_cycles(core, tokens * hidden, 3)
}

/// Rotary position embedding over `tokens × dim`.
pub fn rope_cycles(core: &CoreConfig, tokens: u64, dim: u64) -> Cycle {
    vector_cycles(core, tokens * dim, 4)
}

/// SwiGLU activation (`silu(gate) * up`) over `tokens × intermediate`.
pub fn swiglu_cycles(core: &CoreConfig, tokens: u64, intermediate: u64) -> Cycle {
    vector_cycles(core, tokens * intermediate, 5)
}

/// Attention score+context for one head group on one core:
/// `scores = Q·Kᵀ` (`[q_tokens, head_dim] × [head_dim, kv_tokens]`),
/// softmax, `out = P·V` (`[q_tokens, kv_tokens] × [kv_tokens, head_dim]`).
pub fn attention_cycles(
    chip: &ChipConfig,
    core: &CoreConfig,
    heads: u64,
    q_tokens: u64,
    kv_tokens: u64,
    head_dim: u64,
) -> Cycle {
    if heads == 0 || q_tokens == 0 || kv_tokens == 0 {
        return 0;
    }
    let qk = matmul_cycles(chip, core, q_tokens, head_dim, kv_tokens);
    let sm = softmax_cycles(core, q_tokens, kv_tokens);
    let pv = matmul_cycles(chip, core, q_tokens, kv_tokens, head_dim);
    heads * (qk + sm + pv)
}

/// FLOPs of a `[m,k]×[k,n]` GEMM (for utilization reporting).
pub fn matmul_flops(m: u64, k: u64, n: u64) -> u64 {
    2 * m * k * n
}

/// Achieved MAC utilization of the systolic model for a GEMM (diagnostic).
pub fn matmul_utilization(chip: &ChipConfig, core: &CoreConfig, m: u64, k: u64, n: u64) -> f64 {
    let cycles = matmul_cycles(chip, core, m, k, n);
    if cycles == 0 {
        return 0.0;
    }
    let ideal = matmul_flops(m, k, n) as f64 / (2.0 * core.peak_macs_per_cycle() as f64);
    ideal / cycles as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn chip() -> ChipConfig {
        ChipConfig::large_core() // sa=128, lanes=128
    }

    #[test]
    fn matmul_matches_formula_when_compute_bound() {
        let c = chip();
        // 512x512x512 with sa=128: tiles = 4*4 = 16, t_cycles = 512+128,
        // inject 128 => 16*640+128 = 10368. SRAM roofline: 3*512²*2 B
        // at 512 B/cyc = 3072 cycles < systolic.
        assert_eq!(matmul_cycles(&c, &c.core, 512, 512, 512), 16 * 640 + 128);
    }

    #[test]
    fn matmul_ragged_shapes_pad_up() {
        let c = chip();
        // k=129 needs 2 tile rows (m large enough that the systolic path,
        // not the vector unit, is chosen).
        let a = matmul_cycles(&c, &c.core, 1024, 129, 128);
        let b = matmul_cycles(&c, &c.core, 1024, 128, 128);
        assert_eq!(a, 2 * b - 128); // 2 tiles vs 1 tile, shared inject
    }

    #[test]
    fn gemv_is_mxu_inefficient() {
        // A GEMV achieves far lower systolic utilization than a big GEMM
        // (it runs on the vector unit instead, but the array would idle).
        let c = chip();
        let util = matmul_utilization(&c, &c.core, 1, 4096, 4096);
        let util_big = matmul_utilization(&c, &c.core, 1024, 4096, 4096);
        assert!(util < util_big / 2.0, "gemv {util} vs gemm {util_big}");
        assert!(util_big > 0.5, "large GEMM util should be high: {util_big}");
    }

    #[test]
    fn narrower_array_hurts_gemm_but_not_gemv() {
        // The heterogeneous-decode-core argument (§4.3.1): shrinking
        // sa_dim slows large GEMMs ~4x but GEMVs dispatch to the vector
        // unit, so decode-shaped work is unaffected.
        let c = chip();
        let mut narrow = c.core;
        narrow.sa_dim = 64;
        narrow.sram_bw_gbps_raw = c.core.sram_bw_gbps(c.freq_mhz); // keep feed
        let gemm_wide = matmul_cycles(&c, &c.core, 1024, 4096, 4096) as f64;
        let gemm_narrow = matmul_cycles(&c, &narrow, 1024, 4096, 4096) as f64;
        let gemv_wide = gemv_cycles(&c, &c.core, 4096, 4096) as f64;
        let gemv_narrow = gemv_cycles(&c, &narrow, 4096, 4096) as f64;
        assert!(gemm_narrow / gemm_wide > 3.0);
        assert!(gemv_narrow / gemv_wide < 1.1);
    }

    #[test]
    fn zero_shapes_are_free() {
        let c = chip();
        assert_eq!(matmul_cycles(&c, &c.core, 0, 128, 128), 0);
        assert_eq!(vector_cycles(&c.core, 0, 3), 0);
        assert_eq!(attention_cycles(&c, &c.core, 8, 0, 128, 128), 0);
    }

    #[test]
    fn vector_ops_scale_with_lanes() {
        let c = chip();
        let mut half = c.core;
        half.vector_lanes = 64;
        let full_t = rmsnorm_cycles(&c.core, 128, 4096);
        let half_t = rmsnorm_cycles(&half, 128, 4096);
        assert!(half_t >= 2 * full_t - 1);
    }

    #[test]
    fn attention_scales_with_context() {
        let c = chip();
        let short = attention_cycles(&c, &c.core, 8, 1, 128, 128);
        let long = attention_cycles(&c, &c.core, 8, 1, 4096, 128);
        assert!(long > 4 * short, "short={short} long={long}");
    }

    #[test]
    fn sram_roofline_binds_when_bandwidth_starved() {
        // With auto-scaled SRAM bandwidth the array is always fed (the
        // systolic term binds); explicitly starving the SRAM port makes the
        // roofline take over.
        let c = chip();
        let mut starved = c.core;
        starved.sram_bw_gbps_raw = 8.0; // 16 B/cycle @ 500 MHz
        let (m, k, n) = (512u64, 512, 512);
        let cycles = matmul_cycles(&c, &starved, m, k, n);
        let bytes = (m * k + k * n + m * n) * 2;
        let roofline = (bytes as f64 / 16.0).ceil() as u64;
        assert_eq!(cycles, roofline);
        assert!(cycles > matmul_cycles(&c, &c.core, m, k, n));
    }
}
