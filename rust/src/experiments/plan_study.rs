//! `plan_study` — validate the auto-planner's analytic ranking against
//! transaction-level simulation: the planner's top pick plus every named
//! deployment preset run the same fixed trace on fresh chips, and the
//! study reports both orderings side by side. The acceptance property
//! (gated by the unit test below and by `tools/bench_check` through the
//! bench's `"plan"` section) is that the **top analytic pick lands in the
//! simulated top-2** and never loses to the worst enumerated preset —
//! i.e. the analytic machinery is good enough to *choose* deployments,
//! not just to describe them.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment plan_study
//! ```

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::parallel::plan::{self, DeploymentPlan};
use crate::serving::request::{self, Request};
use crate::serving::scheduler::{self, SchedulerConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};
use crate::util::units::cycles_to_secs;

/// One simulated deployment of the study.
#[derive(Debug, Clone)]
pub struct PlanRun {
    /// Plan label (`auto` for the planner's pick, else the preset name).
    pub plan: String,
    /// Is this the auto-planner's top pick?
    pub auto: bool,
    /// Analytic makespan estimate (cycles, the planner's ranking key).
    pub analytic_score: f64,
    /// 1-based rank by `analytic_score` within the study rows.
    pub analytic_rank: usize,
    /// Simulated wall-clock of the trace (seconds of chip time).
    pub sim_makespan_s: f64,
    /// 1-based rank by `sim_makespan_s` within the study rows (ties
    /// resolve toward the auto row, then by label — deterministic).
    pub sim_rank: usize,
    pub tok_s: f64,
    pub ttft_p50_s: f64,
}

/// The study's fixed trace: batch-arrived 512:48 requests — two prefill
/// chunks plus a decode tail per request, a shape on which the §5.6
/// guidance (K partition, ring placement) is unambiguous.
pub fn study_workload(opts: &Opts) -> WorkloadConfig {
    WorkloadConfig::fixed_ratio(512, 48, opts.pick(24, 6)).with_seed(5)
}

/// Simulate one plan over `reqs` on a fresh large-core chip; returns
/// `(makespan seconds, tokens/s, ttft p50)`.
fn simulate_plan(
    model: &ModelConfig,
    reqs: &[Request],
    plan: &DeploymentPlan,
) -> anyhow::Result<(f64, f64, f64)> {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let sys = SchedulerConfig::from_plan(plan)?;
    let mut sched = sys.build();
    let m = scheduler::simulate_requests(&mut chip, model, reqs.to_vec(), sched.as_mut())?;
    let mut ttft = m.ttft_s();
    Ok((
        cycles_to_secs(m.makespan(), chip.cfg.freq_mhz),
        m.tokens_per_s(),
        ttft.median(),
    ))
}

/// Run the study: the auto-planner's top pick plus the named presets,
/// each simulated on the fixed trace, with both rankings attached.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<PlanRun>> {
    let chip = ChipConfig::large_core();
    let model = ModelConfig::qwen3_4b();
    let w = study_workload(opts);
    let reqs = request::generate(&w);

    let ranked = plan::auto_plan(&chip, &model, &w)?;
    let auto_pick = ranked.first().expect("auto_plan is non-empty").clone();

    // The simulated candidate set: the auto pick plus the presets whose
    // timelines are distinct deployments (hybrid is fusion + a controller
    // — its quiescent timeline duplicates fusion's and is studied by
    // `hybrid_study`, so it would only pad this grid).
    let mut cands: Vec<(String, bool, DeploymentPlan)> =
        vec![("auto".into(), true, auto_pick.plan.clone())];
    for p in DeploymentPlan::presets() {
        if p.mode == plan::PdMode::Hybrid {
            continue;
        }
        cands.push((p.name.clone(), false, p));
    }

    let mut rows: Vec<PlanRun> = Vec::with_capacity(cands.len());
    for (label, auto, p) in &cands {
        let analytic = plan::score_plan(&chip, &model, &w, p)
            .map(|s| s.total_cycles)
            .unwrap_or(f64::INFINITY);
        let (makespan, tok_s, ttft_p50) = simulate_plan(&model, &reqs, p)?;
        rows.push(PlanRun {
            plan: label.clone(),
            auto: *auto,
            analytic_score: analytic,
            analytic_rank: 0,
            sim_makespan_s: makespan,
            sim_rank: 0,
            tok_s,
            ttft_p50_s: ttft_p50,
        });
    }

    // Attach both rankings (1-based; deterministic tie-breaks: the auto
    // row first — it may be configured identically to a preset and then
    // simulates identically — then the label).
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by(|&a, &b| {
        rows[a]
            .analytic_score
            .total_cmp(&rows[b].analytic_score)
            .then_with(|| rows[b].auto.cmp(&rows[a].auto))
            .then_with(|| rows[a].plan.cmp(&rows[b].plan))
    });
    for (rank, &i) in order.iter().enumerate() {
        rows[i].analytic_rank = rank + 1;
    }
    let mut order: Vec<usize> = (0..rows.len()).collect();
    order.sort_by(|&a, &b| {
        rows[a]
            .sim_makespan_s
            .total_cmp(&rows[b].sim_makespan_s)
            .then_with(|| rows[b].auto.cmp(&rows[a].auto))
            .then_with(|| rows[a].plan.cmp(&rows[b].plan))
    });
    for (rank, &i) in order.iter().enumerate() {
        rows[i].sim_rank = rank + 1;
    }
    Ok(rows)
}

/// The `sim_makespan_s` of one row by label.
pub fn makespan(rows: &[PlanRun], label: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.plan == label)
        .map(|r| r.sim_makespan_s)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let chip = ChipConfig::large_core();
    let model = ModelConfig::qwen3_4b();
    let w = study_workload(opts);
    let ranked = plan::auto_plan(&chip, &model, &w)?;
    println!(
        "auto-planner: {} feasible candidates; picked {}",
        ranked.len(),
        ranked[0].plan.summary()
    );

    let rows = bench_rows(opts)?;
    let mut t = Table::new(
        "plan_study — analytic ranking vs transaction-level simulation (Qwen3-4B, 64 cores, 512:48)",
        &[
            "plan",
            "analytic score (Mcyc)",
            "analytic rank",
            "sim makespan (s)",
            "sim rank",
            "tok/s",
            "TTFT p50 (s)",
        ],
    );
    for r in &rows {
        t.row(&[
            if r.auto {
                format!("auto ({})", ranked[0].plan.name)
            } else {
                r.plan.clone()
            },
            f3(r.analytic_score / 1e6),
            r.analytic_rank.to_string(),
            f3(r.sim_makespan_s),
            r.sim_rank.to_string(),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
        ]);
    }
    let auto = rows.iter().find(|r| r.auto).expect("auto row");
    println!(
        "plan_study: auto pick simulated rank {} of {} (analytic rank {}) — top-2 {}",
        auto.sim_rank,
        rows.len(),
        auto.analytic_rank,
        if auto.sim_rank <= 2 { "OK" } else { "VIOLATED" }
    );
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_pick_lands_in_the_simulated_top_2() {
        // The acceptance property at fast scale: the planner's analytic
        // choice must be vindicated by the transaction-level simulator —
        // top pick in the simulated top-2, and never behind the worst
        // enumerated preset.
        let rows = bench_rows(&Opts::fast()).unwrap();
        let auto = rows.iter().find(|r| r.auto).expect("auto row");
        assert!(
            auto.sim_rank <= 2,
            "auto pick simulated rank {} of {}: {:?}",
            auto.sim_rank,
            rows.len(),
            rows.iter()
                .map(|r| (r.plan.clone(), r.sim_makespan_s))
                .collect::<Vec<_>>()
        );
        let worst_preset = rows
            .iter()
            .filter(|r| !r.auto)
            .map(|r| r.sim_makespan_s)
            .fold(0.0f64, f64::max);
        assert!(
            auto.sim_makespan_s <= worst_preset,
            "auto {} slower than the worst preset {}",
            auto.sim_makespan_s,
            worst_preset
        );
        assert_eq!(auto.analytic_rank, 1, "auto row must top the analytic order");
    }

    #[test]
    fn study_rows_are_deterministic() {
        let a = bench_rows(&Opts::fast()).unwrap();
        let b = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.sim_makespan_s, y.sim_makespan_s, "{}", x.plan);
            assert_eq!(x.analytic_score, y.analytic_score, "{}", x.plan);
            assert_eq!((x.sim_rank, x.analytic_rank), (y.sim_rank, y.analytic_rank));
        }
    }

    #[test]
    fn strategy_presets_order_as_fig9_predicts() {
        // On the 512:48 trace the K partition must simulate faster than
        // MN and 2-D at the same layout — the Fig. 9 ordering end-to-end.
        let rows = bench_rows(&Opts::fast()).unwrap();
        let ms = |l: &str| makespan(&rows, l).unwrap_or_else(|| panic!("{l} missing"));
        assert!(ms("fusion") < ms("fusion-mn"), "K !< MN");
        assert!(ms("fusion") < ms("fusion-2d"), "K !< 2D");
    }
}
