//! Core placement for PD disaggregation (Fig. 6).
//!
//! - **DP-prioritized** (WSC-LLM): the chip is first split into `dp` data-
//!   parallel bands; within each band cores are assigned to prefill and
//!   decode by the requested ratio. KV transfers then compete with the
//!   band's own pipeline traffic.
//! - **PP-prioritized** (this paper): pipeline-parallel columns are
//!   assigned from the chip *edges* inward for prefill, leaving decode
//!   cores in the center — every prefill column has an unobstructed mesh
//!   path toward the decode region, maximising prefill→decode KV-transfer
//!   bandwidth while pipeline traffic flows along the columns.

use super::placement::TpGroup;
use crate::sim::noc::Coord;

/// PD-disaggregation placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PdPlacementPolicy {
    /// WSC-LLM style: `dp` bands, split each by ratio.
    DpPrioritized { dp: usize },
    /// Paper's: prefill at the edges, decode in the center.
    PpPrioritized,
}

impl PdPlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PdPlacementPolicy::DpPrioritized { .. } => "dp-prioritized",
            PdPlacementPolicy::PpPrioritized => "pp-prioritized",
        }
    }
}

/// The physical core assignment produced by a policy.
#[derive(Debug, Clone)]
pub struct PdAssignment {
    /// Prefill pipelines: `[pipeline][stage]` TP groups.
    pub prefill_pipelines: Vec<Vec<TpGroup>>,
    /// Decode worker groups (each runs all layers with TP).
    pub decode_groups: Vec<TpGroup>,
    pub policy: PdPlacementPolicy,
}

impl PdAssignment {
    pub fn n_prefill_cores(&self) -> usize {
        self.prefill_pipelines
            .iter()
            .flat_map(|p| p.iter())
            .map(|g| g.len())
            .sum()
    }

    pub fn n_decode_cores(&self) -> usize {
        self.decode_groups.iter().map(|g| g.len()).sum()
    }

    /// Mean Manhattan distance from prefill cores to their nearest decode
    /// core — the KV-transfer distance statistic the edge/center layout
    /// optimises.
    pub fn mean_kv_distance(&self) -> f64 {
        let decode: Vec<Coord> = self
            .decode_groups
            .iter()
            .flat_map(|g| g.coords.iter().cloned())
            .collect();
        if decode.is_empty() {
            return 0.0;
        }
        let mut total = 0usize;
        let mut count = 0usize;
        for p in &self.prefill_pipelines {
            for g in p {
                for &c in &g.coords {
                    total += decode.iter().map(|&d| c.hops_to(d)).min().unwrap();
                    count += 1;
                }
            }
        }
        if count == 0 {
            0.0
        } else {
            total as f64 / count as f64
        }
    }
}

/// Split `n` fleet chips between prefill and decode roles so the slower
/// stage of the prefill→decode pipeline is as fast as possible:
/// minimise `max(prefill_work / n_p, decode_work / n_d)` over
/// `n_p + n_d = n`, both at least 1. Work units are arbitrary but must be
/// commensurable (the fleet planner passes analytic cycles). Ties prefer
/// more decode chips — the memory-bound phase scales worse in practice.
///
/// The same bottleneck criterion the intra-chip [`assign`] ratio sweep
/// (Fig. 11) optimises, lifted to whole chips.
pub fn fleet_split(prefill_work: f64, decode_work: f64, n: usize) -> (usize, usize) {
    assert!(n >= 2, "a disaggregated fleet needs at least 2 chips");
    let p = prefill_work.max(0.0);
    let d = decode_work.max(0.0);
    let mut best = (1usize, n - 1);
    let mut best_cost = f64::INFINITY;
    for n_p in 1..n {
        let n_d = n - n_p;
        let cost = (p / n_p as f64).max(d / n_d as f64);
        // Strict `<`: earlier (smaller n_p, larger n_d) splits win ties.
        if cost < best_cost {
            best_cost = cost;
            best = (n_p, n_d);
        }
    }
    best
}

/// Build a TP group from an arbitrary coordinate list, interleaving the
/// order so logical ring neighbours stay within ~2 hops even on straight
/// column segments.
fn tp_group_from_coords(mut coords: Vec<Coord>) -> TpGroup {
    // Interleave: evens forward, odds backward.
    let n = coords.len();
    let mut order = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        order.push(coords[i]);
        i += 2;
    }
    let mut j = if n % 2 == 0 { n.saturating_sub(1) } else { n.saturating_sub(2) };
    while n > 1 {
        if j % 2 == 1 {
            order.push(coords[j]);
        }
        if j <= 1 {
            break;
        }
        j -= 2;
    }
    if n == 1 {
        order = std::mem::take(&mut coords);
    }
    TpGroup {
        coords: order,
        placement: super::placement::Placement::LinearInterleave,
    }
}

/// Compute the PD core assignment.
///
/// * `rows`/`cols`: chip mesh shape.
/// * `n_prefill`/`n_decode`: core counts (must fit on the chip).
/// * `prefill_tp`: TP size of each prefill pipeline stage.
/// * `prefill_stages`: pipeline stages per prefill pipeline.
/// * `decode_tp`: TP size of each decode group.
pub fn assign(
    rows: usize,
    cols: usize,
    n_prefill: usize,
    n_decode: usize,
    prefill_tp: usize,
    prefill_stages: usize,
    decode_tp: usize,
    policy: PdPlacementPolicy,
) -> anyhow::Result<PdAssignment> {
    anyhow::ensure!(
        n_prefill + n_decode <= rows * cols,
        "{} prefill + {} decode cores exceed the {}x{} chip",
        n_prefill,
        n_decode,
        rows,
        cols
    );
    anyhow::ensure!(prefill_tp > 0 && decode_tp > 0 && prefill_stages > 0);

    let (prefill_coords, decode_coords) = match policy {
        PdPlacementPolicy::PpPrioritized => {
            // Column order: edges first (0, cols-1, 1, cols-2, ...).
            let mut col_order = Vec::with_capacity(cols);
            let (mut lo, mut hi) = (0usize, cols - 1);
            while lo <= hi {
                col_order.push(lo);
                if lo != hi {
                    col_order.push(hi);
                }
                if hi == 0 {
                    break;
                }
                lo += 1;
                hi -= 1;
            }
            let mut all = Vec::with_capacity(rows * cols);
            for &c in &col_order {
                for r in 0..rows {
                    all.push(Coord::new(r, c));
                }
            }
            let prefill: Vec<Coord> = all[..n_prefill].to_vec();
            // Decode takes from the *end* of the edge-first order — i.e.
            // the center columns.
            let decode: Vec<Coord> = all[all.len() - n_decode..].to_vec();
            (prefill, decode)
        }
        PdPlacementPolicy::DpPrioritized { dp } => {
            anyhow::ensure!(dp > 0 && dp <= rows, "dp {dp} must divide the mesh rows");
            let band_rows = rows / dp;
            let per_band_prefill = n_prefill / dp;
            let per_band_decode = n_decode / dp;
            let mut prefill = Vec::new();
            let mut decode = Vec::new();
            for b in 0..dp {
                let r0 = b * band_rows;
                let mut band = Vec::new();
                for r in r0..(r0 + band_rows).min(rows) {
                    for c in 0..cols {
                        band.push(Coord::new(r, c));
                    }
                }
                prefill.extend(band.iter().take(per_band_prefill).cloned());
                decode.extend(
                    band.iter()
                        .skip(per_band_prefill)
                        .take(per_band_decode)
                        .cloned(),
                );
            }
            // Distribute any remainder round-robin from unassigned cores.
            let assigned: std::collections::HashSet<Coord> =
                prefill.iter().chain(decode.iter()).cloned().collect();
            let mut rest: Vec<Coord> = (0..rows)
                .flat_map(|r| (0..cols).map(move |c| Coord::new(r, c)))
                .filter(|c| !assigned.contains(c))
                .collect();
            while prefill.len() < n_prefill {
                prefill.push(rest.remove(0));
            }
            while decode.len() < n_decode {
                decode.push(rest.remove(0));
            }
            (prefill, decode)
        }
    };

    // Chunk prefill coords into pipelines of `stages × tp`.
    let per_pipeline = prefill_tp * prefill_stages;
    let n_pipelines = (prefill_coords.len() / per_pipeline).max(1);
    let mut prefill_pipelines = Vec::with_capacity(n_pipelines);
    for p in 0..n_pipelines {
        let base = p * per_pipeline;
        if base + per_pipeline > prefill_coords.len() {
            break;
        }
        let mut stages = Vec::with_capacity(prefill_stages);
        for s in 0..prefill_stages {
            let c0 = base + s * prefill_tp;
            stages.push(tp_group_from_coords(
                prefill_coords[c0..c0 + prefill_tp].to_vec(),
            ));
        }
        prefill_pipelines.push(stages);
    }
    anyhow::ensure!(
        !prefill_pipelines.is_empty(),
        "not enough prefill cores ({}) for one pipeline of {} stages x TP {}",
        prefill_coords.len(),
        prefill_stages,
        prefill_tp
    );

    // Chunk decode coords into TP groups, preferring column-compact groups:
    // a TP ring inside one mesh column has 1–2-hop neighbours and leaves the
    // row links free for prefill→decode KV transfers (the Fig. 6-b point).
    let mut decode_groups = Vec::new();
    {
        let mut by_col: std::collections::BTreeMap<usize, Vec<Coord>> =
            std::collections::BTreeMap::new();
        for &c in &decode_coords {
            by_col.entry(c.col).or_default().push(c);
        }
        let mut leftovers: Vec<Coord> = Vec::new();
        for (_, mut col) in by_col {
            col.sort();
            let mut it = col.into_iter().peekable();
            loop {
                let chunk: Vec<Coord> = it.by_ref().take(decode_tp).collect();
                if chunk.len() == decode_tp {
                    decode_groups.push(tp_group_from_coords(chunk));
                } else {
                    leftovers.extend(chunk);
                    break;
                }
            }
        }
        leftovers.sort();
        for chunk in leftovers.chunks(decode_tp) {
            if chunk.len() == decode_tp {
                decode_groups.push(tp_group_from_coords(chunk.to_vec()));
            }
        }
    }
    anyhow::ensure!(
        !decode_groups.is_empty(),
        "not enough decode cores ({}) for TP {}",
        decode_coords.len(),
        decode_tp
    );

    Ok(PdAssignment {
        prefill_pipelines,
        decode_groups,
        policy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pp_prioritized_puts_prefill_at_edges() {
        let a = assign(8, 8, 32, 32, 4, 2, 4, PdPlacementPolicy::PpPrioritized).unwrap();
        // Prefill columns should be the 4 edge-most columns (0,7,1,6).
        let prefill_cols: std::collections::HashSet<usize> = a
            .prefill_pipelines
            .iter()
            .flatten()
            .flat_map(|g| g.coords.iter().map(|c| c.col))
            .collect();
        assert_eq!(
            prefill_cols,
            [0usize, 7, 1, 6].into_iter().collect::<std::collections::HashSet<_>>()
        );
        // Decode in the center columns.
        let decode_cols: std::collections::HashSet<usize> = a
            .decode_groups
            .iter()
            .flat_map(|g| g.coords.iter().map(|c| c.col))
            .collect();
        assert_eq!(
            decode_cols,
            [2usize, 3, 4, 5].into_iter().collect::<std::collections::HashSet<_>>()
        );
    }

    #[test]
    fn pp_layout_shortens_kv_distance_vs_dp() {
        let pp = assign(8, 8, 40, 24, 4, 2, 4, PdPlacementPolicy::PpPrioritized).unwrap();
        let dp = assign(8, 8, 40, 24, 4, 2, 4, PdPlacementPolicy::DpPrioritized { dp: 4 }).unwrap();
        // Edge/center layout should not be worse on mean KV distance.
        assert!(
            pp.mean_kv_distance() <= dp.mean_kv_distance() + 0.5,
            "pp={} dp={}",
            pp.mean_kv_distance(),
            dp.mean_kv_distance()
        );
    }

    #[test]
    fn core_counts_respected() {
        let a = assign(8, 8, 48, 16, 4, 3, 8, PdPlacementPolicy::PpPrioritized).unwrap();
        assert_eq!(a.n_prefill_cores(), 48);
        assert_eq!(a.n_decode_cores(), 16);
        assert_eq!(a.prefill_pipelines.len(), 4); // 48 / (4*3)
        assert_eq!(a.decode_groups.len(), 2); // 16 / 8
    }

    #[test]
    fn dp_prioritized_bands() {
        let a = assign(8, 8, 32, 32, 4, 2, 4, PdPlacementPolicy::DpPrioritized { dp: 4 }).unwrap();
        assert_eq!(a.n_prefill_cores(), 32);
        assert_eq!(a.n_decode_cores(), 32);
    }

    #[test]
    fn no_overlap_between_prefill_and_decode() {
        for policy in [
            PdPlacementPolicy::PpPrioritized,
            PdPlacementPolicy::DpPrioritized { dp: 2 },
        ] {
            let a = assign(8, 8, 42, 21, 7, 3, 7, policy).unwrap();
            let prefill: std::collections::HashSet<Coord> = a
                .prefill_pipelines
                .iter()
                .flatten()
                .flat_map(|g| g.coords.iter().cloned())
                .collect();
            let decode: std::collections::HashSet<Coord> = a
                .decode_groups
                .iter()
                .flat_map(|g| g.coords.iter().cloned())
                .collect();
            assert!(prefill.is_disjoint(&decode), "{policy:?}");
        }
    }

    #[test]
    fn too_many_cores_rejected() {
        assert!(assign(4, 4, 12, 8, 4, 1, 4, PdPlacementPolicy::PpPrioritized).is_err());
    }

    #[test]
    fn fleet_split_balances_the_bottleneck() {
        // Equal work, 4 chips: 2/2.
        assert_eq!(fleet_split(100.0, 100.0, 4), (2, 2));
        // Prefill-heavy 3:1 on 4 chips: 3 prefill, 1 decode.
        assert_eq!(fleet_split(300.0, 100.0, 4), (3, 1));
        // Decode-heavy: decode gets the chips, prefill keeps >= 1.
        assert_eq!(fleet_split(10.0, 1000.0, 4), (1, 3));
        // Ties prefer decode chips.
        assert_eq!(fleet_split(0.0, 0.0, 4), (1, 3));
        // Both sides always staffed.
        let (p, d) = fleet_split(1e9, 1e-9, 2);
        assert_eq!((p, d), (1, 1));
    }

    #[test]
    fn paper_fig11_ratios_fit() {
        // P49/D14, P42/D21, P28/D28(+8 idle), P21/D42 on the 64-core chip:
        // TP=7 groups, pipeline depth scaling with the prefill share.
        for (p, d, stages) in [(49, 14, 7), (42, 21, 6), (28, 28, 4), (21, 42, 3)] {
            let a = assign(8, 8, p, d, 7, stages, 7, PdPlacementPolicy::PpPrioritized);
            assert!(a.is_ok(), "P{p}/D{d}: {:?}", a.err());
        }
    }
}
