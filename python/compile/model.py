"""L2: TinyQwen — a Qwen3-style transformer in functional JAX, calling the
L1 Pallas kernels, lowered once by aot.py to HLO text for the rust runtime.

Architecture mirrors the paper's evaluated family at toy scale: RMSNorm,
RoPE, grouped-query attention, SwiGLU FFN, tied embeddings. Weights are
seeded constants baked into the lowered HLO so the rust side only feeds
tokens (and the KV cache it threads between decode steps).

Entry points (both return a tuple, lowered with return_tuple=True):
  prefill(tokens[i32 B,P])            -> (logits[B,P,V], kv[L,2,B,S,KH,D])
  decode(tokens[i32 B], pos[i32], kv) -> (logits[B,V],   kv[L,2,B,S,KH,D])
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention
from compile.kernels.matmul import matmul_batched
from compile.kernels.swiglu import swiglu_batched

# Toy config (exported to artifacts/model_meta.txt; rust parses it).
CONFIG = {
    "vocab": 256,
    "hidden": 64,
    "layers": 2,
    "heads": 4,
    "kv_heads": 2,
    "head_dim": 16,
    "intermediate": 128,
    "max_seq": 64,
    "prefill_len": 16,
    "decode_batch": 2,
}


def init_params(seed: int = 0):
    """Seeded parameter pytree (f32)."""
    c = CONFIG
    h, hd = c["hidden"], c["head_dim"]
    qd = c["heads"] * hd
    kvd = c["kv_heads"] * hd
    keys = jax.random.split(jax.random.PRNGKey(seed), 2 + 7 * c["layers"])
    scale = 0.05
    params = {
        "embed": jax.random.normal(keys[0], (c["vocab"], h)) * scale,
        "final_norm": jnp.ones((h,)),
        "layers": [],
    }
    ki = 1
    for _ in range(c["layers"]):
        params["layers"].append(
            {
                "attn_norm": jnp.ones((h,)),
                "wq": jax.random.normal(keys[ki + 0], (h, qd)) * scale,
                "wk": jax.random.normal(keys[ki + 1], (h, kvd)) * scale,
                "wv": jax.random.normal(keys[ki + 2], (h, kvd)) * scale,
                "wo": jax.random.normal(keys[ki + 3], (qd, h)) * scale,
                "ffn_norm": jnp.ones((h,)),
                "w_gate": jax.random.normal(keys[ki + 4], (h, c["intermediate"])) * scale,
                "w_up": jax.random.normal(keys[ki + 5], (h, c["intermediate"])) * scale,
                "w_down": jax.random.normal(keys[ki + 6], (c["intermediate"], h)) * scale,
            }
        )
        ki += 7
    return params


def _rmsnorm(x, w):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * w


def _rope(x, positions):
    """Rotary embedding; x [..., T, n_heads, d], positions [T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _swiglu(params, x):
    gate = matmul_batched(x, params["w_gate"])
    up = matmul_batched(x, params["w_up"])
    return matmul_batched(swiglu_batched(gate, up), params["w_down"])


def prefill(params, tokens):
    """Full-prompt pass. tokens [B, P] i32 -> (logits [B,P,V], kv)."""
    c = CONFIG
    b, p = tokens.shape
    s, kh, hd, nh = c["max_seq"], c["kv_heads"], c["head_dim"], c["heads"]
    positions = jnp.arange(p)
    x = params["embed"][tokens]  # [B, P, H]
    kv = jnp.zeros((c["layers"], 2, b, s, kh, hd), jnp.float32)

    causal = jnp.tril(jnp.ones((p, p), bool))
    for li, lp in enumerate(params["layers"]):
        xin = _rmsnorm(x, lp["attn_norm"])
        q = matmul_batched(xin, lp["wq"]).reshape(b, p, nh, hd)
        k = matmul_batched(xin, lp["wk"]).reshape(b, p, kh, hd)
        v = matmul_batched(xin, lp["wv"]).reshape(b, p, kh, hd)
        q = _rope(q, positions)
        k = _rope(k, positions)
        kv = kv.at[li, 0, :, :p].set(k)
        kv = kv.at[li, 1, :, :p].set(v)
        # Prefill attention (jnp; the Pallas hot-spot is the decode path).
        groups = nh // kh
        kf = jnp.repeat(k, groups, axis=2)
        vf = jnp.repeat(v, groups, axis=2)
        logits = jnp.einsum("bthd,bshd->bhts", q, kf) / (hd**0.5)
        logits = jnp.where(causal[None, None], logits, -jnp.inf)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", w, vf).reshape(b, p, nh * hd)
        x = x + matmul_batched(attn, lp["wo"])
        x = x + _swiglu(lp, _rmsnorm(x, lp["ffn_norm"]))

    x = _rmsnorm(x, params["final_norm"])
    logits = matmul_batched(x, params["embed"].T)  # tied embeddings
    return logits, kv


def decode(params, tokens, pos, kv):
    """One decode step. tokens [B] i32, pos scalar i32 (tokens go to index
    `pos`; attention covers [0, pos]). Returns (logits [B,V], new kv)."""
    c = CONFIG
    b = tokens.shape[0]
    kh, hd, nh = c["kv_heads"], c["head_dim"], c["heads"]
    x = params["embed"][tokens][:, None, :]  # [B, 1, H]
    positions = pos[None].astype(jnp.int32)

    for li, lp in enumerate(params["layers"]):
        xin = _rmsnorm(x, lp["attn_norm"])
        q = matmul_batched(xin, lp["wq"]).reshape(b, 1, nh, hd)
        k = matmul_batched(xin, lp["wk"]).reshape(b, 1, kh, hd)
        v = matmul_batched(xin, lp["wv"]).reshape(b, 1, kh, hd)
        q = _rope(q, positions)
        k = _rope(k, positions)
        kv = jax.lax.dynamic_update_slice(
            kv, k[None, None, :, :, :, :], (li, 0, 0, pos, 0, 0)
        )
        kv = jax.lax.dynamic_update_slice(
            kv, v[None, None, :, :, :, :], (li, 1, 0, pos, 0, 0)
        )
        kv_len = jnp.full((b,), pos + 1, jnp.int32)
        attn = decode_attention(q[:, 0], kv[li, 0], kv[li, 1], kv_len)  # [B,NH,hd]
        x = x + matmul_batched(attn.reshape(b, 1, nh * hd), lp["wo"])
        x = x + _swiglu(lp, _rmsnorm(x, lp["ffn_norm"]))

    x = _rmsnorm(x, params["final_norm"])
    logits = matmul_batched(x, params["embed"].T)[:, 0]
    return logits, kv


@functools.lru_cache(maxsize=1)
def entry_points(seed: int = 0):
    """(prefill_fn, decode_fn) closed over the seeded parameters; both
    return tuples, ready for jax.jit(...).lower()."""
    params = init_params(seed)

    def prefill_fn(tokens):
        return prefill(params, tokens)

    def decode_fn(tokens, pos, kv):
        return decode(params, tokens, pos, kv)

    return prefill_fn, decode_fn
