//! Deterministic radix/trie index over token-block hashes — the lookup
//! structure behind prefix-sharing KV caching (vLLM/SGLang-style automatic
//! prefix caching, adapted to the paper's fine-grained SRAM blocks).
//!
//! Each node stands for one SRAM block holding one block's worth of prefix
//! tokens; its key is the content hash of that token block, and its parent
//! is the preceding block of the prefix — so a path from the root spells a
//! token prefix, and the longest matching path is exactly the longest
//! cached prefix of an incoming request. Nodes hold the *terminal* token
//! count too, so a partially filled final block of a shared prefix (e.g. a
//! system prompt that is not block-aligned) is matchable; divergence past
//! it is handled by the [`super::kv::KvCache`]'s copy-on-write.
//!
//! Eviction is ref-count-aware LRU: only leaf nodes whose block has no
//! owner besides the index itself are candidates, ordered by last use then
//! node id — fully deterministic (no HashMap iteration order leaks into
//! behaviour; the map is only ever *probed* by key).
//!
//! Matching is **in-flight aware**: a node registered at admission time is
//! [`PENDING`] until the producing prefill actually completes
//! ([`PrefixIndex::mark_ready`]), and [`PrefixIndex::lookup`]/
//! [`PrefixIndex::peek`] only match nodes whose `ready_at` is at or before
//! the probing cycle — so a just-registered block never counts as a hit
//! (and never skips prefill work) before its KV physically exists.

use std::collections::HashMap;

/// Sentinel parent for root-level nodes.
pub const NO_NODE: u32 = u32::MAX;

/// `ready_at` sentinel for blocks whose producing prefill is in flight.
pub const PENDING: u64 = u64::MAX;

/// One token block of a shareable prefix: the content hash of the block
/// and how many tokens it holds (full blocks hold `block_tokens`; the
/// terminal block of a prefix may hold fewer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockKey {
    pub hash: u64,
    pub tokens: u64,
}

#[derive(Debug, Clone, Copy)]
struct Node {
    parent: u32,
    hash: u64,
    block: u32,
    tokens: u64,
    last_use: u64,
    n_children: u32,
    live: bool,
    /// Cycle at which the block's KV is materialised ([`PENDING`] while
    /// the producing prefill is still in flight).
    ready_at: u64,
}

/// A matched or registered prefix block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixBlock {
    pub node: u32,
    pub block: u32,
    pub tokens: u64,
}

/// The trie of cached prefix blocks for one [`super::kv::KvCache`].
#[derive(Debug, Default)]
pub struct PrefixIndex {
    nodes: Vec<Node>,
    /// `(parent node | NO_NODE, block hash) -> node` — probed by key only.
    children: HashMap<(u32, u64), u32>,
    free_slots: Vec<u32>,
    tick: u64,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live (cached) prefix blocks.
    pub fn n_cached(&self) -> usize {
        self.nodes.iter().filter(|n| n.live).count()
    }

    /// Advance the LRU clock (once per lookup).
    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Is `key` already cached as a child of `parent`? (Used to stop
    /// registration when a capped match left cached continuations.)
    pub fn child_of(&self, parent: u32, key: BlockKey) -> Option<u32> {
        self.child(parent, key)
    }

    /// Child of `parent` matching `key` exactly (hash *and* token count).
    fn child(&self, parent: u32, key: BlockKey) -> Option<u32> {
        let &ix = self.children.get(&(parent, key.hash))?;
        let n = &self.nodes[ix as usize];
        (n.live && n.tokens == key.tokens).then_some(ix)
    }

    /// Longest cached-and-ready prefix of `keys`, capped at `max_tokens`:
    /// only nodes whose producing prefill completed at or before cycle
    /// `at` match (registered-but-in-flight blocks are invisible). Touches
    /// every matched node's LRU stamp. Read-only peek via `peek`.
    pub fn lookup(&mut self, keys: &[BlockKey], max_tokens: u64, at: u64) -> Vec<PrefixBlock> {
        let now = self.bump();
        let mut out = Vec::new();
        let mut parent = NO_NODE;
        let mut tokens = 0u64;
        for &key in keys {
            let Some(ix) = self.child(parent, key) else { break };
            if self.nodes[ix as usize].ready_at > at {
                break;
            }
            if tokens + key.tokens > max_tokens {
                break;
            }
            tokens += key.tokens;
            self.nodes[ix as usize].last_use = now;
            out.push(PrefixBlock {
                node: ix,
                block: self.nodes[ix as usize].block,
                tokens: key.tokens,
            });
            parent = ix;
        }
        out
    }

    /// Matched ready token count for `keys` at cycle `at` without mutating
    /// LRU state (used to agree on a common match length across pipeline
    /// stages, and by the cluster router's read-only probe).
    pub fn peek(&self, keys: &[BlockKey], max_tokens: u64, at: u64) -> u64 {
        let mut parent = NO_NODE;
        let mut tokens = 0u64;
        for &key in keys {
            let Some(ix) = self.child(parent, key) else { break };
            if self.nodes[ix as usize].ready_at > at {
                break;
            }
            if tokens + key.tokens > max_tokens {
                break;
            }
            tokens += key.tokens;
            parent = ix;
        }
        tokens
    }

    /// Register `block` as the child of `parent` for `key`, usable by
    /// matches from cycle `ready_at` on (pass [`PENDING`] at admission
    /// time and [`PrefixIndex::mark_ready`] it when the producing prefill
    /// completes). Returns the new node (the caller must hold one
    /// reference on `block` for the index). `parent` is `NO_NODE` for the
    /// first block of a prefix.
    pub fn insert(&mut self, parent: u32, key: BlockKey, block: u32, ready_at: u64) -> u32 {
        debug_assert!(
            self.child(parent, key).is_none(),
            "duplicate prefix insert"
        );
        let now = self.bump();
        let node = Node {
            parent,
            hash: key.hash,
            block,
            tokens: key.tokens,
            last_use: now,
            n_children: 0,
            live: true,
            ready_at,
        };
        let ix = match self.free_slots.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        self.children.insert((parent, key.hash), ix);
        if parent != NO_NODE {
            self.nodes[parent as usize].n_children += 1;
        }
        ix
    }

    /// Record that `node`'s KV exists from cycle `now` on (the producing
    /// prefill completed, or a migrated copy landed). Keeps the earliest
    /// readiness if called twice.
    pub fn mark_ready(&mut self, node: u32, now: u64) {
        let n = &mut self.nodes[node as usize];
        if n.live && now < n.ready_at {
            n.ready_at = now;
        }
    }

    /// Evict the least-recently-used leaf whose block `can_evict` (i.e. is
    /// referenced by nobody but the index). Returns the evicted block so
    /// the caller can drop the index's reference. Deterministic: ties on
    /// `last_use` break on node id.
    pub fn evict_lru(&mut self, can_evict: impl Fn(u32) -> bool) -> Option<u32> {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.live && n.n_children == 0 && can_evict(n.block))
            .min_by_key(|(ix, n)| (n.last_use, *ix))
            .map(|(ix, _)| ix as u32)?;
        Some(self.remove(victim))
    }

    /// Remove one leaf node, returning its block.
    fn remove(&mut self, ix: u32) -> u32 {
        let n = self.nodes[ix as usize];
        debug_assert!(n.live && n.n_children == 0, "removing non-leaf {ix}");
        self.children.remove(&(n.parent, n.hash));
        if n.parent != NO_NODE {
            self.nodes[n.parent as usize].n_children -= 1;
        }
        self.nodes[ix as usize].live = false;
        self.free_slots.push(ix);
        n.block
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(hash: u64) -> BlockKey {
        BlockKey { hash, tokens: 16 }
    }

    #[test]
    fn empty_index_matches_nothing() {
        let mut ix = PrefixIndex::new();
        assert!(ix.lookup(&[key(1), key(2)], u64::MAX, 0).is_empty());
        assert_eq!(ix.peek(&[key(1)], u64::MAX, 0), 0);
    }

    #[test]
    fn longest_prefix_match_walks_the_trie() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        let b = ix.insert(a, key(2), 11, 0);
        ix.insert(b, key(3), 12, 0);
        let m = ix.lookup(&[key(1), key(2), key(9)], u64::MAX, 0);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].block, 10);
        assert_eq!(m[1].block, 11);
        // Full path matches all three.
        assert_eq!(ix.peek(&[key(1), key(2), key(3)], u64::MAX, 0), 48);
        // A different first block matches nothing.
        assert!(ix.lookup(&[key(7)], u64::MAX, 0).is_empty());
    }

    #[test]
    fn partial_terminal_block_requires_exact_token_count() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, BlockKey { hash: 2, tokens: 5 }, 11, 0);
        // Same hash, different fill: no match past the first block.
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 0), 16);
        assert_eq!(
            ix.peek(&[key(1), BlockKey { hash: 2, tokens: 5 }], u64::MAX, 0),
            21
        );
    }

    #[test]
    fn max_tokens_caps_the_match() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        let m = ix.lookup(&[key(1), key(2)], 16, 0);
        assert_eq!(m.len(), 1);
        assert_eq!(ix.peek(&[key(1), key(2)], 20, 0), 16);
    }

    #[test]
    fn pending_blocks_are_invisible_until_marked_ready() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, PENDING);
        let b = ix.insert(a, key(2), 11, PENDING);
        // In flight: nothing matches at any finite cycle.
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 1_000_000), 0);
        assert!(ix.lookup(&[key(1), key(2)], u64::MAX, 1_000_000).is_empty());
        // First block's prefill completes at cycle 500: it matches from
        // then on, but the still-pending continuation does not.
        ix.mark_ready(a, 500);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 499), 0);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 500), 16);
        ix.mark_ready(b, 800);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 800), 32);
        // mark_ready keeps the earliest readiness.
        ix.mark_ready(b, 900);
        assert_eq!(ix.peek(&[key(1), key(2)], u64::MAX, 800), 32);
    }

    #[test]
    fn lru_eviction_prefers_cold_leaves_and_respects_refcounts() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        ix.insert(NO_NODE, key(5), 12, 0);
        // Touch the second root so block 12 is no longer the coldest leaf…
        ix.lookup(&[key(5)], u64::MAX, 0);
        // …leaving block 11 (leaf of the first path) as the LRU victim.
        assert_eq!(ix.evict_lru(|_| true), Some(11));
        // Now block 10 is a leaf again; a refcount guard can protect it.
        assert_eq!(ix.evict_lru(|b| b != 10), Some(12));
        assert_eq!(ix.evict_lru(|b| b != 10), None);
        assert_eq!(ix.evict_lru(|_| true), Some(10));
        assert_eq!(ix.n_cached(), 0);
    }

    #[test]
    fn interior_nodes_are_never_evicted() {
        let mut ix = PrefixIndex::new();
        let a = ix.insert(NO_NODE, key(1), 10, 0);
        ix.insert(a, key(2), 11, 0);
        // Block 10 backs an interior node: only 11 is evictable.
        assert_eq!(ix.evict_lru(|_| true), Some(11));
    }

    #[test]
    fn slots_are_recycled_after_eviction() {
        let mut ix = PrefixIndex::new();
        ix.insert(NO_NODE, key(1), 10, 0);
        assert_eq!(ix.evict_lru(|_| true), Some(10));
        let again = ix.insert(NO_NODE, key(3), 20, 0);
        assert_eq!(again, 0, "freed slot reused");
        assert_eq!(ix.peek(&[key(3)], u64::MAX, 0), 16);
        assert_eq!(ix.n_cached(), 1);
    }
}
