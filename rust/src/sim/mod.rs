//! NpuSim — the multi-level simulation framework (§3 of the paper).
//!
//! Three sub-systems at three fidelity levels:
//!
//! - [`compute`]: **performance models** for operators. Compute latency is
//!   deterministic given shapes, so an analytic model is accurate (the
//!   paper measures ≤3% error on compute-bound workloads).
//! - [`memory`]: **transaction-level modeling** of HBM — four-phase
//!   (BeginReq/EndReq/BeginResp/EndResp) transactions over banked channels
//!   with a bounded outstanding window and out-of-order completion — plus a
//!   `Fast` analytic mode for the Fig. 7-right accuracy/speed comparison.
//! - [`noc`]: **cycle-accurate (link-reservation) routing** — XY routing on
//!   a 2D mesh with handshake path setup and channel locking. Once a path
//!   is locked one flit moves per cycle, so the full transfer can be
//!   modeled as a busy interval on every traversed link without a per-flit
//!   loop (this is the paper's own argument for why cycle-accurate routing
//!   does not dominate simulation time).
//!
//! [`engine`] provides the event queue / resource timelines shared by all
//! three; [`core`] and [`chip`] assemble them into NPU cores on a mesh;
//! [`tracer`] collects utilization and phase statistics; [`interconnect`]
//! adds the lightweight chip-to-chip fabric the multi-chip cluster layer
//! charges its KV migrations against.

pub mod chip;
pub mod compute;
pub mod core;
pub mod engine;
pub mod interconnect;
pub mod memory;
pub mod noc;
pub mod tracer;

pub use chip::ChipSim;
pub use core::CoreSim;
pub use engine::{EventQueue, Timeline};
pub use interconnect::{Interconnect, InterconnectConfig, InterconnectStats};
