//! PD disaggregation (§4.3.1): dedicated prefill pipelines and decode
//! groups, with KV-cache transfer between them over the NoC.
//!
//! Prefill cores run pipeline-parallel stages (prompts stream in without
//! waiting); decode cores run tensor-parallel groups over all layers
//! (autoregression tolerates no pipeline bubbles). The placement policy
//! (Fig. 6) decides where each lives — the paper's PP-prioritized layout
//! puts prefill at the chip edges and decode in the center to shorten and
//! de-contend the KV-transfer paths. Heterogeneous chips override the
//! decode cores' hardware (narrower systolic arrays, fatter HBM — §4.3.1).

use crate::config::{ModelConfig, WorkloadConfig};
use crate::model::{BatchItem, IterBatch};
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::pd_placement::{assign, PdAssignment, PdPlacementPolicy};
use crate::serving::metrics::{Metrics, RequestRecord};
use crate::serving::request::{self, Request};
use crate::serving::worker::StageWorker;
use crate::sim::chip::ChipSim;
use crate::sim::tracer::OpClass;
use crate::util::units::{secs_to_cycles, Cycle};
use std::collections::VecDeque;

/// PD-disaggregation serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct DisaggConfig {
    pub n_prefill: usize,
    pub n_decode: usize,
    /// TP degree of each prefill pipeline stage.
    pub prefill_tp: usize,
    /// Pipeline stages per prefill pipeline.
    pub prefill_stages: usize,
    /// TP degree of each decode group (each group runs all layers).
    pub decode_tp: usize,
    pub policy: PdPlacementPolicy,
    /// Partition for the prefill GEMMs (long sequences → AllGather/2-D).
    pub prefill_strategy: PartitionStrategy,
    /// Partition for the decode GEMVs (M=batch is small → AllReduce).
    pub decode_strategy: PartitionStrategy,
    /// Max concurrent decode requests per group.
    pub max_decode_batch: usize,
    pub kv_share: f64,
}

impl DisaggConfig {
    /// The paper's balanced optimum on the 64-core chip: P42/D21 at TP 7
    /// (Fig. 11's "superior overall performance" configuration).
    pub fn p42_d21() -> Self {
        DisaggConfig {
            n_prefill: 42,
            n_decode: 21,
            prefill_tp: 7,
            prefill_stages: 3,
            decode_tp: 7,
            policy: PdPlacementPolicy::PpPrioritized,
            prefill_strategy: PartitionStrategy::OneDimMN,
            decode_strategy: PartitionStrategy::OneDimK,
            max_decode_batch: 32,
            kv_share: 0.6,
        }
    }

    /// A `P<p>/D<d>` ratio preset on the 64-core chip (Fig. 11 sweep).
    pub fn ratio_64(n_prefill: usize, n_decode: usize, prefill_stages: usize) -> Self {
        DisaggConfig {
            n_prefill,
            n_decode,
            prefill_stages,
            ..Self::p42_d21()
        }
    }
}

impl Default for DisaggConfig {
    fn default() -> Self {
        Self::p42_d21()
    }
}

#[derive(Debug, Clone, Copy)]
struct DecodeReq {
    req: Request,
    first_token: Cycle,
    generated: u64,
    ready_at: Cycle,
}

struct DecodeGroup {
    worker: StageWorker,
    /// Transferred but not yet admitted to the KV cache.
    pending: VecDeque<DecodeReq>,
    active: Vec<DecodeReq>,
}

impl DecodeGroup {
    fn load(&self) -> usize {
        self.pending.len() + self.active.len()
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        let now = self.worker.now(chip);
        let pending = self.pending.front().map(|r| r.ready_at);
        let active = self
            .active
            .iter()
            .filter(|a| a.generated < a.req.output_len as u64)
            .map(|a| a.ready_at)
            .min();
        match (pending, active) {
            (None, None) => None,
            (a, b) => Some(now.max(a.unwrap_or(Cycle::MAX).min(b.unwrap_or(Cycle::MAX)))),
        }
    }
}

/// Simulate a full workload under PD disaggregation.
pub fn simulate_disagg(
    chip: &mut ChipSim,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    cfg: &DisaggConfig,
) -> anyhow::Result<Metrics> {
    simulate_disagg_requests(chip, model, request::generate(workload), cfg)
}

/// Like [`simulate_disagg`] but over an explicit request list (trace
/// replay — see [`crate::serving::trace`]). Requests must be sorted by
/// arrival time.
pub fn simulate_disagg_requests(
    chip: &mut ChipSim,
    model: &ModelConfig,
    reqs: Vec<Request>,
    cfg: &DisaggConfig,
) -> anyhow::Result<Metrics> {
    let a: PdAssignment = assign(
        chip.cfg.rows,
        chip.cfg.cols,
        cfg.n_prefill,
        cfg.n_decode,
        cfg.prefill_tp,
        cfg.prefill_stages,
        cfg.decode_tp,
        cfg.policy,
    )?;

    // Heterogeneous decode cores (Fig. 12): apply the chip's decode-core
    // override to every decode coordinate.
    let decode_core = chip.cfg.decode_core();
    if chip.cfg.decode_core.is_some() {
        for g in &a.decode_groups {
            for &c in &g.coords {
                chip.set_core_config(c, decode_core);
            }
        }
    }

    let layers = model.layers;
    let lps = {
        let base = layers / cfg.prefill_stages;
        let extra = layers % cfg.prefill_stages;
        (0..cfg.prefill_stages)
            .map(|s| base + usize::from(s < extra))
            .collect::<Vec<_>>()
    };
    let core = chip.cfg.core;
    let mut queue: VecDeque<Request> = reqs.into();
    let max_tokens = queue
        .iter()
        .map(|r| r.total_tokens())
        .max()
        .unwrap_or(1);
    let mut pipelines: Vec<Vec<StageWorker>> = a
        .prefill_pipelines
        .iter()
        .map(|stages| {
            stages
                .iter()
                .enumerate()
                .map(|(s, g)| {
                    StageWorker::new(
                        &core,
                        model,
                        g.clone(),
                        cfg.prefill_strategy,
                        lps[s].max(1),
                        s + 1 == stages.len(),
                        2048,
                        cfg.kv_share,
                        max_tokens,
                    )
                })
                .collect()
        })
        .collect();
    let mut groups: Vec<DecodeGroup> = a
        .decode_groups
        .iter()
        .map(|g| DecodeGroup {
            worker: StageWorker::new(
                &decode_core,
                model,
                g.clone(),
                cfg.decode_strategy,
                layers,
                true,
                cfg.max_decode_batch,
                cfg.kv_share,
                max_tokens,
            ),
            pending: VecDeque::new(),
            active: Vec::new(),
        })
        .collect();

    let freq = chip.cfg.freq_mhz;
    let total = queue.len();
    let mut metrics = Metrics::new(freq);
    let mut done = 0usize;
    let mut guard = 0u64;

    while done < total {
        guard += 1;
        anyhow::ensure!(
            guard < 4_000_000,
            "disagg scheduler livelock: {done}/{total} done"
        );
        // Earliest actionable prefill (any pipeline, next queued request).
        let prefill_action: Option<(usize, Cycle)> = if queue.is_empty() {
            None
        } else {
            let arrival = secs_to_cycles(queue.front().unwrap().arrival_s, freq);
            pipelines
                .iter()
                .enumerate()
                .map(|(i, p)| (i, p[0].now(chip).max(arrival)))
                .min_by_key(|&(_, t)| t)
        };
        // Earliest actionable decode tick.
        let decode_action: Option<(usize, Cycle)> = groups
            .iter()
            .enumerate()
            .filter_map(|(i, g)| g.next_action(chip).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t);

        match (prefill_action, decode_action) {
            (Some((pi, tp_)), Some((_, td))) if tp_ <= td => {
                done += run_prefill(
                    chip, model, cfg, &mut pipelines[pi], &mut queue, &mut groups, &mut metrics,
                    freq,
                )?;
            }
            (Some((pi, _)), None) => {
                done += run_prefill(
                    chip, model, cfg, &mut pipelines[pi], &mut queue, &mut groups, &mut metrics,
                    freq,
                )?;
            }
            (_, Some((gi, t))) => {
                done += decode_tick(chip, model, cfg, &mut groups[gi], t, &mut metrics, freq);
            }
            (None, None) => anyhow::bail!("deadlock: {done}/{total} requests done"),
        }
    }
    Ok(metrics)
}

/// Run one whole prompt through a prefill pipeline, then transfer its KV to
/// the least-loaded decode group. Returns completions (requests whose
/// output is a single token finish at prefill).
#[allow(clippy::too_many_arguments)]
fn run_prefill(
    chip: &mut ChipSim,
    model: &ModelConfig,
    cfg: &DisaggConfig,
    pipeline: &mut [StageWorker],
    queue: &mut VecDeque<Request>,
    groups: &mut [DecodeGroup],
    metrics: &mut Metrics,
    freq: f64,
) -> anyhow::Result<usize> {
    let r = queue.pop_front().expect("caller checked");
    let arrival = secs_to_cycles(r.arrival_s, freq);
    pipeline[0].advance_to(chip, arrival);

    for s in pipeline.iter_mut() {
        s.admit(r.id);
    }
    let batch = IterBatch::new(vec![BatchItem::prefill(
        r.id,
        r.input_len as u64,
        r.input_len as u64,
    )]);
    let mut finish = 0;
    for s in 0..pipeline.len() {
        finish = pipeline[s].run(chip, model, &batch);
        if s + 1 < pipeline.len() {
            let bytes = r.input_len as u64 * model.hidden as u64 * model.dtype_bytes;
            let src = pipeline[s].group.coords[0];
            let dst = pipeline[s + 1].group.coords[0];
            let t = chip.send(src, dst, bytes, OpClass::P2P);
            finish = finish.max(t.finish);
        }
    }
    let first_token = finish;

    if r.output_len <= 1 {
        for s in pipeline.iter_mut() {
            s.release(r.id);
        }
        metrics.record(RequestRecord {
            id: r.id,
            arrival,
            first_token,
            finish,
            input_tokens: r.input_len as u64,
            output_tokens: 1,
        });
        return Ok(1);
    }

    // KV transfer to the least-loaded decode group: every prefill core
    // streams its KV shard to a decode core (PP-prioritized placement keeps
    // these paths short and off the pipeline's own columns).
    let gi = groups
        .iter()
        .enumerate()
        .min_by_key(|(_, g)| g.load())
        .map(|(i, _)| i)
        .ok_or_else(|| anyhow::anyhow!("no decode groups"))?;
    let total_kv = r.input_len as u64 * model.kv_bytes_per_token(); // whole model
    let mut ready_at = finish;
    let dst_coords = groups[gi].worker.group.coords.clone();
    let n_layers: usize = pipeline.iter().map(|s| s.exec.layers).sum();
    let mut di = 0usize;
    for stage in pipeline.iter() {
        let stage_kv = total_kv * stage.exec.layers as u64 / n_layers.max(1) as u64;
        let per_core = stage_kv / stage.group.coords.len().max(1) as u64;
        for &src in &stage.group.coords {
            let dst = dst_coords[di % dst_coords.len()];
            di += 1;
            let t = chip.send(src, dst, per_core, OpClass::KvTransfer);
            ready_at = ready_at.max(t.finish);
        }
    }
    for s in pipeline.iter_mut() {
        s.release(r.id);
    }
    groups[gi].pending.push_back(DecodeReq {
        req: r,
        first_token,
        generated: 1,
        ready_at,
    });
    let _ = cfg;
    Ok(0)
}

/// One continuous-batching decode iteration on one group.
fn decode_tick(
    chip: &mut ChipSim,
    model: &ModelConfig,
    cfg: &DisaggConfig,
    group: &mut DecodeGroup,
    t: Cycle,
    metrics: &mut Metrics,
    freq: f64,
) -> usize {
    group.worker.advance_to(chip, t);
    let now = group.worker.now(chip);

    // Admit transferred requests (their prefill KV is appended on arrival).
    while let Some(front) = group.pending.front() {
        if front.ready_at > now
            || group.active.len() >= cfg.max_decode_batch
            || !group.worker.can_admit()
        {
            break;
        }
        let r = group.pending.pop_front().unwrap();
        group.worker.admit(r.req.id);
        group.worker.kv.append(r.req.id, r.req.input_len as u64);
        group.active.push(r);
    }

    let items: Vec<BatchItem> = group
        .active
        .iter()
        .filter(|a| a.generated < a.req.output_len as u64 && a.ready_at <= now)
        .map(|a| BatchItem::decode(a.req.id, a.req.input_len as u64 + a.generated))
        .collect();
    if items.is_empty() {
        return 0;
    }
    let ids: Vec<u64> = items.iter().map(|i| i.request).collect();
    let finish = group.worker.run(chip, model, &IterBatch::new(items));

    let mut completions = 0;
    for a in &mut group.active {
        if ids.contains(&a.req.id) {
            a.generated += 1;
            a.ready_at = finish;
        }
    }
    let mut i = 0;
    while i < group.active.len() {
        if group.active[i].generated >= group.active[i].req.output_len as u64 {
            let a = group.active.swap_remove(i);
            group.worker.release(a.req.id);
            metrics.record(RequestRecord {
                id: a.req.id,
                arrival: secs_to_cycles(a.req.arrival_s, freq),
                first_token: a.first_token,
                finish,
                input_tokens: a.req.input_len as u64,
                output_tokens: a.req.output_len as u64,
            });
            completions += 1;
        } else {
            i += 1;
        }
    }
    completions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn run(workload: &WorkloadConfig, cfg: &DisaggConfig) -> Metrics {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_disagg(&mut chip, &model, workload, cfg).unwrap()
    }

    #[test]
    fn completes_all_requests() {
        let w = WorkloadConfig::fixed_ratio(256, 16, 8);
        let m = run(&w, &DisaggConfig::default());
        assert_eq!(m.n_requests(), 8);
    }

    #[test]
    fn record_invariants_hold() {
        let w = WorkloadConfig::fixed_ratio(128, 32, 6);
        let m = run(&w, &DisaggConfig::default());
        for r in m.records() {
            assert!(r.first_token >= r.arrival);
            assert!(r.finish >= r.first_token);
            assert_eq!(r.output_tokens, 32);
        }
    }

    #[test]
    fn single_token_outputs_finish_at_prefill() {
        let w = WorkloadConfig::fixed_ratio(128, 1, 4);
        let m = run(&w, &DisaggConfig::default());
        for r in m.records() {
            assert_eq!(r.first_token, r.finish);
        }
    }

    #[test]
    fn more_prefill_cores_cut_ttft() {
        // Fig. 11: increasing prefill cores consistently reduces TTFT.
        let w = WorkloadConfig::fixed_ratio(1000, 16, 8);
        let p21 = run(&w, &DisaggConfig::ratio_64(21, 42, 3));
        let p49 = run(&w, &DisaggConfig::ratio_64(49, 14, 7));
        assert!(
            p49.ttft_s().mean() < p21.ttft_s().mean(),
            "P49 {} vs P21 {}",
            p49.ttft_s().mean(),
            p21.ttft_s().mean()
        );
    }

    #[test]
    fn kv_transfer_traffic_recorded() {
        let w = WorkloadConfig::fixed_ratio(512, 8, 2);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_disagg(&mut chip, &model, &w, &DisaggConfig::default()).unwrap();
        assert!(chip.aggregate_tracer().cycles(OpClass::KvTransfer) > 0);
    }

    #[test]
    fn heterogeneous_decode_cores_applied() {
        let mut decode = ChipConfig::large_core().core;
        decode.sa_dim = 32;
        decode.hbm_bw_gbps = 480.0;
        let mut chip = ChipSim::new(ChipConfig::large_core().with_decode_core(decode));
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(128, 8, 2);
        simulate_disagg(&mut chip, &model, &w, &DisaggConfig::default()).unwrap();
        // Center (decode) cores must carry the override.
        let any_decode = chip.core(crate::sim::noc::Coord::new(0, 3));
        assert_eq!(any_decode.cfg.sa_dim, 32);
    }
}
