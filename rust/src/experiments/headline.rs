//! §5.4 headline — "1.32x–6.03x over SOTA": our per-scenario strategy
//! (partition + placement) against the T10 / WaferLLM / WSC-LLM presets,
//! all run through identical simulation machinery.

use crate::baselines::{self, StrategyPreset};
use crate::config::{ChipConfig, ModelConfig};
use crate::experiments::Opts;
use crate::memmgr::planner::{plan, PlanRequest};
use crate::memmgr::KvCache;
use crate::model::exec::{run_iteration, ExecConfig};
use crate::model::{BatchItem, IterBatch};
use crate::parallel::placement::{Region, TpGroup};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};
use crate::util::units::cycles_to_ms;

/// Single-request prefill+decode latency (ms) under a strategy preset.
///
/// `decode_partition` overrides the partition for the decode phase — the
/// per-phase adaptation that is *our* contribution (AllGather/2-D for the
/// long prefill, AllReduce for the GEMV-shaped decode); the baselines pass
/// `None` and keep their single fixed strategy, as published.
pub fn preset_latency_ms(
    chip_cfg: &ChipConfig,
    model: &ModelConfig,
    tp: usize,
    seq: u64,
    decode_steps: u64,
    preset: &StrategyPreset,
    decode_partition: Option<crate::parallel::partition::PartitionStrategy>,
) -> f64 {
    let mut chip = ChipSim::new(chip_cfg.clone());
    let (r, c) = crate::serving::layout::tp_rect(tp, chip_cfg.rows, chip_cfg.cols);
    let group = TpGroup::place(Region::new(0, 0, r, c), preset.placement);
    let p = plan(
        &chip_cfg.core,
        model,
        &PlanRequest {
            layers: model.layers,
            tp,
            iter_tokens: seq as usize,
            kv_share: 0.5,
        },
    );
    let bpt = (model.kv_bytes_per_token_layer() * model.layers as u64 / tp as u64).max(1);
    // SRAM-only presets (T10/WaferLLM) get no HBM KV tier: overflow KV is
    // charged as remote traffic by the executor.
    let hbm_kv = if preset.uses_hbm {
        chip_cfg.core.hbm_bytes
    } else {
        0
    };
    let mut kv = KvCache::new(p.kv_bytes, 16, hbm_kv, bpt, model.max_context as u64);
    kv.admit(1);
    // SRAM-only presets also stream no weights from HBM: if the shard does
    // not fit, it must round-robin through SRAM (modeled as HBM-rate
    // streaming being unavailable → they keep the plan's resident share and
    // re-gather the rest over the NoC each pass, which the MN partition's
    // rotation already charges).
    let exec = ExecConfig::new(preset.partition, model.layers, true);
    let mut t = run_iteration(
        &mut chip,
        &group,
        model,
        &p,
        &exec,
        &IterBatch::new(vec![BatchItem::prefill(1, seq, seq)]),
        &mut kv,
    );
    let dec_exec = ExecConfig::new(
        decode_partition.unwrap_or(preset.partition),
        model.layers,
        true,
    );
    for s in 0..decode_steps {
        t = run_iteration(
            &mut chip,
            &group,
            model,
            &p,
            &dec_exec,
            &IterBatch::new(vec![BatchItem::decode(1, seq + s + 1)]),
            &mut kv,
        );
    }
    cycles_to_ms(t, chip_cfg.freq_mhz)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let models = if opts.fast {
        vec![ModelConfig::qwen3_4b()]
    } else {
        vec![
            ModelConfig::qwen3_1_7b(),
            ModelConfig::qwen3_4b(),
            ModelConfig::qwen3_8b(),
            ModelConfig::qwen3_32b(),
        ]
    };
    let scenarios: Vec<(&str, u64, u64)> = if opts.fast {
        vec![("short prompt", 256, 2)]
    } else {
        vec![("short prompt", 256, 16), ("long prompt", 4096, 16)]
    };
    let tp = 4;
    let chip_cfg = ChipConfig::large_core();

    let mut t = Table::new(
        "§5.4 headline — ours vs SOTA single-request latency (ms), TP=4, 64-core chip",
        &["model", "scenario", "t10", "waferllm", "wsc-llm", "ours", "best speedup"],
    );
    for model in &models {
        for &(name, seq, dec) in &scenarios {
            let ours = baselines::ours(seq, model.hidden as u64, tp);
            let l_ours = preset_latency_ms(
                &chip_cfg,
                model,
                tp,
                seq,
                dec,
                &ours,
                Some(crate::parallel::partition::PartitionStrategy::OneDimK),
            );
            let mut lats = Vec::new();
            for b in baselines::all_baselines() {
                lats.push(preset_latency_ms(&chip_cfg, model, tp, seq, dec, &b, None));
            }
            let best_speedup = lats.iter().cloned().fold(f64::MIN, f64::max) / l_ours;
            t.row(&[
                model.name.clone(),
                name.to_string(),
                f3(lats[0]),
                f3(lats[1]),
                f3(lats[2]),
                f3(l_ours),
                f3(best_speedup),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_beats_every_baseline_somewhere() {
        let chip = ChipConfig::large_core();
        let m = ModelConfig::qwen3_4b();
        let ours = baselines::ours(256, m.hidden as u64, 4);
        let l_ours = preset_latency_ms(
            &chip,
            &m,
            4,
            256,
            2,
            &ours,
            Some(crate::parallel::partition::PartitionStrategy::OneDimK),
        );
        for b in baselines::all_baselines() {
            let l_b = preset_latency_ms(&chip, &m, 4, 256, 2, &b, None);
            assert!(
                l_ours <= l_b * 1.02,
                "ours {l_ours} should not lose to {} {l_b}",
                b.name
            );
        }
    }

    #[test]
    fn speedup_over_t10_is_material_at_short_seq() {
        // The 6.03x headline case: seq << hidden, K-partition vs MN.
        let chip = ChipConfig::large_core();
        let m = ModelConfig::qwen3_4b();
        let ours = baselines::ours(256, m.hidden as u64, 4);
        let l_ours = preset_latency_ms(&chip, &m, 4, 256, 0, &ours, None);
        let l_t10 = preset_latency_ms(&chip, &m, 4, 256, 0, &baselines::t10(), None);
        assert!(
            l_t10 / l_ours > 1.3,
            "expected material speedup, got {}",
            l_t10 / l_ours
        );
    }

    #[test]
    fn table_shape() {
        let t = run(&Opts::fast()).unwrap();
        assert_eq!(t[0].n_rows(), 1);
    }
}
