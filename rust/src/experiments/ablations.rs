//! Ablations over the design choices DESIGN.md calls out — each table
//! isolates one knob of the serving system on a fixed workload:
//!
//! 1. **Chunked-prefill chunk size** (§4.3.2's budget scheduler),
//! 2. **SRAM KV block granularity** (§4.2's fine-grained tier),
//! 3. **SRAM remainder split** between KV blocks and resident weights
//!    (the planner's `kv_share` best-effort policy),
//! 4. **PD placement policy** (DP-prioritized WSC-LLM vs our
//!    PP-prioritized edge/center layout, Fig. 6).

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::experiments::Opts;
use crate::parallel::pd_placement::PdPlacementPolicy;
use crate::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use crate::serving::pd_fusion::{simulate_fusion, FusionConfig};
use crate::sim::chip::ChipSim;
use crate::util::table::{f3, Table};

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(12, 3);
    let mut tables = Vec::new();

    // 1. Chunk size: TTFT/TBT trade-off under mixed load.
    let w = WorkloadConfig::fixed_ratio(opts.pick(1024, 256), opts.pick(128, 16), n)
        .with_arrival(crate::config::ArrivalProcess::Poisson { rate: 4.0 });
    let mut t = Table::new(
        "Ablation 1 — chunked-prefill chunk size (Qwen3-4B, fusion)",
        &["chunk", "TTFT (ms)", "TBT (ms)", "tok/s"],
    );
    for chunk in [64usize, 256, 1024] {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let m = simulate_fusion(
            &mut chip,
            &model,
            &w,
            &FusionConfig {
                chunk,
                budget: chunk + 32,
                ..FusionConfig::default()
            },
        )?;
        t.row(&[
            chunk.to_string(),
            f3(m.ttft_s().mean() * 1e3),
            f3(m.tbt_s().mean() * 1e3),
            f3(m.tokens_per_s()),
        ]);
    }
    tables.push(t);

    // 2. KV block granularity: allocator internal fragmentation vs
    //    bookkeeping (measured via the KvCache directly).
    let mut t = Table::new(
        "Ablation 2 — SRAM KV block granularity (tokens/block)",
        &["block tokens", "requests admitted to SRAM", "SRAM waste %"],
    );
    for block_tokens in [4u64, 16, 64, 256] {
        let bpt = model.kv_bytes_per_token_layer() * 9 / 4; // 9-layer stage, TP4
        let sram = 8 << 20;
        let mut kv = crate::memmgr::KvCache::new(sram, block_tokens, 1 << 30, bpt, 2048);
        // Admit requests of 100 tokens until SRAM blocks run out.
        let mut admitted = 0u64;
        let mut in_sram = 0u64;
        for id in 0..1024 {
            kv.admit(id);
            let a = kv.append(id, 100);
            if a.sram_bytes > 0 {
                in_sram += a.sram_bytes;
                admitted += 1;
            } else {
                break;
            }
        }
        let used = sram - kv.sram_free_bytes();
        let waste = (used.saturating_sub(in_sram)) as f64 / used.max(1) as f64 * 100.0;
        t.row(&[block_tokens.to_string(), admitted.to_string(), f3(waste)]);
    }
    tables.push(t);

    // 3. Planner kv_share split.
    let w3 = WorkloadConfig::fixed_ratio(opts.pick(512, 128), opts.pick(64, 8), n);
    let mut t = Table::new(
        "Ablation 3 — SRAM remainder split (KV share vs resident weights)",
        &["kv_share", "TBT (ms)", "tok/s"],
    );
    for share in [0.1f64, 0.5, 0.9] {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let m = simulate_fusion(
            &mut chip,
            &model,
            &w3,
            &FusionConfig {
                kv_share: share,
                ..FusionConfig::default()
            },
        )?;
        t.row(&[
            f3(share),
            f3(m.tbt_s().mean() * 1e3),
            f3(m.tokens_per_s()),
        ]);
    }
    tables.push(t);

    // 4. PD placement policy (Fig. 6): DP- vs PP-prioritized.
    let w4 = WorkloadConfig::fixed_ratio(opts.pick(512, 128), opts.pick(64, 8), n);
    let mut t = Table::new(
        "Ablation 4 — PD placement policy (P42/D21)",
        &["policy", "TTFT (ms)", "TBT (ms)", "tok/s", "mean KV hops"],
    );
    for (name, policy) in [
        ("pp-prioritized (ours)", PdPlacementPolicy::PpPrioritized),
        ("dp-prioritized (WSC-LLM)", PdPlacementPolicy::DpPrioritized { dp: 4 }),
    ] {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let cfg = DisaggConfig {
            policy,
            ..DisaggConfig::p42_d21()
        };
        let assignment = crate::parallel::pd_placement::assign(
            8, 8, cfg.n_prefill, cfg.n_decode, cfg.prefill_tp, cfg.prefill_stages,
            cfg.decode_tp, policy,
        )?;
        let m = simulate_disagg(&mut chip, &model, &w4, &cfg)?;
        t.row(&[
            name.to_string(),
            f3(m.ttft_s().mean() * 1e3),
            f3(m.tbt_s().mean() * 1e3),
            f3(m.tokens_per_s()),
            f3(assignment.mean_kv_distance()),
        ]);
    }
    tables.push(t);

    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_four_ablations_run() {
        let tables = run(&Opts::fast()).unwrap();
        assert_eq!(tables.len(), 4);
        for t in &tables {
            assert!(t.n_rows() >= 2);
        }
    }

    #[test]
    fn finer_blocks_waste_less_sram() {
        let tables = run(&Opts::fast()).unwrap();
        let csv = tables[1].to_csv();
        let waste: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(2).unwrap().parse().unwrap())
            .collect();
        assert!(
            waste.first().unwrap() <= waste.last().unwrap(),
            "fine blocks should waste no more than coarse: {waste:?}"
        );
    }
}
