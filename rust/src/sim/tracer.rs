//! Simulation tracing: per-operator-class cycle accounting used for the
//! latency-breakdown reports and utilization figures.

use crate::util::units::Cycle;

/// Operator classes tracked by the tracer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Gemm,
    Gemv,
    Attention,
    Vector,
    AllGather,
    AllReduce,
    P2P,
    HbmWeight,
    HbmKv,
    KvSpill,
    KvTransfer,
    Idle,
}

pub const OP_CLASSES: [OpClass; 12] = [
    OpClass::Gemm,
    OpClass::Gemv,
    OpClass::Attention,
    OpClass::Vector,
    OpClass::AllGather,
    OpClass::AllReduce,
    OpClass::P2P,
    OpClass::HbmWeight,
    OpClass::HbmKv,
    OpClass::KvSpill,
    OpClass::KvTransfer,
    OpClass::Idle,
];

impl OpClass {
    fn index(self) -> usize {
        match self {
            OpClass::Gemm => 0,
            OpClass::Gemv => 1,
            OpClass::Attention => 2,
            OpClass::Vector => 3,
            OpClass::AllGather => 4,
            OpClass::AllReduce => 5,
            OpClass::P2P => 6,
            OpClass::HbmWeight => 7,
            OpClass::HbmKv => 8,
            OpClass::KvSpill => 9,
            OpClass::KvTransfer => 10,
            OpClass::Idle => 11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Gemm => "gemm",
            OpClass::Gemv => "gemv",
            OpClass::Attention => "attention",
            OpClass::Vector => "vector",
            OpClass::AllGather => "allgather",
            OpClass::AllReduce => "allreduce",
            OpClass::P2P => "p2p",
            OpClass::HbmWeight => "hbm-weight",
            OpClass::HbmKv => "hbm-kv",
            OpClass::KvSpill => "kv-spill",
            OpClass::KvTransfer => "kv-transfer",
            OpClass::Idle => "idle",
        }
    }
}

/// Cycle totals per operator class.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    cycles: [Cycle; 12],
    counts: [u64; 12],
}

impl Tracer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, class: OpClass, cycles: Cycle) {
        let i = class.index();
        self.cycles[i] += cycles;
        self.counts[i] += 1;
    }

    pub fn cycles(&self, class: OpClass) -> Cycle {
        self.cycles[class.index()]
    }

    pub fn count(&self, class: OpClass) -> u64 {
        self.counts[class.index()]
    }

    pub fn total_cycles(&self) -> Cycle {
        self.cycles.iter().sum()
    }

    /// Merge another tracer (aggregating across cores).
    pub fn merge(&mut self, other: &Tracer) {
        for i in 0..12 {
            self.cycles[i] += other.cycles[i];
            self.counts[i] += other.counts[i];
        }
    }

    /// Render a percentage breakdown, descending.
    pub fn breakdown(&self) -> Vec<(OpClass, Cycle, f64)> {
        let total = self.total_cycles().max(1) as f64;
        let mut rows: Vec<(OpClass, Cycle, f64)> = OP_CLASSES
            .iter()
            .map(|&c| (c, self.cycles(c), self.cycles(c) as f64 / total * 100.0))
            .filter(|&(_, cyc, _)| cyc > 0)
            .collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1));
        rows
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_query() {
        let mut t = Tracer::new();
        t.record(OpClass::Gemm, 100);
        t.record(OpClass::Gemm, 50);
        t.record(OpClass::AllReduce, 30);
        assert_eq!(t.cycles(OpClass::Gemm), 150);
        assert_eq!(t.count(OpClass::Gemm), 2);
        assert_eq!(t.total_cycles(), 180);
    }

    #[test]
    fn merge_adds() {
        let mut a = Tracer::new();
        a.record(OpClass::Vector, 10);
        let mut b = Tracer::new();
        b.record(OpClass::Vector, 20);
        b.record(OpClass::Idle, 5);
        a.merge(&b);
        assert_eq!(a.cycles(OpClass::Vector), 30);
        assert_eq!(a.cycles(OpClass::Idle), 5);
    }

    #[test]
    fn breakdown_sorted_desc_and_filters_zero() {
        let mut t = Tracer::new();
        t.record(OpClass::Gemm, 10);
        t.record(OpClass::AllGather, 90);
        let rows = t.breakdown();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, OpClass::AllGather);
        assert!((rows[0].2 - 90.0).abs() < 1e-9);
    }
}
