//! Streaming request generation from a [`WorkloadConfig`] (§3.2's
//! "streaming request inputs"): synthetic traces whose prompt/output length
//! marginals and arrival processes match the ShareGPT / Mooncake
//! characteristics the paper references (see DESIGN.md "Substitutions").

use crate::config::{ArrivalProcess, WorkloadConfig};
use crate::util::rng::Rng;

/// One serving request of the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Generation length in tokens.
    pub output_len: usize,
}

impl Request {
    pub fn total_tokens(&self) -> usize {
        self.input_len + self.output_len
    }
}

/// Generate the full trace for a workload (sorted by arrival time).
pub fn generate(w: &WorkloadConfig) -> Vec<Request> {
    let mut rng = Rng::new(w.seed);
    let mut out = Vec::with_capacity(w.n_requests);
    let mut t = 0.0f64;
    let mut since_burst = 0.0f64;
    for id in 0..w.n_requests as u64 {
        let arrival_s = match w.arrival {
            ArrivalProcess::Batch => 0.0,
            ArrivalProcess::Poisson { rate } => {
                t += rng.exponential(rate);
                t
            }
            ArrivalProcess::Bursty {
                rate,
                burst_size,
                period_s,
            } => {
                // Poisson baseline with `burst_size` back-to-back arrivals
                // every `period_s` seconds.
                let in_burst = id as usize % (burst_size.max(1)) != 0;
                if in_burst {
                    t
                } else {
                    t += rng.exponential(rate);
                    since_burst += t;
                    if since_burst >= period_s {
                        since_burst = 0.0;
                    }
                    t
                }
            }
        };
        out.push(Request {
            id,
            arrival_s,
            input_len: w.input_len.sample(&mut rng).max(1),
            output_len: w.output_len.sample(&mut rng).max(1),
        });
    }
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LenDist, WorkloadConfig};

    #[test]
    fn deterministic_for_seed() {
        let w = WorkloadConfig::sharegpt_like(32);
        assert_eq!(generate(&w), generate(&w));
        let w2 = w.clone().with_seed(7);
        assert_ne!(generate(&w), generate(&w2));
    }

    #[test]
    fn batch_arrivals_all_at_zero() {
        let w = WorkloadConfig::fixed_ratio(100, 100, 16);
        let reqs = generate(&w);
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
        assert!(reqs.iter().all(|r| r.input_len == 100 && r.output_len == 100));
    }

    #[test]
    fn poisson_arrivals_monotone_and_spread() {
        let w = WorkloadConfig::decode_dominated(64);
        let reqs = generate(&w);
        for pair in reqs.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        // 64 requests at 4 req/s ≈ 16 s span.
        assert!(span > 5.0 && span < 50.0, "span={span}");
    }

    #[test]
    fn lengths_respect_distribution_bounds() {
        let mut w = WorkloadConfig::prefill_dominated(256);
        w.input_len = LenDist::Uniform(100, 200);
        let reqs = generate(&w);
        assert!(reqs.iter().all(|r| (100..=200).contains(&r.input_len)));
    }

    #[test]
    fn bursty_produces_coincident_arrivals() {
        let w = WorkloadConfig::mooncake_like(64);
        let reqs = generate(&w);
        let coincident = reqs
            .windows(2)
            .filter(|p| p[0].arrival_s == p[1].arrival_s)
            .count();
        assert!(coincident > 10, "bursts should co-arrive: {coincident}");
    }
}
