//! Adaptive hybrid PD scheduler (FlexNPU-style dynamic co-location).
//!
//! §4.3 of the paper frames PD-disaggregation vs PD-fusion as a *static*,
//! workload-dependent choice. This scheduler makes it dynamic: it starts
//! fully fused (every pipeline co-locates chunked prefill and decode) and
//! monitors, over a sliding window, (1) the prefill backlog (queued plus
//! in-flight unprefilled prompt tokens), (2) the decode population, and
//! (3) TTFT/TBT SLO headroom over recent completions. Under sustained
//! prefill pressure it *re-partitions*: individual pipelines flip to a
//! dedicated-prefill role — they spend their whole token budget on
//! chunked prefill and hand each freshly prefilled request to the
//! least-loaded fused pipeline over a NoC KV transfer (exactly the
//! disaggregated motion). When the backlog drains, pipelines flip back to
//! fused.
//!
//! Two mechanisms bound re-partition thrash: a *hysteresis* vote count
//! (the controller must suggest the same direction on consecutive
//! evaluations) and a *minimum dwell* in scheduler steps between role
//! changes. Role flips are also graceful: a pipeline flipping to
//! prefill-only finishes its in-flight decodes locally (only requests
//! finishing prefill *after* the flip hand off), so no KV state ever
//! migrates mid-decode.
//!
//! With the controller quiescent (no role changes) the step/tick path is
//! identical to [`FusionScheduler`](super::fusion::FusionScheduler) —
//! asserted bit-for-bit by the tests below.

use super::fusion::AffinityState;
use super::pipe::{self, Handoff, PendingDecode, Pipe};
use super::Scheduler;
use crate::config::ModelConfig;
use crate::memmgr::prefix::{BlockKey, TierMatch};
use crate::memmgr::KV_BLOCK_TOKENS;
use crate::parallel::plan::DeploymentPlan;
use crate::serving::metrics::Metrics;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::Request;
use crate::sim::chip::ChipSim;
use crate::sim::noc::Coord;
use crate::util::units::{cycles_to_secs, Cycle};

/// Hybrid scheduler configuration: the fused-pipeline knobs plus the
/// adaptation controller's.
#[derive(Debug, Clone, Copy)]
pub struct HybridConfig {
    /// Layout/budget knobs shared with PD fusion (tp, stages, chunk,
    /// budget, max_batch, ...).
    pub fusion: FusionConfig,
    /// Controller evaluation period, in scheduler steps.
    pub window: usize,
    /// Consecutive same-direction evaluations required before one
    /// re-partition (hysteresis).
    pub hysteresis: usize,
    /// Minimum scheduler steps between re-partitions (bounds thrash).
    pub min_dwell: usize,
    /// Max fraction of pipelines that may hold the dedicated-prefill role
    /// (at least one pipeline always stays fused).
    pub max_prefill_share: f64,
    /// TTFT SLO target; sustained violations vote for more prefill pipes.
    pub ttft_slo_s: f64,
    /// TBT SLO target; sustained violations vote for more fused pipes.
    pub tbt_slo_s: f64,
}

impl HybridConfig {
    /// Project a [`DeploymentPlan`] onto the hybrid knobs: the fused
    /// layout comes from the plan, the controller keeps its defaults
    /// (they are workload-adaptive, not deployment-shaped).
    pub fn from_plan(plan: &DeploymentPlan) -> Self {
        HybridConfig {
            fusion: FusionConfig::from_plan(plan),
            ..Self::default()
        }
    }
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig {
            fusion: FusionConfig::default(),
            window: 24,
            hysteresis: 2,
            min_dwell: 48,
            max_prefill_share: 0.5,
            ttft_slo_s: 2.0,
            tbt_slo_s: 0.050,
        }
    }
}

/// Role of one pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    /// Co-locates chunked prefill and decode (fusion tick).
    Fused,
    /// Spends its whole budget on prefill; hands completed prefills off.
    PrefillOnly,
}

/// The adaptive hybrid scheduler.
pub struct HybridScheduler {
    cfg: HybridConfig,
    pipes: Vec<Pipe>,
    roles: Vec<Role>,
    /// Round-robin cursor: the pipe the next [`Scheduler::enqueue`]
    /// targets while affinity routing is off.
    next_pipe: usize,
    steps: u64,
    last_change: u64,
    up_votes: u32,
    down_votes: u32,
    repartitions: u64,
    /// Cross-pipe affinity bookkeeping (shared with the fusion policy).
    affinity: AffinityState,
}

impl HybridScheduler {
    pub fn new(cfg: HybridConfig) -> Self {
        HybridScheduler {
            cfg,
            pipes: Vec::new(),
            roles: Vec::new(),
            next_pipe: 0,
            steps: 0,
            last_change: 0,
            up_votes: 0,
            down_votes: 0,
            repartitions: 0,
            affinity: AffinityState::default(),
        }
    }

    /// Pipelines currently holding the dedicated-prefill role.
    pub fn n_prefill_pipes(&self) -> usize {
        self.roles.iter().filter(|r| **r == Role::PrefillOnly).count()
    }

    /// Total role changes performed so far (thrash observability).
    pub fn repartitions(&self) -> u64 {
        self.repartitions
    }

    /// Hard cap on dedicated-prefill pipelines.
    fn max_prefill(&self) -> usize {
        let n = self.pipes.len();
        if n <= 1 {
            return 0;
        }
        (((n as f64) * self.cfg.max_prefill_share).floor() as usize).min(n - 1)
    }

    /// The controller's target number of dedicated-prefill pipelines.
    fn desired_prefill_pipes(&self, metrics: &Metrics, freq: f64) -> usize {
        let n = self.pipes.len();
        // Pressure signal, both sides in "iterations of work": a prefill
        // chunk occupies one iteration; each decode-phase request occupies
        // roughly one budget slot per iteration.
        let prefill_tokens: u64 = self.pipes.iter().map(|p| p.prefill_backlog_tokens()).sum();
        let decode_reqs: u64 = self.pipes.iter().map(|p| p.decode_load() as u64).sum();
        let chunk = self.cfg.fusion.chunk.max(1) as u64;
        let prefill_iters = prefill_tokens.div_ceil(chunk);
        let total = prefill_iters + decode_reqs;
        if total == 0 {
            return self.n_prefill_pipes(); // idle: no vote either way
        }
        let share = prefill_iters as f64 / total as f64;
        let mut desired = (n as f64 * share).round() as usize;
        // SLO headroom nudges over the recent completion window.
        let records = metrics.records();
        let tail = &records[records.len().saturating_sub(16)..];
        if !tail.is_empty() {
            let ttft_viol = tail
                .iter()
                .filter(|r| cycles_to_secs(r.ttft(), freq) > self.cfg.ttft_slo_s)
                .count();
            let tbt_viol = tail
                .iter()
                .filter(|r| r.tbt_secs(freq) > self.cfg.tbt_slo_s)
                .count();
            if ttft_viol * 2 > tail.len() {
                desired += 1;
            }
            if tbt_viol * 2 > tail.len() {
                desired = desired.saturating_sub(1);
            }
        }
        desired.min(self.max_prefill())
    }

    /// One controller evaluation: vote, and re-partition one pipeline when
    /// hysteresis and dwell both allow it.
    fn evaluate(&mut self, metrics: &Metrics, freq: f64) {
        let desired = self.desired_prefill_pipes(metrics, freq);
        let current = self.n_prefill_pipes();
        if desired > current {
            self.up_votes += 1;
            self.down_votes = 0;
        } else if desired < current {
            self.down_votes += 1;
            self.up_votes = 0;
        } else {
            self.up_votes = 0;
            self.down_votes = 0;
            return;
        }
        if self.steps.saturating_sub(self.last_change) < self.cfg.min_dwell as u64 {
            return;
        }
        if desired > current && self.up_votes >= self.cfg.hysteresis.max(1) as u32 {
            self.dedicate_one();
        } else if desired < current && self.down_votes >= self.cfg.hysteresis.max(1) as u32 {
            self.fuse_one();
        }
    }

    /// Flip the least decode-loaded fused pipeline to the prefill role.
    fn dedicate_one(&mut self) {
        if self.n_prefill_pipes() >= self.max_prefill() {
            return;
        }
        let target = (0..self.pipes.len())
            .filter(|&i| self.roles[i] == Role::Fused)
            .min_by_key(|&i| (self.pipes[i].decode_load(), i));
        if let Some(i) = target {
            self.roles[i] = Role::PrefillOnly;
            self.note_change();
        }
    }

    /// Flip the least prefill-backlogged dedicated pipeline back to fused.
    fn fuse_one(&mut self) {
        let target = (0..self.pipes.len())
            .filter(|&i| self.roles[i] == Role::PrefillOnly)
            .min_by_key(|&i| (self.pipes[i].prefill_backlog_tokens(), i));
        if let Some(i) = target {
            self.roles[i] = Role::Fused;
            self.note_change();
        }
    }

    fn note_change(&mut self) {
        self.up_votes = 0;
        self.down_votes = 0;
        self.last_change = self.steps;
        self.repartitions += 1;
    }

    /// Move a freshly prefilled request to a fused pipe: stream its KV
    /// shards over the NoC (disagg-style), then enqueue it for decode
    /// admission there.
    ///
    /// Target selection is **cache-affinity-aware** (the ROADMAP tier
    /// follow-up): with the prefix cache on, candidates are scored by the
    /// same tier-weighted `probe_prefix` overlap `enqueue` routes by — a
    /// fused pipe already holding the request's context keeps related
    /// turns co-located — falling back to least decode load on ties (and
    /// exactly least-loaded, the legacy rule, when nothing matches or the
    /// cache is off).
    fn dispatch_handoff(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        src_pipe: usize,
        h: Handoff,
    ) -> anyhow::Result<()> {
        let affinity: Vec<u64> = if self.cfg.fusion.prefix_cache {
            let keys = h.req.block_keys(KV_BLOCK_TOKENS);
            let limit = (h.req.input_len as u64).saturating_sub(1);
            self.pipes
                .iter()
                .map(|p| {
                    if keys.is_empty() {
                        0
                    } else {
                        p.probe_prefix_tiered(&keys, limit, h.ready_at).score()
                    }
                })
                .collect()
        } else {
            vec![0; self.pipes.len()]
        };
        let dst = (0..self.pipes.len())
            .filter(|&i| self.roles[i] == Role::Fused)
            .min_by_key(|&i| {
                (
                    std::cmp::Reverse(affinity[i]),
                    self.pipes[i].decode_load(),
                    i,
                )
            })
            .ok_or_else(|| anyhow::anyhow!("hybrid scheduler has no fused pipeline"))?;
        let total_kv = h.req.input_len as u64 * model.kv_bytes_per_token();
        let src_stages: Vec<(Vec<Coord>, usize)> = self.pipes[src_pipe]
            .stages
            .iter()
            .map(|s| (s.group.coords.clone(), s.exec.layers))
            .collect();
        let dst_coords: Vec<Coord> = self.pipes[dst]
            .stages
            .iter()
            .flat_map(|s| s.group.coords.iter().copied())
            .collect();
        let ready_at = pipe::stream_kv_shards(chip, &src_stages, &dst_coords, total_kv, h.ready_at);
        self.pipes[dst].pending.push_back(PendingDecode {
            req: h.req,
            first_token: h.first_token,
            ready_at,
        });
        Ok(())
    }
}

impl Scheduler for HybridScheduler {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn prepare(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        max_tokens: usize,
    ) -> anyhow::Result<()> {
        self.pipes = pipe::build_pipes(chip, model, &self.cfg.fusion, max_tokens.max(1))?;
        self.roles = vec![Role::Fused; self.pipes.len()];
        self.next_pipe = 0;
        self.steps = 0;
        self.last_change = 0;
        self.up_votes = 0;
        self.down_votes = 0;
        self.repartitions = 0;
        self.affinity.reset(model.kv_bytes_per_token());
        Ok(())
    }

    fn enqueue(&mut self, chip: &mut ChipSim, req: Request) {
        // Same assignment policy as fusion: static round-robin, or
        // cache-affinity routing with charged NoC imports under
        // `cross_pipe` (a dedicated prefill pipe still prefills its share
        // and hands decode phases off).
        self.affinity.enqueue(
            chip,
            &mut self.pipes,
            &self.cfg.fusion,
            &mut self.next_pipe,
            req,
        );
    }

    fn step(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        metrics: &mut Metrics,
    ) -> anyhow::Result<usize> {
        let freq = chip.cfg.freq_mhz;
        self.steps += 1;
        if self.cfg.window > 0 && self.steps % self.cfg.window as u64 == 0 {
            self.evaluate(metrics, freq);
        }
        // Pick the pipeline with the earliest actionable work.
        let (pi, t) = self
            .pipes
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.next_action(chip, freq).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t)
            .ok_or_else(|| anyhow::anyhow!("hybrid deadlock: no actionable pipeline"))?;
        let extract = self.roles[pi] == Role::PrefillOnly;
        let mut handoffs = Vec::new();
        let completions = self.pipes[pi].tick(
            chip,
            model,
            &self.cfg.fusion,
            t,
            metrics,
            freq,
            extract,
            &mut handoffs,
        );
        for h in handoffs {
            self.dispatch_handoff(chip, model, pi, h)?;
        }
        if completions > 0 {
            self.affinity.on_completions(metrics);
        }
        Ok(completions)
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        pipe::earliest_action(&self.pipes, chip)
    }

    fn pending_work(&self) -> usize {
        pipe::total_pending(&self.pipes)
    }

    fn kv_utilization(&self) -> f64 {
        pipe::mean_kv_utilization(&self.pipes)
    }

    fn backpressure(&self) -> f64 {
        pipe::backpressure(&self.pipes, self.cfg.fusion.max_batch)
    }

    fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        pipe::best_prefix_match(&self.pipes, keys, limit, at)
    }

    fn probe_prefix_tiered(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> TierMatch {
        pipe::best_prefix_match_tiered(&self.pipes, keys, limit, at)
    }

    fn import_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        pipe::seed_all(&mut self.pipes, keys, ready_at);
    }

    fn drain_incomplete(&mut self) -> Vec<super::Incomplete> {
        let mut out: Vec<super::Incomplete> = self
            .pipes
            .iter_mut()
            .flat_map(|p| p.drain_incomplete())
            .collect();
        out.sort_by_key(|i| i.req.id);
        out
    }

    fn collect_cache_stats(&self, out: &mut crate::serving::metrics::CacheStats) {
        for p in &self.pipes {
            p.collect_cache_stats(out);
        }
        self.affinity.collect(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalProcess, ChipConfig, WorkloadConfig};
    use crate::serving::pd_fusion::simulate_fusion;
    use crate::serving::request;
    use crate::serving::scheduler::{simulate, simulate_requests};
    use crate::sim::tracer::OpClass;

    /// A controller that can never fire (window never reached).
    fn quiescent(fusion: FusionConfig) -> HybridConfig {
        HybridConfig {
            fusion,
            window: usize::MAX,
            ..HybridConfig::default()
        }
    }

    /// An eager controller for small test workloads.
    fn eager(fusion: FusionConfig) -> HybridConfig {
        HybridConfig {
            fusion,
            window: 4,
            hysteresis: 1,
            min_dwell: 0,
            ..HybridConfig::default()
        }
    }

    #[test]
    fn quiescent_hybrid_is_bitwise_identical_to_fusion() {
        // With no role changes the hybrid tick path must be the fusion tick
        // path, record for record — this pins the trait refactor.
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6);
        let fcfg = FusionConfig::default();
        let mut c1 = ChipSim::new(ChipConfig::large_core());
        let mf = simulate_fusion(&mut c1, &model, &w, &fcfg).unwrap();
        let mut c2 = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(quiescent(fcfg));
        let mh = simulate(&mut c2, &model, &w, &mut sched).unwrap();
        assert_eq!(sched.repartitions(), 0);
        assert_eq!(mf.records(), mh.records());
        assert_eq!(c1.makespan(), c2.makespan());
    }

    #[test]
    fn controller_dedicates_prefill_pipes_under_pressure() {
        // A burst of long prompts with tiny outputs is pure prefill
        // pressure: the controller must re-partition at least once and
        // every request must still retire exactly once.
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(2048, 4, 12);
        let reqs = request::generate(&w);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(eager(FusionConfig::default()));
        let m = simulate_requests(&mut chip, &model, reqs, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 12);
        assert!(
            sched.repartitions() > 0,
            "controller never re-partitioned under prefill pressure"
        );
        let out: u64 = m.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(out, 12 * 4, "handoff lost or invented tokens");
        for r in m.records() {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
    }

    #[test]
    fn handoffs_move_kv_over_the_noc() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(2048, 8, 12);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(eager(FusionConfig::default()));
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 12);
        if sched.repartitions() > 0 && sched.n_prefill_pipes() > 0 {
            assert!(
                chip.aggregate_tracer().cycles(OpClass::KvTransfer) > 0,
                "dedicated prefill pipes must stream KV to fused pipes"
            );
        }
    }

    #[test]
    fn hysteresis_and_dwell_bound_repartition_thrash() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(1024, 8, 10)
            .with_arrival(ArrivalProcess::Poisson { rate: 4.0 });
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let cfg = HybridConfig {
            fusion: FusionConfig::default(),
            window: 4,
            hysteresis: 1,
            min_dwell: 1_000_000, // effectively one change per run
            ..HybridConfig::default()
        };
        let mut sched = HybridScheduler::new(cfg);
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 10);
        assert!(
            sched.repartitions() <= 1,
            "dwell violated: {} repartitions",
            sched.repartitions()
        );
    }

    #[test]
    fn affinity_aware_handoffs_serve_shared_prefix_traffic() {
        // Dedicated-prefill handoffs under the prefix cache route by
        // tier-weighted cache overlap (least-loaded on ties): the run must
        // stay deterministic and conserve every request/token.
        let model = ModelConfig::qwen3_4b();
        let w = crate::config::WorkloadConfig::shared_prefix(10).with_seed(23);
        let cfg = eager(FusionConfig {
            prefix_cache: true,
            ..FusionConfig::default()
        });
        let run = || {
            let mut chip = ChipSim::new(ChipConfig::large_core());
            let mut sched = HybridScheduler::new(cfg);
            let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
            (m.records().to_vec(), sched.repartitions())
        };
        let (a, ra) = run();
        let (b, rb) = run();
        assert_eq!(a, b, "affinity handoff broke determinism");
        assert_eq!(ra, rb);
        assert_eq!(a.len(), 10);
        for r in &a {
            assert!(r.first_token >= r.arrival, "{r:?}");
            assert!(r.finish >= r.first_token, "{r:?}");
        }
    }

    #[test]
    fn at_least_one_pipe_always_stays_fused() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(4096, 2, 8);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut cfg = eager(FusionConfig::default());
        cfg.max_prefill_share = 1.0; // ask for everything; cap must hold
        let mut sched = HybridScheduler::new(cfg);
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 8);
        assert!(
            sched.n_prefill_pipes() < 4,
            "all pipes dedicated: decode would starve"
        );
    }

    #[test]
    fn single_token_outputs_finish_at_prefill_even_on_dedicated_pipes() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(1024, 1, 8);
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = HybridScheduler::new(eager(FusionConfig::default()));
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 8);
        for r in m.records() {
            assert_eq!(r.first_token, r.finish, "{r:?}");
            assert_eq!(r.output_tokens, 1);
        }
    }
}
