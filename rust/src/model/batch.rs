//! Iteration batch description: which requests run this scheduler tick.

/// Serving phase of a batch item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// One request's contribution to an iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchItem {
    /// Request id (keys into the KV cache).
    pub request: u64,
    /// Query tokens processed this iteration (chunk size for chunked
    /// prefill; 1 for decode).
    pub q_tokens: u64,
    /// KV context length *after* this iteration's tokens are appended.
    pub kv_tokens: u64,
    pub phase: Phase,
}

impl BatchItem {
    pub fn prefill(request: u64, q_tokens: u64, kv_tokens: u64) -> Self {
        BatchItem {
            request,
            q_tokens,
            kv_tokens,
            phase: Phase::Prefill,
        }
    }

    pub fn decode(request: u64, kv_tokens: u64) -> Self {
        BatchItem {
            request,
            q_tokens: 1,
            kv_tokens,
            phase: Phase::Decode,
        }
    }
}

/// The batch of one iteration (may mix prefill chunks and decode steps —
/// that is exactly what PD fusion does).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IterBatch {
    pub items: Vec<BatchItem>,
}

impl IterBatch {
    pub fn new(items: Vec<BatchItem>) -> Self {
        IterBatch { items }
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total query tokens this iteration (the GEMM `M` dimension).
    pub fn total_q_tokens(&self) -> u64 {
        self.items.iter().map(|i| i.q_tokens).sum()
    }

    /// Tokens that need logits (decode steps + prefill chunks finishing a
    /// prompt produce one next-token each; we approximate with one logit
    /// row per item, the standard continuous-batching shape).
    pub fn logit_tokens(&self) -> u64 {
        self.items.len() as u64
    }

    pub fn n_decode(&self) -> usize {
        self.items
            .iter()
            .filter(|i| i.phase == Phase::Decode)
            .count()
    }

    pub fn n_prefill(&self) -> usize {
        self.items.len() - self.n_decode()
    }

    /// Whether every item is a decode step (pure-decode iterations use the
    /// GEMV-shaped path).
    pub fn is_pure_decode(&self) -> bool {
        self.items.iter().all(|i| i.phase == Phase::Decode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_accounting() {
        let b = IterBatch::new(vec![
            BatchItem::prefill(1, 256, 256),
            BatchItem::decode(2, 100),
            BatchItem::decode(3, 50),
        ]);
        assert_eq!(b.total_q_tokens(), 258);
        assert_eq!(b.logit_tokens(), 3);
        assert_eq!(b.n_decode(), 2);
        assert_eq!(b.n_prefill(), 1);
        assert!(!b.is_pure_decode());
    }

    #[test]
    fn pure_decode_batch() {
        let b = IterBatch::new(vec![BatchItem::decode(1, 10), BatchItem::decode(2, 20)]);
        assert!(b.is_pure_decode());
        assert_eq!(b.total_q_tokens(), 2);
    }

    #[test]
    fn empty_batch() {
        let b = IterBatch::default();
        assert!(b.is_empty());
        assert_eq!(b.total_q_tokens(), 0);
    }
}
