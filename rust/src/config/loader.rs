//! Load a full simulation configuration from a TOML file (see
//! `configs/*.toml` for examples). Every key is optional and overrides the
//! named preset, so config files stay small.

use super::{
    ArrivalProcess, ChipConfig, LenDist, MemSimMode, ModelConfig, NocSimMode, WorkloadConfig,
};
use crate::util::minitoml::Document;
use crate::util::units::MB;
use anyhow::{Context, Result};

/// A bundle of chip + model + workload loaded from one file.
#[derive(Debug, Clone)]
pub struct SimConfigBundle {
    pub chip: ChipConfig,
    pub model: ModelConfig,
    pub workload: WorkloadConfig,
}

/// Parse a config file. Layout:
///
/// ```toml
/// [chip]
/// preset = "large_core"     # or small_core / ascend910b
/// sram_mb = 32
/// sa_dim = 128
/// hbm_bw_gbps = 120.0
/// noc_bw_gbps = 128.0
/// mem_mode = "detailed"     # or "fast"
/// noc_mode = "detailed"
///
/// [model]
/// name = "qwen3_4b"
///
/// [workload]
/// preset = "decode_dominated"   # or prefill_dominated / sharegpt / mooncake
/// n_requests = 64
/// rate = 4.0
/// input_len = 1000              # switches to fixed lengths
/// output_len = 100
/// ```
pub fn load_sim_config(text: &str) -> Result<SimConfigBundle> {
    let doc = Document::parse(text).context("parsing config")?;

    // ---- chip ----
    let mut chip = match doc.get_str("chip.preset").unwrap_or("large_core") {
        "large_core" | "large-core" => ChipConfig::large_core(),
        "small_core" | "small-core" => ChipConfig::small_core(),
        "ascend910b" | "ascend" => ChipConfig::ascend910b_like(),
        other => anyhow::bail!("unknown chip preset {other:?}"),
    };
    if let Some(v) = doc.get_int("chip.sram_mb") {
        chip.core.sram_bytes = v as u64 * MB;
    }
    if let Some(v) = doc.get_int("chip.sa_dim") {
        chip.core.sa_dim = v as u64;
    }
    if let Some(v) = doc.get_float("chip.hbm_bw_gbps") {
        chip.core.hbm_bw_gbps = v;
    }
    if let Some(v) = doc.get_float("chip.noc_bw_gbps") {
        chip.noc.link_bw_gbps = v;
    }
    if let Some(v) = doc.get_int("chip.rows") {
        chip.rows = v as usize;
    }
    if let Some(v) = doc.get_int("chip.cols") {
        chip.cols = v as usize;
    }
    if let Some(v) = doc.get_str("chip.mem_mode") {
        chip.mem_mode = match v {
            "detailed" => MemSimMode::Detailed,
            "fast" => MemSimMode::Fast,
            other => anyhow::bail!("unknown mem_mode {other:?}"),
        };
    }
    if let Some(v) = doc.get_str("chip.noc_mode") {
        chip.noc.mode = match v {
            "detailed" => NocSimMode::Detailed,
            "fast" => NocSimMode::Fast,
            other => anyhow::bail!("unknown noc_mode {other:?}"),
        };
    }
    chip.validate()?;

    // ---- model ----
    let model = ModelConfig::by_name(doc.get_str("model.name").unwrap_or("qwen3_4b"))?;

    // ---- workload ----
    let n_requests = doc.get_int("workload.n_requests").unwrap_or(32) as usize;
    let mut workload = match doc.get_str("workload.preset").unwrap_or("decode_dominated") {
        "prefill_dominated" => WorkloadConfig::prefill_dominated(n_requests),
        "decode_dominated" => WorkloadConfig::decode_dominated(n_requests),
        "sharegpt" | "sharegpt_like" => WorkloadConfig::sharegpt_like(n_requests),
        "mooncake" | "mooncake_like" => WorkloadConfig::mooncake_like(n_requests),
        other => anyhow::bail!("unknown workload preset {other:?}"),
    };
    if let (Some(i), Some(o)) = (
        doc.get_int("workload.input_len"),
        doc.get_int("workload.output_len"),
    ) {
        workload.input_len = LenDist::Fixed(i as usize);
        workload.output_len = LenDist::Fixed(o as usize);
    }
    if let Some(rate) = doc.get_float("workload.rate") {
        workload.arrival = ArrivalProcess::Poisson { rate };
    }
    if let Some(seed) = doc.get_int("workload.seed") {
        workload.seed = seed as u64;
    }

    Ok(SimConfigBundle {
        chip,
        model,
        workload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_load() {
        let b = load_sim_config("").unwrap();
        assert_eq!(b.chip.n_cores(), 64);
        assert_eq!(b.model.name, "qwen3_4b");
    }

    #[test]
    fn overrides_apply() {
        let text = r#"
[chip]
preset = "small_core"
sram_mb = 48
sa_dim = 32
mem_mode = "fast"

[model]
name = "qwen3_8b"

[workload]
preset = "prefill_dominated"
n_requests = 16
input_len = 1000
output_len = 100
"#;
        let b = load_sim_config(text).unwrap();
        assert_eq!(b.chip.n_cores(), 256);
        assert_eq!(b.chip.core.sram_bytes, 48 * MB);
        assert_eq!(b.chip.core.sa_dim, 32);
        assert_eq!(b.chip.mem_mode, MemSimMode::Fast);
        assert_eq!(b.model.name, "qwen3_8b");
        assert_eq!(b.workload.n_requests, 16);
        assert_eq!(b.workload.input_len, LenDist::Fixed(1000));
    }

    #[test]
    fn bad_preset_errors() {
        assert!(load_sim_config("[chip]\npreset = \"gpu\"\n").is_err());
    }
}
