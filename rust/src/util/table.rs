//! Text-table rendering + CSV output for the experiment harness.
//!
//! Every `experiments/fig*.rs` builds a [`Table`] with the same rows/series
//! the paper's figure reports, prints it, and optionally writes a CSV under
//! `results/`.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able values.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:>w$}  ", c, w = widths[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
        let _ = writeln!(out, "{}", "-".repeat(total.min(160)));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV under `dir/<name>.csv`, creating the directory.
    pub fn write_csv(&self, dir: &Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with 3 significant-ish decimals for table cells.
pub fn f3(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn f3_ranges() {
        assert_eq!(f3(0.0), "0");
        assert_eq!(f3(1234.5), "1234");
        assert_eq!(f3(12.34), "12.3");
        assert_eq!(f3(1.2345), "1.234");
    }
}
