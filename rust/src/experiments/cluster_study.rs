//! `cluster_study` — multi-chip serving sweep: chips × router × scheduler
//! on (1) a shared-prefix multi-turn conversational workload (where
//! prefix-hit-aware routing should win: conversation turns return to the
//! chip holding their cached context) and (2) a Poisson ShareGPT-like
//! workload with nothing shareable (where least-loaded should match or
//! beat static round-robin). Rows feed the serving bench's
//! `BENCH_serving.json` `"cluster"` section via [`bench_grid`].
//!
//! ```sh
//! cargo run --release -p npusim -- experiment cluster_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, ModelConfig, PrefixSharing, WorkloadConfig};
use crate::experiments::Opts;
use crate::serving::cluster::{self, ClusterConfig, ClusterMetrics, RouterPolicy};
use crate::serving::metrics::Metrics;
use crate::serving::pd_disagg::DisaggConfig;
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{self, Request};
use crate::serving::scheduler::{HybridConfig, SchedulerConfig};
use crate::util::table::{f3, Table};

/// One measured cluster cell.
#[derive(Debug, Clone)]
pub struct ClusterRun {
    pub workload: &'static str,
    pub sched: &'static str,
    pub router: &'static str,
    pub chips: usize,
    pub tok_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tbt_p99_ms: f64,
    pub hit_rate: f64,
    pub migrations: u64,
    pub icn_mb: f64,
}

/// The shared-prefix conversational trace: 3-turn chats, think time
/// between turns, one system prompt *per conversation* (agent-style
/// sessions, each with its own long personalized context) — so every
/// upper turn has a long cached prefix on exactly one chip, and routing
/// decides whether it is found or recomputed. `n_groups` equals the
/// conversation count (`n_requests / turns`).
pub fn shared_cluster_trace(opts: &Opts) -> Vec<Request> {
    let n = opts.pick(48, 18);
    let mut w = WorkloadConfig::shared_prefix(n);
    w.prefix = Some(PrefixSharing {
        n_groups: n / 3,
        shared_prefix_len: opts.pick(1024, 512),
        turns: 3,
        think_time_s: opts.pick(2.0, 0.5),
    });
    if opts.fast {
        w.arrival = ArrivalProcess::Poisson { rate: 8.0 };
    }
    request::generate(&w)
}

/// The no-sharing Poisson trace (pure load-balancing exercise).
pub fn poisson_cluster_trace(opts: &Opts) -> Vec<Request> {
    request::generate(&WorkloadConfig::sharegpt_like(opts.pick(48, 12)))
}

/// The three per-chip schedulers of the sweep, prefix caching on. Fusion
/// and hybrid run one chip-wide pipeline (TP 16 × 4 stages) so the chip's
/// prefix cache is a single pool and routing decisions map 1:1 onto cache
/// affinity; disagg keeps the paper's P42/D21 split.
pub fn cluster_systems() -> [(&'static str, SchedulerConfig); 3] {
    let fusion = FusionConfig {
        tp: 16,
        stages: 4,
        prefix_cache: true,
        ..FusionConfig::default()
    };
    [
        ("fusion", SchedulerConfig::Fusion(fusion)),
        (
            "disagg",
            SchedulerConfig::Disagg(DisaggConfig {
                prefix_cache: true,
                ..DisaggConfig::p42_d21()
            }),
        ),
        (
            "hybrid",
            SchedulerConfig::Hybrid(HybridConfig {
                fusion,
                ..HybridConfig::default()
            }),
        ),
    ]
}

/// Run one cluster cell; returns the per-chip rollup and its aggregate.
pub fn run_cell(
    model: &ModelConfig,
    reqs: &[Request],
    sched: &SchedulerConfig,
    router: RouterPolicy,
    chips: usize,
) -> anyhow::Result<(ClusterMetrics, Metrics)> {
    let cfg = ClusterConfig::new(ChipConfig::large_core(), chips, *sched, router);
    let cm = cluster::simulate_cluster_requests(&cfg, model, reqs.to_vec())?;
    let agg = cm.aggregate();
    Ok((cm, agg))
}

fn cell_row(
    workload: &'static str,
    sched: &'static str,
    router: RouterPolicy,
    chips: usize,
    cm: &ClusterMetrics,
    agg: &Metrics,
) -> ClusterRun {
    let mut ttft = agg.ttft_s();
    let mut tbt = agg.tbt_s();
    ClusterRun {
        workload,
        sched,
        router: router.name(),
        chips,
        tok_s: agg.tokens_per_s(),
        ttft_p50_s: ttft.median(),
        ttft_p99_s: ttft.p99(),
        tbt_p99_ms: tbt.p99() * 1e3,
        hit_rate: agg.cache.prefix_hit_rate(),
        migrations: cm.migrations,
        icn_mb: cm.interconnect.bytes as f64 / (1 << 20) as f64,
    }
}

/// The bench grid: both workloads × all schedulers × all routers on a
/// fixed 2-chip cluster — the rows `BENCH_serving.json` gates on.
pub fn bench_grid(opts: &Opts) -> anyhow::Result<Vec<ClusterRun>> {
    grid(opts, &[2])
}

fn grid(opts: &Opts, chip_counts: &[usize]) -> anyhow::Result<Vec<ClusterRun>> {
    let model = ModelConfig::qwen3_4b();
    let workloads: [(&'static str, Vec<Request>); 2] = [
        ("shared-prefix", shared_cluster_trace(opts)),
        ("poisson", poisson_cluster_trace(opts)),
    ];
    let systems = cluster_systems();
    let mut out = Vec::new();
    for (wname, reqs) in &workloads {
        for (sname, sched) in &systems {
            for router in RouterPolicy::ALL {
                for &chips in chip_counts {
                    let (cm, agg) = run_cell(&model, reqs, sched, router, chips)?;
                    anyhow::ensure!(
                        agg.n_requests() == reqs.len(),
                        "{wname}/{sname}/{}/{chips}: {} of {} requests completed",
                        router.name(),
                        agg.n_requests(),
                        reqs.len()
                    );
                    out.push(cell_row(*wname, *sname, router, chips, &cm, &agg));
                }
            }
        }
    }
    Ok(out)
}

/// TTFT p50 of one `(workload, sched, router)` cell at the smallest chip
/// count in `runs` (comparison helper for tests and the bench gate).
pub fn ttft_p50(runs: &[ClusterRun], workload: &str, sched: &str, router: &str) -> Option<f64> {
    runs.iter()
        .filter(|r| r.workload == workload && r.sched == sched && r.router == router)
        .min_by_key(|r| r.chips)
        .map(|r| r.ttft_p50_s)
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let chip_counts: Vec<usize> = opts.pick(vec![2, 4], vec![2]);
    let runs = grid(opts, &chip_counts)?;

    let mut t = Table::new(
        "cluster_study — chips × router × scheduler (Qwen3-4B, large-core chips)",
        &[
            "workload",
            "sched",
            "router",
            "chips",
            "tok/s",
            "TTFT p50 (s)",
            "TTFT p99 (s)",
            "TBT p99 (ms)",
            "hit rate (%)",
            "migrations",
            "ICN MB",
        ],
    );
    for r in &runs {
        t.row(&[
            r.workload.to_string(),
            r.sched.to_string(),
            r.router.to_string(),
            r.chips.to_string(),
            f3(r.tok_s),
            f3(r.ttft_p50_s),
            f3(r.ttft_p99_s),
            f3(r.tbt_p99_ms),
            f3(r.hit_rate * 100.0),
            r.migrations.to_string(),
            f3(r.icn_mb),
        ]);
    }

    let (rr, prefix) = (
        ttft_p50(&runs, "shared-prefix", "fusion", "rr").unwrap_or(0.0),
        ttft_p50(&runs, "shared-prefix", "fusion", "prefix").unwrap_or(0.0),
    );
    println!(
        "cluster_study: shared-prefix fusion TTFT p50 — rr {rr:.4}s vs prefix-aware {prefix:.4}s \
         ({:.1}% cut)",
        if rr > 0.0 { (1.0 - prefix / rr) * 100.0 } else { 0.0 }
    );

    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_shareable() {
        let opts = Opts::fast();
        let shared = shared_cluster_trace(&opts);
        assert_eq!(shared.len(), 18);
        assert!(request::shared_token_fraction(&shared) >= 0.5);
        assert_eq!(shared, shared_cluster_trace(&opts));
        let poisson = poisson_cluster_trace(&opts);
        assert_eq!(poisson.len(), 12);
        assert!(poisson.iter().all(|r| r.prefix.is_none()));
    }

    #[test]
    fn prefix_router_beats_round_robin_on_shared_prefix_ttft_p50() {
        // The acceptance property, at fast scale on the fusion system:
        // routing conversation turns back to the chip holding their cached
        // context must cut the median TTFT vs static round-robin.
        let runs = bench_grid(&Opts::fast()).unwrap();
        // Grid shape: 2 workloads × 3 scheds × 3 routers at 2 chips.
        assert_eq!(runs.len(), 18);
        assert!(runs.iter().all(|r| r.chips == 2));
        let rr = ttft_p50(&runs, "shared-prefix", "fusion", "rr").unwrap();
        let prefix = ttft_p50(&runs, "shared-prefix", "fusion", "prefix").unwrap();
        assert!(
            prefix < rr,
            "prefix-aware TTFT p50 {prefix} !< round-robin {rr}"
        );
        // Hybrid runs the same single chip-wide pipeline (its controller
        // cannot dedicate with one pipe), so it must win exactly like
        // fusion; disagg's prompt-to-pipeline pull is cache-blind inside
        // the chip, so it only gets a statistical edge — allow 5% slack.
        let rr = ttft_p50(&runs, "shared-prefix", "hybrid", "rr").unwrap();
        let prefix = ttft_p50(&runs, "shared-prefix", "hybrid", "prefix").unwrap();
        assert!(
            prefix < rr,
            "hybrid: prefix-aware TTFT p50 {prefix} !< round-robin {rr}"
        );
        let rr = ttft_p50(&runs, "shared-prefix", "disagg", "rr").unwrap();
        let prefix = ttft_p50(&runs, "shared-prefix", "disagg", "prefix").unwrap();
        assert!(
            prefix <= rr * 1.05,
            "disagg: prefix-aware TTFT p50 {prefix} far above round-robin {rr}"
        );
        // Hit-aware routing must actually hit more than blind round-robin.
        let hit = |router: &str| {
            runs.iter()
                .find(|r| {
                    r.workload == "shared-prefix" && r.sched == "fusion" && r.router == router
                })
                .unwrap()
                .hit_rate
        };
        assert!(hit("prefix") > hit("rr"), "routing on hits did not lift hit rate");
    }
}
