//! Overload end-to-end: drive a 2-chip cluster well past its measured
//! sustainable rate with a priority-mixed flash crowd and check the
//! control-plane contract — the bounded admission queue engages (work is
//! shed instead of piling up), high-priority traffic stays within its
//! TTFT SLO while the low class absorbs the shedding, and the run
//! terminates (the event-budget guard in the cluster driver would error
//! out otherwise).

use npusim::config::{ChipConfig, ModelConfig};
use npusim::experiments::overload_study;
use npusim::serving::cluster::{self, ClusterConfig, RouterPolicy, ShedPolicy};
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request::Priority;
use npusim::serving::scheduler::SchedulerConfig;

fn overload_cluster(shed: ShedPolicy, queue_cap: usize, slo_ttft_s: f64) -> ClusterConfig {
    let mut cfg = ClusterConfig::new(
        ChipConfig::large_core(),
        2,
        SchedulerConfig::Fusion(FusionConfig {
            tp: 16,
            stages: 4,
            ..FusionConfig::default()
        }),
        RouterPolicy::LeastLoaded,
    )
    .with_shed(shed, queue_cap);
    cfg.slo_ttft_s = slo_ttft_s;
    cfg
}

#[test]
fn flash_crowd_backpressure_sheds_low_and_keeps_high_within_slo() {
    let model = ModelConfig::qwen3_4b();
    // Calibrate the per-chip service rate, then offer a spike far past
    // the 2-chip cluster's capacity (the short trace needs a harsh
    // factor to build the same backlog a long 2x spike would).
    let per_chip = overload_study::sustainable_rate(&model, 8).unwrap();
    let slo_ttft_s = overload_study::SLO_SERVICE_PERIODS / per_chip;
    let reqs = overload_study::flash_crowd_trace(32, per_chip * 2.0, 6.0);
    let offered = reqs.len();
    let offered_of =
        |class: Priority| reqs.iter().filter(|r| r.priority == class).count() as u64;
    assert!(offered_of(Priority::High) > 0 && offered_of(Priority::Low) > 0);

    let cfg = overload_cluster(ShedPolicy::Drop, 2, slo_ttft_s);
    // Terminates: the driver's event guard fails the run otherwise.
    let cm = cluster::simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
    let agg = cm.aggregate();

    // The bounded queue engaged: overload was refused, not absorbed, and
    // the books balance exactly.
    let ctl = &agg.control;
    assert!(ctl.shed_requests > 0, "overload never tripped the bounded queue");
    assert_eq!(
        agg.n_requests() as u64 + ctl.shed_requests,
        offered as u64,
        "completed + shed != offered"
    );
    assert_eq!(ctl.shed_by_class.iter().sum::<u64>(), ctl.shed_requests);

    // Priority contract: high is never shed and its tail TTFT holds the
    // SLO; the low class absorbs shedding at least as hard as normal.
    assert_eq!(ctl.shed_by_class[Priority::High.index()], 0);
    assert_eq!(
        agg.n_requests_of(Priority::High) as u64,
        offered_of(Priority::High),
        "a high-priority request went missing"
    );
    let high_p99 = agg.ttft_s_of(Priority::High).p99();
    assert!(
        high_p99 <= slo_ttft_s,
        "high-priority TTFT p99 {high_p99:.4}s blew the {slo_ttft_s:.4}s SLO"
    );
    let shed_frac = |class: Priority| {
        ctl.shed_by_class[class.index()] as f64 / offered_of(class).max(1) as f64
    };
    assert!(ctl.shed_by_class[Priority::Low.index()] > 0, "low never shed");
    assert!(
        shed_frac(Priority::Low) >= shed_frac(Priority::Normal),
        "low class did not absorb shedding first ({:.2} vs {:.2})",
        shed_frac(Priority::Low),
        shed_frac(Priority::Normal)
    );
}

#[test]
fn defer_retries_under_the_same_crowd_and_still_terminates() {
    let model = ModelConfig::qwen3_4b();
    let per_chip = overload_study::sustainable_rate(&model, 8).unwrap();
    let slo_ttft_s = overload_study::SLO_SERVICE_PERIODS / per_chip;
    let reqs = overload_study::flash_crowd_trace(32, per_chip * 2.0, 6.0);
    let offered = reqs.len() as u64;

    let cfg = overload_cluster(ShedPolicy::Defer, 2, slo_ttft_s);
    let cm = cluster::simulate_cluster_requests(&cfg, &model, reqs).unwrap();
    let agg = cm.aggregate();
    let ctl = &agg.control;
    assert!(ctl.deferrals > 0, "overload never deferred an arrival");
    assert_eq!(agg.n_requests() as u64 + ctl.shed_requests, offered);
    // Bounded retries: nothing loops forever, and each deferred request
    // retried at most MAX_DEFERRALS times before completing or shedding.
    assert!(ctl.deferrals <= offered * 8, "deferral retries unbounded");
}
