//! Speculative-decoding properties: exact token conservation across the
//! acceptance range (reject-all through accept-all), KV byte/refcount
//! conservation under rollback, seeded determinism, and the flags-off
//! golden pin (spec unset must reproduce the vanilla timeline).

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::memmgr::KvCache;
use npusim::parallel::plan::SpecConfig;
use npusim::serving::metrics::Metrics;
use npusim::serving::pd_disagg::DisaggConfig;
use npusim::serving::pd_fusion::FusionConfig;
use npusim::serving::request::{self, Request};
use npusim::serving::scheduler::{self, HybridConfig, SchedulerConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::prop;
use std::fmt::Write as _;

/// `Σ (output_len − 1)`: the decode path owes exactly this many tokens
/// (the first output token of every request comes from its prefill).
fn expected_decode_tokens(reqs: &[Request]) -> u64 {
    reqs.iter()
        .map(|r| (r.output_len as u64).saturating_sub(1))
        .sum()
}

fn run(sys: &SchedulerConfig, reqs: Vec<Request>) -> Metrics {
    let model = ModelConfig::qwen3_4b();
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let mut sched = sys.build();
    scheduler::simulate_requests(&mut chip, &model, reqs, sched.as_mut())
        .unwrap_or_else(|e| panic!("{} failed: {e:#}", sys.name()))
}

/// Canonical text rendering (same shape as the golden-metrics pin): any
/// cycle-level drift in the speculative path shows up as a byte diff.
fn summarize(m: &Metrics) -> String {
    let mut records: Vec<_> = m.records().to_vec();
    records.sort_by_key(|r| r.id);
    let mut out = String::new();
    let _ = writeln!(out, "n={} makespan={}", m.n_requests(), m.makespan());
    for r in records {
        let _ = writeln!(
            out,
            "id={} arrival={} first={} finish={} in={} out={}",
            r.id, r.arrival, r.first_token, r.finish, r.input_tokens, r.output_tokens
        );
    }
    out
}

fn assert_conserves(label: &str, m: &Metrics, offered: usize, expected: u64) {
    assert_eq!(m.n_requests(), offered, "{label}: lost/duplicated requests");
    assert_eq!(
        m.spec.decode_tokens_committed, expected,
        "{label}: decode committed {} tokens, expected {expected}",
        m.spec.decode_tokens_committed
    );
    assert_eq!(
        m.spec.drafted_tokens,
        m.spec.accepted_tokens + m.spec.rejected_tokens,
        "{label}: draft ledger does not balance"
    );
}

#[test]
fn fusion_conserves_tokens_across_the_acceptance_range() {
    // Reject-all (acceptance ≈ 0: every verify commits exactly the one
    // bonus token), mid-range, and accept-all (u ∈ [0,1) < 1.0 always):
    // the committed total must be bit-exact in every regime.
    let w = WorkloadConfig::fixed_ratio(64, 10, 6).with_seed(7);
    let reqs = request::generate(&w);
    let expected = expected_decode_tokens(&reqs);
    for gamma in [1u64, 4, 8] {
        for acceptance in [1e-9, 0.5, 1.0] {
            let sys = SchedulerConfig::Fusion(FusionConfig {
                spec: Some(SpecConfig::new(gamma, acceptance)),
                ..FusionConfig::default()
            });
            let m = run(&sys, reqs.clone());
            let label = format!("fusion g{gamma} a{acceptance}");
            assert_conserves(&label, &m, reqs.len(), expected);
            assert!(m.spec.drafted_tokens > 0, "{label}: never drafted");
            if acceptance == 1.0 {
                assert_eq!(m.spec.rejected_tokens, 0, "{label}: accept-all rejected");
            } else if acceptance == 1e-9 {
                assert!(
                    m.spec.acceptance_rate() <= 0.01,
                    "{label}: reject-all accepted {:.3} of drafts",
                    m.spec.acceptance_rate()
                );
            }
        }
    }
}

#[test]
fn random_spec_configs_conserve_tokens() {
    // Randomized gamma × acceptance × workload: conservation is a hard
    // invariant, not a property of the tuned study points.
    prop::check("spec token conservation", 6, |rng| {
        let gamma = *rng.choose(&[1u64, 2, 3, 5, 8]);
        let acceptance = rng.range_f64(0.05, 1.0);
        let n = rng.range(2, 6);
        let output = rng.range(4, 16);
        let w = WorkloadConfig::fixed_ratio(48, output, n).with_seed(rng.next_u64());
        let reqs = request::generate(&w);
        let expected = expected_decode_tokens(&reqs);
        let sys = SchedulerConfig::Fusion(FusionConfig {
            spec: Some(SpecConfig::new(gamma, acceptance)),
            ..FusionConfig::default()
        });
        let m = run(&sys, reqs.clone());
        assert_conserves(
            &format!("fusion g{gamma} a{acceptance:.3} n{n} out{output}"),
            &m,
            reqs.len(),
            expected,
        );
    });
}

#[test]
fn disagg_and_hybrid_decode_legs_conserve_tokens() {
    // The prefill→decode handoff carries speculation state across chips'
    // role boundary; neither the disagg decode leg nor the hybrid
    // controller may lose or mint a token.
    let w = WorkloadConfig::fixed_ratio(128, 12, 5).with_seed(11);
    let reqs = request::generate(&w);
    let expected = expected_decode_tokens(&reqs);
    let spec = Some(SpecConfig::new(4, 0.8));
    let disagg = SchedulerConfig::Disagg(DisaggConfig {
        spec,
        ..DisaggConfig::p42_d21()
    });
    let md = run(&disagg, reqs.clone());
    assert_conserves("disagg g4 a0.8", &md, reqs.len(), expected);
    assert!(md.spec.drafted_tokens > 0, "disagg decode leg never drafted");

    let hybrid = SchedulerConfig::Hybrid(HybridConfig {
        fusion: FusionConfig {
            spec,
            ..FusionConfig::default()
        },
        ..HybridConfig::default()
    });
    let mh = run(&hybrid, reqs.clone());
    assert_conserves("hybrid g4 a0.8", &mh, reqs.len(), expected);
}

#[test]
fn kv_rollback_conserves_bytes_and_refcounts() {
    // Random append/truncate interleavings over several chains: rollback
    // must free exactly the rejected bytes, residency must track the
    // logical token count, and releasing everything must return the
    // allocator to empty (no leaked blocks, no double frees).
    prop::check("kv rollback conservation", 32, |rng| {
        let bytes_per_token = 8u64;
        let mut kv = KvCache::new(1 << 22, 16, 1 << 22, bytes_per_token, 4096);
        let ids = [1u64, 2, 3];
        let mut tokens = [0u64; 3];
        for &id in &ids {
            assert!(kv.admit(id));
        }
        let mut rolled_back = 0u64;
        for _ in 0..40 {
            let i = rng.range(0, ids.len());
            let id = ids[i];
            if tokens[i] == 0 || rng.chance(0.6) {
                let n = rng.range_u64(1, 24);
                let a = kv.append(id, n);
                assert_eq!(a.sram_bytes + a.hbm_bytes, n * bytes_per_token);
                tokens[i] += n;
            } else {
                let n = rng.range_u64(1, tokens[i] + 1);
                let freed = kv.truncate(id, n);
                assert_eq!(freed, n * bytes_per_token, "truncate freed wrong bytes");
                tokens[i] -= n;
                rolled_back += freed;
            }
            assert_eq!(
                kv.residency(id).total(),
                tokens[i] * bytes_per_token,
                "residency drifted from the logical chain length"
            );
        }
        assert_eq!(kv.stats().rollback_bytes, rolled_back);
        for &id in &ids {
            kv.release(id);
        }
        assert_eq!(kv.n_active(), 0);
        assert_eq!(kv.sram_used_bytes(), 0, "rollback leaked SRAM blocks");
    });
}

#[test]
fn seeded_speculation_is_deterministic_and_parameter_sensitive() {
    // The per-(request, position) counter-mode sampler makes a spec run a
    // pure function of (trace, config): two runs are byte-identical, and
    // the draft/accept ledgers match to the token. Changing the
    // acceptance must change the timeline (the sampler is not dead code).
    let w = WorkloadConfig::fixed_ratio(64, 12, 4).with_seed(13);
    let reqs = request::generate(&w);
    let cfg = |acceptance: f64| {
        SchedulerConfig::Fusion(FusionConfig {
            spec: Some(SpecConfig::new(4, acceptance)),
            ..FusionConfig::default()
        })
    };
    let a = run(&cfg(0.8), reqs.clone());
    let b = run(&cfg(0.8), reqs.clone());
    assert_eq!(summarize(&a), summarize(&b), "spec run not deterministic");
    assert_eq!(a.spec.drafted_tokens, b.spec.drafted_tokens);
    assert_eq!(a.spec.accepted_tokens, b.spec.accepted_tokens);
    assert_eq!(a.spec.verify_m_p50(), b.spec.verify_m_p50());
    let c = run(&cfg(0.2), reqs.clone());
    assert_ne!(
        summarize(&a),
        summarize(&c),
        "acceptance never changed the schedule"
    );
}

#[test]
fn spec_off_is_the_default_and_bit_identical_to_vanilla() {
    // The flags-off golden pin: speculation is strictly opt-in. The
    // defaults carry no SpecConfig, an explicit `spec: None` reproduces
    // the default timeline byte-for-byte, and a vanilla run reports zero
    // speculative activity.
    assert!(FusionConfig::default().spec.is_none());
    assert!(DisaggConfig::default().spec.is_none());
    let w = WorkloadConfig::fixed_ratio(256, 24, 6).with_seed(7);
    let reqs = request::generate(&w);
    let default_run = run(
        &SchedulerConfig::Fusion(FusionConfig::default()),
        reqs.clone(),
    );
    let explicit_off = run(
        &SchedulerConfig::Fusion(FusionConfig {
            spec: None,
            ..FusionConfig::default()
        }),
        reqs.clone(),
    );
    assert_eq!(
        summarize(&default_run),
        summarize(&explicit_off),
        "spec: None perturbed the vanilla timeline"
    );
    assert_eq!(default_run.spec.drafted_tokens, 0);
    assert_eq!(default_run.spec.verify_steps, 0);
    assert_eq!(default_run.spec.rejected_tokens, 0);
    // Vanilla still owes the exact decode-token total — the ledger is
    // live (and conserved) even with speculation off.
    assert_eq!(
        default_run.spec.decode_tokens_committed,
        expected_decode_tokens(&reqs)
    );
}
