"""L2 model correctness: shapes, determinism, and the prefill/decode
consistency that the rust request path depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


C = model.CONFIG
B, P = C["decode_batch"], C["prefill_len"]
KV_SHAPE = (C["layers"], 2, B, C["max_seq"], C["kv_heads"], C["head_dim"])


def toy_tokens(seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0, C["vocab"])


class TestShapes:
    def test_prefill_shapes(self, params):
        logits, kv = model.prefill(params, toy_tokens())
        assert logits.shape == (B, P, C["vocab"])
        assert kv.shape == KV_SHAPE

    def test_decode_shapes(self, params):
        _, kv = model.prefill(params, toy_tokens())
        tok = jnp.array([1, 2], jnp.int32)
        logits, kv2 = model.decode(params, tok, jnp.int32(P), kv)
        assert logits.shape == (B, C["vocab"])
        assert kv2.shape == KV_SHAPE

    def test_kv_written_only_in_prefix(self, params):
        _, kv = model.prefill(params, toy_tokens())
        assert float(jnp.abs(kv[:, :, :, P:]).max()) == 0.0
        assert float(jnp.abs(kv[:, :, :, :P]).max()) > 0.0


class TestConsistency:
    def test_deterministic(self, params):
        a, _ = model.prefill(params, toy_tokens())
        b, _ = model.prefill(params, toy_tokens())
        np.testing.assert_array_equal(a, b)

    def test_decode_matches_prefill_logits(self, params):
        """Teacher-forcing: decoding token t with the prefix's KV must give
        the same logits as prefill's position-t output."""
        tokens = toy_tokens(7)
        full_logits, _ = model.prefill(params, tokens)
        # Prefill only the first P-1 tokens, then decode token P-1.
        prefix = tokens.at[:, P - 1].set(0)  # value at P-1 unused below
        _, kv = model.prefill(params, prefix)
        # Zero the KV the prefix wrote at position P-1 onward is absent
        # anyway; decode step writes position P-1.
        kv = kv.at[:, :, :, P - 1 :].set(0.0)
        logits, _ = model.decode(params, tokens[:, P - 1], jnp.int32(P - 1), kv)
        np.testing.assert_allclose(
            logits, full_logits[:, P - 1], rtol=2e-3, atol=2e-3
        )

    def test_decode_steps_accumulate_kv(self, params):
        _, kv = model.prefill(params, toy_tokens())
        tok = jnp.array([3, 4], jnp.int32)
        _, kv1 = model.decode(params, tok, jnp.int32(P), kv)
        assert float(jnp.abs(kv1[:, :, :, P]).max()) > 0.0
        assert float(jnp.abs(kv1[:, :, :, P + 1 :]).max()) == 0.0

    def test_position_changes_output(self, params):
        _, kv = model.prefill(params, toy_tokens())
        tok = jnp.array([5, 6], jnp.int32)
        a, _ = model.decode(params, tok, jnp.int32(P), kv)
        b, _ = model.decode(params, tok, jnp.int32(P + 3), kv)
        assert not np.allclose(a, b)


class TestExport:
    def test_aot_export_writes_artifacts(self, tmp_path):
        from compile import aot

        outputs = aot.export(tmp_path)
        for name in ("prefill", "decode", "meta"):
            assert outputs[name].exists()
        hlo = outputs["prefill"].read_text()
        assert "HloModule" in hlo
        meta = outputs["meta"].read_text()
        assert "vocab=256" in meta
