//! Transaction-level HBM channel model (§3.1).
//!
//! Each memory request is decomposed into four TLM phases —
//! `BeginReq → EndReq → BeginResp → EndResp` — and large accesses are split
//! into per-burst transactions that interleave across banks, complete
//! out of order, and are limited by a bounded outstanding-request window.
//! This captures the "out-of-order, outstanding and interleaving"
//! behaviour the paper calls out as mis-estimated by flat
//! `bytes / bandwidth` models, while remaining event-driven and fast.
//!
//! The `Fast` mode *is* the flat model (`latency + bytes/bw`), kept for the
//! Fig. 7-right accuracy/efficiency comparison.

use crate::config::{ChipConfig, CoreConfig, MemSimMode};
use crate::sim::engine::{OutstandingWindow, Timeline};
use crate::util::units::{ceil_div, Cycle};

/// Minimum burst granularity: one bank transaction moves at least this many
/// bytes (HBM2e pseudo-channel burst: 32B × BL8 ≈ 256B). For very wide
/// channels the effective burst grows so that the 1-cycle command phase
/// never artificially limits bandwidth (see [`HbmChannel::burst_bytes`]).
const MIN_BURST_BYTES: u64 = 256;

/// Command-phase occupancy on the request bus (BeginReq→EndReq).
const REQ_CYCLES: Cycle = 1;

/// Maximum simulated bursts per access. Small and medium accesses keep
/// per-burst TLM fidelity; very large sequential streams (weight loads of
/// hundreds of MB) coarsen to `MAX_BURSTS` proportionally larger bursts —
/// they are bandwidth-bound and bank-pipeline perfectly, so coarsening
/// changes the completion time by <1 burst while keeping simulation cost
/// bounded (the paper's own efficiency argument for multi-level modeling).
const MAX_BURSTS: u64 = 16;

/// The four TLM phase timestamps of one transaction (recorded for tracing
/// and asserted on in tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlmPhases {
    pub begin_req: Cycle,
    pub end_req: Cycle,
    pub begin_resp: Cycle,
    pub end_resp: Cycle,
}

/// Aggregate channel statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct HbmStats {
    pub transactions: u64,
    pub bytes: u64,
    /// Cycles requests waited on the outstanding window.
    pub window_stall: Cycle,
    /// Cycles requests waited on busy banks.
    pub bank_stall: Cycle,
    /// Cycles requests waited on the shared data bus.
    pub bus_stall: Cycle,
}

/// One core-local HBM channel.
#[derive(Debug)]
pub struct HbmChannel {
    mode: MemSimMode,
    /// Bank availability (column-access occupancy; the bank streams its
    /// burst for `data_cycles` and is then free — row-activation latency is
    /// a pipeline *delay*, not occupancy).
    banks: Vec<Timeline>,
    /// Shared data bus, tracked at sub-cycle resolution so per-burst
    /// rounding does not eat bandwidth.
    bus_free: f64,
    bus_busy: f64,
    bus_stall: f64,
    /// Request/command bus.
    req_bus: Timeline,
    window: OutstandingWindow,
    /// Intrinsic access latency (activation + CAS + PHY), cycles.
    access_latency: Cycle,
    /// Nominal (fault-free) data-bus bytes per core cycle; the baseline
    /// [`HbmChannel::set_throttle`] scales from.
    base_bytes_per_cycle: f64,
    /// Data-bus bytes per core cycle (nominal × current throttle factor).
    bytes_per_cycle: f64,
    /// `1 / bytes_per_cycle` (hoisted: the burst loop is the simulator's
    /// hottest path and division/libm-ceil dominated it — §Perf opt 1).
    inv_bytes_per_cycle: f64,
    /// Round-robin bank interleave cursor (address-interleaving stand-in).
    next_bank: usize,
    stats: HbmStats,
}

/// Branchy integer ceil of a non-negative f64 — avoids the libm `ceil`
/// call that showed up at ~12% of serving-run profiles (§Perf opt 1).
#[inline(always)]
fn ceil_f64(x: f64) -> Cycle {
    let t = x as Cycle;
    t + u64::from((t as f64) < x)
}

impl HbmChannel {
    pub fn new(chip: &ChipConfig, core: &CoreConfig) -> Self {
        HbmChannel {
            mode: chip.mem_mode,
            banks: vec![Timeline::new(); chip.hbm_banks.max(1)],
            bus_free: 0.0,
            bus_busy: 0.0,
            bus_stall: 0.0,
            req_bus: Timeline::new(),
            window: OutstandingWindow::new(chip.hbm_outstanding.max(1)),
            access_latency: chip.hbm_latency_cycles,
            base_bytes_per_cycle: core.hbm_bytes_per_cycle(chip.freq_mhz),
            bytes_per_cycle: core.hbm_bytes_per_cycle(chip.freq_mhz),
            inv_bytes_per_cycle: {
                let bpc = core.hbm_bytes_per_cycle(chip.freq_mhz);
                if bpc > 0.0 {
                    1.0 / bpc
                } else {
                    0.0
                }
            },
            next_bank: 0,
            stats: HbmStats::default(),
        }
    }

    /// Effective burst size: at least [`MIN_BURST_BYTES`], grown on wide
    /// channels so one command cycle per burst sustains full bandwidth.
    fn burst_bytes(&self) -> u64 {
        MIN_BURST_BYTES.max((self.bytes_per_cycle * 4.0).ceil() as u64)
    }

    /// Whether this channel has any bandwidth at all.
    pub fn present(&self) -> bool {
        self.bytes_per_cycle > 0.0
    }

    /// Throttle the data bus to `factor` × nominal bandwidth (fault
    /// injection: thermal/RAS throttling). `factor = 1.0` restores the
    /// nominal rate exactly, so the fault-free path is bit-identical.
    /// Accesses already timed keep their completion cycles; only future
    /// bursts see the new rate.
    pub fn set_throttle(&mut self, factor: f64) {
        assert!(factor > 0.0 && factor <= 1.0, "throttle factor {factor}");
        self.bytes_per_cycle = self.base_bytes_per_cycle * factor;
        self.inv_bytes_per_cycle = if self.bytes_per_cycle > 0.0 {
            1.0 / self.bytes_per_cycle
        } else {
            0.0
        };
    }

    /// Submit an access of `bytes` at `issue`; returns the completion cycle
    /// (EndResp of the last burst).
    ///
    /// In `Detailed` mode the access is split into burst-sized transactions
    /// which interleave across banks and may complete out of order; the
    /// returned cycle is the max EndResp. In `Fast` mode the analytic
    /// estimate `issue + latency + bytes/bw` is returned.
    pub fn access(&mut self, issue: Cycle, bytes: u64) -> Cycle {
        assert!(self.present(), "HBM access on a core without HBM");
        if bytes == 0 {
            return issue;
        }
        self.stats.transactions += 1;
        self.stats.bytes += bytes;
        match self.mode {
            MemSimMode::Fast => {
                issue + self.access_latency + ceil_f64(bytes as f64 * self.inv_bytes_per_cycle)
            }
            MemSimMode::Detailed => {
                let fine = self.burst_bytes();
                // Coarsen huge streams so one access simulates at most
                // MAX_BURSTS transactions (see MAX_BURSTS).
                let unit = fine.max(ceil_div(bytes, MAX_BURSTS).div_ceil(fine) * fine);
                let mut last_end = issue;
                let n_bursts = ceil_div(bytes, unit);
                for b in 0..n_bursts {
                    let burst_bytes = if b == n_bursts - 1 {
                        bytes - b * unit
                    } else {
                        unit
                    };
                    let phases = self.burst(issue, burst_bytes);
                    last_end = last_end.max(phases.end_resp);
                }
                last_end
            }
        }
    }

    /// Simulate one burst through the four TLM phases.
    fn burst(&mut self, issue: Cycle, bytes: u64) -> TlmPhases {
        // Phase 1: BeginReq — the request is accepted once an outstanding
        // slot is free and the command bus is available.
        let slot_at = self.window.acquire(issue);
        self.stats.window_stall += slot_at - issue;
        let begin_req = self.req_bus.reserve(slot_at, REQ_CYCLES);
        // Phase 2: EndReq — command transferred.
        let end_req = begin_req + REQ_CYCLES;

        // Bank access: interleave across banks round-robin (the
        // address-interleaving that gives HBM its parallelism). The bank is
        // *occupied* only while streaming its burst (column-access
        // occupancy); the activation/CAS latency is a pipeline delay. A
        // busy bank delays BeginResp — this is where out-of-order
        // completion arises: a later burst hitting an idle bank can respond
        // before an earlier burst queued on a busy bank.
        let bank = self.next_bank;
        self.next_bank = (self.next_bank + 1) % self.banks.len();
        let data_frac = bytes as f64 * self.inv_bytes_per_cycle;
        let occupancy = ceil_f64(data_frac).max(1);
        let bank_start = self.banks[bank].reserve(end_req, occupancy);
        self.stats.bank_stall += bank_start - end_req;
        let bank_ready = bank_start + self.access_latency;

        // Phase 3: BeginResp — shared data bus granted (sub-cycle
        // accounting so per-burst rounding does not eat bandwidth).
        let begin_resp_f = (bank_ready as f64).max(self.bus_free);
        self.bus_stall += begin_resp_f - bank_ready as f64;
        self.bus_free = begin_resp_f + data_frac;
        self.bus_busy += data_frac;
        let begin_resp = begin_resp_f as Cycle; // non-negative: trunc = floor
        // Phase 4: EndResp — data transferred.
        let end_resp = ceil_f64(self.bus_free);
        self.window.complete(end_resp);
        self.stats.bus_stall = self.bus_stall as Cycle;
        TlmPhases {
            begin_req,
            end_req,
            begin_resp,
            end_resp,
        }
    }

    pub fn stats(&self) -> HbmStats {
        self.stats
    }

    /// Cycles the data bus has been busy (utilization numerator).
    pub fn bus_busy(&self) -> Cycle {
        self.bus_busy.round() as Cycle
    }

    pub fn reset(&mut self) {
        for b in &mut self.banks {
            b.reset();
        }
        self.bus_free = 0.0;
        self.bus_busy = 0.0;
        self.bus_stall = 0.0;
        self.req_bus.reset();
        self.window.reset();
        self.next_bank = 0;
        self.stats = HbmStats::default();
        self.set_throttle(1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn chan(mode: MemSimMode) -> HbmChannel {
        let mut chip = ChipConfig::large_core();
        chip.mem_mode = mode;
        // 120 GB/s @ 500 MHz = 240 B/cycle.
        HbmChannel::new(&chip, &chip.core)
    }

    #[test]
    fn fast_mode_is_flat_model() {
        let mut c = chan(MemSimMode::Fast);
        // 240 B/cycle, latency 60: 24000 bytes -> 60 + 100 = 160.
        assert_eq!(c.access(0, 24_000), 160);
        // Fast mode has no state: same access again gives same latency.
        assert_eq!(c.access(0, 24_000), 160);
    }

    #[test]
    fn detailed_single_burst_phases_are_ordered() {
        let mut c = chan(MemSimMode::Detailed);
        let phases = c.burst(0, 256);
        assert!(phases.begin_req < phases.end_req);
        assert!(phases.end_req <= phases.begin_resp);
        assert!(phases.begin_resp < phases.end_resp);
        // latency components: req 1 + access 60 + transfer ceil(256/240)=2.
        assert_eq!(phases.end_resp, 1 + 60 + 2);
    }

    #[test]
    fn detailed_streams_overlap_across_banks() {
        let mut c = chan(MemSimMode::Detailed);
        // A large sequential read: bursts pipeline across 16 banks, so the
        // effective rate approaches the bus bandwidth rather than
        // (latency + transfer) per burst.
        let bytes = 1024 * 1024u64;
        let done = c.access(0, bytes);
        let ideal = (bytes as f64 / 240.0) as Cycle;
        assert!(done >= ideal, "cannot beat the data bus: {done} < {ideal}");
        // Within 2x of the pure-bandwidth bound (pipelining works).
        assert!(done < 2 * ideal + 200, "done={done} ideal={ideal}");
    }

    #[test]
    fn detailed_contention_slower_than_isolated() {
        let mut c = chan(MemSimMode::Detailed);
        let t1 = c.access(0, 64 * 1024);
        // A second stream issued at the same time must queue behind.
        let t2 = c.access(0, 64 * 1024);
        assert!(t2 > t1);
        assert!(c.stats().bank_stall + c.stats().bus_stall > 0);
    }

    #[test]
    fn detailed_is_slower_or_equal_to_fast_under_load() {
        let mut cd = chan(MemSimMode::Detailed);
        let mut cf = chan(MemSimMode::Fast);
        let mut td = 0;
        let mut tf = 0;
        for i in 0..8 {
            td = td.max(cd.access(i * 10, 128 * 1024));
            tf = tf.max(cf.access(i * 10, 128 * 1024));
        }
        // The flat model ignores contention entirely.
        assert!(td > tf, "detailed {td} vs fast {tf}");
    }

    #[test]
    fn throttle_scales_fast_mode_and_restores_exactly() {
        let mut c = chan(MemSimMode::Fast);
        assert_eq!(c.access(0, 24_000), 160);
        c.set_throttle(0.5); // 120 B/cycle: 60 + 200 = 260.
        assert_eq!(c.access(0, 24_000), 260);
        c.set_throttle(1.0);
        assert_eq!(c.access(0, 24_000), 160, "factor 1.0 must be bit-exact");
    }

    #[test]
    fn reset_clears_throttle() {
        let mut c = chan(MemSimMode::Fast);
        c.set_throttle(0.25);
        c.reset();
        assert_eq!(c.access(0, 24_000), 160);
    }

    #[test]
    fn zero_bytes_is_noop() {
        let mut c = chan(MemSimMode::Detailed);
        assert_eq!(c.access(42, 0), 42);
        assert_eq!(c.stats().transactions, 0);
    }

    #[test]
    fn stats_accumulate() {
        let mut c = chan(MemSimMode::Detailed);
        c.access(0, 1000);
        c.access(0, 1000);
        assert_eq!(c.stats().transactions, 2);
        assert_eq!(c.stats().bytes, 2000);
    }

    #[test]
    fn reset_clears_state() {
        let mut c = chan(MemSimMode::Detailed);
        c.access(0, 1024 * 1024);
        c.reset();
        // After reset a fresh single burst sees an idle channel again:
        // req 1 + access 60 + transfer ceil(256/240)=2.
        assert_eq!(c.access(0, 256), 63);
        assert_eq!(c.stats().bytes, 256);
    }
}
