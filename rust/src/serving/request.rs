//! Streaming request generation from a [`WorkloadConfig`] (§3.2's
//! "streaming request inputs"): synthetic traces whose prompt/output length
//! marginals and arrival processes match the ShareGPT / Mooncake
//! characteristics the paper references (see DESIGN.md "Substitutions"),
//! plus shared-prefix / multi-turn conversational traces for the
//! prefix-caching study.

use crate::config::{ArrivalProcess, PrefixSharing, PriorityMix, WorkloadConfig};
use crate::memmgr::prefix::BlockKey;
use crate::util::rng::Rng;

/// Scheduling class of a request, carried end-to-end from the workload
/// generator through routing, admission and per-pipe batching. The
/// derive order makes comparisons read naturally:
/// `Low < Normal < High`, so "may `a` preempt `b`" is
/// `a.priority > b.priority`. `Normal` is the default — a trace with no
/// mix configured behaves exactly like the pre-priority simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// All classes, lowest first (matches the derive order).
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    /// Stable index for per-class counters (`0 = low, 1 = normal, 2 = high`).
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => anyhow::bail!("unknown priority {other:?} (low|normal|high)"),
        }
    }
}

/// Content identity of a request's shareable prompt prefix, at two scopes:
///
/// - the **group** scope is the system prompt shared by every conversation
///   of a prefix group (`group_tokens` leading tokens);
/// - the **conversation** scope is the accumulated context shared by the
///   turns of one conversation (`conv_tokens` leading tokens, a superset
///   of the group prefix on turns ≥ 2).
///
/// Token-block hashes derive deterministically from these ids, so two
/// requests produce equal block hashes exactly where their simulated token
/// streams agree. `Prefix::default()` means "nothing shareable".
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Prefix {
    pub group_id: u64,
    pub group_tokens: u32,
    pub conv_id: u64,
    pub conv_tokens: u32,
}

impl Prefix {
    /// Total shareable leading tokens.
    pub fn shared_tokens(&self) -> u64 {
        (self.group_tokens as u64).max(self.conv_tokens as u64)
    }

    pub fn is_none(&self) -> bool {
        self.shared_tokens() == 0
    }
}

/// One serving request of the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Generation length in tokens.
    pub output_len: usize,
    /// Shareable-prefix identity (default: nothing shareable).
    pub prefix: Prefix,
    /// Scheduling class (default: [`Priority::Normal`]).
    pub priority: Priority,
}

impl Request {
    pub fn total_tokens(&self) -> usize {
        self.input_len + self.output_len
    }

    /// Token-block content keys of this request's shareable prefix, at
    /// `block_tokens` granularity. Blocks fully inside the group prefix
    /// hash under the group scope (shared across conversations); later
    /// blocks inside the conversation context hash under the conversation
    /// scope (shared across turns); the terminal block may be partial.
    /// The non-shareable remainder of the prompt gets no keys — it is
    /// never cached.
    pub fn block_keys(&self, block_tokens: u64) -> Vec<BlockKey> {
        let shared = self.prefix.shared_tokens().min(self.input_len as u64);
        if shared == 0 || block_tokens == 0 {
            return Vec::new();
        }
        let group = (self.prefix.group_tokens as u64).min(shared);
        let mut keys = Vec::new();
        let mut pos = 0u64;
        let mut idx = 0u64;
        while pos < shared {
            let end = (pos + block_tokens).min(shared);
            let tokens = end - pos;
            let (tag, scope) = if end <= group {
                (1u64, self.prefix.group_id)
            } else {
                (2u64, self.prefix.conv_id)
            };
            keys.push(BlockKey {
                hash: block_hash(tag, scope, idx, tokens),
                tokens,
            });
            pos = end;
            idx += 1;
        }
        keys
    }
}

/// SplitMix64 finalizer (the same mixer the RNG seeds through). Also the
/// per-(request, position) mixer of the speculative-decode acceptance
/// sampler, which needs a counter-mode hash rather than a stream RNG so
/// acceptance draws are independent of batching order.
pub(crate) fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic content hash of one prefix token block.
fn block_hash(tag: u64, scope: u64, idx: u64, tokens: u64) -> u64 {
    let mut h = 0x243F_6A88_85A3_08D3u64;
    for v in [tag, scope, idx, tokens] {
        h = splitmix64(h ^ v);
    }
    h
}

/// Sample one arrival offset according to the workload's process.
fn next_arrival(
    w: &WorkloadConfig,
    rng: &mut Rng,
    t: &mut f64,
    since_burst: &mut f64,
    seq: u64,
) -> f64 {
    match w.arrival {
        ArrivalProcess::Batch => 0.0,
        ArrivalProcess::Poisson { rate } => {
            *t += rng.exponential(rate);
            *t
        }
        ArrivalProcess::Bursty {
            rate,
            burst_size,
            period_s,
        } => {
            // Poisson baseline with `burst_size` back-to-back arrivals
            // every `period_s` seconds.
            let in_burst = seq as usize % (burst_size.max(1)) != 0;
            if in_burst {
                *t
            } else {
                *t += rng.exponential(rate);
                *since_burst += *t;
                if *since_burst >= period_s {
                    *since_burst = 0.0;
                }
                *t
            }
        }
        ArrivalProcess::FlashCrowd {
            base_rate,
            peak_rate,
            spike_start_s,
            spike_len_s,
        } => {
            // Inhomogeneous Poisson with a rectangular rate spike: the
            // next gap is drawn at the rate in force *now*, which is the
            // standard thinning-free approximation for step rates.
            let rate = if *t >= spike_start_s && *t < spike_start_s + spike_len_s {
                peak_rate
            } else {
                base_rate
            };
            *t += rng.exponential(rate);
            *t
        }
        ArrivalProcess::Diurnal {
            base_rate,
            peak_rate,
            period_s,
        } => {
            // Raised-cosine rate cycle starting at the trough; like
            // FlashCrowd, each gap is drawn at the rate in force *now*
            // (thinning-free approximation — exact in the limit of gaps
            // short against the period).
            let phase = (*t / period_s.max(1e-9)) * std::f64::consts::TAU;
            let rate = base_rate + (peak_rate - base_rate) * 0.5 * (1.0 - phase.cos());
            *t += rng.exponential(rate.max(1e-9));
            *t
        }
    }
}

/// Sample a priority class from the workload's mix. The inert default mix
/// performs **no** RNG draw, so traces generated before priorities existed
/// keep their exact byte-level timelines (pinned by golden tests).
fn sample_priority(mix: &PriorityMix, rng: &mut Rng) -> Priority {
    if mix.is_uniform() {
        return Priority::Normal;
    }
    let u = rng.f64();
    if u < mix.high {
        Priority::High
    } else if u < mix.high + mix.low {
        Priority::Low
    } else {
        Priority::Normal
    }
}

/// Generate the full trace for a workload (sorted by arrival time).
pub fn generate(w: &WorkloadConfig) -> Vec<Request> {
    match &w.prefix {
        Some(ps) => generate_shared(w, *ps),
        None => generate_plain(w),
    }
}

fn generate_plain(w: &WorkloadConfig) -> Vec<Request> {
    let mut rng = Rng::new(w.seed);
    let mut out = Vec::with_capacity(w.n_requests);
    let mut t = 0.0f64;
    let mut since_burst = 0.0f64;
    for id in 0..w.n_requests as u64 {
        let arrival_s = next_arrival(w, &mut rng, &mut t, &mut since_burst, id);
        out.push(Request {
            id,
            arrival_s,
            input_len: w.input_len.sample(&mut rng).max(1),
            output_len: w.output_len.sample(&mut rng).max(1),
            prefix: Prefix::default(),
            priority: sample_priority(&w.priority_mix, &mut rng),
        });
    }
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    out
}

/// Shared-prefix / multi-turn trace: `n_requests` turns spread over
/// `n_requests / turns` conversations. Every conversation opens with its
/// prefix group's `shared_prefix_len`-token system prompt; turn *t*'s
/// prompt is the whole accumulated context (prior prompts + outputs) plus
/// freshly sampled user tokens, arriving `think_time_s` after the previous
/// turn. Arrivals of conversation openers follow the workload's process.
fn generate_shared(w: &WorkloadConfig, ps: PrefixSharing) -> Vec<Request> {
    if w.n_requests == 0 {
        return Vec::new();
    }
    let mut rng = Rng::new(w.seed);
    let turns = ps.turns.max(1);
    let n_groups = ps.n_groups.max(1);
    let n_convs = w.n_requests.div_ceil(turns).max(1);
    let mut out = Vec::with_capacity(w.n_requests);
    let mut t = 0.0f64;
    let mut since_burst = 0.0f64;
    let mut id = 0u64;
    'outer: for conv in 0..n_convs as u64 {
        let start = next_arrival(w, &mut rng, &mut t, &mut since_burst, conv);
        let mut context = 0usize; // accumulated conversation context
        for turn in 0..turns {
            let user_tokens = w.input_len.sample(&mut rng).max(1);
            let output_len = w.output_len.sample(&mut rng).max(1);
            let (group_tokens, conv_tokens, input_len) = if turn == 0 {
                let input = ps.shared_prefix_len + user_tokens;
                (ps.shared_prefix_len, 0, input)
            } else {
                (ps.shared_prefix_len, context, context + user_tokens)
            };
            out.push(Request {
                id,
                arrival_s: start + turn as f64 * ps.think_time_s.max(0.0),
                input_len,
                output_len,
                prefix: Prefix {
                    group_id: conv % n_groups as u64,
                    group_tokens: group_tokens.min(u32::MAX as usize) as u32,
                    conv_id: conv,
                    conv_tokens: conv_tokens.min(u32::MAX as usize) as u32,
                },
                priority: sample_priority(&w.priority_mix, &mut rng),
            });
            context = input_len + output_len;
            id += 1;
            if out.len() >= w.n_requests {
                break 'outer;
            }
        }
    }
    out.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    out
}

/// Fraction of all prompt tokens covered by shareable prefixes (trace
/// diagnostics; the bench harness reports it alongside hit rates).
pub fn shared_token_fraction(reqs: &[Request]) -> f64 {
    let total: u64 = reqs.iter().map(|r| r.input_len as u64).sum();
    if total == 0 {
        return 0.0;
    }
    let shared: u64 = reqs
        .iter()
        .map(|r| r.prefix.shared_tokens().min(r.input_len as u64))
        .sum();
    shared as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LenDist, WorkloadConfig};

    #[test]
    fn deterministic_for_seed() {
        let w = WorkloadConfig::sharegpt_like(32);
        assert_eq!(generate(&w), generate(&w));
        let w2 = w.clone().with_seed(7);
        assert_ne!(generate(&w), generate(&w2));
    }

    #[test]
    fn batch_arrivals_all_at_zero() {
        let w = WorkloadConfig::fixed_ratio(100, 100, 16);
        let reqs = generate(&w);
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
        assert!(reqs.iter().all(|r| r.input_len == 100 && r.output_len == 100));
        assert!(reqs.iter().all(|r| r.prefix.is_none()));
    }

    #[test]
    fn poisson_arrivals_monotone_and_spread() {
        let w = WorkloadConfig::decode_dominated(64);
        let reqs = generate(&w);
        for pair in reqs.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        let span = reqs.last().unwrap().arrival_s;
        // 64 requests at 4 req/s ≈ 16 s span.
        assert!(span > 5.0 && span < 50.0, "span={span}");
    }

    #[test]
    fn lengths_respect_distribution_bounds() {
        let mut w = WorkloadConfig::prefill_dominated(256);
        w.input_len = LenDist::Uniform(100, 200);
        let reqs = generate(&w);
        assert!(reqs.iter().all(|r| (100..=200).contains(&r.input_len)));
    }

    #[test]
    fn bursty_produces_coincident_arrivals() {
        let w = WorkloadConfig::mooncake_like(64);
        let reqs = generate(&w);
        let coincident = reqs
            .windows(2)
            .filter(|p| p[0].arrival_s == p[1].arrival_s)
            .count();
        assert!(coincident > 10, "bursts should co-arrive: {coincident}");
    }

    #[test]
    fn shared_prefix_trace_shares_group_and_conversation_scopes() {
        let w = WorkloadConfig::shared_prefix(12);
        let reqs = generate(&w);
        assert_eq!(reqs.len(), 12);
        let ps = w.prefix.unwrap();
        // Every request opens with the group system prompt.
        assert!(reqs
            .iter()
            .all(|r| r.prefix.group_tokens as usize == ps.shared_prefix_len));
        assert!(reqs.iter().all(|r| r.input_len > ps.shared_prefix_len));
        // Later turns share strictly more than the system prompt.
        assert!(reqs
            .iter()
            .any(|r| r.prefix.conv_tokens as usize > ps.shared_prefix_len));
        // The headline property for the study: most prompt tokens shareable.
        assert!(
            shared_token_fraction(&reqs) >= 0.5,
            "shared fraction {}",
            shared_token_fraction(&reqs)
        );
        // Deterministic.
        assert_eq!(reqs, generate(&w));
    }

    #[test]
    fn block_keys_agree_exactly_where_streams_agree() {
        let ps = Prefix {
            group_id: 1,
            group_tokens: 40,
            conv_id: 100,
            conv_tokens: 0,
        };
        let a = Request {
            id: 1,
            arrival_s: 0.0,
            input_len: 200,
            output_len: 8,
            prefix: ps,
            priority: Priority::Normal,
        };
        // Same group, different conversation: shares the group blocks.
        let b = Request {
            id: 2,
            arrival_s: 0.0,
            input_len: 150,
            output_len: 8,
            prefix: Prefix { conv_id: 101, ..ps },
            priority: Priority::Normal,
        };
        let (ka, kb) = (a.block_keys(16), b.block_keys(16));
        // 40 tokens = 2 full group blocks + 1 partial block still fully
        // inside the group prefix — all three shared across conversations.
        assert_eq!(ka.len(), 3);
        assert_eq!(ka, kb);
        assert_eq!(ka[2].tokens, 8);
        // A block *straddling* the group boundary hashes under the
        // conversation scope, so it does not leak across conversations.
        let a50 = Request {
            prefix: Prefix {
                conv_tokens: 50,
                ..ps
            },
            ..a
        };
        let b50 = Request {
            prefix: Prefix {
                conv_id: 101,
                conv_tokens: 50,
                ..ps
            },
            ..b
        };
        let (ka50, kb50) = (a50.block_keys(16), b50.block_keys(16));
        assert_eq!(ka50.len(), 4);
        assert_eq!(ka50[..2], kb50[..2], "full group blocks still shared");
        assert_ne!(ka50[2], kb50[2], "straddler is conversation-scoped");
        // The straddler also differs from the group-scope partial at the
        // same index (different scope and token count).
        assert_ne!(ka50[2], ka[2]);
        // A later turn of conversation 100 re-derives a's early blocks.
        let c = Request {
            id: 3,
            arrival_s: 1.0,
            input_len: 400,
            output_len: 8,
            prefix: Prefix {
                group_id: 1,
                group_tokens: 40,
                conv_id: 100,
                conv_tokens: 210,
            },
            priority: Priority::Normal,
        };
        let kc = c.block_keys(16);
        assert_eq!(kc[0], ka[0]);
        assert_eq!(kc[1], ka[1]);
        assert_eq!(kc.len(), 14); // 13 full blocks + a 2-token partial
        assert_eq!(kc.last().unwrap().tokens, 2);
        // No prefix, no keys.
        let d = Request {
            prefix: Prefix::default(),
            ..a
        };
        assert!(d.block_keys(16).is_empty());
    }

    #[test]
    fn default_mix_generates_all_normal_without_perturbing_the_trace() {
        // A workload with no priority mix must generate the exact same
        // lengths/arrivals as before priorities existed (no RNG draws),
        // with every request normal-class.
        let w = WorkloadConfig::sharegpt_like(32);
        let reqs = generate(&w);
        assert!(reqs.iter().all(|r| r.priority == Priority::Normal));
        // And turning the mix on changes only the priorities: the
        // (id, arrival, lengths) tuples stay identical because the
        // priority draw happens after the length draws of each request.
        let mixed = generate(
            &w.clone()
                .with_priority_mix(crate::config::PriorityMix { high: 0.25, low: 0.25 }),
        );
        assert_eq!(reqs.len(), mixed.len());
        for (a, b) in reqs.iter().zip(&mixed) {
            assert_eq!(
                (a.id, a.arrival_s, a.input_len, a.output_len),
                (b.id, b.arrival_s, b.input_len, b.output_len)
            );
        }
        assert!(mixed.iter().any(|r| r.priority == Priority::High));
        assert!(mixed.iter().any(|r| r.priority == Priority::Low));
    }

    #[test]
    fn flash_crowd_spike_compresses_arrival_gaps() {
        let mut w = WorkloadConfig::sharegpt_like(200);
        w = w.with_arrival(ArrivalProcess::FlashCrowd {
            base_rate: 2.0,
            peak_rate: 50.0,
            spike_start_s: 5.0,
            spike_len_s: 10.0,
        });
        let reqs = generate(&w);
        for pair in reqs.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        let mean_gap = |lo: f64, hi: f64| {
            let pts: Vec<f64> = reqs
                .iter()
                .map(|r| r.arrival_s)
                .filter(|a| (lo..hi).contains(a))
                .collect();
            if pts.len() < 2 {
                f64::INFINITY
            } else {
                (pts[pts.len() - 1] - pts[0]) / (pts.len() - 1) as f64
            }
        };
        let before = mean_gap(0.0, 5.0);
        let during = mean_gap(5.0, 15.0);
        assert!(
            during < before / 4.0,
            "spike gap {during} not ≪ base gap {before}"
        );
        // Deterministic for the seed.
        assert_eq!(reqs, generate(&w));
    }

    #[test]
    fn diurnal_arrivals_crest_at_half_period() {
        let mut w = WorkloadConfig::sharegpt_like(300);
        w = w.with_arrival(ArrivalProcess::Diurnal {
            base_rate: 1.0,
            peak_rate: 30.0,
            period_s: 40.0,
        });
        let reqs = generate(&w);
        for pair in reqs.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        // The crest (around t = period/2) must pack arrivals much denser
        // than the trough at the start of the cycle.
        let count = |lo: f64, hi: f64| {
            reqs.iter()
                .filter(|r| (lo..hi).contains(&r.arrival_s))
                .count()
        };
        let trough = count(0.0, 5.0).max(1);
        let crest = count(15.0, 25.0);
        assert!(
            crest > 4 * trough,
            "crest {crest} not ≫ trough {trough} arrivals"
        );
        // Deterministic for the seed.
        assert_eq!(reqs, generate(&w));
    }

    #[test]
    fn priority_ordering_reads_naturally() {
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::Low.index(), 0);
        assert_eq!(Priority::High.index(), 2);
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn turn_arrivals_follow_think_time_and_stay_sorted() {
        let mut w = WorkloadConfig::shared_prefix(9);
        if let Some(ps) = &mut w.prefix {
            ps.turns = 3;
            ps.think_time_s = 2.0;
        }
        let reqs = generate(&w);
        for pair in reqs.windows(2) {
            assert!(pair[0].arrival_s <= pair[1].arrival_s);
        }
        // Conversations have 3 distinct arrival times 2 s apart.
        let conv0: Vec<&Request> = reqs.iter().filter(|r| r.prefix.conv_id == 0).collect();
        assert_eq!(conv0.len(), 3);
        assert!((conv0[1].arrival_s - conv0[0].arrival_s - 2.0).abs() < 1e-9);
    }
}
