//! Per-chip fleet description for the cluster layer.
//!
//! The pre-redesign `ClusterConfig` cloned one `(ChipConfig,
//! SchedulerConfig)` across N identical chips. A [`FleetSpec`] instead
//! describes each chip individually — its hardware variant, the scheduler
//! it runs, the deployment plan that scheduler was projected from, and its
//! serving [`ChipRole`] — which is what cluster-level PD disaggregation
//! over heterogeneous chips needs: compute-heavy prefill chips streaming
//! finished KV to HBM-heavy decode chips.

use crate::config::ChipConfig;
use crate::parallel::plan::{ChipRole, DeploymentPlan, FleetPlan};
use crate::serving::scheduler::SchedulerConfig;

/// One chip of the fleet.
#[derive(Debug, Clone)]
pub struct ChipSpec {
    /// Hardware configuration of this chip.
    pub hw: ChipConfig,
    /// Scheduler the chip runs (also the template a restart rebuilds from).
    pub sched: SchedulerConfig,
    /// Provenance: the deployment plan `sched` was projected from, if any.
    pub plan: Option<DeploymentPlan>,
    /// Serving role in the fleet.
    pub role: ChipRole,
}

impl ChipSpec {
    /// A general-purpose chip (no plan provenance).
    pub fn new(hw: ChipConfig, sched: SchedulerConfig) -> Self {
        ChipSpec {
            hw,
            sched,
            plan: None,
            role: ChipRole::General,
        }
    }

    /// Project a chip spec from a deployment plan (keeps the plan as
    /// provenance).
    pub fn from_plan(hw: ChipConfig, plan: &DeploymentPlan) -> anyhow::Result<Self> {
        let sched = SchedulerConfig::from_plan(plan)?;
        Ok(ChipSpec {
            hw,
            sched,
            plan: Some(plan.clone()),
            role: ChipRole::General,
        })
    }

    pub fn with_role(mut self, role: ChipRole) -> Self {
        self.role = role;
        self
    }
}

/// The whole fleet, one [`ChipSpec`] per chip.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub chips: Vec<ChipSpec>,
}

impl FleetSpec {
    pub fn new(chips: Vec<ChipSpec>) -> Self {
        FleetSpec { chips }
    }

    /// The legacy shape: `n` identical general-purpose chips.
    pub fn homogeneous(hw: ChipConfig, n: usize, sched: SchedulerConfig) -> Self {
        FleetSpec {
            chips: (0..n.max(1)).map(|_| ChipSpec::new(hw.clone(), sched)).collect(),
        }
    }

    /// Materialize a planned fleet ([`crate::parallel::plan::plan_fleet`])
    /// into runnable chip specs.
    pub fn from_plan_fleet(fleet: &FleetPlan) -> anyhow::Result<Self> {
        let chips = fleet
            .chips
            .iter()
            .map(|c| Ok(ChipSpec::from_plan(c.hw.clone(), &c.plan)?.with_role(c.role)))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(FleetSpec { chips })
    }

    pub fn n_chips(&self) -> usize {
        self.chips.len()
    }

    /// The fleet's shared clock (validated uniform).
    pub fn freq_mhz(&self) -> f64 {
        self.chips.first().map(|c| c.hw.freq_mhz).unwrap_or(0.0)
    }

    /// Chips that may run prompt processing (prefill or general role).
    pub fn prefill_capable(&self) -> Vec<usize> {
        (0..self.chips.len())
            .filter(|&i| self.chips[i].role != ChipRole::Decode)
            .collect()
    }

    /// Chips that may run decode legs (decode or general role).
    pub fn decode_capable(&self) -> Vec<usize> {
        (0..self.chips.len())
            .filter(|&i| self.chips[i].role != ChipRole::Prefill)
            .collect()
    }

    /// Whether any chip is role-specialized: if so, the cluster frontend
    /// splits each request into a prefill leg and a decode leg with a
    /// cross-chip KV handoff between them.
    pub fn is_disaggregated(&self) -> bool {
        self.chips.iter().any(|c| c.role != ChipRole::General)
    }

    /// Structural checks the cluster driver relies on: a non-empty fleet,
    /// one shared clock domain (the event loop and the fabric count cycles
    /// in it), valid chips, and — when role-specialized — at least one
    /// chip on each side of the prefill→decode handoff.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.chips.is_empty(), "empty fleet");
        let freq = self.chips[0].hw.freq_mhz;
        for (i, c) in self.chips.iter().enumerate() {
            c.hw.validate()?;
            anyhow::ensure!(
                c.hw.freq_mhz == freq,
                "fleet chips must share one clock domain: chip {i} runs {} MHz, chip 0 runs {freq} MHz",
                c.hw.freq_mhz
            );
        }
        if self.is_disaggregated() {
            anyhow::ensure!(
                !self.prefill_capable().is_empty(),
                "role-specialized fleet has no prefill-capable chip"
            );
            anyhow::ensure!(
                !self.decode_capable().is_empty(),
                "role-specialized fleet has no decode-capable chip"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::pd_fusion::FusionConfig;

    fn sched() -> SchedulerConfig {
        SchedulerConfig::Fusion(FusionConfig::default())
    }

    #[test]
    fn homogeneous_fleet_matches_legacy_shape() {
        let f = FleetSpec::homogeneous(ChipConfig::large_core(), 4, sched());
        assert_eq!(f.n_chips(), 4);
        assert!(!f.is_disaggregated());
        assert_eq!(f.prefill_capable(), vec![0, 1, 2, 3]);
        assert_eq!(f.decode_capable(), vec![0, 1, 2, 3]);
        f.validate().unwrap();
        // Zero chips clamps to one, like the legacy `n_chips.max(1)`.
        assert_eq!(FleetSpec::homogeneous(ChipConfig::large_core(), 0, sched()).n_chips(), 1);
    }

    #[test]
    fn role_split_fleet_partitions_capabilities() {
        let f = FleetSpec::new(vec![
            ChipSpec::new(ChipConfig::prefill_optimized(), sched()).with_role(ChipRole::Prefill),
            ChipSpec::new(ChipConfig::prefill_optimized(), sched()).with_role(ChipRole::Prefill),
            ChipSpec::new(ChipConfig::decode_optimized(), sched()).with_role(ChipRole::Decode),
            ChipSpec::new(ChipConfig::large_core(), sched()),
        ]);
        assert!(f.is_disaggregated());
        assert_eq!(f.prefill_capable(), vec![0, 1, 3]);
        assert_eq!(f.decode_capable(), vec![2, 3]);
        f.validate().unwrap();
    }

    #[test]
    fn validate_rejects_broken_fleets() {
        // Empty.
        assert!(FleetSpec::new(vec![]).validate().is_err());
        // Mixed clock domains.
        let mut slow = ChipConfig::large_core();
        slow.freq_mhz = 250.0;
        let f = FleetSpec::new(vec![
            ChipSpec::new(ChipConfig::large_core(), sched()),
            ChipSpec::new(slow, sched()),
        ]);
        assert!(f.validate().is_err());
        // All-prefill disaggregated fleet: nobody can decode.
        let f = FleetSpec::new(vec![
            ChipSpec::new(ChipConfig::large_core(), sched()).with_role(ChipRole::Prefill),
            ChipSpec::new(ChipConfig::large_core(), sched()).with_role(ChipRole::Prefill),
        ]);
        assert!(f.validate().is_err());
    }

    #[test]
    fn chip_spec_from_plan_keeps_provenance() {
        let plan = DeploymentPlan::fusion_default();
        let s = ChipSpec::from_plan(ChipConfig::large_core(), &plan).unwrap();
        assert_eq!(s.plan.as_ref().unwrap().name, plan.name);
        assert_eq!(s.role, ChipRole::General);
    }
}
