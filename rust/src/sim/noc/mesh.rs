//! 2D-mesh NoC model with XY routing and channel locking.
//!
//! The paper's router is cycle-accurate with a handshake mechanism: a path
//! is first established hop by hop (router arbitration), and once the
//! handshake completes the channel is *locked* and one flit moves per
//! cycle. Because the locked path streams deterministically, the whole
//! transfer can be represented as a busy interval on every traversed link —
//! latency and contention are cycle-accurate without a per-flit loop
//! (the paper makes the same observation to keep routing simulation fast).
//!
//! Deadlock freedom: links along a path are acquired in a single global
//! order (ascending link index). Combined with XY routing (which is itself
//! deadlock-free in a mesh) this prevents circular waits even when
//! collectives issue many simultaneous transfers. This channel-locking
//! mechanism is also what penalises WaferLLM's interleaved placement in
//! §5.4 — two-hop logical-neighbour transfers hold two links for the whole
//! transfer duration.

use crate::config::{ChipConfig, NocSimMode};
use crate::sim::engine::Timeline;
use crate::util::units::Cycle;

/// Physical core coordinate on the mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn new(row: usize, col: usize) -> Self {
        Coord { row, col }
    }

    /// Manhattan distance (number of mesh hops under XY routing).
    pub fn hops_to(&self, other: Coord) -> usize {
        self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
    }
}

/// Outgoing link direction from a core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    North,
    East,
    South,
    West,
}

/// Result of one simulated transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// When the transfer was issued.
    pub issued: Cycle,
    /// When the path lock was granted (== issued if uncontended).
    pub start: Cycle,
    /// When the last flit arrived at the destination.
    pub finish: Cycle,
    /// Mesh hops traversed.
    pub hops: usize,
}

impl Transfer {
    /// Cycles spent waiting on busy links.
    pub fn waited(&self) -> Cycle {
        self.start - self.issued
    }
}

/// Aggregate NoC statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct NocStats {
    pub transfers: u64,
    pub bytes: u64,
    pub total_hops: u64,
    /// Total cycles transfers waited for locked channels.
    pub contention: Cycle,
}

/// The mesh: per-directional-link busy timelines.
#[derive(Debug)]
pub struct Mesh {
    rows: usize,
    cols: usize,
    mode: NocSimMode,
    router_latency: Cycle,
    /// `1 / link_bytes_per_cycle` (hot-path division hoist — §Perf opt 1).
    inv_link_bytes_per_cycle: f64,
    /// `links[core_id * 4 + dir]` = outgoing link timeline.
    links: Vec<Timeline>,
    stats: NocStats,
    /// Scratch buffer for path link ids (avoids per-transfer allocation).
    path_buf: Vec<usize>,
}

impl Mesh {
    pub fn new(chip: &ChipConfig) -> Self {
        Mesh {
            rows: chip.rows,
            cols: chip.cols,
            mode: chip.noc.mode,
            router_latency: chip.noc.router_latency,
            inv_link_bytes_per_cycle: 1.0 / chip.noc.link_bytes_per_cycle(chip.freq_mhz),
            links: vec![Timeline::new(); chip.rows * chip.cols * 4],
            stats: NocStats::default(),
            path_buf: Vec::with_capacity(chip.rows + chip.cols),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }

    fn core_id(&self, c: Coord) -> usize {
        debug_assert!(c.row < self.rows && c.col < self.cols, "coord {c:?} off-mesh");
        c.row * self.cols + c.col
    }

    fn link_id(&self, from: Coord, dir: Direction) -> usize {
        self.core_id(from) * 4
            + match dir {
                Direction::North => 0,
                Direction::East => 1,
                Direction::South => 2,
                Direction::West => 3,
            }
    }

    /// Build the XY route from `src` to `dst` into `out` (link ids in
    /// traversal order).
    fn route_into(&self, src: Coord, dst: Coord, out: &mut Vec<usize>) {
        out.clear();
        let mut cur = src;
        // X first (columns), then Y (rows).
        while cur.col != dst.col {
            let dir = if dst.col > cur.col {
                Direction::East
            } else {
                Direction::West
            };
            out.push(self.link_id(cur, dir));
            cur.col = if dst.col > cur.col { cur.col + 1 } else { cur.col - 1 };
        }
        while cur.row != dst.row {
            let dir = if dst.row > cur.row {
                Direction::South
            } else {
                Direction::North
            };
            out.push(self.link_id(cur, dir));
            cur.row = if dst.row > cur.row { cur.row + 1 } else { cur.row - 1 };
        }
    }

    /// Serialization cycles for `bytes` on one locked channel.
    fn ser_cycles(&self, bytes: u64) -> Cycle {
        let x = bytes as f64 * self.inv_link_bytes_per_cycle;
        let t = x as Cycle;
        (t + u64::from((t as f64) < x)).max(1)
    }

    /// Simulate one point-to-point transfer issued at `earliest`.
    pub fn transfer(&mut self, src: Coord, dst: Coord, bytes: u64, earliest: Cycle) -> Transfer {
        let hops = src.hops_to(dst);
        if hops == 0 || bytes == 0 {
            return Transfer {
                issued: earliest,
                start: earliest,
                finish: earliest,
                hops: 0,
            };
        }
        self.stats.transfers += 1;
        self.stats.bytes += bytes;
        self.stats.total_hops += hops as u64;

        let setup = self.router_latency * hops as Cycle;
        let ser = self.ser_cycles(bytes);

        match self.mode {
            NocSimMode::Fast => Transfer {
                issued: earliest,
                start: earliest,
                finish: earliest + setup + ser,
                hops,
            },
            NocSimMode::Detailed => {
                // Handshake: the path is acquired link by link in global
                // link-id order (deadlock freedom); the channel is locked
                // from the granted start until the tail flit clears.
                let mut path = std::mem::take(&mut self.path_buf);
                self.route_into(src, dst, &mut path);
                // Lock start: all links must be simultaneously free.
                let mut start = earliest;
                // Ordered acquisition: examine links in ascending id.
                path.sort_unstable();
                for &l in &path {
                    start = start.max(self.links[l].probe(start));
                }
                let hold = setup + ser;
                for &l in &path {
                    self.links[l].reserve_at(start, hold);
                }
                self.path_buf = path;
                self.stats.contention += start - earliest;
                Transfer {
                    issued: earliest,
                    start,
                    finish: start + hold,
                    hops,
                }
            }
        }
    }

    /// Analytic (uncontended) latency for `bytes` over `hops` hops — used
    /// by planners that need a cost estimate without mutating link state.
    pub fn estimate(&self, hops: usize, bytes: u64) -> Cycle {
        if hops == 0 || bytes == 0 {
            return 0;
        }
        self.router_latency * hops as Cycle + self.ser_cycles(bytes)
    }

    pub fn stats(&self) -> NocStats {
        self.stats
    }

    /// Peak per-link busy cycles (hotspot detection in reports).
    pub fn max_link_busy(&self) -> Cycle {
        self.links.iter().map(|l| l.busy_cycles()).max().unwrap_or(0)
    }

    /// Sum of busy cycles over all links.
    pub fn total_link_busy(&self) -> Cycle {
        self.links.iter().map(|l| l.busy_cycles()).sum()
    }

    pub fn reset(&mut self) {
        for l in &mut self.links {
            l.reset();
        }
        self.stats = NocStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, NocSimMode};

    fn mesh(mode: NocSimMode) -> Mesh {
        let mut chip = ChipConfig::large_core(); // 8x8, 128 GB/s links @500MHz = 256 B/cyc
        chip.noc.mode = mode;
        Mesh::new(&chip)
    }

    #[test]
    fn xy_route_lengths() {
        let m = mesh(NocSimMode::Detailed);
        assert_eq!(Coord::new(0, 0).hops_to(Coord::new(0, 3)), 3);
        assert_eq!(Coord::new(2, 1).hops_to(Coord::new(5, 4)), 6);
        let mut path = Vec::new();
        m.route_into(Coord::new(2, 1), Coord::new(5, 4), &mut path);
        assert_eq!(path.len(), 6);
    }

    #[test]
    fn uncontended_latency_is_setup_plus_serialization() {
        let mut m = mesh(NocSimMode::Detailed);
        // 2560 bytes over 256 B/cycle = 10 cycles; 2 hops × 2 = 4 setup.
        let t = m.transfer(Coord::new(0, 0), Coord::new(0, 2), 2560, 100);
        assert_eq!(t.start, 100);
        assert_eq!(t.finish, 100 + 4 + 10);
        assert_eq!(t.hops, 2);
        assert_eq!(t.waited(), 0);
    }

    #[test]
    fn fast_mode_matches_uncontended_detailed() {
        let mut md = mesh(NocSimMode::Detailed);
        let mut mf = mesh(NocSimMode::Fast);
        let a = md.transfer(Coord::new(1, 1), Coord::new(3, 4), 10_000, 0);
        let b = mf.transfer(Coord::new(1, 1), Coord::new(3, 4), 10_000, 0);
        assert_eq!(a.finish, b.finish);
    }

    #[test]
    fn overlapping_paths_contend_in_detailed_mode() {
        let mut m = mesh(NocSimMode::Detailed);
        // Both transfers cross link (0,0)->(0,1).
        let t1 = m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        let t2 = m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        assert!(t2.start >= t1.finish, "second must wait for channel unlock");
        assert!(m.stats().contention > 0);
    }

    #[test]
    fn disjoint_paths_do_not_contend() {
        let mut m = mesh(NocSimMode::Detailed);
        let t1 = m.transfer(Coord::new(0, 0), Coord::new(0, 1), 25_600, 0);
        let t2 = m.transfer(Coord::new(3, 0), Coord::new(3, 1), 25_600, 0);
        assert_eq!(t1.start, 0);
        assert_eq!(t2.start, 0);
        assert_eq!(m.stats().contention, 0);
    }

    #[test]
    fn fast_mode_ignores_contention() {
        let mut m = mesh(NocSimMode::Fast);
        let t1 = m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        let t2 = m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        assert_eq!(t1.finish, t2.finish);
    }

    #[test]
    fn self_transfer_is_free() {
        let mut m = mesh(NocSimMode::Detailed);
        let t = m.transfer(Coord::new(2, 2), Coord::new(2, 2), 1000, 50);
        assert_eq!(t.finish, 50);
        assert_eq!(t.hops, 0);
        assert_eq!(m.stats().transfers, 0);
    }

    #[test]
    fn estimate_matches_uncontended_transfer() {
        let mut m = mesh(NocSimMode::Detailed);
        let est = m.estimate(3, 5000);
        let t = m.transfer(Coord::new(0, 0), Coord::new(0, 3), 5000, 0);
        assert_eq!(t.finish, est);
    }

    #[test]
    fn opposite_directions_are_separate_channels() {
        let mut m = mesh(NocSimMode::Detailed);
        // A->B and B->A use different directional links: no contention.
        let t1 = m.transfer(Coord::new(0, 0), Coord::new(0, 1), 25_600, 0);
        let t2 = m.transfer(Coord::new(0, 1), Coord::new(0, 0), 25_600, 0);
        assert_eq!(t1.start, 0);
        assert_eq!(t2.start, 0);
    }

    #[test]
    fn reset_clears_links() {
        let mut m = mesh(NocSimMode::Detailed);
        m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        m.reset();
        let t = m.transfer(Coord::new(0, 0), Coord::new(0, 4), 25_600, 0);
        assert_eq!(t.start, 0);
    }
}
