//! Network-on-chip sub-system (§3.1 "routing system"): XY-routed 2D mesh
//! with handshake path setup, channel locking, and per-link contention.

mod mesh;

pub use mesh::{Coord, Direction, Mesh, NocStats, Transfer};
