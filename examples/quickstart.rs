//! Quickstart: simulate a small LLM serving workload on a 64-core NPU and
//! print the serving metrics — the 20-line tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::sim::chip::ChipSim;

fn main() -> anyhow::Result<()> {
    // Hardware: the paper's Table-3 "large-core" chip (8x8 mesh, 128x128
    // systolic arrays, 32 MB SRAM + core-local HBM per core).
    let mut chip = ChipSim::new(ChipConfig::large_core());

    // Model + workload: Qwen3-4B under a decode-dominated trace.
    let model = ModelConfig::qwen3_4b();
    let workload = WorkloadConfig::decode_dominated(8);

    // Serving strategy: PD fusion with chunked prefill (§4.3.2).
    let metrics = simulate_fusion(&mut chip, &model, &workload, &FusionConfig::default())?;

    println!("requests completed : {}", metrics.n_requests());
    println!("TTFT mean          : {:.1} ms", metrics.ttft_s().mean() * 1e3);
    println!("TBT  mean          : {:.2} ms", metrics.tbt_s().mean() * 1e3);
    println!("throughput         : {:.1} tok/s", metrics.tokens_per_s());

    println!("\nwhere the cycles went:");
    for (class, cycles, pct) in chip.aggregate_tracer().breakdown() {
        println!("  {:<12} {:>14} cycles  {:>5.1}%", class.name(), cycles, pct);
    }
    Ok(())
}
