//! Deterministic pseudo-random number generation and the distributions the
//! serving workload generators need (uniform, exponential, normal,
//! log-normal, Poisson, Zipf).
//!
//! The generator is `xoshiro256**` seeded through SplitMix64 — fast, high
//! quality, and fully reproducible across platforms, which matters because
//! every experiment in `experiments/` pins a seed so figures regenerate
//! identically.

/// A seedable, deterministic PRNG (`xoshiro256**`).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (panics if `lo >= hi`).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "range_u64: empty range {lo}..{hi}");
        let span = hi - lo;
        // Lemire-style rejection-free-enough mapping; bias is negligible for
        // span << 2^64 and acceptable for simulation workloads.
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (mean `1/rate`). Used for
    /// Poisson-process inter-arrival times in the streaming request
    /// generator.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / rate
    }

    /// Standard normal variate (Box–Muller; one value per call).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal variate parameterised by the *underlying* normal's
    /// `mu`/`sigma`. Prompt- and output-length distributions in real traces
    /// (ShareGPT, Mooncake) are well fit by log-normals.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Poisson variate (Knuth's method; fine for the small lambdas used in
    /// batching tests).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda > 0.0);
        if lambda > 30.0 {
            // Normal approximation for large lambda.
            return self.normal(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (rejection
    /// sampling). Models skewed expert popularity for the MoE router.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        assert!(n > 0);
        // Inverse-CDF over the (precomputable but small) harmonic weights
        // would allocate; use rejection sampling instead.
        let hmax = zeta_partial(n, s);
        loop {
            let u = self.f64() * hmax;
            // Walk is O(n) worst case; callers use small n (experts ≤ 128).
            let mut acc = 0.0;
            for k in 0..n {
                acc += 1.0 / ((k + 1) as f64).powf(s);
                if u <= acc {
                    return k;
                }
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

fn zeta_partial(n: usize, s: f64) -> f64 {
    (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1, "mean={mean}");
        assert!((var - 9.0).abs() < 0.4, "var={var}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(4.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_toward_head() {
        let mut r = Rng::new(19);
        let mut counts = [0usize; 8];
        for _ in 0..20_000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 4, "counts={counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }
}
