//! `fleet_study` — cluster-level PD disaggregation over heterogeneous
//! chips: the same prefill-heavy trace (ShareGPT-like prompt band,
//! short outputs, Poisson arrivals) served by
//!
//! - `homog-fused`         — the best homogeneous fused fleet
//!   ([`plan::plan_fleet_fused`]): every chip a `large_core` clone running
//!   the top fused plan over its share of the workload.
//! - `fleet-planned`       — whatever [`plan::plan_fleet`] picks for this
//!   workload at equal chip count. On a prefill-heavy mix the planner
//!   must choose the role-specialized fleet: compute-heavy prefill chips
//!   streaming finished prompt KV to HBM-heavy decode chips over the
//!   interconnect ([`crate::sim::interconnect`]).
//! - `fleet-planned-crash` — the planned fleet with a decode chip crashed
//!   mid-trace and never restarted ([`RecoveryPolicy::Recover`]).
//!
//! The gated acceptance properties (`BENCH_serving.json` `"fleet"`
//! section, checked by `tools/bench_check`):
//!
//! 1. **Specialization pays**: on the prefill-heavy mix the planned
//!    fleet is disaggregated, performs cross-chip handoffs, and its
//!    goodput-under-SLO strictly beats the homogeneous fused fleet at
//!    equal chip count.
//! 2. **Exactly-once across the handoff**: `completed + shed == offered`
//!    in every scenario, and every completed request reports exactly its
//!    offered input/output token counts (`tokens_exact`) — splitting a
//!    request into prefill and decode legs neither loses nor duplicates
//!    tokens, including under a decode-chip crash.
//!
//! ```sh
//! cargo run --release -p npusim -- experiment fleet_study
//! ```

use crate::config::{ArrivalProcess, ChipConfig, LenDist, ModelConfig, WorkloadConfig};
use crate::experiments::{overload_study, Opts};
use crate::parallel::plan::{self, FleetPlan};
use crate::serving::cluster::{self, ClusterConfig, ClusterMetrics, RouterPolicy};
use crate::serving::faults::{FaultEvent, FaultKind, FaultSchedule, RecoveryPolicy};
use crate::serving::fleet::FleetSpec;
use crate::serving::request::{self, Request};
use crate::sim::interconnect::InterconnectConfig;
use crate::util::table::{f3, Table};
use std::collections::HashMap;

/// Fleet size of the study: enough chips that the planner has a real
/// prefill/decode staffing choice to make.
pub const FLEET_CHIPS: usize = 4;

/// One fleet-scenario cell.
#[derive(Debug, Clone)]
pub struct FleetRun {
    pub fleet: &'static str,
    pub chips: usize,
    pub n_prefill: usize,
    pub n_decode: usize,
    pub disaggregated: bool,
    pub offered: usize,
    pub completed: usize,
    pub shed: u64,
    /// Prefill→decode cross-chip KV handoffs (0 for homogeneous fleets).
    pub handoffs: u64,
    pub crashes: u64,
    /// Every completed request reports exactly its offered input/output
    /// token counts (exactly-once across the leg split).
    pub tokens_exact: bool,
    pub slo_ttft_s: f64,
    pub goodput_tok_s: f64,
    pub tok_s: f64,
    /// Interconnect traffic (migrations + handoffs), MB.
    pub icn_mb: f64,
}

/// The prefill-heavy trace of the study: ShareGPT-like long prompts,
/// short outputs, Poisson arrivals at `rate`.
fn fleet_workload(n: usize, rate: f64) -> WorkloadConfig {
    let mut w = WorkloadConfig::fixed_ratio(768, 32, n);
    w.name = "fleet-prefill-heavy".into();
    w.input_len = LenDist::Uniform(512, 1024);
    w.output_len = LenDist::Uniform(16, 48);
    w.with_arrival(ArrivalProcess::Poisson { rate: rate.max(1.0) })
        .with_seed(13)
}

/// Exactly-once token accounting: every completed record must carry its
/// request's offered input/output token counts, so a fleet handoff can
/// neither lose nor double-count a token.
fn tokens_exact(reqs: &[Request], cm: &ClusterMetrics) -> bool {
    let want: HashMap<u64, (u64, u64)> = reqs
        .iter()
        .map(|r| (r.id, (r.input_len as u64, r.output_len as u64)))
        .collect();
    cm.aggregate().records().iter().all(|rec| {
        want.get(&rec.id)
            .is_some_and(|&(i, o)| rec.input_tokens == i && rec.output_tokens == o)
    })
}

/// Run one planned fleet over the trace; conservation (exactly-once) is
/// asserted here so every caller inherits gate 2.
fn run_fleet(
    name: &'static str,
    model: &ModelConfig,
    fleet: &FleetPlan,
    reqs: Vec<Request>,
    slo_ttft_s: f64,
    faults: Option<FaultSchedule>,
) -> anyhow::Result<FleetRun> {
    let offered = reqs.len();
    let spec = FleetSpec::from_plan_fleet(fleet)?;
    let mut b = ClusterConfig::builder(spec)
        .router(RouterPolicy::LeastLoaded)
        .slo_ttft_s(slo_ttft_s);
    if let Some(f) = faults {
        b = b.faults(f);
    }
    let cfg = b.build();
    let cm = cluster::simulate_cluster_requests(&cfg, model, reqs.clone())?;
    anyhow::ensure!(
        cm.conserves(offered),
        "{name}: {} completed + {} shed != {offered} offered",
        cm.n_requests(),
        cm.shed_requests()
    );
    let exact = tokens_exact(&reqs, &cm);
    let agg = cm.aggregate();
    Ok(FleetRun {
        fleet: name,
        chips: fleet.chips.len(),
        n_prefill: fleet.n_prefill(),
        n_decode: fleet.n_decode(),
        disaggregated: fleet.disaggregated,
        offered,
        completed: cm.n_requests(),
        shed: cm.shed_requests(),
        handoffs: cm.handoffs,
        crashes: cm.faults.crashes,
        tokens_exact: exact,
        slo_ttft_s,
        goodput_tok_s: agg.goodput_tokens_per_s(slo_ttft_s, overload_study::SLO_TBT_S),
        tok_s: agg.tokens_per_s(),
        icn_mb: cm.interconnect.bytes as f64 / (1 << 20) as f64,
    })
}

/// The three-scenario comparison the bench's `"fleet"` section reports.
pub fn bench_rows(opts: &Opts) -> anyhow::Result<Vec<FleetRun>> {
    let model = ModelConfig::qwen3_4b();
    let n = opts.pick(96, 24);
    let per_chip = overload_study::sustainable_rate(&model, opts.pick(24, 8))?;
    // Prompts here are roughly twice the calibration mix's, so 0.4x the
    // nominal fleet rate is a prefill-pressured (not saturated) operating
    // point, and the SLO stretches by the same factor.
    let rate = per_chip * FLEET_CHIPS as f64 * 0.4;
    let slo_ttft_s = 2.0 * overload_study::SLO_SERVICE_PERIODS / per_chip;
    let w = fleet_workload(n, rate);
    let reqs = request::generate(&w);
    let icn = InterconnectConfig::default();
    let chip = ChipConfig::large_core();
    let homog = plan::plan_fleet_fused(&chip, &model, &w, FLEET_CHIPS)?;
    let planned = plan::plan_fleet(&chip, &model, &w, FLEET_CHIPS, &icn)?;
    // Crash the first decode chip mid-trace (prefill chips lead the
    // planned fleet's chip list) and never restart it.
    let crash_chip = planned.n_prefill().min(FLEET_CHIPS - 1);
    let horizon = n as f64 / rate.max(1.0);
    let crash = FaultSchedule::new(vec![FaultEvent {
        at_s: 0.3 * horizon,
        chip: crash_chip,
        kind: FaultKind::ChipCrash {
            restart_after_s: None,
        },
    }])
    .with_retries(6, 0.002)
    .with_recovery(RecoveryPolicy::Recover);
    Ok(vec![
        run_fleet("homog-fused", &model, &homog, reqs.clone(), slo_ttft_s, None)?,
        run_fleet("fleet-planned", &model, &planned, reqs.clone(), slo_ttft_s, None)?,
        run_fleet(
            "fleet-planned-crash",
            &model,
            &planned,
            reqs,
            slo_ttft_s,
            Some(crash),
        )?,
    ])
}

pub fn run(opts: &Opts) -> anyhow::Result<Vec<Table>> {
    let runs = bench_rows(opts)?;

    let mut t = Table::new(
        "fleet_study — fleet-level PD disaggregation on a prefill-heavy trace \
         (Qwen3-4B, 4 chips, planned silicon per role)",
        &[
            "fleet",
            "P/D chips",
            "offered",
            "completed",
            "shed",
            "handoffs",
            "crashes",
            "tokens exact",
            "icn MB",
            "goodput tok/s (SLO)",
            "tok/s",
        ],
    );
    for r in &runs {
        t.row(&[
            r.fleet.to_string(),
            format!("{}/{}", r.n_prefill, r.n_decode),
            r.offered.to_string(),
            r.completed.to_string(),
            r.shed.to_string(),
            r.handoffs.to_string(),
            r.crashes.to_string(),
            r.tokens_exact.to_string(),
            f3(r.icn_mb),
            f3(r.goodput_tok_s),
            f3(r.tok_s),
        ]);
    }

    let by = |s: &str| runs.iter().find(|r| r.fleet == s).unwrap();
    let (homog, planned) = (by("homog-fused"), by("fleet-planned"));
    println!(
        "fleet_study: goodput under SLO (TTFT<{:.4}s) — homog-fused {:.1} tok/s vs \
         planned {} P{}/D{} {:.1} tok/s ({:+.0}%), {} handoffs moved {:.2} MB of KV",
        planned.slo_ttft_s,
        homog.goodput_tok_s,
        if planned.disaggregated { "fleet-disagg" } else { "fleet-fused" },
        planned.n_prefill,
        planned.n_decode,
        planned.goodput_tok_s,
        if homog.goodput_tok_s > 0.0 {
            (planned.goodput_tok_s / homog.goodput_tok_s - 1.0) * 100.0
        } else {
            0.0
        },
        planned.handoffs,
        planned.icn_mb
    );

    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_trace_is_deterministic_and_prefill_heavy() {
        let w = fleet_workload(32, 40.0);
        let reqs = request::generate(&w);
        assert_eq!(reqs.len(), 32);
        assert_eq!(reqs, request::generate(&w));
        assert!(reqs.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        for r in &reqs {
            assert!(r.input_len >= 512 && r.input_len <= 1024);
            assert!(r.output_len >= 16 && r.output_len <= 48);
            assert!(r.input_len > 8 * r.output_len, "prefill-heavy by construction");
        }
    }

    #[test]
    fn gates_hold_at_fast_scale() {
        // The bench_check gates, asserted at the same scale CI smoke-runs:
        // exactly-once (inside run_fleet), token exactness across the leg
        // split, the planner choosing specialization on a prefill-heavy
        // mix, and specialization strictly beating the homogeneous fused
        // fleet on goodput-under-SLO at equal chip count.
        let runs = bench_rows(&Opts::fast()).unwrap();
        assert_eq!(runs.len(), 3);
        let by = |s: &str| runs.iter().find(|r| r.fleet == s).unwrap();
        let (homog, planned, crash) =
            (by("homog-fused"), by("fleet-planned"), by("fleet-planned-crash"));
        for r in &runs {
            assert_eq!(r.chips, FLEET_CHIPS, "{}", r.fleet);
            assert!(r.tokens_exact, "{}: token counts drifted across the handoff", r.fleet);
        }
        assert!(!homog.disaggregated);
        assert_eq!(homog.handoffs, 0);
        assert_eq!(homog.completed, homog.offered);
        assert!(
            planned.disaggregated,
            "the planner must specialize on a prefill-heavy mix"
        );
        assert!(planned.n_prefill >= 1 && planned.n_decode >= 1);
        assert!(planned.handoffs > 0, "a disaggregated fleet must hand off");
        assert!(planned.icn_mb > 0.0);
        assert!(
            planned.goodput_tok_s > homog.goodput_tok_s,
            "planned fleet {} !> homogeneous {}",
            planned.goodput_tok_s,
            homog.goodput_tok_s
        );
        assert_eq!(crash.crashes, 1);
        assert!(crash.handoffs > 0);
    }
}
