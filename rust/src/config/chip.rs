//! Chip / core / NoC hardware configuration (paper Table 3).
//!
//! A chip is a `rows × cols` 2D mesh of NPU cores. Each core has a systolic
//! array (GEMM), a vector unit (elementwise/softmax/norms), local SRAM
//! scratchpad, an optional core-local HBM stack, a DMA engine and a NoC
//! router with four directional links. Heterogeneous PD-disaggregation
//! (§4.3.1) is expressed by giving decode cores their own [`CoreConfig`].

use crate::util::units::{gbps_to_bytes_per_cycle, MB};

/// Simulation fidelity for the memory system (§3.1): transaction-level
/// (detailed, near-cycle-accurate) or analytic performance model (fast).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemSimMode {
    /// 4-phase TLM with banked HBM, bounded outstanding window, OOO completion.
    #[default]
    Detailed,
    /// `bytes / bandwidth + fixed latency` analytic estimate.
    Fast,
}

/// Simulation fidelity for the NoC (§3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NocSimMode {
    /// Handshake path setup + channel locking + per-link contention.
    #[default]
    Detailed,
    /// `hops × hop_latency + bytes / bandwidth`, no contention.
    Fast,
}

/// Per-core hardware resources.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreConfig {
    /// Systolic array dimension (the array is `sa_dim × sa_dim` MACs).
    pub sa_dim: u64,
    /// Vector unit lanes; each lane has 64 ALUs (Table 3).
    pub vector_lanes: u64,
    /// Local SRAM scratchpad capacity in bytes.
    pub sram_bytes: u64,
    /// SRAM bandwidth in GB/s. `0.0` means "scaled with the systolic array"
    /// (Table 3: *SRAM bandwidth per core — scaled with SA*); see
    /// [`CoreConfig::sram_bw_gbps`].
    pub sram_bw_gbps_raw: f64,
    /// Core-local HBM bandwidth in GB/s (0 = no HBM attached to this core).
    pub hbm_bw_gbps: f64,
    /// Core-local HBM capacity in bytes.
    pub hbm_bytes: u64,
}

impl CoreConfig {
    /// Effective SRAM bandwidth. When not set explicitly it scales with
    /// the core's compute capability (Table 3: *SRAM bandwidth per core —
    /// scaled with SA*; §5.5: "automatically adjust SRAM bandwidth to
    /// match the computational capability"): enough to stream two bf16
    /// operands per lane of the wider of the systolic array and the
    /// vector unit — `4 × max(sa_dim, vector_lanes) bytes/cycle`.
    pub fn sram_bw_gbps(&self, freq_mhz: f64) -> f64 {
        if self.sram_bw_gbps_raw > 0.0 {
            self.sram_bw_gbps_raw
        } else {
            let bytes_per_cycle = 4.0 * self.sa_dim.max(self.vector_lanes) as f64;
            bytes_per_cycle * freq_mhz * 1e6 / 1e9
        }
    }

    /// SRAM bytes/cycle at `freq_mhz`.
    pub fn sram_bytes_per_cycle(&self, freq_mhz: f64) -> f64 {
        gbps_to_bytes_per_cycle(self.sram_bw_gbps(freq_mhz), freq_mhz)
    }

    /// HBM bytes/cycle at `freq_mhz`.
    pub fn hbm_bytes_per_cycle(&self, freq_mhz: f64) -> f64 {
        gbps_to_bytes_per_cycle(self.hbm_bw_gbps, freq_mhz)
    }

    /// Peak MACs/cycle of the systolic array.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        self.sa_dim * self.sa_dim
    }

    /// Peak vector ALU ops/cycle.
    pub fn peak_vector_ops_per_cycle(&self) -> u64 {
        self.vector_lanes * 64
    }

    pub fn has_hbm(&self) -> bool {
        self.hbm_bw_gbps > 0.0 && self.hbm_bytes > 0
    }
}

/// NoC link/router configuration. Each core has 4 directional links
/// (N/E/S/W) of `link_bw_gbps` each.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocConfig {
    /// Per-link bandwidth in GB/s.
    pub link_bw_gbps: f64,
    /// Router traversal latency in cycles (handshake/arbitration per hop).
    pub router_latency: u64,
    /// Simulation mode.
    pub mode: NocSimMode,
}

impl NocConfig {
    /// Link width in bytes per cycle at `freq_mhz` (one flit per cycle once
    /// the path is locked — §3.1).
    pub fn link_bytes_per_cycle(&self, freq_mhz: f64) -> f64 {
        gbps_to_bytes_per_cycle(self.link_bw_gbps, freq_mhz)
    }
}

/// Whole-chip configuration: the 2D mesh of cores.
#[derive(Debug, Clone, PartialEq)]
pub struct ChipConfig {
    pub name: String,
    /// Mesh rows.
    pub rows: usize,
    /// Mesh columns.
    pub cols: usize,
    /// Core clock in MHz (Table 3: 500 MHz).
    pub freq_mhz: f64,
    /// Default (prefill / homogeneous) core resources.
    pub core: CoreConfig,
    /// Override for decode cores under heterogeneous PD-disaggregation.
    /// `None` = homogeneous chip.
    pub decode_core: Option<CoreConfig>,
    pub noc: NocConfig,
    pub mem_mode: MemSimMode,
    /// Fixed HBM access latency component in cycles (row activation etc.).
    pub hbm_latency_cycles: u64,
    /// Number of HBM banks per core-local stack (Detailed mem mode).
    pub hbm_banks: usize,
    /// Max outstanding HBM transactions per core (Detailed mem mode).
    pub hbm_outstanding: usize,
    /// Element size in bytes for weights/activations (bf16 = 2).
    pub dtype_bytes: u64,
}

impl ChipConfig {
    pub fn n_cores(&self) -> usize {
        self.rows * self.cols
    }

    /// Core resources for a decode core (falls back to the default core on
    /// homogeneous chips).
    pub fn decode_core(&self) -> CoreConfig {
        self.decode_core.unwrap_or(self.core)
    }

    /// Paper Table 3 "Large-core" preset: 64 cores, 8×8 mesh.
    pub fn large_core() -> Self {
        ChipConfig {
            name: "large-core-64".into(),
            rows: 8,
            cols: 8,
            freq_mhz: 500.0,
            core: CoreConfig {
                sa_dim: 128,
                vector_lanes: 128,
                sram_bytes: 32 * MB,
                sram_bw_gbps_raw: 0.0,
                hbm_bw_gbps: 120.0,
                hbm_bytes: 4 * 1024 * MB,
            },
            decode_core: None,
            noc: NocConfig {
                link_bw_gbps: 128.0,
                router_latency: 2,
                mode: NocSimMode::Detailed,
            },
            mem_mode: MemSimMode::Detailed,
            hbm_latency_cycles: 60,
            hbm_banks: 16,
            hbm_outstanding: 16,
            dtype_bytes: 2,
        }
    }

    /// Paper Table 3 "Small-core" preset: 256 cores, 16×16 mesh.
    pub fn small_core() -> Self {
        ChipConfig {
            name: "small-core-256".into(),
            rows: 16,
            cols: 16,
            freq_mhz: 500.0,
            core: CoreConfig {
                sa_dim: 64,
                vector_lanes: 64,
                sram_bytes: 16 * MB,
                sram_bw_gbps_raw: 0.0,
                hbm_bw_gbps: 40.0,
                hbm_bytes: 1024 * MB,
            },
            decode_core: None,
            noc: NocConfig {
                link_bw_gbps: 64.0,
                router_latency: 2,
                mode: NocSimMode::Detailed,
            },
            mem_mode: MemSimMode::Detailed,
            hbm_latency_cycles: 60,
            hbm_banks: 8,
            hbm_outstanding: 16,
            dtype_bytes: 2,
        }
    }

    /// An Ascend-910B-class configuration used for the Fig. 7 validation:
    /// ~25 "DaVinci" cube cores, large cube units, shared HBM modelled as
    /// core-local slices of the aggregate ~1.6 TB/s.
    pub fn ascend910b_like() -> Self {
        ChipConfig {
            name: "ascend910b-like".into(),
            rows: 5,
            cols: 5,
            freq_mhz: 1000.0,
            core: CoreConfig {
                sa_dim: 128, // 16^3 cube ~ 4096 MACs/cycle ≈ 64x64; x2 for bf16 rate
                vector_lanes: 64,
                sram_bytes: 24 * MB,
                sram_bw_gbps_raw: 0.0,
                hbm_bw_gbps: 64.0, // ~1.6 TB/s / 25 cores
                hbm_bytes: 2 * 1024 * MB,
            },
            decode_core: None,
            noc: NocConfig {
                link_bw_gbps: 96.0,
                router_latency: 2,
                mode: NocSimMode::Detailed,
            },
            mem_mode: MemSimMode::Detailed,
            hbm_latency_cycles: 80,
            hbm_banks: 16,
            hbm_outstanding: 32,
            dtype_bytes: 2,
        }
    }

    /// Compute-heavy fleet variant for prefill-role chips: a wider
    /// systolic array (and matching vector width) buys prompt-processing
    /// throughput, while HBM stays at the large-core baseline — long
    /// prefills are MAC-bound, not bandwidth-bound.
    pub fn prefill_optimized() -> Self {
        let mut c = Self::large_core();
        c.name = "prefill-opt-64".into();
        c.core.sa_dim = 192;
        c.core.vector_lanes = 192;
        c
    }

    /// HBM-heavy fleet variant for decode-role chips: decode is memory-
    /// bound (A-IO), so the array shrinks and per-core HBM bandwidth
    /// doubles relative to the large-core baseline.
    pub fn decode_optimized() -> Self {
        let mut c = Self::large_core();
        c.name = "decode-opt-64".into();
        c.core.sa_dim = 96;
        c.core.hbm_bw_gbps = 240.0;
        c
    }

    /// Set both simulation modes at once (Fig. 7-right's mode comparison).
    pub fn with_sim_modes(mut self, mem: MemSimMode, noc: NocSimMode) -> Self {
        self.mem_mode = mem;
        self.noc.mode = noc;
        self
    }

    /// Builder-style knobs used by the configuration-space sweeps (Fig. 8).
    pub fn with_sram_mb(mut self, mb: u64) -> Self {
        self.core.sram_bytes = mb * MB;
        self
    }
    pub fn with_sa_dim(mut self, dim: u64) -> Self {
        self.core.sa_dim = dim;
        self
    }
    pub fn with_hbm_bw(mut self, gbps: f64) -> Self {
        self.core.hbm_bw_gbps = gbps;
        self
    }
    pub fn with_noc_bw(mut self, gbps: f64) -> Self {
        self.noc.link_bw_gbps = gbps;
        self
    }
    /// Heterogeneous decode-core override (Fig. 12 sweeps).
    pub fn with_decode_core(mut self, core: CoreConfig) -> Self {
        self.decode_core = Some(core);
        self
    }

    /// Sanity checks; experiments call this after building a config.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.rows > 0 && self.cols > 0, "empty mesh");
        anyhow::ensure!(self.freq_mhz > 0.0, "bad frequency");
        anyhow::ensure!(self.core.sa_dim > 0, "bad systolic dim");
        anyhow::ensure!(self.core.sram_bytes > 0, "no SRAM");
        anyhow::ensure!(self.noc.link_bw_gbps > 0.0, "no NoC bandwidth");
        anyhow::ensure!(self.dtype_bytes > 0, "bad dtype");
        if let Some(d) = &self.decode_core {
            anyhow::ensure!(d.sa_dim > 0 && d.sram_bytes > 0, "bad decode core");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ChipConfig::large_core().validate().unwrap();
        ChipConfig::small_core().validate().unwrap();
        ChipConfig::ascend910b_like().validate().unwrap();
    }

    #[test]
    fn preset_core_counts_match_table3() {
        assert_eq!(ChipConfig::large_core().n_cores(), 64);
        assert_eq!(ChipConfig::small_core().n_cores(), 256);
    }

    #[test]
    fn fleet_variants_specialize_against_baseline() {
        let base = ChipConfig::large_core();
        let p = ChipConfig::prefill_optimized();
        let d = ChipConfig::decode_optimized();
        p.validate().unwrap();
        d.validate().unwrap();
        // Same mesh and clock as the baseline (fleets require uniform freq).
        assert_eq!(p.n_cores(), base.n_cores());
        assert_eq!(p.freq_mhz, base.freq_mhz);
        assert_eq!(d.freq_mhz, base.freq_mhz);
        // Prefill variant: more MACs, baseline HBM.
        assert!(p.core.peak_macs_per_cycle() > base.core.peak_macs_per_cycle());
        assert_eq!(p.core.hbm_bw_gbps, base.core.hbm_bw_gbps);
        // Decode variant: fewer MACs, more HBM bandwidth.
        assert!(d.core.peak_macs_per_cycle() < base.core.peak_macs_per_cycle());
        assert!(d.core.hbm_bw_gbps > base.core.hbm_bw_gbps);
        // Distinct names so bench rows are self-describing.
        assert_ne!(p.name, base.name);
        assert_ne!(d.name, base.name);
    }

    #[test]
    fn sram_bw_scales_with_sa() {
        let c = ChipConfig::large_core();
        // 4 bytes/cycle per SA lane at 128 lanes, 500 MHz => 256 GB/s.
        let bw = c.core.sram_bw_gbps(c.freq_mhz);
        assert!((bw - 256.0).abs() < 1e-6, "bw={bw}");
        // Explicit value wins.
        let mut core = c.core;
        core.sram_bw_gbps_raw = 100.0;
        assert_eq!(core.sram_bw_gbps(c.freq_mhz), 100.0);
    }

    #[test]
    fn builder_knobs() {
        let c = ChipConfig::large_core()
            .with_sram_mb(64)
            .with_sa_dim(32)
            .with_hbm_bw(240.0)
            .with_noc_bw(480.0);
        assert_eq!(c.core.sram_bytes, 64 * MB);
        assert_eq!(c.core.sa_dim, 32);
        assert_eq!(c.core.hbm_bw_gbps, 240.0);
        assert_eq!(c.noc.link_bw_gbps, 480.0);
    }

    #[test]
    fn decode_core_fallback() {
        let c = ChipConfig::large_core();
        assert_eq!(c.decode_core(), c.core);
        let mut d = c.core;
        d.sa_dim = 32;
        let c2 = c.with_decode_core(d);
        assert_eq!(c2.decode_core().sa_dim, 32);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ChipConfig::large_core();
        c.rows = 0;
        assert!(c.validate().is_err());
        let mut c = ChipConfig::large_core();
        c.core.sram_bytes = 0;
        assert!(c.validate().is_err());
    }
}
