//! PD study: when should a chip disaggregate prefill/decode, and when
//! should it fuse them? A compact version of the paper's §5.5 comparison
//! (Figs. 11/14) over workload input:output ratios.
//!
//! Run: `cargo run --release --example pd_study`

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::sim::chip::ChipSim;
use npusim::util::table::{f3, Table};

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::qwen3_4b();
    let ratios: [(usize, usize); 3] = [(128, 512), (256, 256), (1000, 100)];
    let n = 8;

    let mut t = Table::new(
        "PD disaggregation vs PD fusion (Qwen3-4B, 64 cores)",
        &["in:out", "system", "tok/s", "TTFT ms", "TBT ms"],
    );
    for (input, output) in ratios {
        let w = WorkloadConfig::fixed_ratio(input, output, n);

        let mut chip = ChipSim::new(ChipConfig::large_core());
        let fusion = simulate_fusion(&mut chip, &model, &w, &FusionConfig::default())?;

        let mut chip = ChipSim::new(ChipConfig::large_core());
        let disagg = simulate_disagg(&mut chip, &model, &w, &DisaggConfig::p42_d21())?;

        for (name, m) in [("fusion", &fusion), ("disagg P42/D21", &disagg)] {
            t.row(&[
                format!("{input}:{output}"),
                name.to_string(),
                f3(m.tokens_per_s()),
                f3(m.ttft_s().mean() * 1e3),
                f3(m.tbt_s().mean() * 1e3),
            ]);
        }
    }
    t.print();
    println!(
        "\nguidance (§5.6): fusion wins decode-dominated workloads; heterogeneous\n\
         disaggregation wins prefill-dominated ones and keeps TBT stable."
    );
    Ok(())
}
