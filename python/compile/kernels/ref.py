"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is straight-line jax.numpy with no Pallas — the reference
semantics the kernels (and therefore the AOT artifacts rust executes) are
validated against in python/tests/.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain f32 matmul."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def decode_attention_ref(q, k, v, kv_len):
    """Masked single-token attention; shapes as kernels.attention.

    q: [B, H, d]; k, v: [B, S, KH, d]; kv_len: [B] -> [B, H, d].
    """
    b, h, d = q.shape
    _, s, kh, _ = k.shape
    groups = h // kh
    k_full = jnp.repeat(k, groups, axis=2)  # [B, S, H, d]
    v_full = jnp.repeat(v, groups, axis=2)
    q = q.astype(jnp.float32)
    logits = jnp.einsum("bhd,bshd->bhs", q, k_full.astype(jnp.float32))
    logits = logits / (d**0.5)
    mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", p, v_full.astype(jnp.float32))


def swiglu_ref(gate, up):
    """silu(gate) * up in plain jnp."""
    gate = gate.astype(jnp.float32)
    return gate / (1.0 + jnp.exp(-gate)) * up.astype(jnp.float32)
