//! Serving metrics: TTFT, TBT, end-to-end latency, throughput, SLO
//! attainment — the quantities every figure in §5.5 reports.

use crate::util::stats::Summary;
use crate::util::units::{cycles_to_secs, Cycle};

/// Lifecycle timestamps of one completed request (in simulated cycles).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: Cycle,
    /// First output token produced (end of prefill).
    pub first_token: Cycle,
    /// Last output token produced.
    pub finish: Cycle,
    pub input_tokens: u64,
    pub output_tokens: u64,
}

impl RequestRecord {
    /// Time To First Token, cycles.
    pub fn ttft(&self) -> Cycle {
        self.first_token.saturating_sub(self.arrival)
    }

    /// Mean Time Between Tokens, cycles (0 for single-token outputs).
    pub fn tbt(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish - self.first_token) as f64 / (self.output_tokens - 1) as f64
    }

    /// End-to-end latency, cycles.
    pub fn e2e(&self) -> Cycle {
        self.finish.saturating_sub(self.arrival)
    }

    /// Mean Time Between Tokens in seconds at `freq_mhz` (0 for
    /// single-token outputs) — the one conversion shared by reporting and
    /// SLO checks.
    pub fn tbt_secs(&self, freq_mhz: f64) -> f64 {
        self.tbt() / (freq_mhz * 1e6)
    }
}

/// Aggregated metrics over a serving run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    records: Vec<RequestRecord>,
    freq_mhz: f64,
}

impl Metrics {
    pub fn new(freq_mhz: f64) -> Self {
        Metrics {
            records: Vec::new(),
            freq_mhz,
        }
    }

    pub fn record(&mut self, r: RequestRecord) {
        debug_assert!(r.first_token >= r.arrival && r.finish >= r.first_token, "{r:?}");
        self.records.push(r);
    }

    pub fn n_requests(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Makespan: last finish cycle.
    pub fn makespan(&self) -> Cycle {
        self.records.iter().map(|r| r.finish).max().unwrap_or(0)
    }

    /// TTFT distribution in seconds.
    pub fn ttft_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .map(|r| cycles_to_secs(r.ttft(), self.freq_mhz)),
        )
    }

    /// TBT distribution in seconds.
    pub fn tbt_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .filter(|r| r.output_tokens > 1)
                .map(|r| r.tbt_secs(self.freq_mhz)),
        )
    }

    /// End-to-end latency distribution in seconds.
    pub fn e2e_s(&self) -> Summary {
        Summary::from_samples(
            self.records
                .iter()
                .map(|r| cycles_to_secs(r.e2e(), self.freq_mhz)),
        )
    }

    /// Output-token throughput over the makespan, tokens/s.
    pub fn tokens_per_s(&self) -> f64 {
        let tokens: u64 = self.records.iter().map(|r| r.output_tokens).sum();
        let span = cycles_to_secs(self.makespan(), self.freq_mhz);
        if span <= 0.0 {
            return 0.0;
        }
        tokens as f64 / span
    }

    /// Completed requests per second.
    pub fn requests_per_s(&self) -> f64 {
        let span = cycles_to_secs(self.makespan(), self.freq_mhz);
        if span <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / span
    }

    /// Fraction of requests meeting both SLO targets (seconds).
    pub fn slo_attainment(&self, ttft_target_s: f64, tbt_target_s: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let ok = self
            .records
            .iter()
            .filter(|r| {
                cycles_to_secs(r.ttft(), self.freq_mhz) <= ttft_target_s
                    && r.tbt_secs(self.freq_mhz) <= tbt_target_s
            })
            .count();
        ok as f64 / self.records.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, arrival: Cycle, first: Cycle, finish: Cycle, out: u64) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            first_token: first,
            finish,
            input_tokens: 100,
            output_tokens: out,
        }
    }

    #[test]
    fn per_request_derivations() {
        let r = rec(1, 1000, 3000, 13_000, 11);
        assert_eq!(r.ttft(), 2000);
        assert_eq!(r.e2e(), 12_000);
        assert!((r.tbt() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn single_token_tbt_is_zero() {
        assert_eq!(rec(1, 0, 10, 10, 1).tbt(), 0.0);
    }

    #[test]
    fn aggregate_conversions() {
        let mut m = Metrics::new(500.0); // 5e8 cycles/s
        m.record(rec(1, 0, 5_000_000, 255_000_000, 51)); // ttft 10ms, tbt 10ms
        m.record(rec(2, 0, 10_000_000, 260_000_000, 51));
        assert_eq!(m.n_requests(), 2);
        assert!((m.ttft_s().mean() - 0.015).abs() < 1e-9);
        assert!((m.tbt_s().mean() - 0.01).abs() < 1e-9);
        // 102 tokens over 0.52 s.
        assert!((m.tokens_per_s() - 102.0 / 0.52).abs() < 1e-6);
    }

    #[test]
    fn slo_attainment_counts() {
        let mut m = Metrics::new(500.0);
        m.record(rec(1, 0, 5_000_000, 255_000_000, 51)); // ttft 10ms tbt 10ms
        m.record(rec(2, 0, 500_000_000, 600_000_000, 2)); // ttft 1s
        assert!((m.slo_attainment(0.1, 0.5) - 0.5).abs() < 1e-9);
        assert!((m.slo_attainment(2.0, 0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new(500.0);
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.slo_attainment(1.0, 1.0), 0.0);
        assert_eq!(m.makespan(), 0);
    }
}
