//! Smoke test: every experiment of the paper regenerates in fast mode and
//! produces non-empty tables with the expected row structure.

use npusim::experiments::{self, Opts};

#[test]
fn every_experiment_regenerates_fast() {
    for id in experiments::ALL {
        let tables = experiments::run(id, &Opts::fast())
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e:#}"));
        assert!(!tables.is_empty(), "{id}: no tables");
        for t in &tables {
            assert!(t.n_rows() > 0, "{id}: empty table");
        }
    }
}

#[test]
fn csvs_written_when_out_dir_given() {
    let dir = std::env::temp_dir().join(format!("npusim_smoke_{}", std::process::id()));
    let opts = Opts {
        fast: true,
        out_dir: Some(dir.clone()),
    };
    experiments::run("table2", &opts).unwrap();
    assert!(dir.join("table2.csv").exists());
    let csv = std::fs::read_to_string(dir.join("table2.csv")).unwrap();
    assert!(csv.lines().count() >= 5, "header + 4 strategies");
    let _ = std::fs::remove_dir_all(dir);
}
