//! Trace replay: serve a Mooncake-format JSONL trace through both serving
//! strategies and compare. Uses a bundled synthetic trace if no path is
//! given: `cargo run --release --example trace_replay [-- path/to.jsonl]`

use npusim::config::{ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::pd_disagg::{simulate_disagg_requests, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion_requests, FusionConfig};
use npusim::serving::{request, trace};
use npusim::sim::chip::ChipSim;
use npusim::util::table::{f3, Table};

fn main() -> anyhow::Result<()> {
    // Load the trace (or synthesise a Mooncake-like one, round-tripped
    // through the JSONL format to exercise the parser end to end).
    let reqs = match std::env::args().nth(1) {
        Some(path) => {
            println!("replaying {path}");
            trace::load_jsonl(&path, Some(32))?
        }
        None => {
            let synthetic = request::generate(&WorkloadConfig::mooncake_like(12));
            let jsonl = trace::to_jsonl(&synthetic);
            println!("no trace given; using a synthetic Mooncake-like trace:");
            for line in jsonl.lines().take(3) {
                println!("  {line}");
            }
            println!("  ... ({} requests)", synthetic.len());
            trace::parse_jsonl(&jsonl)?
        }
    };
    let total_in: usize = reqs.iter().map(|r| r.input_len).sum();
    let total_out: usize = reqs.iter().map(|r| r.output_len).sum();
    println!(
        "trace: {} requests, {total_in} prompt tokens, {total_out} output tokens\n",
        reqs.len()
    );

    let model = ModelConfig::qwen3_4b();
    let mut t = Table::new(
        "trace replay — PD fusion vs PD disaggregation (Qwen3-4B, 64 cores)",
        &["system", "TTFT ms", "TBT ms", "e2e s", "tok/s"],
    );
    for (name, disagg) in [("fusion (TP16)", false), ("disagg P42/D21", true)] {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let m = if disagg {
            simulate_disagg_requests(&mut chip, &model, reqs.clone(), &DisaggConfig::p42_d21())?
        } else {
            simulate_fusion_requests(
                &mut chip,
                &model,
                reqs.clone(),
                &FusionConfig {
                    tp: 16,
                    stages: 1,
                    ..FusionConfig::default()
                },
            )?
        };
        t.row(&[
            name.to_string(),
            f3(m.ttft_s().mean() * 1e3),
            f3(m.tbt_s().mean() * 1e3),
            f3(m.e2e_s().mean()),
            f3(m.tokens_per_s()),
        ]);
    }
    t.print();
    Ok(())
}
