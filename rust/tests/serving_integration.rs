//! Cross-module integration: scheduler × executor × memory manager × NoC,
//! exercised through the public API the way an adopter would.

use npusim::config::{load_sim_config, ArrivalProcess, ChipConfig, ModelConfig, WorkloadConfig};
use npusim::serving::pd_disagg::{simulate_disagg, DisaggConfig};
use npusim::serving::pd_fusion::{simulate_fusion, FusionConfig};
use npusim::serving::request;
use npusim::sim::chip::ChipSim;
use npusim::sim::tracer::OpClass;

fn small_workload(n: usize) -> WorkloadConfig {
    WorkloadConfig::fixed_ratio(96, 12, n)
}

#[test]
fn fusion_conserves_requests_and_tokens() {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let model = ModelConfig::qwen3_4b();
    let w = small_workload(6);
    let m = simulate_fusion(&mut chip, &model, &w, &FusionConfig::default()).unwrap();
    assert_eq!(m.n_requests(), 6);
    let total_out: u64 = m.records().iter().map(|r| r.output_tokens).sum();
    assert_eq!(total_out, 6 * 12);
    // The chip actually did transformer work.
    let tr = chip.aggregate_tracer();
    assert!(tr.cycles(OpClass::Gemm) > 0);
    assert!(tr.cycles(OpClass::Attention) > 0);
    assert!(tr.cycles(OpClass::AllReduce) + tr.cycles(OpClass::AllGather) > 0);
}

#[test]
fn prefix_cached_serving_conserves_tokens_and_skips_prefill() {
    // Cross-module: trie index × ref-counted blocks × scheduler admission.
    // Every request still retires exactly once with its full output, while
    // a large share of prompt tokens never re-prefills.
    let model = ModelConfig::qwen3_4b();
    let w = WorkloadConfig::shared_prefix(8);
    let expect_out: u64 = request::generate(&w)
        .iter()
        .map(|r| r.output_len as u64)
        .sum();
    let cfg = FusionConfig {
        prefix_cache: true,
        ..FusionConfig::default()
    };
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let m = simulate_fusion(&mut chip, &model, &w, &cfg).unwrap();
    assert_eq!(m.n_requests(), 8);
    let out: u64 = m.records().iter().map(|r| r.output_tokens).sum();
    assert_eq!(out, expect_out, "prefix skipping lost or invented tokens");
    for r in m.records() {
        assert!(r.first_token >= r.arrival, "{r:?}");
        assert!(r.finish >= r.first_token, "{r:?}");
    }
    assert!(m.cache.prefix_hits > 0, "no prefix hits on a shared trace");
    assert!(m.cache.prefill_tokens_skipped > 0);
    assert!(m.cache.kv_bytes_deduped > 0);
}

#[test]
fn disagg_conserves_requests_and_transfers_kv() {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let model = ModelConfig::qwen3_4b();
    let w = small_workload(6);
    let m = simulate_disagg(&mut chip, &model, &w, &DisaggConfig::p42_d21()).unwrap();
    assert_eq!(m.n_requests(), 6);
    assert!(chip.aggregate_tracer().cycles(OpClass::KvTransfer) > 0);
}

#[test]
fn fusion_and_disagg_agree_on_workload_scale() {
    // Same workload, same chip: the two schedulers must land within an
    // order of magnitude of each other (they share every model below).
    let model = ModelConfig::qwen3_4b();
    let w = small_workload(4);
    let mut c1 = ChipSim::new(ChipConfig::large_core());
    let f = simulate_fusion(&mut c1, &model, &w, &FusionConfig::default()).unwrap();
    let mut c2 = ChipSim::new(ChipConfig::large_core());
    let d = simulate_disagg(&mut c2, &model, &w, &DisaggConfig::p42_d21()).unwrap();
    let ratio = f.e2e_s().mean() / d.e2e_s().mean();
    assert!(ratio > 0.05 && ratio < 20.0, "ratio={ratio}");
}

#[test]
fn streaming_arrivals_respected_by_both_schedulers() {
    let model = ModelConfig::qwen3_4b();
    let w = small_workload(5).with_arrival(ArrivalProcess::Poisson { rate: 2.0 });
    let arrivals: Vec<f64> = request::generate(&w).iter().map(|r| r.arrival_s).collect();
    assert!(arrivals.iter().any(|&a| a > 0.1), "trace has spread");

    let mut chip = ChipSim::new(ChipConfig::large_core());
    let m = simulate_fusion(&mut chip, &model, &w, &FusionConfig::default()).unwrap();
    for r in m.records() {
        assert!(r.first_token >= r.arrival, "{r:?}");
    }
}

#[test]
fn moe_model_serves_end_to_end() {
    let mut chip = ChipSim::new(ChipConfig::large_core());
    let model = ModelConfig::qwen3_30b_a3b();
    let w = WorkloadConfig::fixed_ratio(64, 6, 2);
    let m = simulate_fusion(&mut chip, &model, &w, &FusionConfig::default()).unwrap();
    assert_eq!(m.n_requests(), 2);
    assert!(chip.aggregate_tracer().cycles(OpClass::P2P) > 0, "MoE dispatch traffic");
}

#[test]
fn toml_config_drives_simulation() {
    let text = r#"
[chip]
preset = "large_core"
sram_mb = 16
mem_mode = "fast"
noc_mode = "fast"

[model]
name = "qwen3_1.7b"

[workload]
n_requests = 3
input_len = 64
output_len = 8
"#;
    let bundle = load_sim_config(text).unwrap();
    let mut chip = ChipSim::new(bundle.chip);
    let m = simulate_fusion(
        &mut chip,
        &bundle.model,
        &bundle.workload,
        &FusionConfig::default(),
    )
    .unwrap();
    assert_eq!(m.n_requests(), 3);
}

#[test]
fn fast_modes_run_faster_than_detailed() {
    use npusim::config::{MemSimMode, NocSimMode};
    let model = ModelConfig::qwen3_4b();
    let w = small_workload(3);
    let t0 = std::time::Instant::now();
    let mut c = ChipSim::new(ChipConfig::large_core());
    simulate_fusion(&mut c, &model, &w, &FusionConfig::default()).unwrap();
    let wall_detailed = t0.elapsed();
    let t0 = std::time::Instant::now();
    let mut c = ChipSim::new(
        ChipConfig::large_core().with_sim_modes(MemSimMode::Fast, NocSimMode::Fast),
    );
    simulate_fusion(&mut c, &model, &w, &FusionConfig::default()).unwrap();
    let wall_fast = t0.elapsed();
    // Fast mode must not be slower by more than noise.
    assert!(
        wall_fast <= wall_detailed * 3,
        "fast {wall_fast:?} vs detailed {wall_detailed:?}"
    );
}
