//! PD disaggregation behind the [`Scheduler`] trait: dedicated prefill
//! pipelines stream whole prompts, decode groups run continuous batching,
//! and completed prefills move their KV over the NoC (§4.3.1). This is the
//! former `pd_disagg::simulate_disagg` loop split into `init`/`step`.

use super::pipe;
use super::Scheduler;
use crate::config::ModelConfig;
use crate::memmgr::prefix::{BlockKey, TierMatch};
use crate::memmgr::KV_BLOCK_TOKENS;
use crate::model::{BatchItem, IterBatch};
use crate::parallel::pd_placement::{assign, PdAssignment};
use crate::serving::metrics::{Metrics, RequestRecord};
use crate::serving::pd_disagg::DisaggConfig;
use crate::serving::request::Request;
use crate::serving::worker::StageWorker;
use crate::sim::chip::ChipSim;
use crate::sim::noc::Coord;
use crate::sim::tracer::OpClass;
use crate::util::units::{secs_to_cycles, Cycle};
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy)]
struct DecodeReq {
    req: Request,
    first_token: Cycle,
    generated: u64,
    ready_at: Cycle,
}

struct DecodeGroup {
    worker: StageWorker,
    /// Transferred but not yet admitted to the KV cache.
    pending: VecDeque<DecodeReq>,
    active: Vec<DecodeReq>,
}

impl DecodeGroup {
    fn load(&self) -> usize {
        self.pending.len() + self.active.len()
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        let now = self.worker.now(chip);
        let pending = self.pending.front().map(|r| r.ready_at);
        let active = self
            .active
            .iter()
            .filter(|a| a.generated < a.req.output_len as u64)
            .map(|a| a.ready_at)
            .min();
        match (pending, active) {
            (None, None) => None,
            (a, b) => Some(now.max(a.unwrap_or(Cycle::MAX).min(b.unwrap_or(Cycle::MAX)))),
        }
    }
}

/// Upper bound on how long the cache-affinity pull may delay a prompt past
/// the earliest-available prefill pipeline, per matched token (the order
/// of the per-token prefill work a hit replaces): waiting on a busy holder
/// longer than the recompute it saves can only lose, so beyond this the
/// pull falls back to earliest-available.
const AFFINITY_WAIT_CYCLES_PER_TOKEN: Cycle = 512;

/// The disaggregated scheduler: prompts queue globally, prefill pipelines
/// pull whole prompts, decode groups continuously batch transferred
/// requests.
pub struct DisaggScheduler {
    cfg: DisaggConfig,
    pipelines: Vec<Vec<StageWorker>>,
    groups: Vec<DecodeGroup>,
    queue: VecDeque<Request>,
}

impl DisaggScheduler {
    pub fn new(cfg: DisaggConfig) -> Self {
        DisaggScheduler {
            cfg,
            pipelines: Vec::new(),
            groups: Vec::new(),
            queue: VecDeque::new(),
        }
    }

    /// The prompt the next prefill pull takes: the highest-class *arrived*
    /// prompt (stable FIFO within a class), falling back to the front —
    /// whose arrival sets the wake-up time — while nothing has arrived.
    /// Uniform-priority queues always pick the front (the arrived set is a
    /// prefix of the arrival-sorted queue), reducing to the legacy pull.
    fn next_prompt(&self, chip: &ChipSim, freq: f64) -> Option<usize> {
        if self.queue.is_empty() {
            return None;
        }
        let t_ref = self
            .pipelines
            .iter()
            .map(|p| p[0].now(chip))
            .min()
            .unwrap_or(0);
        Some(
            (0..self.queue.len())
                .filter(|&i| secs_to_cycles(self.queue[i].arrival_s, freq) <= t_ref)
                .min_by_key(|&i| (std::cmp::Reverse(self.queue[i].priority), i))
                .unwrap_or(0),
        )
    }

    /// Earliest actionable prefill `(pipeline, queue index, cycle)` and
    /// decode `(group, cycle)` — one selection rule shared by `step`
    /// (which acts on it) and `next_action` (which only reports it), so
    /// the two can never disagree about what is actionable.
    ///
    /// With `cross_pipe` the prefill pull is **cache-affinity-aware**: the
    /// pulled prompt goes to the pipeline holding its best cached-and-ready
    /// prefix (tier-weighted score; ties → earliest available, then lower
    /// index) instead of whichever pipeline frees first, so a correctly
    /// routed request no longer lands on a non-caching pipeline.
    #[allow(clippy::type_complexity)]
    fn actions(&self, chip: &ChipSim) -> (Option<(usize, usize, Cycle)>, Option<(usize, Cycle)>) {
        let freq = chip.cfg.freq_mhz;
        let prefill = if let Some(qi) = self.next_prompt(chip, freq) {
            let front = &self.queue[qi];
            let arrival = secs_to_cycles(front.arrival_s, freq);
            let cands: Vec<(usize, Cycle)> = self
                .pipelines
                .iter()
                .enumerate()
                .map(|(i, p)| (i, p[0].now(chip).max(arrival)))
                .collect();
            let t_min = cands.iter().map(|&(_, t)| t).min().unwrap_or(0);
            // Probing here (rather than only at pull time) keeps `step`
            // and `next_action` agreeing on the chosen pipeline; the walk
            // is O(pipelines × stages × prefix blocks) of pure trie
            // probes, small next to one simulated iteration.
            let affinity = if self.cfg.cross_pipe && self.cfg.prefix_cache {
                let keys = front.block_keys(KV_BLOCK_TOKENS);
                let limit = (front.input_len as u64).saturating_sub(1);
                if keys.is_empty() {
                    None
                } else {
                    cands
                        .iter()
                        .map(|&(i, t)| {
                            let m = self.pipelines[i]
                                .iter()
                                .map(|s| s.peek_prefix_tiered(&keys, limit, t))
                                .min_by_key(|m| (m.total(), m.sram_tokens))
                                .unwrap_or_default();
                            (i, t, m)
                        })
                        // A holder only wins while the extra wait stays
                        // under what recomputing the match would cost —
                        // unbounded waiting would starve the prompt behind
                        // one popular pipeline.
                        .filter(|&(_, t, m)| {
                            m.total() > 0
                                && t <= t_min
                                    .saturating_add(m.total() * AFFINITY_WAIT_CYCLES_PER_TOKEN)
                        })
                        .min_by_key(|&(i, t, m)| (std::cmp::Reverse(m.score()), t, i))
                        .map(|(i, t, _)| (i, t))
                }
            } else {
                None
            };
            affinity
                .or_else(|| cands.into_iter().min_by_key(|&(_, t)| t))
                .map(|(i, t)| (i, qi, t))
        } else {
            None
        };
        let decode = self
            .groups
            .iter()
            .enumerate()
            .filter_map(|(i, g)| g.next_action(chip).map(|t| (i, t)))
            .min_by_key(|&(_, t)| t);
        (prefill, decode)
    }
}

impl Scheduler for DisaggScheduler {
    fn name(&self) -> &'static str {
        "disagg"
    }

    fn prepare(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        max_tokens: usize,
    ) -> anyhow::Result<()> {
        let cfg = &self.cfg;
        let a: PdAssignment = assign(
            chip.cfg.rows,
            chip.cfg.cols,
            cfg.n_prefill,
            cfg.n_decode,
            cfg.prefill_tp,
            cfg.prefill_stages,
            cfg.decode_tp,
            cfg.policy,
        )?;

        // Heterogeneous decode cores (Fig. 12): apply the chip's decode-core
        // override to every decode coordinate.
        let decode_core = chip.cfg.decode_core();
        if chip.cfg.decode_core.is_some() {
            for g in &a.decode_groups {
                for &c in &g.coords {
                    chip.set_core_config(c, decode_core);
                }
            }
        }

        let layers = model.layers;
        let lps = {
            let base = layers / cfg.prefill_stages;
            let extra = layers % cfg.prefill_stages;
            (0..cfg.prefill_stages)
                .map(|s| base + usize::from(s < extra))
                .collect::<Vec<_>>()
        };
        let core = chip.cfg.core;
        self.queue = VecDeque::new();
        let max_tokens = max_tokens.max(1);
        self.pipelines = a
            .prefill_pipelines
            .iter()
            .map(|stages| {
                stages
                    .iter()
                    .enumerate()
                    .map(|(s, g)| {
                        // Whole prompts stream through these pipelines, so
                        // the Fig. 9 phase switch matters here: short
                        // prompts (M below the plan threshold) fall back
                        // to the AllReduce partition per dist_gemm call.
                        let exec = crate::model::exec::ExecConfig::new(
                            cfg.prefill_strategy,
                            lps[s].max(1),
                            s + 1 == stages.len(),
                        )
                        .with_small_m(cfg.decode_strategy, cfg.m_threshold);
                        StageWorker::new(
                            &core,
                            model,
                            g.clone(),
                            exec,
                            2048,
                            cfg.kv_share,
                            max_tokens,
                        )
                        .with_prefix_cache(cfg.prefix_cache)
                        .with_hbm_tier(cfg.prefix_cache && cfg.hbm_tier, cfg.hbm_tier_frac)
                        .with_memo(cfg.memo)
                        .with_sim_level(cfg.sim_level)
                    })
                    .collect()
            })
            .collect();
        // Vanilla decode runs GEMV-shaped iterations, so the groups pin
        // `decode_strategy` statically. Speculative decoding turns each
        // iteration into a verify GEMM of `batch * (gamma + 1)` rows —
        // large enough to cross the Fig. 9 boundary — so with `--spec` the
        // groups get the same phase switch as the prefill pipelines:
        // verify batches above the threshold run `prefill_strategy`,
        // everything smaller keeps the decode partition. A plan that left
        // the switch off (`m_threshold` 0) learns the cost-model crossover
        // here, since a threshold of 0 would wrongly force every batch
        // onto the large-M strategy.
        let decode_exec = match cfg.spec {
            Some(_) => {
                let threshold = if cfg.m_threshold > 0 {
                    cfg.m_threshold
                } else {
                    crate::parallel::plan::learned_m_threshold(
                        &chip.cfg,
                        model,
                        cfg.decode_tp,
                        cfg.prefill_strategy,
                        cfg.decode_strategy,
                    )
                };
                crate::model::exec::ExecConfig::new(cfg.prefill_strategy, layers, true)
                    .with_small_m(cfg.decode_strategy, threshold)
            }
            None => crate::model::exec::ExecConfig::new(cfg.decode_strategy, layers, true),
        };
        self.groups = a
            .decode_groups
            .iter()
            .map(|g| DecodeGroup {
                // Decode groups receive whole-prompt KV over the NoC, so
                // they never prefix-match — only the memo applies there.
                worker: StageWorker::new(
                    &decode_core,
                    model,
                    g.clone(),
                    decode_exec,
                    cfg.max_decode_batch,
                    cfg.kv_share,
                    max_tokens,
                )
                .with_memo(cfg.memo)
                .with_sim_level(cfg.sim_level),
                pending: VecDeque::new(),
                active: Vec::new(),
            })
            .collect();
        Ok(())
    }

    fn enqueue(&mut self, chip: &mut ChipSim, req: Request) {
        // Prompts queue globally; the cache-affinity decision (which
        // pipeline pulls the prompt) happens at pull time in `actions`.
        let _ = chip;
        self.queue.push_back(req);
    }

    fn step(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        metrics: &mut Metrics,
    ) -> anyhow::Result<usize> {
        let freq = chip.cfg.freq_mhz;
        let (prefill_action, decode_action) = self.actions(chip);

        match (prefill_action, decode_action) {
            (Some((pi, qi, tp_)), Some((_, td))) if tp_ <= td => run_prefill(
                chip,
                model,
                &mut self.pipelines[pi],
                &mut self.queue,
                qi,
                &mut self.groups,
                metrics,
                freq,
                self.cfg.prefix_cache,
            ),
            (Some((pi, qi, _)), None) => run_prefill(
                chip,
                model,
                &mut self.pipelines[pi],
                &mut self.queue,
                qi,
                &mut self.groups,
                metrics,
                freq,
                self.cfg.prefix_cache,
            ),
            (_, Some((gi, t))) => Ok(decode_tick(
                chip,
                model,
                &self.cfg,
                &mut self.groups[gi],
                t,
                metrics,
                freq,
            )),
            (None, None) => anyhow::bail!("disagg deadlock: no actionable work"),
        }
    }

    fn next_action(&self, chip: &ChipSim) -> Option<Cycle> {
        let (prefill, decode) = self.actions(chip);
        match (prefill.map(|(_, _, t)| t), decode.map(|(_, t)| t)) {
            (None, None) => None,
            (a, b) => Some(a.unwrap_or(Cycle::MAX).min(b.unwrap_or(Cycle::MAX))),
        }
    }

    fn pending_work(&self) -> usize {
        self.queue.len() + self.groups.iter().map(|g| g.load()).sum::<usize>()
    }

    fn kv_utilization(&self) -> f64 {
        // Decode groups gate steady-state admission (their KV holds the
        // whole-request residency); prefill pipelines only stage prompts.
        if self.groups.is_empty() {
            return 0.0;
        }
        self.groups
            .iter()
            .map(|g| g.worker.kv.utilization())
            .sum::<f64>()
            / self.groups.len() as f64
    }

    fn backpressure(&self) -> f64 {
        // Decode-group admission slots gate steady-state throughput; the
        // global prompt queue measured against twice those slots, max'd
        // with decode KV occupancy, is how saturated this chip looks to
        // the cluster frontend.
        let slots = self.cfg.max_decode_batch.max(1) * self.groups.len().max(1);
        let q = (self.pending_work() as f64 / (2 * slots) as f64).min(1.0);
        q.max(self.kv_utilization())
    }

    fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        // Prefill pipelines hold the prefix caches; an incoming prompt may
        // run on any of them, so report the best pipeline's ready match.
        self.pipelines
            .iter()
            .map(|p| {
                p.iter()
                    .map(|s| s.peek_prefix(keys, limit, at))
                    .min()
                    .unwrap_or(0)
            })
            .max()
            .unwrap_or(0)
    }

    fn probe_prefix_tiered(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> TierMatch {
        self.pipelines
            .iter()
            .map(|p| {
                p.iter()
                    .map(|s| s.peek_prefix_tiered(keys, limit, at))
                    .min_by_key(|m| (m.total(), m.sram_tokens))
                    .unwrap_or_default()
            })
            .max_by_key(|m| (m.score(), m.total()))
            .unwrap_or_default()
    }

    fn import_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        // Prompts are pulled by whichever prefill pipeline frees first, so
        // a migrated copy must be visible to all of them.
        for p in &mut self.pipelines {
            for s in p.iter_mut() {
                s.kv.seed_prefix(keys, ready_at);
            }
        }
    }

    fn drain_incomplete(&mut self) -> Vec<super::Incomplete> {
        // Prefill runs whole-prompt inside one step, so between steps a
        // request is either queued (nothing computed) or in a decode
        // group (fully prefilled, part-decoded).
        let mut out: Vec<super::Incomplete> = self
            .queue
            .drain(..)
            .map(|req| super::Incomplete {
                req,
                prefilled: 0,
                generated: 0,
            })
            .collect();
        for g in &mut self.groups {
            for d in g.pending.drain(..).chain(g.active.drain(..)) {
                out.push(super::Incomplete {
                    req: d.req,
                    prefilled: d.req.input_len as u64,
                    generated: d.generated,
                });
            }
        }
        out.sort_by_key(|i| i.req.id);
        out
    }

    fn collect_cache_stats(&self, out: &mut crate::serving::metrics::CacheStats) {
        let workers = self
            .pipelines
            .iter()
            .flatten()
            .chain(self.groups.iter().map(|g| &g.worker));
        pipe::collect_worker_stats(workers, out);
    }
}

/// Run one whole prompt through a prefill pipeline, then transfer its KV to
/// the least-loaded decode group. Returns completions (requests whose
/// output is a single token finish at prefill). With the prefix cache on,
/// the cached prefix's chunks are skipped: only the unmatched prompt tail
/// is prefilled (the decode group still receives whole-prompt KV).
#[allow(clippy::too_many_arguments)]
fn run_prefill(
    chip: &mut ChipSim,
    model: &ModelConfig,
    pipeline: &mut [StageWorker],
    queue: &mut VecDeque<Request>,
    qi: usize,
    groups: &mut [DecodeGroup],
    metrics: &mut Metrics,
    freq: f64,
    prefix_cache: bool,
) -> anyhow::Result<usize> {
    let r = queue.remove(qi).expect("caller checked");
    let arrival = secs_to_cycles(r.arrival_s, freq);
    pipeline[0].advance_to(chip, arrival);
    let now = pipeline[0].now(chip);

    let mut matched = 0u64;
    if prefix_cache {
        matched = pipe::admit_with_prefix(chip, pipeline, &r, model, metrics, now);
    } else {
        for s in pipeline.iter_mut() {
            s.admit(r.id);
        }
    }
    let batch = IterBatch::new(vec![BatchItem::prefill(
        r.id,
        r.input_len as u64 - matched,
        r.input_len as u64,
    )]);
    let mut finish = 0;
    for s in 0..pipeline.len() {
        finish = pipeline[s].run(chip, model, &batch);
        if s + 1 < pipeline.len() {
            let bytes = (r.input_len as u64 - matched) * model.hidden as u64 * model.dtype_bytes;
            let src = pipeline[s].group.coords[0];
            let dst = pipeline[s + 1].group.coords[0];
            let t = chip.send(src, dst, bytes, OpClass::P2P);
            finish = finish.max(t.finish);
        }
    }
    let first_token = finish;
    if prefix_cache {
        // The whole prompt is prefilled in one shot: every prefix block
        // this request registered is matchable from `finish` on.
        for s in pipeline.iter_mut() {
            s.note_prefilled(r.id, r.input_len as u64, finish);
        }
    }

    if r.output_len <= 1 {
        for s in pipeline.iter_mut() {
            s.release(r.id);
        }
        metrics.record(RequestRecord {
            id: r.id,
            arrival,
            first_token,
            finish,
            input_tokens: r.input_len as u64,
            output_tokens: 1,
            priority: r.priority,
        });
        return Ok(1);
    }

    // KV transfer to the least-loaded decode group: every prefill core
    // streams its KV shard to a decode core (PP-prioritized placement keeps
    // these paths short and off the pipeline's own columns).
    let gi = groups
        .iter()
        .enumerate()
        .min_by_key(|(_, g)| g.load())
        .map(|(i, _)| i)
        .ok_or_else(|| anyhow::anyhow!("no decode groups"))?;
    let total_kv = r.input_len as u64 * model.kv_bytes_per_token(); // whole model
    let src_stages: Vec<(Vec<Coord>, usize)> = pipeline
        .iter()
        .map(|s| (s.group.coords.clone(), s.exec.layers))
        .collect();
    let dst_coords = groups[gi].worker.group.coords.clone();
    let ready_at = pipe::stream_kv_shards(chip, &src_stages, &dst_coords, total_kv, finish);
    for s in pipeline.iter_mut() {
        s.release(r.id);
    }
    groups[gi].pending.push_back(DecodeReq {
        req: r,
        first_token,
        generated: 1,
        ready_at,
    });
    Ok(0)
}

/// One continuous-batching decode iteration on one group.
fn decode_tick(
    chip: &mut ChipSim,
    model: &ModelConfig,
    cfg: &DisaggConfig,
    group: &mut DecodeGroup,
    t: Cycle,
    metrics: &mut Metrics,
    freq: f64,
) -> usize {
    group.worker.advance_to(chip, t);
    let now = group.worker.now(chip);

    // Admit transferred requests (their prefill KV is appended on arrival).
    while let Some(front) = group.pending.front() {
        if front.ready_at > now
            || group.active.len() >= cfg.max_decode_batch
            || !group.worker.can_admit()
        {
            break;
        }
        let r = group.pending.pop_front().unwrap();
        group.worker.admit(r.req.id);
        group.worker.kv.append(r.req.id, r.req.input_len as u64);
        group.active.push(r);
    }

    // Schedule ready decodes; with speculative decoding each becomes one
    // verify item of `d + 1` query tokens (drafts capped so even an
    // accept-all round commits exactly `output_len` tokens).
    let mut items = Vec::new();
    let mut scheduled: Vec<(u64, u64)> = Vec::new(); // (request id, drafts)
    for a in group
        .active
        .iter()
        .filter(|a| a.generated < a.req.output_len as u64 && a.ready_at <= now)
    {
        let d = match cfg.spec {
            Some(sc) => sc
                .gamma
                .min((a.req.output_len as u64 - a.generated).saturating_sub(1)),
            None => 0,
        };
        items.push(BatchItem {
            request: a.req.id,
            q_tokens: 1 + d,
            kv_tokens: a.req.input_len as u64 + a.generated,
            phase: crate::model::Phase::Decode,
        });
        scheduled.push((a.req.id, d));
    }
    if items.is_empty() {
        return 0;
    }

    // Draft pass of a speculative round (see the fused pipe's tick): the
    // deepest request's draft count, each step priced at `draft_cost_frac`
    // of the group's layer weight stream.
    let gamma_used = scheduled.iter().map(|&(_, d)| d).max().unwrap_or(0);
    if gamma_used > 0 {
        let frac = cfg.spec.map_or(0.0, |sc| sc.draft_cost_frac);
        let bytes = (group.worker.plan.weight_hbm_bytes as f64 * frac) as u64 * gamma_used;
        if bytes > 0 {
            for &c in &group.worker.group.coords {
                chip.core_mut(c).hbm_access(bytes, OpClass::HbmWeight);
            }
        }
    }
    let batch = IterBatch::new(items);
    if gamma_used > 0 {
        let threshold = group.worker.exec.small_m.map_or(0, |(_, t)| t);
        metrics.spec.observe_verify_m(batch.total_q_tokens(), threshold);
    }
    metrics.spec.decode_weight_streams += 1;
    let finish = group.worker.run(chip, model, &batch);

    // Commit: a plain step commits one token; a verify item commits the
    // leading accepted drafts plus the corrected/bonus token and truncates
    // the rejected tail off the group's paged KV, charged on the spill
    // channel (see `pipe::spec_accepted` for the sampler's determinism).
    let mut completions = 0;
    for (id, d) in scheduled {
        let ai = group
            .active
            .iter()
            .position(|a| a.req.id == id)
            .expect("scheduled request is active");
        if d == 0 {
            group.active[ai].generated += 1;
            group.active[ai].ready_at = finish;
            metrics.spec.decode_tokens_committed += 1;
            continue;
        }
        let sc = cfg.spec.expect("drafted tokens without a spec config");
        let k = pipe::spec_accepted(id, group.active[ai].generated, d, sc.acceptance);
        let rejected = d - k;
        let mut landed = finish;
        if rejected > 0 {
            group.worker.kv.truncate(id, rejected);
            landed = landed.max(pipe::charge_kv_swap(chip, &group.worker, model, rejected));
            metrics.spec.rejected_tokens += rejected;
        }
        metrics.spec.drafted_tokens += d;
        metrics.spec.accepted_tokens += k;
        metrics.spec.decode_tokens_committed += k + 1;
        group.active[ai].generated += k + 1;
        group.active[ai].ready_at = landed;
    }
    let mut i = 0;
    while i < group.active.len() {
        if group.active[i].generated >= group.active[i].req.output_len as u64 {
            let a = group.active.swap_remove(i);
            group.worker.release(a.req.id);
            metrics.record(RequestRecord {
                id: a.req.id,
                arrival: secs_to_cycles(a.req.arrival_s, freq),
                first_token: a.first_token,
                finish,
                input_tokens: a.req.input_len as u64,
                output_tokens: a.req.output_len as u64,
                priority: a.req.priority,
            });
            completions += 1;
        } else {
            i += 1;
        }
    }
    completions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipConfig, WorkloadConfig};
    use crate::serving::scheduler::simulate;

    #[test]
    fn decode_admission_respects_batch_cap_without_starvation() {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::fixed_ratio(128, 12, 9);
        let cfg = DisaggConfig {
            max_decode_batch: 2,
            ..DisaggConfig::p42_d21()
        };
        let mut sched = DisaggScheduler::new(cfg);
        let m = simulate(&mut chip, &model, &w, &mut sched).unwrap();
        assert_eq!(m.n_requests(), 9);
        let out: u64 = m.records().iter().map(|r| r.output_tokens).sum();
        assert_eq!(out, 9 * 12);
    }
}
