//! Core placement strategies (Fig. 4): mapping logical TP ranks onto
//! physical mesh coordinates within a rectangular region, and slicing the
//! chip into pipeline-stage regions.
//!
//! Placement determines the physical hop count between *logically adjacent*
//! ring ranks, which directly scales ring-collective cost:
//!
//! - **linear-seq** (T10): ranks in row-major order; neighbours are 1 hop
//!   apart but the ring wrap-around crosses the whole region.
//! - **linear-interleave** (WaferLLM): even ranks forward, odd ranks
//!   backward; every logical neighbour (wrap included) is ≤ 2 hops.
//! - **ring**: a Hamiltonian cycle over the region (boustrophedon); every
//!   logical neighbour is exactly 1 hop — but the region's internal links
//!   are monopolised, lowering inter-pipeline bandwidth.
//! - **mesh2d**: ranks arranged as an `R×C` grid for 2-D partition; each
//!   row and column forms its own small ring.

use crate::sim::noc::Coord;

/// A rectangular sub-block of the chip mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub row0: usize,
    pub col0: usize,
    pub rows: usize,
    pub cols: usize,
}

impl Region {
    pub fn new(row0: usize, col0: usize, rows: usize, cols: usize) -> Self {
        assert!(rows > 0 && cols > 0);
        Region {
            row0,
            col0,
            rows,
            cols,
        }
    }

    /// Full-chip region.
    pub fn whole(rows: usize, cols: usize) -> Self {
        Self::new(0, 0, rows, cols)
    }

    pub fn n_cores(&self) -> usize {
        self.rows * self.cols
    }

    /// Row-major coordinates.
    pub fn coords(&self) -> Vec<Coord> {
        let mut v = Vec::with_capacity(self.n_cores());
        for r in 0..self.rows {
            for c in 0..self.cols {
                v.push(Coord::new(self.row0 + r, self.col0 + c));
            }
        }
        v
    }

    /// Split into `n` horizontal bands (pipeline stages). Bands get
    /// `rows/n` rows each, the remainder distributed to the first bands.
    pub fn split_rows(&self, n: usize) -> Vec<Region> {
        assert!(n > 0 && n <= self.rows, "cannot split {} rows into {n}", self.rows);
        let base = self.rows / n;
        let extra = self.rows % n;
        let mut out = Vec::with_capacity(n);
        let mut r = self.row0;
        for i in 0..n {
            let h = base + usize::from(i < extra);
            out.push(Region::new(r, self.col0, h, self.cols));
            r += h;
        }
        out
    }
}

/// Core placement strategy for a TP group (Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    LinearSeq,
    LinearInterleave,
    Ring,
    Mesh2D,
}

impl Placement {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "linear" | "linear_seq" | "linear-seq" | "seq" => Placement::LinearSeq,
            "interleave" | "linear_interleave" | "linear-interleave" => Placement::LinearInterleave,
            "ring" => Placement::Ring,
            "mesh" | "mesh2d" | "2d" => Placement::Mesh2D,
            other => anyhow::bail!("unknown placement {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::LinearSeq => "linear-seq",
            Placement::LinearInterleave => "linear-interleave",
            Placement::Ring => "ring",
            Placement::Mesh2D => "mesh2d",
        }
    }

    pub fn all() -> [Placement; 4] {
        [
            Placement::LinearSeq,
            Placement::LinearInterleave,
            Placement::Ring,
            Placement::Mesh2D,
        ]
    }
}

/// A placed TP group: physical coordinates in **logical ring order**
/// (rank i's ring successor is rank i+1 mod n).
#[derive(Debug, Clone, PartialEq)]
pub struct TpGroup {
    pub coords: Vec<Coord>,
    pub placement: Placement,
}

impl TpGroup {
    /// Place a TP group of the full region size.
    pub fn place(region: Region, placement: Placement) -> TpGroup {
        let coords = match placement {
            Placement::LinearSeq => region.coords(),
            Placement::LinearInterleave => interleave(&region.coords()),
            Placement::Ring => hamiltonian_ring(region),
            // For Mesh2D the ring order is the boustrophedon cycle too;
            // 2-D partition addressing uses `mesh_grid` instead.
            Placement::Mesh2D => hamiltonian_ring(region),
        };
        TpGroup { coords, placement }
    }

    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    /// Physical hops between each logical ring neighbour pair.
    pub fn ring_hop_counts(&self) -> Vec<usize> {
        let n = self.coords.len();
        (0..n)
            .map(|i| self.coords[i].hops_to(self.coords[(i + 1) % n]))
            .collect()
    }

    /// Max hop between logical ring neighbours (`alpha` in Table 2).
    pub fn max_ring_hop(&self) -> usize {
        self.ring_hop_counts().into_iter().max().unwrap_or(0)
    }

    /// Arrange the group as an `rows × cols` logical grid for 2-D
    /// partition: `grid[i][j]` is the core at logical row i, column j.
    /// Logical rows map to physical mesh rows of the region when shapes
    /// allow, so row-rings and column-rings are physically compact.
    pub fn mesh_grid(&self, rows: usize, cols: usize) -> Vec<Vec<Coord>> {
        assert_eq!(rows * cols, self.coords.len(), "grid shape mismatch");
        // Sort coords into row-major physical order, then chunk.
        let mut sorted = self.coords.clone();
        sorted.sort();
        let mut grid = Vec::with_capacity(rows);
        for i in 0..rows {
            let mut row: Vec<Coord> = sorted[i * cols..(i + 1) * cols].to_vec();
            // Interleave within the row so each row-ring has ≤2-hop
            // neighbours even when the physical row is a line.
            row = interleave(&row);
            grid.push(row);
        }
        grid
    }
}

/// WaferLLM interleaved order: even positions forward then odd positions
/// backward, bounding every logical-neighbour distance (wrap included) to
/// ≤ 2 physical hops on a line.
fn interleave(line: &[Coord]) -> Vec<Coord> {
    let mut out = Vec::with_capacity(line.len());
    let mut i = 0;
    while i < line.len() {
        out.push(line[i]);
        i += 2;
    }
    let mut j = if line.len() % 2 == 0 {
        line.len().saturating_sub(1)
    } else {
        line.len().saturating_sub(2)
    };
    loop {
        if j % 2 == 1 {
            out.push(line[j]);
        }
        if j <= 1 {
            break;
        }
        j -= 2;
    }
    out
}

/// Hamiltonian cycle over a rectangular region (every consecutive pair — and
/// the wrap — 1 hop apart). Exists when either side is even; degenerate
/// regions (single row/col) and odd×odd regions fall back to a
/// boustrophedon path whose wrap is the only long hop.
fn hamiltonian_ring(region: Region) -> Vec<Coord> {
    let (h, w) = (region.rows, region.cols);
    let at = |r: usize, c: usize| Coord::new(region.row0 + r, region.col0 + c);
    if h == 1 || w == 1 {
        return region.coords(); // line: no cycle possible
    }
    if w % 2 == 0 || h % 2 == 0 {
        // Reserve column 0: go down it last. Snake through columns 1..w
        // over all rows, ending back at row 0, then walk column 0 upward.
        // Construction: row 0 from (0,0) to (0,w-1); snake rows 1..h over
        // columns w-1..1; finish down column 0? Simpler known-good:
        // - top row left→right
        // - snake the remaining rows right→left / left→right over
        //   columns 1..w
        // - column 0 from bottom back to top
        let mut out = Vec::with_capacity(h * w);
        for c in 0..w {
            out.push(at(0, c));
        }
        // rows 1..h over columns w-1..=1, boustrophedon
        for r in 1..h {
            if r % 2 == 1 {
                for c in (1..w).rev() {
                    out.push(at(r, c));
                }
            } else {
                for c in 1..w {
                    out.push(at(r, c));
                }
            }
        }
        // We are now at row h-1, column (1 if (h-1)%2==1 else w-1).
        // For the cycle to close via column 0 we must be at column 1;
        // that requires h even (last snaked row index h-1 odd). When h is
        // odd but w is even, transpose the construction.
        if h % 2 == 0 {
            for r in (1..h).rev() {
                out.push(at(r, 0));
            }
            return out;
        }
        // h odd, w even: transpose (walk row 0 reserved along the other axis).
        let mut out = Vec::with_capacity(h * w);
        for r in 0..h {
            out.push(at(r, 0));
        }
        for c in 1..w {
            if c % 2 == 1 {
                for r in (1..h).rev() {
                    out.push(at(r, c));
                }
            } else {
                for r in 1..h {
                    out.push(at(r, c));
                }
            }
        }
        for c in (1..w).rev() {
            out.push(at(0, c));
        }
        return out;
    }
    // Odd × odd: boustrophedon path (wrap is the long hop).
    let mut out = Vec::with_capacity(h * w);
    for r in 0..h {
        if r % 2 == 0 {
            for c in 0..w {
                out.push(at(r, c));
            }
        } else {
            for c in (0..w).rev() {
                out.push(at(r, c));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use std::collections::HashSet;

    fn assert_is_permutation(group: &[Coord], region: Region) {
        let set: HashSet<Coord> = group.iter().cloned().collect();
        let expect: HashSet<Coord> = region.coords().into_iter().collect();
        assert_eq!(set, expect, "placement must be a permutation of the region");
        assert_eq!(group.len(), region.n_cores());
    }

    #[test]
    fn region_split_rows_covers_exactly() {
        let r = Region::whole(8, 8);
        let parts = r.split_rows(3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(|p| p.rows).sum::<usize>(), 8);
        assert_eq!(parts[0].rows, 3); // 8 = 3+3+2
        assert_eq!(parts[2].row0, 6);
    }

    #[test]
    fn linear_seq_row_major() {
        let g = TpGroup::place(Region::new(0, 0, 1, 4), Placement::LinearSeq);
        assert_eq!(
            g.coords,
            vec![
                Coord::new(0, 0),
                Coord::new(0, 1),
                Coord::new(0, 2),
                Coord::new(0, 3)
            ]
        );
        // Wrap-around is the long hop: 3.
        assert_eq!(g.max_ring_hop(), 3);
    }

    #[test]
    fn interleave_bounds_hops_to_two() {
        for n in [4usize, 5, 6, 7, 8, 16] {
            let g = TpGroup::place(Region::new(0, 0, 1, n), Placement::LinearInterleave);
            assert_is_permutation(&g.coords, Region::new(0, 0, 1, n));
            assert!(
                g.max_ring_hop() <= 2,
                "n={n}: hops {:?}",
                g.ring_hop_counts()
            );
        }
    }

    #[test]
    fn ring_is_all_one_hop_on_even_regions() {
        for (h, w) in [(2usize, 2usize), (2, 4), (4, 4), (2, 8), (4, 8), (3, 4), (4, 3)] {
            let region = Region::new(0, 0, h, w);
            let g = TpGroup::place(region, Placement::Ring);
            assert_is_permutation(&g.coords, region);
            assert_eq!(
                g.max_ring_hop(),
                1,
                "({h},{w}) hops {:?} coords {:?}",
                g.ring_hop_counts(),
                g.coords
            );
        }
    }

    #[test]
    fn ring_odd_odd_falls_back_to_path() {
        let region = Region::new(0, 0, 3, 3);
        let g = TpGroup::place(region, Placement::Ring);
        assert_is_permutation(&g.coords, region);
        // Interior hops are all 1; only the wrap is long.
        let hops = g.ring_hop_counts();
        assert!(hops[..hops.len() - 1].iter().all(|&h| h == 1));
    }

    #[test]
    fn ring_single_row_is_path() {
        let g = TpGroup::place(Region::new(2, 0, 1, 6), Placement::Ring);
        assert_eq!(g.max_ring_hop(), 5);
    }

    #[test]
    fn mesh_grid_shapes() {
        let g = TpGroup::place(Region::new(0, 0, 4, 4), Placement::Mesh2D);
        let grid = g.mesh_grid(4, 4);
        assert_eq!(grid.len(), 4);
        let mut all: Vec<Coord> = grid.iter().flatten().cloned().collect();
        all.sort();
        assert_eq!(all, Region::new(0, 0, 4, 4).coords());
        // Each logical row lives on one physical row: row rings compact.
        for row in &grid {
            let r0 = row[0].row;
            assert!(row.iter().all(|c| c.row == r0));
        }
    }

    #[test]
    fn prop_placements_are_permutations() {
        check("placements are permutations", 128, |rng| {
            let h = rng.range(1, 6);
            let w = rng.range(1, 6);
            let region = Region::new(rng.range(0, 4), rng.range(0, 4), h, w);
            for p in Placement::all() {
                let g = TpGroup::place(region, p);
                let set: HashSet<Coord> = g.coords.iter().cloned().collect();
                assert_eq!(set.len(), region.n_cores(), "{p:?} {region:?}");
                for c in &g.coords {
                    assert!(c.row >= region.row0 && c.row < region.row0 + h);
                    assert!(c.col >= region.col0 && c.col < region.col0 + w);
                }
            }
        });
    }

    #[test]
    fn prop_ring_beats_or_ties_linear_seq_wrap() {
        check("ring wrap <= linear wrap", 64, |rng| {
            let h = rng.range(1, 6);
            let w = rng.range(1, 6);
            let region = Region::new(0, 0, h, w);
            let ring = TpGroup::place(region, Placement::Ring);
            let lin = TpGroup::place(region, Placement::LinearSeq);
            assert!(ring.max_ring_hop() <= lin.max_ring_hop());
        });
    }
}
