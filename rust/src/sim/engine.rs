//! Discrete-event simulation primitives.
//!
//! Two building blocks shared by every sub-system:
//!
//! - [`EventQueue`]: a deterministic min-heap of timestamped events (FIFO
//!   among equal timestamps), used by the TLM memory model and the serving
//!   engine's arrival/retirement loop.
//! - [`Timeline`]: a busy-interval tracker for a serially-reusable resource
//!   (a NoC link, an HBM data bus, a bank, a systolic array). Reserving a
//!   duration returns the actual start cycle — the event-driven equivalent
//!   of waiting on the resource.
//!
//! Both are strictly deterministic (FIFO tie-breaks, no wall-clock, no
//! map-iteration order). That determinism is what lets the cluster driver
//! ([`crate::serving::cluster`]) step independent chips on worker threads
//! under a conservative window and still reproduce the sequential
//! schedule byte-for-byte: within a window each chip's events replay in
//! exactly the order this queue would have produced them.

use crate::util::units::Cycle;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A timestamped event carrying a payload.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry<T> {
    time: Cycle,
    seq: u64,
    payload: T,
}

impl<T: Eq> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via Reverse at the call site; tie-break on insertion
        // order for determinism.
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}
impl<T: Eq> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue: events at equal times pop in push order.
#[derive(Debug, Default)]
pub struct EventQueue<T: Eq> {
    heap: BinaryHeap<Reverse<Entry<T>>>,
    seq: u64,
}

impl<T: Eq> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, time: Cycle, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq, payload }));
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(Cycle, T)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.payload))
    }

    /// Earliest pending timestamp.
    pub fn peek_time(&self) -> Option<Cycle> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Drop all pending events and restart the FIFO tie-break counter —
    /// the `reset` every other sim primitive already has. Keeps the heap's
    /// allocation, so experiment loops can reuse one queue across sweep
    /// points instead of reallocating.
    pub fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }
}

/// Busy-interval tracker for a serially-reusable resource.
///
/// `reserve(earliest, duration)` answers: *if I ask for the resource no
/// earlier than `earliest`, when do I actually get it, and until when is it
/// then busy?* The resource is modeled as available again at `free_at`;
/// requests are served in call order (which the callers keep deterministic).
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    free_at: Cycle,
    /// Total cycles the resource was actually occupied (for utilization).
    busy: Cycle,
    /// Total cycles requesters waited behind earlier reservations.
    contended: Cycle,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `duration` cycles no earlier than `earliest`; returns the
    /// granted start cycle.
    pub fn reserve(&mut self, earliest: Cycle, duration: Cycle) -> Cycle {
        let start = earliest.max(self.free_at);
        self.contended += start - earliest;
        self.free_at = start + duration;
        self.busy += duration;
        start
    }

    /// Reserve `duration` starting *exactly* at `start` (caller must have
    /// probed availability first — used for multi-resource atomic locking
    /// where all resources must start together, e.g. NoC channel locking).
    pub fn reserve_at(&mut self, start: Cycle, duration: Cycle) {
        debug_assert!(
            start >= self.free_at,
            "reserve_at({start}) before free_at({})",
            self.free_at
        );
        self.free_at = start + duration;
        self.busy += duration;
    }

    /// When the resource next becomes free.
    pub fn free_at(&self) -> Cycle {
        self.free_at
    }

    /// Would-be start for a reservation, without committing.
    pub fn probe(&self, earliest: Cycle) -> Cycle {
        earliest.max(self.free_at)
    }

    /// Total busy cycles granted so far.
    pub fn busy_cycles(&self) -> Cycle {
        self.busy
    }

    /// Total cycles spent waiting behind prior reservations.
    pub fn contended_cycles(&self) -> Cycle {
        self.contended
    }

    /// Reset to idle (reused between simulation runs).
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// A sliding window limiting the number of in-flight transactions
/// (outstanding-request modeling for HBM §3.1). `acquire` blocks (in
/// simulated time) until a slot frees.
///
/// (§Perf opt 2 note: a flat-`Vec` linear-scan variant was tried and
/// measured ~40% *slower* on the per-burst hot path — `complete` pays an
/// O(capacity) eviction scan every call; the heap's O(log n) wins. Kept
/// as a heap; see EXPERIMENTS.md §Perf iteration log.)
#[derive(Debug, Clone)]
pub struct OutstandingWindow {
    completions: BinaryHeap<Reverse<Cycle>>,
    capacity: usize,
}

impl OutstandingWindow {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        OutstandingWindow {
            completions: BinaryHeap::new(),
            capacity,
        }
    }

    /// Ask for a slot at `earliest`; returns when the slot is granted
    /// (may be later if the window is full). The caller must then
    /// [`OutstandingWindow::complete`] the transaction.
    pub fn acquire(&mut self, earliest: Cycle) -> Cycle {
        if self.completions.len() < self.capacity {
            return earliest;
        }
        // Window full: wait for the earliest completion.
        let Reverse(first_done) = self.completions.pop().expect("non-empty");
        earliest.max(first_done)
    }

    /// Record a transaction completing at `time`.
    pub fn complete(&mut self, time: Cycle) {
        self.completions.push(Reverse(time));
        // Keep only what can still block future acquires.
        while self.completions.len() > self.capacity {
            self.completions.pop();
        }
    }

    pub fn reset(&mut self) {
        self.completions.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_queue_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(10, "b");
        q.push(5, "a");
        q.push(10, "c");
        assert_eq!(q.pop(), Some((5, "a")));
        assert_eq!(q.pop(), Some((10, "b")));
        assert_eq!(q.pop(), Some((10, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn event_queue_clear_resets_order_state() {
        let mut q = EventQueue::new();
        q.push(1, "stale");
        q.push(2, "stale2");
        q.clear();
        assert!(q.is_empty());
        // FIFO tie-break restarts: same-time pushes pop in push order again.
        q.push(10, "x");
        q.push(10, "y");
        assert_eq!(q.pop(), Some((10, "x")));
        assert_eq!(q.pop(), Some((10, "y")));
    }

    #[test]
    fn timeline_serializes_overlapping_requests() {
        let mut t = Timeline::new();
        assert_eq!(t.reserve(0, 10), 0);
        assert_eq!(t.reserve(5, 10), 10); // waits for first to finish
        assert_eq!(t.reserve(100, 10), 100); // idle gap
        assert_eq!(t.busy_cycles(), 30);
        assert_eq!(t.contended_cycles(), 5);
    }

    #[test]
    fn timeline_probe_does_not_commit() {
        let mut t = Timeline::new();
        t.reserve(0, 10);
        assert_eq!(t.probe(3), 10);
        assert_eq!(t.free_at(), 10);
    }

    #[test]
    fn outstanding_window_blocks_when_full() {
        let mut w = OutstandingWindow::new(2);
        assert_eq!(w.acquire(0), 0);
        w.complete(100);
        assert_eq!(w.acquire(0), 0);
        w.complete(50);
        // Window holds completions at 100 and 50; next acquire waits for 50.
        assert_eq!(w.acquire(10), 50);
        w.complete(120);
        // Now completions 100 and 120 are in flight; next waits for 100.
        assert_eq!(w.acquire(0), 100);
    }

    #[test]
    fn outstanding_window_unblocked_when_under_capacity() {
        let mut w = OutstandingWindow::new(4);
        for i in 0..4 {
            assert_eq!(w.acquire(i), i);
        }
    }
}
