//! The memory sub-system: transaction-level HBM channels and SRAM
//! scratchpad bandwidth modeling (§3.1 "memory system").

mod hbm;
mod sram;

pub use hbm::{HbmChannel, HbmStats, TlmPhases};
pub use sram::SramPort;
