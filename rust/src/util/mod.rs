//! In-tree substrates for functionality that would normally come from
//! external crates (`rand`, `clap`, `toml`, `serde_json`, `proptest`,
//! `criterion`).
//!
//! The build environment is fully offline and the vendored crate set only
//! contains the `xla` dependency closure, so these are implemented from
//! scratch. Each module is small, tested, and dependency-free.

pub mod bench;
pub mod cli;
pub mod logging;
pub mod minijson;
pub mod minitoml;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
