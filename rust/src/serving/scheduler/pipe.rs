//! Shared fused-pipeline machinery: the chunked-prefill budget scheduler
//! over one pipeline of [`StageWorker`] stages.
//!
//! [`FusionScheduler`](super::fusion::FusionScheduler) runs every pipe in
//! fused mode; [`HybridScheduler`](super::hybrid::HybridScheduler) reuses
//! the exact same tick for its fused pipes and flips individual pipes into
//! *prefill-only* mode, where freshly prefilled requests are extracted as
//! [`Handoff`]s (their decode phase runs on a fused pipe after a NoC KV
//! transfer) instead of decoding locally.
//!
//! With `FusionConfig::cross_pipe` the pipe set also shares prefix caches
//! chip-wide: [`route_request`] scores pipes by probed (tier-weighted)
//! prefix overlap against load instead of round-robin, and
//! [`stream_prefix_over_noc`] streams a matched prefix from an overloaded
//! holder pipe to a lighter one over the on-chip NoC — charged and
//! delayed-landing, exactly like the cluster layer's inter-chip migration,
//! so a sibling-pipe hit costs a KV transfer rather than a recompute.

use crate::config::ModelConfig;
use crate::memmgr::prefix::{BlockKey, TierMatch};
use crate::model::{BatchItem, IterBatch};
use crate::serving::layout::PipelineLayout;
use crate::serving::metrics::{CacheStats, Metrics, RequestRecord};
use crate::serving::pd_fusion::FusionConfig;
use crate::serving::request::{Priority, Request};
use crate::serving::worker::StageWorker;
use crate::sim::chip::ChipSim;
use crate::sim::noc::Coord;
use crate::sim::tracer::OpClass;
use crate::util::units::{secs_to_cycles, Cycle};
use std::collections::VecDeque;

/// How many times one request may be preempted before it becomes
/// non-preemptible — bounds worst-case starvation so a steady high-class
/// stream cannot livelock a parked low-class decode.
pub(crate) const MAX_PREEMPTIONS: u8 = 3;

/// Fixed seed of the speculative-decode acceptance sampler. Acceptance is
/// a property of the modeled draft model, not of the workload, so it is
/// not configurable — one seed keeps every policy's draws comparable.
pub(crate) const SPEC_SEED: u64 = 0x5bec_dec0_0000_0001;

/// Leading accepted drafts of one speculative round: `d` i.i.d. Bernoulli
/// draws hashed counter-mode from (request id, absolute output position),
/// so acceptance is bit-for-bit deterministic and independent of batch
/// composition, tick timing and scheduler policy. Verification commits
/// the corrected token at the first rejection, discarding the rest of the
/// round — so the return value `k` means `k + 1` tokens commit and
/// `d - k` drafts roll back.
pub(crate) fn spec_accepted(id: u64, pos0: u64, d: u64, acceptance: f64) -> u64 {
    let base = crate::serving::request::splitmix64(SPEC_SEED ^ crate::serving::request::splitmix64(id));
    for j in 0..d {
        let bits = crate::serving::request::splitmix64(base ^ (pos0 + j));
        let u = (bits >> 11) as f64 / (1u64 << 53) as f64; // uniform [0, 1)
        if u >= acceptance {
            return j;
        }
    }
    d
}

/// In-flight request state on a pipe.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Active {
    pub req: Request,
    /// Prompt tokens already prefilled.
    pub prefilled: u64,
    /// Output tokens generated (first comes from the final prefill chunk).
    pub generated: u64,
    pub first_token: Option<Cycle>,
    /// Earliest cycle the next decode step may start (autoregressive
    /// dependency — this is what makes deep pipelines hurt decode).
    pub ready_at: Cycle,
    /// Times this request has been preempted (capped at
    /// [`MAX_PREEMPTIONS`]); survives park/resume cycles.
    pub preemptions: u8,
}

/// A preempted decode-phase request parked off the pipe: its KV was
/// spilled to the HBM channel and its slot freed for a higher class.
/// Resumption re-appends the KV (reload charged on the same channel) and
/// continues decoding from `generated` — prefill is never recomputed and
/// `first_token` is preserved, so the retired record's token counts and
/// TTFT are exactly what an unpreempted run would have produced.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Parked {
    pub req: Request,
    pub generated: u64,
    pub first_token: Option<Cycle>,
    pub parked_at: Cycle,
    pub preemptions: u8,
}

impl Active {
    pub fn is_prefilling(&self) -> bool {
        self.prefilled < self.req.input_len as u64
    }

    pub fn is_done(&self) -> bool {
        !self.is_prefilling() && self.generated >= self.req.output_len as u64
    }
}

/// A decode-phase request transferred to a fused pipe (hybrid handoff):
/// its prefill ran elsewhere and its KV arrives at `ready_at`.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PendingDecode {
    pub req: Request,
    pub first_token: Cycle,
    pub ready_at: Cycle,
}

/// A freshly prefilled request leaving a prefill-only pipe.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Handoff {
    pub req: Request,
    pub first_token: Cycle,
    pub ready_at: Cycle,
}

/// One pipeline of TP stages with its request queue and in-flight set.
pub(crate) struct Pipe {
    pub stages: Vec<StageWorker>,
    pub queue: VecDeque<Request>,
    pub active: Vec<Active>,
    /// Transferred decode-phase requests not yet admitted to the KV cache
    /// (always empty under pure fusion).
    pub pending: VecDeque<PendingDecode>,
    /// Preempted decode-phase requests awaiting resumption (always empty
    /// under uniform priorities — preemption only fires across classes).
    pub parked: Vec<Parked>,
}

/// Carve the chip into fused pipelines per the fusion layout knobs.
pub(crate) fn build_pipes(
    chip: &ChipSim,
    model: &ModelConfig,
    cfg: &FusionConfig,
    max_tokens: usize,
) -> anyhow::Result<Vec<Pipe>> {
    let layout = PipelineLayout::build(
        chip.cfg.rows,
        chip.cfg.cols,
        cfg.tp,
        cfg.stages,
        cfg.placement,
    )?;
    let lps = layout.layers_per_stage(model.layers);
    let core = chip.cfg.core;
    let pipes: Vec<Pipe> = layout
        .pipelines
        .iter()
        .map(|groups| Pipe {
            stages: groups
                .iter()
                .enumerate()
                .map(|(s, g)| {
                    let exec = crate::model::exec::ExecConfig::new(
                        cfg.strategy,
                        lps[s].max(1),
                        s + 1 == groups.len(),
                    )
                    .with_small_m(cfg.small_m_strategy, cfg.m_threshold);
                    StageWorker::new(
                        &core,
                        model,
                        g.clone(),
                        exec,
                        cfg.budget.max(cfg.chunk),
                        cfg.kv_share,
                        max_tokens,
                    )
                    .with_prefix_cache(cfg.prefix_cache)
                    .with_hbm_tier(cfg.prefix_cache && cfg.hbm_tier, cfg.hbm_tier_frac)
                    .with_memo(cfg.memo)
                    .with_sim_level(cfg.sim_level)
                })
                .collect(),
            queue: VecDeque::new(),
            active: Vec::new(),
            pending: VecDeque::new(),
            parked: Vec::new(),
        })
        .collect();
    anyhow::ensure!(!pipes.is_empty(), "no pipelines fit the chip");
    Ok(pipes)
}

/// Prefix-cache admission over a slice of pipeline stages at cycle `now`:
/// match the longest cached-and-ready prefix — committing the *minimum*
/// across stages so every stage skips the same chunks (SRAM pressure can
/// differ per stage) — and record the request-level cache metrics. At
/// least one prompt token always prefills (it produces the first output
/// token). HBM-demoted matches are re-promoted during admission and their
/// HBM→SRAM streams charged on the stages; a promotion that fails under
/// extreme SRAM pressure shortens the committed match (the running
/// minimum of the per-stage actuals), so no stage skips chunks whose KV
/// it never stored. The min-rule is safe in the *skip* direction only: a
/// stage that already committed a longer match before a later stage's
/// promotion failed keeps its extra shared blocks and re-appends the
/// re-prefilled tokens, so its residency (and attention pricing) runs
/// pessimistically high by up to the shortened delta for that request's
/// lifetime — accepted, since the failure needs SRAM so exhausted that
/// even demotion found no victim. Returns the matched token count.
/// Shared by the fusion/hybrid tick and the disagg prefill pipeline so
/// cache accounting cannot diverge between policies.
pub(crate) fn admit_with_prefix(
    chip: &mut ChipSim,
    stages: &mut [StageWorker],
    r: &Request,
    model: &ModelConfig,
    metrics: &mut Metrics,
    now: Cycle,
) -> u64 {
    let keys = r.block_keys(crate::memmgr::KV_BLOCK_TOKENS);
    let limit = (r.input_len as u64).saturating_sub(1);
    let mut matched = stages
        .iter()
        .map(|s| s.peek_prefix(&keys, limit, now))
        .min()
        .unwrap_or(0);
    for s in stages.iter_mut() {
        matched = matched.min(s.admit_prefixed(r.id, &keys, matched, now));
        s.charge_tier_traffic(chip);
    }
    // Hit-rate denominator scoping: only admissions that actually consult
    // the index (non-empty shareable-prefix keys) count as lookups, so
    // unshareable prompts — and, in mixed clusters, whole cache-disabled
    // chips — cannot dilute the rate.
    if !keys.is_empty() {
        metrics.cache.prefix_lookups += 1;
        if matched > 0 {
            metrics.cache.prefix_hits += 1;
            metrics.cache.prefill_tokens_skipped += matched;
            metrics.cache.kv_bytes_deduped += matched * model.kv_bytes_per_token();
        }
    }
    metrics.cache.prefill_tokens_total += r.input_len as u64;
    matched
}

/// Pipe-set folds shared by the fusion and hybrid schedulers' cluster
/// probes — one implementation so the two policies cannot drift.
pub(crate) fn earliest_action(pipes: &[Pipe], chip: &ChipSim) -> Option<Cycle> {
    let freq = chip.cfg.freq_mhz;
    pipes.iter().filter_map(|p| p.next_action(chip, freq)).min()
}

pub(crate) fn total_pending(pipes: &[Pipe]) -> usize {
    pipes.iter().map(|p| p.pending_work()).sum()
}

pub(crate) fn mean_kv_utilization(pipes: &[Pipe]) -> f64 {
    if pipes.is_empty() {
        return 0.0;
    }
    pipes.iter().map(|p| p.kv_utilization()).sum::<f64>() / pipes.len() as f64
}

/// Best pipe wins: the router cares whether *some* admission could share.
/// Under static round-robin admission this is an optimistic upper bound;
/// with `cross_pipe` on, [`route_request`] actually steers the admission
/// to (or imports from) the best pipe, making the probe accurate.
pub(crate) fn best_prefix_match(pipes: &[Pipe], keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
    pipes
        .iter()
        .map(|p| p.probe_prefix(keys, limit, at))
        .max()
        .unwrap_or(0)
}

/// Tier-split variant of [`best_prefix_match`]: the best pipe's match by
/// affinity score (fast-tier tokens weigh double), ties by total then by
/// pipe order — the cluster router's two-tier hit-quality probe.
pub(crate) fn best_prefix_match_tiered(
    pipes: &[Pipe],
    keys: &[BlockKey],
    limit: u64,
    at: Cycle,
) -> TierMatch {
    pipes
        .iter()
        .map(|p| p.probe_prefix_tiered(keys, limit, at))
        .max_by_key(|m| (m.score(), m.total()))
        .unwrap_or_default()
}

/// Where a cache-affinity-routed request goes, and whether its matched
/// prefix KV is imported from a sibling pipe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct PipeRoute {
    /// Destination pipe of the admission.
    pub pipe: usize,
    /// `Some(holder)`: stream the matched prefix from `holder`'s caches to
    /// `pipe` over the NoC before admission (charged, delayed landing).
    pub import_from: Option<usize>,
    /// The holder's total ready match in tokens (what an import moves).
    pub match_tokens: u64,
}

/// Cache-affinity pipe selection (`cross_pipe`): score each pipe by probed
/// tier-weighted prefix overlap against its load. The longest-scoring
/// holder wins (ties → lighter load, then lower index); with no match the
/// request goes to the least-loaded pipe. A holder whose pending work
/// exceeds the lightest pipe's by more than `affinity_gap` is considered
/// overloaded: the request is routed to the lightest pipe and the match is
/// imported over the NoC instead of queueing behind the backlog —
/// the same queue-versus-transfer tradeoff the cluster router makes
/// between chips.
pub(crate) fn route_request(
    pipes: &[Pipe],
    keys: &[BlockKey],
    limit: u64,
    at: Cycle,
    affinity_gap: usize,
) -> PipeRoute {
    let loads: Vec<usize> = pipes.iter().map(|p| p.pending_work()).collect();
    let lightest = (0..pipes.len())
        .min_by_key(|&i| (loads[i], i))
        .unwrap_or(0);
    if keys.is_empty() {
        return PipeRoute {
            pipe: lightest,
            import_from: None,
            match_tokens: 0,
        };
    }
    let hits: Vec<TierMatch> = pipes
        .iter()
        .map(|p| p.probe_prefix_tiered(keys, limit, at))
        .collect();
    let holder = (0..pipes.len())
        .filter(|&i| hits[i].total() > 0)
        .min_by_key(|&i| (std::cmp::Reverse(hits[i].score()), loads[i], i));
    match holder {
        None => PipeRoute {
            pipe: lightest,
            import_from: None,
            match_tokens: 0,
        },
        Some(h) => {
            let overloaded = loads[h] > loads[lightest].saturating_add(affinity_gap);
            if overloaded && h != lightest {
                PipeRoute {
                    pipe: lightest,
                    import_from: Some(h),
                    match_tokens: hits[h].total(),
                }
            } else {
                PipeRoute {
                    pipe: h,
                    import_from: None,
                    match_tokens: hits[h].total(),
                }
            }
        }
    }
}

/// Stream a matched prefix's KV from pipe `src`'s caches toward pipe
/// `dst` over the on-chip NoC — stage by stage, each stage moving its
/// layer-share from its lead core to the destination stage's lead core.
/// Returns the landing cycle (no earlier than `at`). The transfer is
/// charged on the mesh (link occupancy + contention), mirroring the
/// cluster layer's inter-chip migration one level down the hierarchy; the
/// caller seeds `dst`'s caches (see `Pipe::seed_prefix`) once it knows
/// the deferred admission instant.
#[allow(clippy::too_many_arguments)]
pub(crate) fn stream_prefix_over_noc(
    chip: &mut ChipSim,
    pipes: &[Pipe],
    src: usize,
    dst: usize,
    tokens: u64,
    kv_bytes_per_token: u64,
    at: Cycle,
) -> Cycle {
    let total = tokens * kv_bytes_per_token;
    let total_layers: usize = pipes[src].stages.iter().map(|s| s.exec.layers).sum();
    let n_stages = pipes[src].stages.len().min(pipes[dst].stages.len());
    let mut landing = at;
    for s in 0..n_stages {
        let bytes = total * pipes[src].stages[s].exec.layers as u64 / total_layers.max(1) as u64;
        let from = pipes[src].stages[s].group.coords[0];
        let to = pipes[dst].stages[s].group.coords[0];
        let t = chip.send(from, to, bytes, OpClass::KvTransfer);
        landing = landing.max(t.finish);
    }
    landing
}

/// Seed every pipe: static round-robin admission may land the migrated
/// request on any of them, and a seeded-but-unused copy is cheap
/// (evictable, index-owned) next to a recomputed prefill.
pub(crate) fn seed_all(pipes: &mut [Pipe], keys: &[BlockKey], ready_at: Cycle) {
    for p in pipes {
        p.seed_prefix(keys, ready_at);
    }
}

/// Fold worker-level sharing/memo counters (COW, evictions, memo hits)
/// into `out` — the request-level hit counters are recorded at admission.
pub(crate) fn collect_worker_stats<'a>(
    workers: impl Iterator<Item = &'a StageWorker>,
    out: &mut CacheStats,
) {
    for s in workers {
        let k = s.kv.stats();
        out.cow_copies += k.cow_copies;
        out.prefix_evictions += k.prefix_evictions;
        out.tier_demotions += k.tier_demotions;
        out.tier_promotions += k.tier_promotions;
        out.tier_dropped += k.tier_dropped;
        if let Some(m) = &s.memo {
            out.memo_hits += m.hits;
            out.memo_misses += m.misses;
        }
    }
}

/// Stream a request's KV shards over the NoC: each source stage holds
/// `layers / total layers` of the KV, split evenly across its cores, and
/// every source core sends its shard to a destination core round-robin.
/// Returns the cycle at which the last shard lands. Shared by the disagg
/// prefill→decode transfer and the hybrid prefill-pipe handoff, so the
/// KV-transfer accounting cannot diverge between the two policies.
pub(crate) fn stream_kv_shards(
    chip: &mut ChipSim,
    src_stages: &[(Vec<Coord>, usize)],
    dst_coords: &[Coord],
    total_kv: u64,
    start: Cycle,
) -> Cycle {
    let n_layers: usize = src_stages.iter().map(|(_, layers)| *layers).sum();
    let mut ready_at = start;
    let mut di = 0usize;
    for (coords, layers) in src_stages {
        let stage_kv = total_kv * *layers as u64 / n_layers.max(1) as u64;
        let per_core = stage_kv / coords.len().max(1) as u64;
        for &src in coords {
            let dst = dst_coords[di % dst_coords.len()];
            di += 1;
            let t = chip.send(src, dst, per_core, OpClass::KvTransfer);
            ready_at = ready_at.max(t.finish);
        }
    }
    ready_at
}

/// One iteration's admission under the token budget: decode steps first
/// (they bound TBT), leftover budget to chunked prefill (SARATHI-style).
/// Decode items are additionally capped to `1/n_stages` of the ready set so
/// consecutive ticks form microbatches that *pipeline* through the stages.
pub(crate) struct BatchPlan {
    pub items: Vec<BatchItem>,
    /// Indices into `active` of the scheduled decode steps.
    pub decode_idx: Vec<usize>,
    /// Draft tokens scheduled for each decode step (parallel to
    /// `decode_idx`; all zero with speculative decoding off). A decode
    /// with `d` drafts runs as one verify item of `d + 1` query tokens.
    pub drafted: Vec<u64>,
    /// `(index into active, chunk tokens)` of the scheduled prefill chunks.
    pub prefill_idx: Vec<(usize, u64)>,
}

pub(crate) fn plan_batch(
    active: &[Active],
    now: Cycle,
    n_stages: usize,
    cfg: &FusionConfig,
) -> BatchPlan {
    let mut items = Vec::new();
    let mut budget = cfg.budget as u64;
    let mut decode_idx = Vec::new();
    let mut drafted = Vec::new();
    let mut prefill_idx = Vec::new();
    // Token budget and microbatch slots go to the highest class first; the
    // sort is stable, so uniform-priority batches keep the legacy index
    // order bit-for-bit.
    let mut order: Vec<usize> = (0..active.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(active[i].req.priority));
    let n_ready = active
        .iter()
        .filter(|a| !a.is_done() && !a.is_prefilling() && a.ready_at <= now)
        .count();
    let micro_cap = n_ready.div_ceil(n_stages.max(1)).max(1);
    for &i in &order {
        let a = &active[i];
        if a.is_done() {
            continue;
        }
        if !a.is_prefilling() && a.ready_at <= now && budget > 0 && decode_idx.len() < micro_cap {
            // Speculative decoding: draft up to `gamma` tokens and verify
            // them together with the regular next token in one item of
            // `d + 1` query tokens (the Fig. 9 large-M shape). Drafts are
            // capped so even accept-all commits exactly `output_len`
            // tokens, and each verify token consumes one budget unit.
            let d = match cfg.spec {
                Some(sc) => sc
                    .gamma
                    .min((a.req.output_len as u64 - a.generated).saturating_sub(1))
                    .min(budget - 1),
                None => 0,
            };
            items.push(BatchItem {
                request: a.req.id,
                q_tokens: 1 + d,
                kv_tokens: a.req.input_len as u64 + a.generated,
                phase: crate::model::Phase::Decode,
            });
            decode_idx.push(i);
            drafted.push(d);
            budget -= 1 + d;
        }
    }
    for &i in &order {
        let a = &active[i];
        if a.is_prefilling() && budget > 0 {
            let remaining = a.req.input_len as u64 - a.prefilled;
            let chunk = remaining.min(cfg.chunk as u64).min(budget);
            items.push(BatchItem::prefill(a.req.id, chunk, a.prefilled + chunk));
            prefill_idx.push((i, chunk));
            budget -= chunk;
        }
    }
    BatchPlan {
        items,
        decode_idx,
        drafted,
        prefill_idx,
    }
}

/// Price one stage's share of a parked request's KV spill (or reload) on
/// the group cores' HBM channel — the same transaction-priced path as KV
/// spill, so preemption is never free. Returns the landing cycle (equals
/// the cores' clock on SRAM-only chips, where the channel is absent and
/// the spill degrades to a free park).
pub(crate) fn charge_kv_swap(
    chip: &mut ChipSim,
    stage: &StageWorker,
    model: &ModelConfig,
    tokens: u64,
) -> Cycle {
    let tp = stage.group.len().max(1) as u64;
    let bytes = (model.kv_bytes_per_token_layer() * stage.exec.layers as u64 / tp).max(1) * tokens;
    let mut done = 0;
    for &c in &stage.group.coords {
        done = done.max(chip.core_mut(c).hbm_access(bytes, OpClass::KvSpill));
    }
    done
}

/// Highest-class arrived request in `queue` (stable FIFO within a class:
/// uniform-priority queues reduce to the legacy front-of-queue pick).
pub(crate) fn best_arrived_idx(queue: &VecDeque<Request>, now: Cycle, freq: f64) -> Option<usize> {
    (0..queue.len())
        .filter(|&i| secs_to_cycles(queue[i].arrival_s, freq) <= now)
        .min_by_key(|&i| (std::cmp::Reverse(queue[i].priority), i))
}

/// Saturation of the most-loaded pipe in `[0, 1]`: queue depth measured
/// against twice the admission slots, max'd with KV occupancy — the
/// chip-side signal the cluster frontend throttles admissions by.
pub(crate) fn backpressure(pipes: &[Pipe], max_batch: usize) -> f64 {
    pipes
        .iter()
        .map(|p| {
            let q = p.pending_work() as f64 / (2 * max_batch.max(1)) as f64;
            q.min(1.0).max(p.kv_utilization())
        })
        .fold(0.0, f64::max)
}

impl Pipe {
    pub(crate) fn stage0_now(&self, chip: &ChipSim) -> Cycle {
        self.stages[0].now(chip)
    }

    /// Fold this pipe's per-worker sharing/memo counters into `out`.
    pub(crate) fn collect_cache_stats(&self, out: &mut CacheStats) {
        collect_worker_stats(self.stages.iter(), out);
    }

    /// Earliest cycle at which this pipe can do useful work, or `None`.
    pub(crate) fn next_action(&self, chip: &ChipSim, freq: f64) -> Option<Cycle> {
        let now = self.stage0_now(chip);
        if self.active.iter().any(|a| a.is_prefilling()) {
            return Some(now);
        }
        let next_decode = self
            .active
            .iter()
            .filter(|a| !a.is_done())
            .map(|a| a.ready_at)
            .min();
        if let Some(t) = next_decode {
            return Some(now.max(t));
        }
        if !self.parked.is_empty() {
            // No actives left, so resumption capacity exists: tick now.
            return Some(now);
        }
        let pending = self.pending.front().map(|p| p.ready_at);
        let queued = self
            .queue
            .front()
            .map(|r| secs_to_cycles(r.arrival_s, freq));
        match (pending, queued) {
            (None, None) => None,
            (a, b) => Some(now.max(a.unwrap_or(Cycle::MAX).min(b.unwrap_or(Cycle::MAX)))),
        }
    }

    /// Requests on this pipe that have not retired yet (queued, pending
    /// transfer, or in flight) — the cluster router's queue-depth signal.
    pub(crate) fn pending_work(&self) -> usize {
        self.queue.len()
            + self.pending.len()
            + self.parked.len()
            + self.active.iter().filter(|a| !a.is_done()).count()
    }

    /// Longest cached-and-ready prefix for `keys` usable by an admission
    /// on this pipe at cycle `at` — the minimum across stages, the same
    /// rule [`admit_with_prefix`] commits to.
    pub(crate) fn probe_prefix(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> u64 {
        self.stages
            .iter()
            .map(|s| s.peek_prefix(keys, limit, at))
            .min()
            .unwrap_or(0)
    }

    /// Tier-split [`Pipe::probe_prefix`]: the most conservative stage view
    /// (smallest total, then smallest fast-tier share), matching the
    /// min-across-stages rule admission commits to.
    pub(crate) fn probe_prefix_tiered(&self, keys: &[BlockKey], limit: u64, at: Cycle) -> TierMatch {
        self.stages
            .iter()
            .map(|s| s.peek_prefix_tiered(keys, limit, at))
            .min_by_key(|m| (m.total(), m.sram_tokens))
            .unwrap_or_default()
    }

    /// Mean occupancy of the stages' admission-limiting KV tier.
    pub(crate) fn kv_utilization(&self) -> f64 {
        if self.stages.is_empty() {
            return 0.0;
        }
        self.stages.iter().map(|s| s.kv.utilization()).sum::<f64>() / self.stages.len() as f64
    }

    /// Seed a migrated prefix copy into every stage cache, matchable from
    /// `ready_at` (when the inter-chip transfer lands).
    pub(crate) fn seed_prefix(&mut self, keys: &[BlockKey], ready_at: Cycle) {
        for s in &mut self.stages {
            s.kv.seed_prefix(keys, ready_at);
        }
    }

    /// Decode-phase load (pending + active decodes) — the hybrid router's
    /// least-loaded signal.
    pub(crate) fn decode_load(&self) -> usize {
        self.pending.len()
            + self
                .active
                .iter()
                .filter(|a| !a.is_prefilling() && !a.is_done())
                .count()
    }

    /// Queued plus in-flight-unprefilled prompt tokens (the controller's
    /// prefill-pressure signal).
    pub(crate) fn prefill_backlog_tokens(&self) -> u64 {
        let queued: u64 = self.queue.iter().map(|r| r.input_len as u64).sum();
        let inflight: u64 = self
            .active
            .iter()
            .filter(|a| a.is_prefilling())
            .map(|a| a.req.input_len as u64 - a.prefilled)
            .sum();
        queued + inflight
    }

    /// Park the best preemption victim strictly below `class`: a
    /// decode-phase active (prefills are never torn mid-chunk, and a
    /// decode whose step is still in flight through the stages is left
    /// alone) that has been preempted fewer than [`MAX_PREEMPTIONS`]
    /// times. Lowest class first, then the one with the most work left
    /// (freeing the slot longest), then index. The victim's KV spill is
    /// charged on the stages' HBM channel and released; returns whether a
    /// victim was parked. Never fires under uniform priorities — the
    /// strict `<` keeps same-class workloads preemption-free.
    pub(crate) fn preempt_below(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        class: Priority,
        now: Cycle,
        metrics: &mut Metrics,
    ) -> bool {
        let victim = (0..self.active.len())
            .filter(|&i| {
                let a = &self.active[i];
                a.req.priority < class
                    && !a.is_prefilling()
                    && !a.is_done()
                    && a.ready_at <= now
                    && a.preemptions < MAX_PREEMPTIONS
            })
            .min_by_key(|&i| {
                let a = &self.active[i];
                (
                    a.req.priority,
                    std::cmp::Reverse(a.req.output_len as u64 - a.generated),
                    i,
                )
            });
        let Some(vi) = victim else {
            return false;
        };
        let a = self.active.swap_remove(vi);
        let tokens = a.req.input_len as u64 + a.generated;
        for si in 0..self.stages.len() {
            charge_kv_swap(chip, &self.stages[si], model, tokens);
            self.stages[si].release(a.req.id);
        }
        metrics.control.preemptions += 1;
        self.parked.push(Parked {
            req: a.req,
            generated: a.generated,
            first_token: a.first_token,
            parked_at: now,
            preemptions: a.preemptions + 1,
        });
        true
    }

    /// Remove and return every request this pipe still holds — queued,
    /// in flight, transferred-but-unadmitted, or parked — for crash
    /// recovery. Stage KV is *not* released: the chip is dead and the
    /// pipe is discarded (or rebuilt cold on restart) by the caller.
    pub(crate) fn drain_incomplete(&mut self) -> Vec<super::Incomplete> {
        use super::Incomplete;
        let mut out = Vec::new();
        for req in self.queue.drain(..) {
            out.push(Incomplete {
                req,
                prefilled: 0,
                generated: 0,
            });
        }
        // Completed actives retire within their own tick, so everything
        // still here is genuinely unfinished.
        for a in self.active.drain(..) {
            out.push(Incomplete {
                req: a.req,
                prefilled: a.prefilled,
                generated: a.generated,
            });
        }
        for p in self.pending.drain(..) {
            out.push(Incomplete {
                req: p.req,
                prefilled: p.req.input_len as u64,
                generated: 1,
            });
        }
        for p in self.parked.drain(..) {
            out.push(Incomplete {
                req: p.req,
                prefilled: p.req.input_len as u64,
                generated: p.generated,
            });
        }
        out
    }

    /// One scheduler iteration on this pipe at time `t`. Returns the number
    /// of retired requests; when `extract_handoffs` is set, requests whose
    /// prefill completed this tick are pushed to `handoffs` (instead of
    /// decoding locally) and do not count as retired unless already done.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn tick(
        &mut self,
        chip: &mut ChipSim,
        model: &ModelConfig,
        cfg: &FusionConfig,
        t: Cycle,
        metrics: &mut Metrics,
        freq: f64,
        extract_handoffs: bool,
        handoffs: &mut Vec<Handoff>,
    ) -> usize {
        self.stages[0].advance_to(chip, t);
        let now = self.stage0_now(chip);

        // Resume parked (preempted) requests while capacity lasts, highest
        // class first (FIFO within a class). Their KV was spilled at
        // preemption; re-admission re-appends it and charges the reload
        // stream, so resumption is priced but prefill never recomputes.
        while !self.parked.is_empty()
            && self.active.len() < cfg.max_batch
            && self.stages.iter().all(|s| s.can_admit())
        {
            let pi = (0..self.parked.len())
                .min_by_key(|&i| (std::cmp::Reverse(self.parked[i].req.priority), i))
                .unwrap();
            let p = self.parked.remove(pi);
            let tokens = p.req.input_len as u64 + p.generated;
            let mut landed = now;
            for s in &mut self.stages {
                s.admit(p.req.id);
                s.kv.append(p.req.id, tokens);
            }
            for s in &self.stages {
                landed = landed.max(charge_kv_swap(chip, s, model, tokens));
            }
            metrics.control.resumes += 1;
            metrics.control.resume_wait_cycles += landed.saturating_sub(p.parked_at);
            self.active.push(Active {
                req: p.req,
                prefilled: p.req.input_len as u64,
                generated: p.generated,
                first_token: p.first_token,
                ready_at: landed,
                preemptions: p.preemptions,
            });
        }

        // Admit arrived requests while capacity lasts — highest class
        // first (stable FIFO within a class, so uniform-priority queues
        // reduce to the legacy front-of-queue order bit-for-bit). A
        // saturated pipe may make room for a higher class by preempting
        // the lowest-class decode-phase active below it.
        loop {
            let Some(qi) = best_arrived_idx(&self.queue, now, freq) else {
                break;
            };
            let capacity =
                self.active.len() < cfg.max_batch && self.stages.iter().all(|s| s.can_admit());
            if !capacity {
                let mut class = self.queue[qi].priority;
                // SLO-deadline-triggered preemption (opt-in via
                // `slo_preempt`): a candidate that has already burned more
                // than half its TTFT budget in the queue preempts as if
                // one class higher, so a projected breach can evict
                // equal-class work — not only strictly lower classes.
                // `None` (the default) never reaches this branch's extra
                // arithmetic, keeping the legacy path bit-identical.
                if let Some(slo) = cfg.slo_preempt {
                    let waited =
                        now.saturating_sub(secs_to_cycles(self.queue[qi].arrival_s, freq));
                    if waited > secs_to_cycles(slo * 0.5, freq) {
                        class = match class {
                            Priority::Low => Priority::Normal,
                            _ => Priority::High,
                        };
                    }
                }
                if !self.preempt_below(chip, model, class, now, metrics) {
                    break;
                }
                continue;
            }
            let r = self.queue.remove(qi).unwrap();
            let mut matched = 0u64;
            if cfg.prefix_cache {
                matched = admit_with_prefix(chip, &mut self.stages, &r, model, metrics, now);
            } else {
                for s in &mut self.stages {
                    s.admit(r.id);
                }
            }
            self.active.push(Active {
                req: r,
                prefilled: matched,
                generated: 0,
                first_token: None,
                ready_at: 0,
                preemptions: 0,
            });
        }

        // Admit transferred decode-phase requests (hybrid handoffs): their
        // prefill KV is appended on arrival, like a disagg decode group.
        while let Some(front) = self.pending.front() {
            if front.ready_at > now
                || self.active.len() >= cfg.max_batch
                || !self.stages.iter().all(|s| s.can_admit())
            {
                break;
            }
            let p = self.pending.pop_front().unwrap();
            for s in &mut self.stages {
                s.admit(p.req.id);
                s.kv.append(p.req.id, p.req.input_len as u64);
            }
            self.active.push(Active {
                req: p.req,
                prefilled: p.req.input_len as u64,
                generated: 1,
                first_token: Some(p.first_token),
                ready_at: p.ready_at,
                preemptions: 0,
            });
        }

        let plan = plan_batch(&self.active, now, self.stages.len(), cfg);
        if plan.items.is_empty() {
            return 0;
        }
        let batch = IterBatch::new(plan.items);

        // Draft pass of a speculative round: the requests draft in
        // lockstep, so the round runs the draft model for the deepest
        // request's draft count and each step streams the draft weights
        // once per stage — priced at `draft_cost_frac` of the stage's
        // layer weight stream on the same HBM channels the verify pass
        // uses. With `--spec` off (all-zero drafts) nothing is charged.
        let gamma_used = plan.drafted.iter().copied().max().unwrap_or(0);
        if gamma_used > 0 {
            let frac = cfg.spec.map_or(0.0, |sc| sc.draft_cost_frac);
            for s in &self.stages {
                let bytes = (s.plan.weight_hbm_bytes as f64 * frac) as u64 * gamma_used;
                if bytes > 0 {
                    for &c in &s.group.coords {
                        chip.core_mut(c).hbm_access(bytes, OpClass::HbmWeight);
                    }
                }
            }
        }

        // Stream the batch through the pipeline stages.
        let q = batch.total_q_tokens();
        if gamma_used > 0 {
            let threshold = self.stages[0].exec.small_m.map_or(0, |(_, t)| t);
            metrics.spec.observe_verify_m(q, threshold);
        }
        if !plan.decode_idx.is_empty() {
            metrics.spec.decode_weight_streams += 1;
        }
        let mut finish = 0;
        for s in 0..self.stages.len() {
            finish = self.stages[s].run(chip, model, &batch);
            if s + 1 < self.stages.len() {
                let bytes = self.stages[s].handoff_bytes(&chip.cfg.clone(), model, q);
                let src = self.stages[s].group.coords[0];
                let dst = self.stages[s + 1].group.coords[0];
                let tr = chip.send(src, dst, bytes, OpClass::P2P);
                finish = finish.max(tr.finish);
            }
        }

        // Update request states.
        let mut newly_prefilled: Vec<u64> = Vec::new();
        let mut prefill_progress: Vec<(u64, u64)> = Vec::new();
        for (i, chunk) in plan.prefill_idx {
            let a = &mut self.active[i];
            a.prefilled += chunk;
            if cfg.prefix_cache {
                prefill_progress.push((a.req.id, a.prefilled));
            }
            if !a.is_prefilling() {
                // Final prefill chunk emits the first output token.
                a.first_token = Some(finish);
                a.generated = 1;
                a.ready_at = finish;
                newly_prefilled.push(a.req.id);
            }
        }
        // In-flight-aware matching: prefix blocks registered at admission
        // become matchable exactly as the producing prefill passes them.
        for &(id, upto) in &prefill_progress {
            for s in &mut self.stages {
                s.note_prefilled(id, upto, finish);
            }
        }
        // Commit decode steps. A plain step commits one token. A verify
        // item of `d + 1` query tokens commits the leading accepted drafts
        // plus the corrected/bonus token, and the rejected tail — whose KV
        // the iteration already appended — is truncated off every stage's
        // paged chain and its writeback charged on the spill channel, so
        // misspeculation is never free. Commit and rollback happen inside
        // this tick, before any preemption can observe the request, so a
        // parked-mid-speculation request always parks with exact
        // (generated, KV) state.
        for (&i, &d) in plan.decode_idx.iter().zip(&plan.drafted) {
            if d == 0 {
                let a = &mut self.active[i];
                a.generated += 1;
                a.ready_at = finish;
                metrics.spec.decode_tokens_committed += 1;
                continue;
            }
            let sc = cfg.spec.expect("drafted tokens without a spec config");
            let (id, pos0) = (self.active[i].req.id, self.active[i].generated);
            let k = spec_accepted(id, pos0, d, sc.acceptance);
            let rejected = d - k;
            let mut landed = finish;
            if rejected > 0 {
                for si in 0..self.stages.len() {
                    self.stages[si].kv.truncate(id, rejected);
                    landed = landed.max(charge_kv_swap(chip, &self.stages[si], model, rejected));
                }
                metrics.spec.rejected_tokens += rejected;
            }
            metrics.spec.drafted_tokens += d;
            metrics.spec.accepted_tokens += k;
            metrics.spec.decode_tokens_committed += k + 1;
            let a = &mut self.active[i];
            a.generated += k + 1;
            a.ready_at = landed;
        }

        // Retire completed requests; in prefill-only mode, extract the
        // requests that finished prefill this tick for decode handoff
        // (draining decodes admitted earlier still finish locally).
        let mut completions = 0;
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].is_done() {
                let a = self.active.swap_remove(i);
                for s in &mut self.stages {
                    s.release(a.req.id);
                }
                metrics.record(RequestRecord {
                    id: a.req.id,
                    arrival: secs_to_cycles(a.req.arrival_s, freq),
                    first_token: a.first_token.unwrap_or(finish),
                    finish,
                    input_tokens: a.req.input_len as u64,
                    output_tokens: a.req.output_len as u64,
                    priority: a.req.priority,
                });
                completions += 1;
            } else if extract_handoffs && newly_prefilled.contains(&self.active[i].req.id) {
                let a = self.active.swap_remove(i);
                for s in &mut self.stages {
                    s.release(a.req.id);
                }
                handoffs.push(Handoff {
                    req: a.req,
                    first_token: a.first_token.unwrap_or(finish),
                    ready_at: a.ready_at.max(finish),
                });
            } else {
                i += 1;
            }
        }
        completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Phase;

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            input_len: input,
            output_len: output,
            prefix: crate::serving::request::Prefix::default(),
            priority: Priority::Normal,
        }
    }

    fn decoding(id: u64, input: usize, output: usize, generated: u64, ready_at: Cycle) -> Active {
        Active {
            req: req(id, input, output),
            prefilled: input as u64,
            generated,
            first_token: Some(1),
            ready_at,
            preemptions: 0,
        }
    }

    fn prefilling(id: u64, input: usize, prefilled: u64) -> Active {
        Active {
            req: req(id, input, 8),
            prefilled,
            generated: 0,
            first_token: None,
            ready_at: 0,
            preemptions: 0,
        }
    }

    #[test]
    fn decode_steps_precede_prefill_chunks() {
        let active = vec![
            prefilling(1, 1024, 0),
            decoding(2, 64, 16, 4, 0),
            prefilling(3, 512, 256),
            decoding(4, 64, 16, 2, 0),
        ];
        let plan = plan_batch(&active, 100, 1, &FusionConfig::default());
        let first_prefill = plan
            .items
            .iter()
            .position(|i| i.phase == Phase::Prefill)
            .unwrap();
        let last_decode = plan
            .items
            .iter()
            .rposition(|i| i.phase == Phase::Decode)
            .unwrap();
        assert!(
            last_decode < first_prefill,
            "decode-first ordering violated: {:?}",
            plan.items
        );
        assert_eq!(plan.decode_idx, vec![1, 3]);
    }

    #[test]
    fn chunk_accounting_respects_budget() {
        let cfg = FusionConfig {
            budget: 300,
            chunk: 128,
            ..FusionConfig::default()
        };
        let active = vec![
            decoding(1, 64, 16, 4, 0),
            prefilling(2, 1024, 0),
            prefilling(3, 1024, 960), // only 64 tokens left
            prefilling(4, 4096, 0),
        ];
        let plan = plan_batch(&active, 0, 1, &cfg);
        let decode_units = plan.decode_idx.len() as u64;
        let prefill_units: u64 = plan.prefill_idx.iter().map(|&(_, c)| c).sum();
        assert!(decode_units + prefill_units <= 300, "budget exceeded");
        for &(i, chunk) in &plan.prefill_idx {
            assert!(chunk <= 128, "chunk {chunk} > configured 128");
            assert!(chunk <= active[i].req.input_len as u64 - active[i].prefilled);
        }
        // Partial chunk for the nearly-done prompt.
        assert!(plan.prefill_idx.contains(&(2, 64)));
    }

    #[test]
    fn decode_microbatching_caps_per_stage_share() {
        // 8 ready decodes on a 4-stage pipe: at most ceil(8/4)=2 per tick so
        // consecutive ticks pipeline through the stages.
        let active: Vec<Active> = (0..8).map(|i| decoding(i, 64, 16, 2, 0)).collect();
        let plan = plan_batch(&active, 0, 4, &FusionConfig::default());
        assert_eq!(plan.decode_idx.len(), 2);
        // With a single stage, all 8 go at once.
        let plan1 = plan_batch(&active, 0, 1, &FusionConfig::default());
        assert_eq!(plan1.decode_idx.len(), 8);
    }

    #[test]
    fn done_and_not_ready_requests_are_skipped() {
        let active = vec![
            decoding(1, 64, 4, 4, 0),   // done
            decoding(2, 64, 16, 4, 500), // not ready until 500
            decoding(3, 64, 16, 4, 0),  // ready
        ];
        let plan = plan_batch(&active, 100, 1, &FusionConfig::default());
        assert_eq!(plan.decode_idx, vec![2]);
        let plan_late = plan_batch(&active, 500, 1, &FusionConfig::default());
        assert_eq!(plan_late.decode_idx, vec![1, 2]);
    }

    #[test]
    fn zero_ready_decodes_still_allows_prefill() {
        let active = vec![prefilling(1, 300, 0)];
        let cfg = FusionConfig {
            budget: 288,
            chunk: 256,
            ..FusionConfig::default()
        };
        let plan = plan_batch(&active, 0, 4, &cfg);
        assert!(plan.decode_idx.is_empty());
        assert_eq!(plan.prefill_idx, vec![(0, 256)]);
    }

    #[test]
    fn high_class_decodes_win_the_microbatch_slots() {
        // 4 ready decodes, 4 stages → micro_cap 1: the lone slot goes to
        // the High request even though it sits last.
        let mut active: Vec<Active> = (0..4).map(|i| decoding(i, 64, 16, 2, 0)).collect();
        active[3].req.priority = Priority::High;
        let plan = plan_batch(&active, 0, 4, &FusionConfig::default());
        assert_eq!(plan.decode_idx, vec![3]);
        // Uniform priorities keep the legacy index order exactly.
        active[3].req.priority = Priority::Normal;
        let plan = plan_batch(&active, 0, 4, &FusionConfig::default());
        assert_eq!(plan.decode_idx, vec![0]);
    }

    #[test]
    fn priority_budget_goes_to_high_prefills_first() {
        let cfg = FusionConfig {
            budget: 256,
            chunk: 256,
            ..FusionConfig::default()
        };
        let mut active = vec![prefilling(1, 512, 0), prefilling(2, 512, 0)];
        active[1].req.priority = Priority::High;
        let plan = plan_batch(&active, 0, 1, &cfg);
        assert_eq!(plan.prefill_idx, vec![(1, 256)]);
    }

    #[test]
    fn arrived_pick_is_priority_then_fifo() {
        let freq = 1000.0;
        let mut queue: VecDeque<Request> = VecDeque::new();
        queue.push_back(req(1, 64, 8));
        let mut low = req(2, 64, 8);
        low.priority = Priority::Low;
        queue.push_back(low);
        let mut high = req(3, 64, 8);
        high.priority = Priority::High;
        high.arrival_s = 1.0;
        queue.push_back(high);
        let now_early = secs_to_cycles(0.5, freq);
        // High has not arrived yet: FIFO among the arrived same-or-lower.
        assert_eq!(best_arrived_idx(&queue, now_early, freq), Some(0));
        let now_late = secs_to_cycles(2.0, freq);
        assert_eq!(best_arrived_idx(&queue, now_late, freq), Some(2));
        // Uniform priorities pick the front, like the legacy loop.
        for r in queue.iter_mut() {
            r.priority = Priority::Normal;
        }
        assert_eq!(best_arrived_idx(&queue, now_late, freq), Some(0));
        assert_eq!(best_arrived_idx(&VecDeque::new(), now_late, freq), None);
    }
}
