//! Configuration layer: chip (hardware), model (LLM), and workload (trace)
//! configuration, plus TOML loading for all three.
//!
//! The chip configuration space mirrors Table 3 of the paper; the model
//! presets cover the evaluated Qwen3 family (1.7B–32B dense, 30B-A3B MoE);
//! workloads cover the prefill-dominated and decode-dominated serving
//! traces of §5.1.

mod chip;
mod loader;
mod model;
mod workload;

pub use chip::{ChipConfig, CoreConfig, MemSimMode, NocConfig, NocSimMode};
pub use loader::load_sim_config;
pub use model::{ModelConfig, MoeConfig};
pub use workload::{ArrivalProcess, LenDist, PrefixSharing, PriorityMix, WorkloadConfig};
