//! Coarse-grained HBM ring buffer (Fig. 5, right).
//!
//! Spilled KV caches live in HBM as one contiguous **whole-request buffer**
//! sized for the maximum token length — HBM strongly favours long
//! sequential bursts, so fine-grained blocks would waste its bandwidth.
//! Buffers are allocated from a ring: an advancing head pointer with
//! in-order reclamation at the tail, matching the FIFO-ish lifetime of
//! serving requests.

/// Handle on one request's HBM KV buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingAlloc {
    /// Allocation id (monotonic; used for in-order reclamation).
    pub id: u64,
    /// Byte offset of the buffer within the ring.
    pub offset: u64,
    /// Buffer size in bytes.
    pub bytes: u64,
}

/// Ring-buffer allocator over an HBM byte capacity.
#[derive(Debug, Clone)]
pub struct RingBuffer {
    capacity: u64,
    /// Next byte to allocate (monotonic, un-wrapped).
    head: u64,
    /// Oldest live byte (monotonic, un-wrapped).
    tail: u64,
    /// Live allocations in ring order (front = oldest).
    live: std::collections::VecDeque<RingAlloc>,
    next_id: u64,
}

impl RingBuffer {
    pub fn new(capacity: u64) -> Self {
        RingBuffer {
            capacity,
            head: 0,
            tail: 0,
            live: std::collections::VecDeque::new(),
            next_id: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn bytes_live(&self) -> u64 {
        self.head - self.tail
    }

    pub fn bytes_free(&self) -> u64 {
        self.capacity - self.bytes_live()
    }

    pub fn n_live(&self) -> usize {
        self.live.len()
    }

    /// Allocate a whole-request buffer of `bytes`. Fails (returns `None`)
    /// when the ring cannot hold it — the scheduler must then defer
    /// admission (§4.3's budget/admission control is built on this signal).
    pub fn alloc(&mut self, bytes: u64) -> Option<RingAlloc> {
        if bytes == 0 || bytes > self.bytes_free() {
            return None;
        }
        let a = RingAlloc {
            id: self.next_id,
            offset: self.head % self.capacity.max(1),
            bytes,
        };
        self.next_id += 1;
        self.head += bytes;
        self.live.push_back(a);
        Some(a)
    }

    /// Free an allocation. Space is reclaimed in ring order: the tail only
    /// advances past buffers that are themselves freed, so freeing out of
    /// order defers reclamation (the paper's coarse-grained trade-off).
    pub fn free(&mut self, id: u64) {
        if let Some(pos) = self.live.iter().position(|a| a.id == id) {
            self.live[pos].bytes = self.live[pos].bytes.wrapping_neg(); // mark dead
            // Advance tail over every leading dead buffer.
            while let Some(front) = self.live.front() {
                let dead = (front.bytes as i64) < 0;
                if !dead {
                    break;
                }
                let bytes = front.bytes.wrapping_neg();
                self.tail += bytes;
                self.live.pop_front();
            }
        }
    }

    /// Fraction of capacity held by freed-but-unreclaimed buffers
    /// (fragmentation diagnostic).
    pub fn dead_fraction(&self) -> f64 {
        if self.capacity == 0 {
            return 0.0;
        }
        let dead: u64 = self
            .live
            .iter()
            .filter(|a| (a.bytes as i64) < 0)
            .map(|a| a.bytes.wrapping_neg())
            .sum();
        dead as f64 / self.capacity as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn alloc_and_free_in_order() {
        let mut r = RingBuffer::new(1000);
        let a = r.alloc(400).unwrap();
        let b = r.alloc(400).unwrap();
        assert!(r.alloc(400).is_none(), "over capacity");
        r.free(a.id);
        assert_eq!(r.bytes_free(), 600);
        let c = r.alloc(500).unwrap();
        assert_eq!(c.offset, 800 % 1000);
        r.free(b.id);
        r.free(c.id);
        assert_eq!(r.bytes_free(), 1000);
        assert_eq!(r.n_live(), 0);
    }

    #[test]
    fn out_of_order_free_defers_reclamation() {
        let mut r = RingBuffer::new(1000);
        let a = r.alloc(300).unwrap();
        let b = r.alloc(300).unwrap();
        // Free the *second* buffer: tail cannot move past the live first.
        r.free(b.id);
        assert_eq!(r.bytes_free(), 400);
        assert!(r.dead_fraction() > 0.29);
        // Freeing the first reclaims both.
        r.free(a.id);
        assert_eq!(r.bytes_free(), 1000);
        assert_eq!(r.dead_fraction(), 0.0);
    }

    #[test]
    fn wraps_around() {
        let mut r = RingBuffer::new(100);
        let a = r.alloc(60).unwrap();
        r.free(a.id);
        let b = r.alloc(60).unwrap();
        assert_eq!(b.offset, 60); // offset wraps modulo capacity
        let c = r.alloc(40).unwrap();
        assert_eq!(c.offset, 20);
    }

    #[test]
    fn zero_alloc_rejected() {
        let mut r = RingBuffer::new(100);
        assert!(r.alloc(0).is_none());
    }

    #[test]
    fn prop_accounting_consistent() {
        check("ring accounting", 128, |rng| {
            let mut r = RingBuffer::new(10_000);
            let mut ids = Vec::new();
            for _ in 0..rng.range(1, 64) {
                if rng.chance(0.6) {
                    if let Some(a) = r.alloc(rng.range_u64(1, 2000)) {
                        ids.push(a.id);
                    }
                } else if !ids.is_empty() {
                    let i = rng.range(0, ids.len());
                    r.free(ids.swap_remove(i));
                }
                assert!(r.bytes_live() <= r.capacity());
                assert!(r.bytes_free() <= r.capacity());
            }
            // Draining everything restores full capacity.
            for id in ids {
                r.free(id);
            }
            assert_eq!(r.bytes_free(), 10_000);
        });
    }
}
