//! Multi-chip serving cluster: N independent [`ChipSim`]s behind a
//! streamed admission frontend and a pluggable [`Router`].
//!
//! The single-chip drivers pre-load a whole trace into one scheduler; the
//! cluster driver instead *streams* — requests are released into a
//! cluster-level queue at their arrival times and routed to a chip based
//! on the chips' state **at that moment** (queue depth, KV occupancy,
//! prefix-cache contents). Three routing policies ship:
//!
//! - [`RouterPolicy::RoundRobin`] — static, state-blind baseline.
//! - [`RouterPolicy::LeastLoaded`] — minimises `(pending requests, KV
//!   occupancy)` at admission.
//! - [`RouterPolicy::PrefixAware`] — probes every chip's prefix index
//!   (read-only, in-flight-aware, **tier-split**: an SRAM-resident hit
//!   outranks an equal-length HBM-demoted one, which pays a re-promotion
//!   stream) and routes to the chip holding the best cached-and-ready
//!   prefix of the prompt; falls back to
//!   least-loaded on a miss. When the holder chip is overloaded (pending
//!   work exceeds the lightest chip's by the configured migration gap,
//!   `ClusterConfig::migrate_load_gap`), it routes to the lightest chip and
//!   *migrates* the matched prefix KV over the inter-chip fabric
//!   ([`crate::sim::interconnect`]) — charging the transfer's latency and
//!   bandwidth rather than recomputing the prefill.
//!
//! Every chip runs its own [`Scheduler`] (fusion, disagg, or hybrid —
//! mixes are allowed via [`simulate_cluster_mixed`]); the driver
//! interleaves chips deterministically by their earliest actionable cycle
//! and rolls per-chip [`Metrics`] up into a cluster aggregate.

use crate::config::{ChipConfig, ModelConfig, WorkloadConfig};
use crate::memmgr::prefix::{keys_prefix, BlockKey, TierMatch};
use crate::memmgr::KV_BLOCK_TOKENS;
use crate::parallel::plan::ChipRole;
use crate::serving::faults::{FaultKind, FaultSchedule, RecoveryPolicy};
use crate::serving::fleet::FleetSpec;
use crate::serving::metrics::{CacheStats, ControlStats, Metrics, RequestRecord};
use crate::serving::request::{self, Prefix, Priority, Request};
use crate::serving::scheduler::{Incomplete, Scheduler, SchedulerConfig};
use crate::sim::chip::ChipSim;
use crate::sim::interconnect::{Interconnect, InterconnectConfig, InterconnectStats};
use crate::util::cli::CliEnum;
use crate::util::units::{cycles_to_secs, secs_to_cycles, Cycle};
use std::collections::{HashMap, HashSet, VecDeque};

/// High bit of a request id, reserved to tag the prefill leg of a
/// fleet-disaggregated request so leg records cannot collide with real
/// ids. The decode leg keeps the original id (the merged record reports
/// under it), and its synthetic handoff [`Prefix`] uses the same bit to
/// keep its conversation scope private to the request.
const FLEET_LEG_BIT: u64 = 1 << 63;

/// Frontend overload response (CLI `--shed-policy`). With
/// [`ShedPolicy::None`] (the default) the admission path is bit-identical
/// to the pre-control-plane driver: every arrival routes immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Admit everything (legacy behaviour; the queue is unbounded).
    #[default]
    None,
    /// Reject overload arrivals outright: a shed request never runs and
    /// is counted in [`ControlStats::shed_requests`] by class.
    Drop,
    /// Re-time overload arrivals to the cluster's next actionable cycle
    /// (bounded retries); sustained overload degrades to a shed.
    Defer,
}

impl CliEnum for ShedPolicy {
    const WHAT: &'static str = "shed policy";
    const TABLE: &'static [(&'static str, &'static [&'static str], ShedPolicy)] = &[
        ("none", &["off"], ShedPolicy::None),
        ("drop", &["shed"], ShedPolicy::Drop),
        ("defer", &[], ShedPolicy::Defer),
    ];
}

impl ShedPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Self::parse_cli(s)
    }

    pub fn name(&self) -> &'static str {
        self.cli_name()
    }
}

/// Deferral retry bound: after this many re-timings one request degrades
/// to a shed (sustained overload must not recycle arrivals forever).
const MAX_DEFERRALS: u32 = 8;

/// Minimum re-timing step of one deferral, in seconds — keeps a deferred
/// arrival strictly later than the admission that bounced it even when
/// the cycle→seconds round-trip rounds down.
const DEFER_BACKOFF_S: f64 = 1e-4;

/// Load gain of the adaptive defer backoff: the per-deferral step is
/// `DEFER_BACKOFF_S · (1 + gain · backpressure) · 2^retries`, so a lightly
/// loaded fleet retries almost immediately while a saturated one spaces
/// retries out instead of thrashing the admission path. Backpressure is
/// clamped to `[0, 1]`, so one step never exceeds
/// `DEFER_BACKOFF_S · (1 + DEFER_LOAD_GAIN) · 2^(MAX_DEFERRALS-1)` and the
/// deferral chain still terminates within [`MAX_DEFERRALS`] re-timings.
const DEFER_LOAD_GAIN: f64 = 9.0;

/// Where the shed/defer saturation test looks (CLI `--shed-scope`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedScope {
    /// Shed only when **every** routable chip is saturated for the
    /// arrival's class (the original cluster-global check).
    #[default]
    Global,
    /// Route first, then shed when the **target** chip is saturated: a
    /// hot-spotted cluster keeps admitting onto its lightly loaded chips
    /// instead of waiting for the last chip to fill up.
    PerChip,
}

impl CliEnum for ShedScope {
    const WHAT: &'static str = "shed scope";
    const TABLE: &'static [(&'static str, &'static [&'static str], ShedScope)] = &[
        ("global", &["cluster"], ShedScope::Global),
        ("per-chip", &["chip", "perchip"], ShedScope::PerChip),
    ];
}

impl ShedScope {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Self::parse_cli(s)
    }

    pub fn name(&self) -> &'static str {
        self.cli_name()
    }
}

/// Routing policy selector (CLI `--router`, experiment sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastLoaded,
    PrefixAware,
}

impl CliEnum for RouterPolicy {
    const WHAT: &'static str = "router";
    const TABLE: &'static [(&'static str, &'static [&'static str], RouterPolicy)] = &[
        ("rr", &["round-robin", "roundrobin"], RouterPolicy::RoundRobin),
        ("least", &["least-loaded", "ll"], RouterPolicy::LeastLoaded),
        ("prefix", &["prefix-aware", "hit-aware"], RouterPolicy::PrefixAware),
    ];
}

impl RouterPolicy {
    /// All policies, in sweep order.
    pub const ALL: [RouterPolicy; 3] = [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::PrefixAware,
    ];

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Self::parse_cli(s)
    }

    pub fn name(&self) -> &'static str {
        self.cli_name()
    }

    /// Instantiate the policy. `migrate_load_gap` only affects
    /// [`RouterPolicy::PrefixAware`].
    pub fn build(&self, migrate_load_gap: usize) -> Box<dyn Router> {
        match self {
            RouterPolicy::RoundRobin => Box::new(RoundRobinRouter { next: 0 }),
            RouterPolicy::LeastLoaded => Box::new(LeastLoadedRouter),
            RouterPolicy::PrefixAware => Box::new(PrefixAwareRouter {
                load_gap: migrate_load_gap,
            }),
        }
    }
}

/// One chip's routing-relevant state at an admission instant.
#[derive(Debug, Clone, Copy)]
pub struct ChipView {
    /// Requests enqueued on the chip but not yet retired.
    pub pending_work: usize,
    /// KV occupancy of the admission-limiting tier, in per-mille
    /// (integer so routing comparisons are exact and deterministic).
    pub kv_occupancy_milli: u64,
    /// Longest cached-and-ready prefix (tokens) the chip could share with
    /// this request, across both cache tiers (0 when the prompt has no
    /// shareable prefix, the chip holds none of it, or its prefill is
    /// still in flight).
    pub prefix_match: u64,
    /// The SRAM-resident portion of `prefix_match` — the two-tier hit
    /// quality signal: a fast-tier match shares for free, an HBM-demoted
    /// one pays a re-promotion stream first.
    pub prefix_sram: u64,
}

impl ChipView {
    fn load_key(&self) -> (usize, u64) {
        (self.pending_work, self.kv_occupancy_milli)
    }

    /// Tier-weighted match score, the prefix router's ranking key —
    /// delegated to [`TierMatch::score`] so the weighting cannot drift
    /// from the in-chip pipe-affinity scoring.
    fn match_score(&self) -> u64 {
        TierMatch {
            sram_tokens: self.prefix_sram,
            hbm_tokens: self.prefix_match.saturating_sub(self.prefix_sram),
        }
        .score()
    }
}

/// Where a request goes, and whether its prefix KV migrates first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub chip: usize,
    /// `Some(holder)`: stream the matched prefix from `holder`'s cache to
    /// `chip` over the interconnect before admission (charged, not free).
    pub migrate_from: Option<usize>,
}

/// A cluster admission router: one decision per arriving request, based on
/// read-only per-chip state snapshots.
pub trait Router {
    fn name(&self) -> &'static str;

    /// Does this policy read [`ChipView::prefix_match`]? The driver skips
    /// the per-arrival trie probes (every stage of every pipe of every
    /// chip) for policies that never look at them.
    fn wants_prefix(&self) -> bool {
        false
    }

    fn route(&mut self, req: &Request, views: &[ChipView]) -> RouteDecision;
}

/// Chip with the least `(pending work, KV occupancy)`, ties on index.
fn least_loaded(views: &[ChipView]) -> usize {
    views
        .iter()
        .enumerate()
        .min_by_key(|(i, v)| (v.load_key(), *i))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Static round-robin (the state-blind baseline).
struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        let chip = self.next % views.len().max(1);
        self.next = (self.next + 1) % views.len().max(1);
        RouteDecision {
            chip,
            migrate_from: None,
        }
    }
}

/// Least `(queue depth, KV occupancy)` at each admission.
struct LeastLoadedRouter;

impl Router for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        RouteDecision {
            chip: least_loaded(views),
            migrate_from: None,
        }
    }
}

/// Longest-ready-prefix-first, least-loaded fallback, migration under
/// holder overload.
struct PrefixAwareRouter {
    load_gap: usize,
}

impl Router for PrefixAwareRouter {
    fn name(&self) -> &'static str {
        "prefix"
    }

    fn wants_prefix(&self) -> bool {
        true
    }

    fn route(&mut self, _req: &Request, views: &[ChipView]) -> RouteDecision {
        let lightest = least_loaded(views);
        // Best tier-weighted match wins (an SRAM-resident hit outranks an
        // equal-length HBM-demoted one); ties go to the less loaded
        // holder, then to the lower chip index (deterministic).
        let holder = views
            .iter()
            .enumerate()
            .filter(|(_, v)| v.prefix_match > 0)
            .min_by_key(|(i, v)| (std::cmp::Reverse(v.match_score()), v.load_key(), *i))
            .map(|(i, _)| i);
        match holder {
            None => RouteDecision {
                chip: lightest,
                migrate_from: None,
            },
            Some(h) => {
                let overloaded = views[h].pending_work
                    > views[lightest].pending_work.saturating_add(self.load_gap);
                if overloaded && h != lightest {
                    // Queueing on the holder would cost more than moving
                    // the KV: migrate the prefix to the lightest chip.
                    RouteDecision {
                        chip: lightest,
                        migrate_from: Some(h),
                    }
                } else {
                    RouteDecision {
                        chip: h,
                        migrate_from: None,
                    }
                }
            }
        }
    }
}

/// Cluster topology + policy configuration.
///
/// Construction goes through [`ClusterBuilder`] (one typed path); the
/// legacy homogeneous constructors ([`ClusterConfig::new`] and the
/// `with_*` chain) are thin shims over it.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-chip fleet description: hardware, scheduler, plan provenance,
    /// and serving role. Role-specialized fleets switch the frontend into
    /// cross-chip PD disaggregation (prefill legs on prefill chips, decode
    /// legs handed off with their KV over the interconnect).
    pub fleet: FleetSpec,
    pub router: RouterPolicy,
    pub interconnect: InterconnectConfig,
    /// Pending-work excess over the lightest chip above which the prefix
    /// router migrates the matched KV instead of queueing on the holder.
    pub migrate_load_gap: usize,
    /// Frontend overload response ([`ShedPolicy::None`] = legacy
    /// unbounded admission, bit-identical to the pre-control-plane path).
    pub shed: ShedPolicy,
    /// Per-chip pending-work bound for Low-class arrivals while shedding
    /// is on; Normal tolerates twice this, High is never shed. Ignored
    /// under [`ShedPolicy::None`].
    pub queue_cap: usize,
    /// TTFT target the frontend's goodput accounting reports against
    /// (does not gate admission — queue depth and scheduler backpressure
    /// do; this is the SLO the shed policy is protecting).
    pub slo_ttft_s: f64,
    /// Saturation scope of the shed/defer check (global = legacy).
    pub shed_scope: ShedScope,
    /// Deterministic fault schedule (`None` = fault-free, bit-identical to
    /// the pre-fault driver). With `Some`, the frontend additionally runs
    /// heartbeat-style failure detection and KV-aware recovery — see
    /// [`crate::serving::faults`].
    pub faults: Option<FaultSchedule>,
    /// Worker threads advancing independent chips inside each conservative
    /// synchronization window (CLI `--sim-threads`). `1` (the default)
    /// keeps the literal sequential event loop; any `N > 1` is
    /// byte-identical to it by construction — see the window invariant at
    /// [`simulate_cluster_mixed`]. The `NPUSIM_SIM_THREADS` env var
    /// overrides a default of 1 (so CI can exercise the parallel path
    /// across the whole suite without touching call sites).
    pub sim_threads: usize,
}

impl ClusterConfig {
    /// Start the one construction path: a typed builder over a fleet.
    pub fn builder(fleet: FleetSpec) -> ClusterBuilder {
        ClusterBuilder::new(fleet)
    }

    /// Legacy homogeneous constructor: `n_chips` clones of one
    /// `(chip, sched)` pair. Thin shim over [`ClusterBuilder`].
    pub fn new(
        chip: ChipConfig,
        n_chips: usize,
        sched: SchedulerConfig,
        router: RouterPolicy,
    ) -> Self {
        Self::builder(FleetSpec::homogeneous(chip, n_chips, sched))
            .router(router)
            .build()
    }

    /// Number of chips in the fleet.
    pub fn n_chips(&self) -> usize {
        self.fleet.n_chips()
    }

    /// The fleet's shared clock (chips are validated to one clock domain).
    pub fn freq_mhz(&self) -> f64 {
        self.fleet.freq_mhz()
    }

    /// Re-open this config as a builder (the `with_*` shims route through
    /// it so every mutation shares the single construction path).
    fn to_builder(self) -> ClusterBuilder {
        ClusterBuilder {
            fleet: self.fleet,
            router: self.router,
            interconnect: self.interconnect,
            migrate_load_gap: self.migrate_load_gap,
            shed: self.shed,
            queue_cap: self.queue_cap,
            slo_ttft_s: self.slo_ttft_s,
            shed_scope: self.shed_scope,
            faults: self.faults,
            sim_threads: self.sim_threads,
        }
    }

    /// Enable SLO-aware overload control (legacy shim).
    pub fn with_shed(self, shed: ShedPolicy, queue_cap: usize) -> Self {
        self.to_builder().shed(shed, queue_cap).build()
    }

    /// Select the shed saturation scope (legacy shim).
    pub fn with_shed_scope(self, scope: ShedScope) -> Self {
        self.to_builder().shed_scope(scope).build()
    }

    /// Attach a deterministic fault schedule (legacy shim).
    pub fn with_faults(self, faults: FaultSchedule) -> Self {
        self.to_builder().faults(faults).build()
    }

    /// Build a cluster where every chip runs the deployment a
    /// [`crate::parallel::plan::DeploymentPlan`] describes.
    pub fn from_plan(
        chip: ChipConfig,
        n_chips: usize,
        plan: &crate::parallel::plan::DeploymentPlan,
        router: RouterPolicy,
    ) -> anyhow::Result<Self> {
        Ok(Self::new(
            chip,
            n_chips,
            SchedulerConfig::from_plan(plan)?,
            router,
        ))
    }
}

/// The single typed construction path for [`ClusterConfig`]: defaults
/// match the pre-redesign positional constructor exactly.
#[derive(Debug, Clone)]
pub struct ClusterBuilder {
    fleet: FleetSpec,
    router: RouterPolicy,
    interconnect: InterconnectConfig,
    migrate_load_gap: usize,
    shed: ShedPolicy,
    queue_cap: usize,
    slo_ttft_s: f64,
    shed_scope: ShedScope,
    faults: Option<FaultSchedule>,
    sim_threads: usize,
}

impl ClusterBuilder {
    pub fn new(fleet: FleetSpec) -> Self {
        ClusterBuilder {
            fleet,
            router: RouterPolicy::RoundRobin,
            interconnect: InterconnectConfig::default(),
            migrate_load_gap: 8,
            shed: ShedPolicy::default(),
            queue_cap: 32,
            slo_ttft_s: 2.0,
            shed_scope: ShedScope::default(),
            faults: None,
            sim_threads: 1,
        }
    }

    pub fn router(mut self, router: RouterPolicy) -> Self {
        self.router = router;
        self
    }

    pub fn interconnect(mut self, icn: InterconnectConfig) -> Self {
        self.interconnect = icn;
        self
    }

    pub fn migrate_load_gap(mut self, gap: usize) -> Self {
        self.migrate_load_gap = gap;
        self
    }

    /// Enable SLO-aware overload control.
    pub fn shed(mut self, shed: ShedPolicy, queue_cap: usize) -> Self {
        self.shed = shed;
        self.queue_cap = queue_cap.max(1);
        self
    }

    pub fn shed_scope(mut self, scope: ShedScope) -> Self {
        self.shed_scope = scope;
        self
    }

    pub fn slo_ttft_s(mut self, slo: f64) -> Self {
        self.slo_ttft_s = slo;
        self
    }

    pub fn faults(mut self, faults: FaultSchedule) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Worker threads for the conservative-window parallel stepping path
    /// (clamped to at least 1).
    pub fn sim_threads(mut self, threads: usize) -> Self {
        self.sim_threads = threads.max(1);
        self
    }

    pub fn build(self) -> ClusterConfig {
        ClusterConfig {
            fleet: self.fleet,
            router: self.router,
            interconnect: self.interconnect,
            migrate_load_gap: self.migrate_load_gap,
            shed: self.shed,
            queue_cap: self.queue_cap,
            slo_ttft_s: self.slo_ttft_s,
            shed_scope: self.shed_scope,
            faults: self.faults,
            sim_threads: self.sim_threads,
        }
    }
}

/// Fault-plane counters of one cluster run (all zero when
/// [`ClusterConfig::faults`] is `None`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Chip crashes injected (a crash on an already-down chip is ignored).
    pub crashes: u64,
    /// Crashed chips brought back cold after their restart window.
    pub restarts: u64,
    /// Link / HBM degradation windows injected.
    pub degradations: u64,
    /// Summed crash→detection latency in cycles (heartbeat-bounded).
    pub detect_cycles: u64,
    /// Distinct stranded requests re-dispatched onto a surviving chip.
    pub recovered: u64,
    /// Recovery dispatches, including repeats and naive resubmissions.
    pub retries: u64,
    /// Stranded requests shed after exhausting the retry budget (or when
    /// no chip could ever serve them again).
    pub recovery_shed: u64,
    /// Tokens recovery re-ran: un-restorable prompt prefill plus lost
    /// decode progress.
    pub tokens_recomputed: u64,
    /// Prompt tokens restored from a surviving cross-chip prefix copy
    /// instead of recomputed.
    pub tokens_restored: u64,
}

impl FaultStats {
    /// Mean crash→detection latency in seconds (0 with no crashes).
    pub fn mean_detect_s(&self, freq_mhz: f64) -> f64 {
        if self.crashes == 0 {
            return 0.0;
        }
        cycles_to_secs(self.detect_cycles, freq_mhz) / self.crashes as f64
    }
}

/// Recovery accounting of one stranded-then-redispatched request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryRecord {
    pub id: u64,
    /// Which retry attempt this dispatch was (1 = first).
    pub retries: u32,
    /// Cycles from the crash that stranded it to this re-admission.
    pub recovery_cycles: Cycle,
    /// Prompt tokens re-prefilled plus decode tokens regenerated.
    pub tokens_recomputed: u64,
    /// Prompt tokens restored from a surviving cached prefix copy.
    pub tokens_restored: u64,
}

/// Per-chip metrics plus the cluster-level rollup inputs.
#[derive(Debug)]
pub struct ClusterMetrics {
    pub per_chip: Vec<Metrics>,
    /// Requests admitted per chip (the routing histogram; recovery
    /// re-dispatches count again on their new chip).
    pub routed: Vec<usize>,
    /// Prefix migrations the router performed.
    pub migrations: u64,
    /// Frontend control-plane counters (sheds and deferrals happen before
    /// any chip sees the request, so they live here rather than on a
    /// chip's [`Metrics`]; preemption/resume counters live per chip).
    pub control: ControlStats,
    pub interconnect: InterconnectStats,
    /// Prefill→decode cross-chip KV handoffs the fleet frontend performed
    /// (0 unless the fleet is role-specialized).
    pub handoffs: u64,
    /// Fault-plane counters (all zero without a fault schedule).
    pub faults: FaultStats,
    /// One record per recovery dispatch, sorted by `(id, retries)`.
    pub recovery: Vec<RecoveryRecord>,
    freq_mhz: f64,
}

impl ClusterMetrics {
    /// Total completed requests across chips.
    pub fn n_requests(&self) -> usize {
        self.per_chip.iter().map(|m| m.n_requests()).sum()
    }

    /// Requests the frontend shed (never admitted to any chip, or dropped
    /// by recovery after exhausting its retry budget).
    pub fn shed_requests(&self) -> u64 {
        self.control.shed_requests
    }

    /// Exactly-once conservation: every offered request either completed
    /// or was shed — nothing stranded, nothing served twice. The fault
    /// study gates on this holding through crashes and recoveries.
    pub fn conserves(&self, offered: usize) -> bool {
        self.n_requests() + self.shed_requests() as usize == offered
    }

    /// Merge every chip's records and cache counters into one [`Metrics`]
    /// (cluster-level TTFT/TBT distributions, throughput over the global
    /// makespan, aggregate cache rates), folding the frontend's shed and
    /// deferral counters in with the chips' preemption counters.
    pub fn aggregate(&self) -> Metrics {
        let mut out = Metrics::new(self.freq_mhz);
        for m in &self.per_chip {
            out.absorb(m);
        }
        out.control.merge(&self.control);
        out
    }
}

/// A migrated request waiting for its KV to land on the target chip.
struct Transit {
    landing: Cycle,
    dst: usize,
    req: Request,
    keys: Vec<BlockKey>,
    /// Whether this is a fleet decode leg (its synthetic handoff keys must
    /// not be mistaken for a migratable trace prefix by the dedup check).
    leg: bool,
}

/// One chip's fault-plane health as the frontend tracks it.
struct ChipHealth {
    /// `Some(crash_cycle)` while the chip is down.
    down_since: Option<Cycle>,
    /// Whether the heartbeat (or a restart) has already discovered the
    /// crash and drained the stranded work. Until detection the frontend
    /// still routes to the dead chip — exactly the heartbeat-interval
    /// blind window the fault study measures.
    detected: bool,
    /// Active HBM throttle factor (1.0 = nominal).
    hbm_factor: f64,
    /// Active egress-link degradation factor (1.0 = nominal).
    link_factor: f64,
}

impl ChipHealth {
    fn new() -> Self {
        ChipHealth {
            down_since: None,
            detected: false,
            hbm_factor: 1.0,
            link_factor: 1.0,
        }
    }

    fn up(&self) -> bool {
        self.down_since.is_none()
    }

    /// What the frontend believes: a crashed chip stays routable until the
    /// heartbeat discovers it.
    fn believed_up(&self) -> bool {
        self.up() || !self.detected
    }

    /// Advertised capacity in per-mille of nominal — degraded chips shrink
    /// it so routers steer proportionally more load elsewhere.
    fn capacity_milli(&self) -> u64 {
        (((self.hbm_factor * self.link_factor) * 1000.0).round() as u64).max(1)
    }
}

/// Internal control-plane events of the fault machinery, processed as a
/// fourth event source of the cluster loop (ties broken by insertion
/// sequence for determinism).
enum Ctrl {
    /// Scheduled fault fires (index into `FaultSchedule::events`).
    Inject(usize),
    /// Heartbeat probe discovers the crash of `chip` at `crash`.
    Detect { chip: usize, crash: Cycle },
    /// A crashed chip comes back cold.
    Restart { chip: usize },
    /// A degradation window ends (`hbm`: HBM throttle vs egress link).
    Expire { chip: usize, hbm: bool },
    /// A recovered request re-dispatches after its backoff.
    Retry {
        req: Request,
        attempt: u32,
        crash: Cycle,
        generated: u64,
    },
}

/// Fault-plane runtime state of one cluster run.
struct FaultRt {
    schedule: FaultSchedule,
    health: Vec<ChipHealth>,
    /// Pending control events as `(cycle, seq, event)`; the earliest
    /// `(cycle, seq)` fires next.
    ctrl: Vec<(Cycle, u64, Ctrl)>,
    seq: u64,
    /// Recovery attempts per stranded request id.
    retries: HashMap<u64, u32>,
    /// First-seen arrival cycle per request id (recovered requests rebase
    /// to it, so TTFT honestly includes downtime + redo).
    orig_arrival: HashMap<u64, Cycle>,
    /// `(id, original arrival)` of every request that entered recovery.
    rebase: Vec<(u64, Cycle)>,
    stats: FaultStats,
    recovery: Vec<RecoveryRecord>,
}

impl FaultRt {
    fn new(schedule: FaultSchedule, n: usize, freq: f64) -> Self {
        let mut f = FaultRt {
            health: (0..n).map(|_| ChipHealth::new()).collect(),
            ctrl: Vec::new(),
            seq: 0,
            retries: HashMap::new(),
            orig_arrival: HashMap::new(),
            rebase: Vec::new(),
            stats: FaultStats::default(),
            recovery: Vec::new(),
            schedule,
        };
        for (idx, ev) in f.schedule.events.clone().iter().enumerate() {
            f.push(secs_to_cycles(ev.at_s, freq), Ctrl::Inject(idx));
        }
        f
    }

    fn push(&mut self, at: Cycle, ev: Ctrl) {
        self.ctrl.push((at, self.seq, ev));
        self.seq += 1;
    }

    /// Cycle of the next pending control event ([`Cycle::MAX`] if none).
    fn next_cycle(&self) -> Cycle {
        self.ctrl
            .iter()
            .map(|(c, s, _)| (*c, *s))
            .min()
            .map(|(c, _)| c)
            .unwrap_or(Cycle::MAX)
    }

    /// Remove and return the earliest `(cycle, seq)` control event.
    fn pop_next(&mut self) -> Option<(Cycle, Ctrl)> {
        let k = self
            .ctrl
            .iter()
            .enumerate()
            .min_by_key(|(_, (c, s, _))| (*c, *s))
            .map(|(k, _)| k)?;
        let (c, _, ev) = self.ctrl.remove(k);
        Some((c, ev))
    }

    /// Earliest pending restart, if any (the all-chips-down fallback).
    fn restart_pending(&self) -> Option<Cycle> {
        self.ctrl
            .iter()
            .filter(|(_, _, e)| matches!(e, Ctrl::Restart { .. }))
            .map(|(c, _, _)| *c)
            .min()
    }
}

/// Shared defer-or-shed tail of every admission rejection: re-time the
/// arrival back into the sorted stream under [`ShedPolicy::Defer`] (with
/// the load-adaptive exponential backoff), degrade to a shed past
/// [`MAX_DEFERRALS`] or under [`ShedPolicy::Drop`].
#[allow(clippy::too_many_arguments)]
fn reject_arrival(
    mut req: Request,
    shed: ShedPolicy,
    backoff_base_s: f64,
    retime_floor: Cycle,
    freq: f64,
    stream: &mut VecDeque<Request>,
    deferred: &mut HashMap<u64, u32>,
    control: &mut ControlStats,
    done: &mut usize,
) {
    let retries = deferred.get(&req.id).copied().unwrap_or(0);
    if shed == ShedPolicy::Defer && retries < MAX_DEFERRALS {
        deferred.insert(req.id, retries + 1);
        control.deferrals += 1;
        req.arrival_s = (cycles_to_secs(retime_floor, freq).max(req.arrival_s))
            + backoff_base_s * (1u64 << retries.min(30)) as f64;
        let at = stream
            .iter()
            .position(|r| r.arrival_s > req.arrival_s)
            .unwrap_or(stream.len());
        stream.insert(at, req);
    } else {
        control.shed_requests += 1;
        control.shed_by_class[req.priority.index()] += 1;
        *done += 1;
    }
}

/// Load-adaptive defer backoff base from one backpressure probe (clamped
/// to `[0, 1]`): the minimum re-timing step scaled by how saturated the
/// probed admission path is.
fn defer_backoff_from(bp: f64) -> f64 {
    DEFER_BACKOFF_S * (1.0 + DEFER_LOAD_GAIN * bp.clamp(0.0, 1.0))
}

/// Cluster-global defer backoff base: the worst probed backpressure across
/// the routable chips — the right signal when admission failed because
/// *every* chip was saturated ([`ShedScope::Global`]). The per-chip scope
/// instead feeds [`defer_backoff_from`] the routed target's own probe: the
/// retry will re-route, so one hot chip far from the target must not
/// stretch the whole cluster's retry spacing.
fn defer_backoff(scheds: &[Box<dyn Scheduler>], avail: &[usize]) -> f64 {
    let bp = avail
        .iter()
        .map(|&i| scheds[i].backpressure())
        .fold(0.0, f64::max);
    defer_backoff_from(bp)
}

/// Handle one request stranded by a dead chip: bounded-backoff retry under
/// [`RecoveryPolicy::Recover`], client-timeout resubmission through the
/// normal (sheddable) stream under [`RecoveryPolicy::Resubmit`], shed once
/// the retry budget is exhausted. `crash` is when the work was lost, `now`
/// when the frontend found out.
#[allow(clippy::too_many_arguments)]
fn recover_lost(
    f: &mut FaultRt,
    control: &mut ControlStats,
    done: &mut usize,
    stream: &mut VecDeque<Request>,
    freq: f64,
    inc: Incomplete,
    crash: Cycle,
    now: Cycle,
) {
    let id = inc.req.id;
    let attempt = f.retries.get(&id).copied().unwrap_or(0) + 1;
    if attempt > f.schedule.max_retries {
        control.shed_requests += 1;
        control.shed_by_class[inc.req.priority.index()] += 1;
        f.stats.recovery_shed += 1;
        *done += 1;
        return;
    }
    f.retries.insert(id, attempt);
    if let Some(&orig) = f.orig_arrival.get(&id) {
        f.rebase.push((id, orig));
    }
    match f.schedule.recovery {
        RecoveryPolicy::Recover => {
            let backoff = f.schedule.retry_backoff_s * (1u64 << (attempt - 1).min(30)) as f64;
            let at = now + secs_to_cycles(backoff, freq).max(1);
            f.push(
                at,
                Ctrl::Retry {
                    req: inc.req,
                    attempt,
                    crash,
                    generated: inc.generated,
                },
            );
        }
        RecoveryPolicy::Resubmit { client_timeout_s } => {
            // The frontend does nothing; the client notices via timeout
            // and resubmits, paying the full timeout before the request
            // even re-enters admission (and it can be shed there).
            let mut req = inc.req;
            req.arrival_s = (cycles_to_secs(crash, freq) + client_timeout_s)
                .max(cycles_to_secs(now, freq));
            let at = stream
                .iter()
                .position(|r| r.arrival_s > req.arrival_s)
                .unwrap_or(stream.len());
            stream.insert(at, req);
            f.stats.retries += 1;
        }
    }
}

/// Simulate a synthetic workload on the cluster.
pub fn simulate_cluster(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    workload: &WorkloadConfig,
) -> anyhow::Result<ClusterMetrics> {
    simulate_cluster_requests(cfg, model, request::generate(workload))
}

/// Simulate an explicit (arrival-sorted) request list on the cluster,
/// each chip running the scheduler its fleet spec names.
pub fn simulate_cluster_requests(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    reqs: Vec<Request>,
) -> anyhow::Result<ClusterMetrics> {
    let scheds: Vec<Box<dyn Scheduler>> = cfg.fleet.chips.iter().map(|c| c.sched.build()).collect();
    simulate_cluster_mixed(cfg, model, reqs, scheds)
}

/// Simulate with an explicit per-chip scheduler list (mixed policies:
/// e.g. chip 0 fused, chip 1 disaggregated). `scheds.len()` must equal
/// the fleet size; requests must be sorted by arrival time.
pub fn simulate_cluster_mixed(
    cfg: &ClusterConfig,
    model: &ModelConfig,
    reqs: Vec<Request>,
    mut scheds: Vec<Box<dyn Scheduler>>,
) -> anyhow::Result<ClusterMetrics> {
    cfg.fleet.validate()?;
    let n = cfg.fleet.n_chips();
    anyhow::ensure!(
        scheds.len() == n,
        "cluster has {n} chips but {} schedulers",
        scheds.len()
    );
    anyhow::ensure!(
        reqs.iter().all(|r| r.id & FLEET_LEG_BIT == 0),
        "request ids must not use the reserved fleet-leg bit"
    );
    let freq = cfg.fleet.freq_mhz();
    let mut chips: Vec<ChipSim> = cfg
        .fleet
        .chips
        .iter()
        .map(|c| ChipSim::new(c.hw.clone()))
        .collect();
    let max_tokens = reqs.iter().map(|r| r.total_tokens()).max().unwrap_or(1);
    for (i, s) in scheds.iter_mut().enumerate() {
        s.prepare(&mut chips[i], model, max_tokens)?;
    }
    let mut icn = Interconnect::new(cfg.interconnect, n, freq);
    let mut router = cfg.router.build(cfg.migrate_load_gap);
    if let Some(s) = &cfg.faults {
        anyhow::ensure!(
            s.events.iter().all(|e| e.chip < n),
            "fault schedule targets a chip >= {n}"
        );
    }
    // Fault-plane runtime (`None` keeps every downstream branch on its
    // bit-identical fault-free path).
    let mut fault: Option<FaultRt> = cfg
        .faults
        .as_ref()
        .map(|s| FaultRt::new(s.clone(), n, freq));

    // `total` counts retirements the loop must wait for; each fleet
    // handoff adds one (the decode leg retires separately from its
    // prefill leg).
    let mut total = reqs.len();
    let mut stream: VecDeque<Request> = reqs.into();
    let mut transit: Vec<Transit> = Vec::new();
    // `(request id, true arrival cycle, destination chip)` of every
    // migration — used to rebase recorded arrivals after the run.
    let mut migrated_log: Vec<(u64, Cycle, usize)> = Vec::new();
    let mut per_chip: Vec<Metrics> = (0..n).map(|_| Metrics::new(freq)).collect();
    let mut routed = vec![0usize; n];
    let mut migrations = 0u64;
    let mut control = ControlStats::default();
    // Deferral retries by request id (Defer policy only).
    let mut deferred: HashMap<u64, u32> = HashMap::new();
    // Fleet PD disaggregation: role-specialized fleets split each request
    // into a prefill leg (routed among prefill-capable chips) and a decode
    // leg created at prefill completion and shipped — with its prompt KV —
    // to a decode-capable chip over the interconnect.
    let fleet_disagg = cfg.fleet.is_disaggregated();
    let prefill_ok: Vec<bool> = cfg
        .fleet
        .chips
        .iter()
        .map(|c| c.role != ChipRole::Decode)
        .collect();
    let decode_ok: Vec<bool> = cfg
        .fleet
        .chips
        .iter()
        .map(|c| c.role != ChipRole::Prefill)
        .collect();
    // Original request of each in-flight prefill leg, keyed by leg id.
    let mut handoff: HashMap<u64, Request> = HashMap::new();
    // Ids that entered the cluster as decode legs (role-aware recovery).
    let mut decode_ids: HashSet<u64> = HashSet::new();
    // Per-chip high-water mark into its record list (completion scan).
    let mut rec_cursor = vec![0usize; n];
    let mut handoffs = 0u64;
    let mut done = 0usize;
    let mut guard = 0u64;
    let par_threads = effective_sim_threads(cfg.sim_threads);

    while done < total {
        guard += 1;
        anyhow::ensure!(
            guard < 64_000_000,
            "cluster livelock: {done}/{total} requests done"
        );
        // Four event sources: the arrival stream, in-flight migrations,
        // the fault control plane, and the chips themselves. Process the
        // earliest; ties prefer admissions (arrival, then transit, then
        // control) so routing always sees every request released up to the
        // chips' next actionable cycle. Without faults the control source
        // is permanently idle and the ordering is bit-identical to the
        // three-source driver.
        let arr_t = stream
            .front()
            .map(|r| secs_to_cycles(r.arrival_s, freq))
            .unwrap_or(Cycle::MAX);
        let tra = transit
            .iter()
            .enumerate()
            .min_by_key(|(k, t)| (t.landing, *k))
            .map(|(k, t)| (k, t.landing));
        let tra_t = tra.map(|(_, c)| c).unwrap_or(Cycle::MAX);
        let act = (0..n)
            .filter(|&i| fault.as_ref().map_or(true, |f| f.health[i].up()))
            .filter_map(|i| scheds[i].next_action(&chips[i]).map(|t| (t, i)))
            .min();
        let act_t = act.map(|(t, _)| t).unwrap_or(Cycle::MAX);
        let ctrl_t = fault.as_ref().map_or(Cycle::MAX, |f| f.next_cycle());
        anyhow::ensure!(
            arr_t != Cycle::MAX
                || tra_t != Cycle::MAX
                || act_t != Cycle::MAX
                || ctrl_t != Cycle::MAX,
            "cluster deadlock: {done}/{total} requests done, nothing actionable"
        );

        if arr_t <= tra_t && arr_t <= ctrl_t && arr_t <= act_t {
            // Release one arrival and route it on current chip state.
            let req = stream.pop_front().expect("arr_t finite");
            let now = secs_to_cycles(req.arrival_s, freq);
            if let Some(f) = fault.as_mut() {
                // First-seen arrival, for honest post-recovery TTFT.
                f.orig_arrival.entry(req.id).or_insert(now);
            }
            // In-flight migrations count toward their destination's load,
            // so a transfer window cannot look like an idle chip (which
            // would flood it with duplicate migrations).
            let mut transit_load = vec![0usize; n];
            for t in &transit {
                transit_load[t.dst] += 1;
            }
            // Chips the frontend believes are alive: all of them without
            // faults, and until the heartbeat discovers a crash even the
            // dead one (that blind window is part of the fault model). In
            // a role-specialized fleet, arrivals (prefill legs) route only
            // among prefill-capable chips.
            let avail: Vec<usize> = match fault.as_ref() {
                Some(f) => (0..n).filter(|&i| f.health[i].believed_up()).collect(),
                None => (0..n).collect(),
            };
            let avail: Vec<usize> = if fleet_disagg {
                avail.into_iter().filter(|&i| prefill_ok[i]).collect()
            } else {
                avail
            };
            if avail.is_empty() {
                // Whole-cluster outage: hold the arrival for the next
                // restart, or shed it when nothing will ever come back.
                let f = fault.as_mut().expect("outage implies faults");
                match f.restart_pending() {
                    Some(rc) => {
                        let mut req = req;
                        let at = secs_to_cycles(req.arrival_s, freq).max(rc) + 1;
                        req.arrival_s = cycles_to_secs(at, freq);
                        let pos = stream
                            .iter()
                            .position(|r| r.arrival_s > req.arrival_s)
                            .unwrap_or(stream.len());
                        stream.insert(pos, req);
                    }
                    None => {
                        control.shed_requests += 1;
                        control.shed_by_class[req.priority.index()] += 1;
                        done += 1;
                    }
                }
                continue;
            }
            // SLO-aware admission control: when the saturation test for
            // this arrival's class fails — queue depth (including KV in
            // transit toward the chip) exceeds the class cap, or the chip
            // reports hard backpressure — the frontend sheds or defers
            // instead of queueing behind work the SLO cannot survive.
            // Low tolerates `queue_cap`, Normal twice that, High is never
            // shed; `ShedPolicy::None` skips the check entirely. The
            // global scope demands every chip be saturated (down chips
            // count as saturated); the per-chip scope routes first and
            // tests only the routed target below.
            let shed_active = cfg.shed != ShedPolicy::None && req.priority != Priority::High;
            let cap = match req.priority {
                Priority::Low => cfg.queue_cap,
                _ => cfg.queue_cap.saturating_mul(2),
            };
            if shed_active && cfg.shed_scope == ShedScope::Global {
                // Saturation ranges over the chips this arrival could
                // actually route to (decode-role chips never take
                // arrivals, so they cannot keep admission open).
                let overloaded = (0..n).filter(|&i| !fleet_disagg || prefill_ok[i]).all(|i| {
                    let dead = fault
                        .as_ref()
                        .map_or(false, |f| !f.health[i].believed_up());
                    dead || scheds[i].pending_work() + transit_load[i] >= cap
                        || scheds[i].backpressure() >= 0.999
                });
                if overloaded {
                    let base = defer_backoff(&scheds, &avail);
                    reject_arrival(
                        req,
                        cfg.shed,
                        base,
                        act_t.min(tra_t),
                        freq,
                        &mut stream,
                        &mut deferred,
                        &mut control,
                        &mut done,
                    );
                    continue;
                }
            }
            // Fleet PD disaggregation: admit only the *prefill leg* here —
            // the prompt plus the first generated token. The decode leg is
            // created at the leg's completion and handed off, with its
            // prompt KV, to a decode-capable chip over the interconnect.
            // Single-token requests have no decode leg and run whole, as
            // does a decode leg re-entering the stream via client
            // resubmission (its prefill leg already completed once;
            // splitting again would double-merge that leg's record).
            let req = if fleet_disagg
                && req.output_len >= 2
                && req.id & FLEET_LEG_BIT == 0
                && !decode_ids.contains(&req.id)
            {
                let mut leg = req;
                leg.id = req.id | FLEET_LEG_BIT;
                leg.output_len = 1;
                if let Some(f) = fault.as_mut() {
                    let a = *f.orig_arrival.get(&req.id).unwrap_or(&now);
                    f.orig_arrival.entry(leg.id).or_insert(a);
                }
                handoff.insert(leg.id, req);
                leg
            } else {
                req
            };
            let keys = req.block_keys(KV_BLOCK_TOKENS);
            let limit = (req.input_len as u64).saturating_sub(1);
            let probe = router.wants_prefix() && !keys.is_empty();
            let views: Vec<ChipView> = avail
                .iter()
                .map(|&i| {
                    let s = &scheds[i];
                    // A dead-but-undiscovered chip cannot stream KV out,
                    // so it never advertises a prefix match (no migration
                    // sources among the dead).
                    let alive = fault.as_ref().map_or(true, |f| f.health[i].up());
                    let hit = if probe && alive {
                        s.probe_prefix_tiered(&keys, limit, now)
                    } else {
                        TierMatch::default()
                    };
                    let mut pending = s.pending_work() + transit_load[i];
                    if let Some(f) = fault.as_ref() {
                        // Degraded chips advertise proportionally more
                        // load, so routers steer around them (identity at
                        // full capacity).
                        pending = ((pending as u64).saturating_mul(1000)
                            / f.health[i].capacity_milli())
                            as usize;
                    }
                    ChipView {
                        pending_work: pending,
                        kv_occupancy_milli: (s.kv_utilization() * 1000.0).round() as u64,
                        prefix_match: hit.total(),
                        prefix_sram: hit.sram_tokens,
                    }
                })
                .collect();
            let d = router.route(&req, &views);
            anyhow::ensure!(
                d.chip < avail.len(),
                "router returned chip {} of {}",
                d.chip,
                avail.len()
            );
            let target = avail[d.chip];
            if shed_active && cfg.shed_scope == ShedScope::PerChip {
                let saturated = views[d.chip].pending_work >= cap
                    || scheds[target].backpressure() >= 0.999;
                if saturated {
                    // The rejection is about *this* chip, and the deferred
                    // retry re-routes across the fleet — so back off by the
                    // target's own saturation, not the fleet-wide maximum.
                    let base = defer_backoff_from(scheds[target].backpressure());
                    reject_arrival(
                        req,
                        cfg.shed,
                        base,
                        act_t.min(tra_t),
                        freq,
                        &mut stream,
                        &mut deferred,
                        &mut control,
                        &mut done,
                    );
                    continue;
                }
            }
            match d.migrate_from {
                Some(src_v) if avail[src_v] != target && views[src_v].prefix_match > 0 => {
                    let src = avail[src_v];
                    // A migration of this prefix may already be in flight
                    // (co-arriving turns of one conversation while the
                    // holder stays overloaded): piggyback on it instead of
                    // paying a duplicate transfer of the same bytes.
                    let dup = transit
                        .iter()
                        .find(|t| !t.leg && !t.keys.is_empty() && t.keys.first() == keys.first())
                        .map(|t| (t.dst, t.landing));
                    // Piggybacked requests carry no seed keys (the
                    // original transit seeds the cache for both).
                    let (dst, landing, transit_keys) = match dup {
                        Some((dst, landing)) => (dst, landing, Vec::new()),
                        None => {
                            // Stream the matched prefix KV across the
                            // fabric; the request (and its seeded blocks)
                            // reach the target chip when the last byte
                            // lands.
                            let matched = views[src_v].prefix_match;
                            let bytes = matched * model.kv_bytes_per_token();
                            let landing = icn.transfer(src, target, bytes, now);
                            migrations += 1;
                            (target, landing, keys_prefix(&keys, matched))
                        }
                    };
                    // Admission is deferred to the landing instant so the
                    // request actually matches the migrated copy; the
                    // recorded arrival is rebased afterwards so TTFT
                    // charges the wait.
                    routed[dst] += 1;
                    migrated_log.push((req.id, now, dst));
                    let mut req = req;
                    req.arrival_s = req.arrival_s.max(cycles_to_secs(landing, freq));
                    transit.push(Transit {
                        landing,
                        dst,
                        req,
                        keys: transit_keys,
                        leg: false,
                    });
                }
                _ => {
                    routed[target] += 1;
                    scheds[target].enqueue(&mut chips[target], req);
                }
            }
        } else if tra_t <= ctrl_t && tra_t <= act_t {
            // A migrated prefix landed: seed the target chip's cache and
            // release the request there. Readiness is derived from the
            // request's (seconds-rounded) arrival so the float round-trip
            // can never land the admission one cycle before the seed.
            let (k, _) = tra.expect("tra_t finite");
            let t = transit.swap_remove(k);
            let dead = fault.as_ref().map_or(false, |f| !f.health[t.dst].up());
            if dead {
                // The destination died while the KV was in flight: the
                // transfer is lost with it, and the request enters the
                // recovery path with zero progress.
                let f = fault.as_mut().expect("dead chip implies faults");
                recover_lost(
                    f,
                    &mut control,
                    &mut done,
                    &mut stream,
                    freq,
                    Incomplete {
                        req: t.req,
                        prefilled: 0,
                        generated: 0,
                    },
                    t.landing,
                    t.landing,
                );
            } else {
                let ready = secs_to_cycles(t.req.arrival_s, freq).min(t.landing);
                scheds[t.dst].import_prefix(&t.keys, ready);
                scheds[t.dst].enqueue(&mut chips[t.dst], t.req);
            }
        } else if ctrl_t <= act_t {
            // Fault control plane: injections, heartbeat detections,
            // restarts, degradation expiries, and recovery retries.
            let (now, ev) = fault
                .as_mut()
                .expect("ctrl_t finite")
                .pop_next()
                .expect("ctrl_t finite");
            let f = fault.as_mut().expect("ctrl_t finite");
            match ev {
                Ctrl::Inject(idx) => {
                    let ev = f.schedule.events[idx];
                    let chip = ev.chip;
                    match ev.kind {
                        FaultKind::ChipCrash { restart_after_s } => {
                            if f.health[chip].up() {
                                f.health[chip].down_since = Some(now);
                                f.health[chip].detected = false;
                                f.stats.crashes += 1;
                                // Detection at the next heartbeat tick
                                // strictly after the crash.
                                let hb = secs_to_cycles(f.schedule.heartbeat_s, freq).max(1);
                                f.push((now / hb + 1) * hb, Ctrl::Detect { chip, crash: now });
                                if let Some(rs) = restart_after_s {
                                    let at = now + secs_to_cycles(rs, freq).max(1);
                                    f.push(at, Ctrl::Restart { chip });
                                }
                            }
                        }
                        FaultKind::LinkDegrade { factor, duration_s } => {
                            f.health[chip].link_factor = factor;
                            icn.set_degrade(chip, factor);
                            f.stats.degradations += 1;
                            let at = now + secs_to_cycles(duration_s, freq).max(1);
                            f.push(at, Ctrl::Expire { chip, hbm: false });
                        }
                        FaultKind::HbmThrottle { factor, duration_s } => {
                            f.health[chip].hbm_factor = factor;
                            if f.health[chip].up() {
                                chips[chip].set_hbm_throttle(factor);
                            }
                            f.stats.degradations += 1;
                            let at = now + secs_to_cycles(duration_s, freq).max(1);
                            f.push(at, Ctrl::Expire { chip, hbm: true });
                        }
                    }
                }
                Ctrl::Detect { chip, crash } => {
                    // Heartbeat probe: drain and recover the stranded work
                    // (skip when a pre-heartbeat restart already did).
                    if f.health[chip].down_since.is_some() && !f.health[chip].detected {
                        f.health[chip].detected = true;
                        f.stats.detect_cycles += now.saturating_sub(crash);
                        for inc in scheds[chip].drain_incomplete() {
                            recover_lost(
                                f,
                                &mut control,
                                &mut done,
                                &mut stream,
                                freq,
                                inc,
                                crash,
                                now,
                            );
                        }
                    }
                }
                Ctrl::Restart { chip } => {
                    if let Some(crash) = f.health[chip].down_since {
                        if !f.health[chip].detected {
                            // Restart outran the heartbeat: the stranded
                            // work is still discovered only now.
                            f.health[chip].detected = true;
                            f.stats.detect_cycles += now.saturating_sub(crash);
                            for inc in scheds[chip].drain_incomplete() {
                                recover_lost(
                                    f,
                                    &mut control,
                                    &mut done,
                                    &mut stream,
                                    freq,
                                    inc,
                                    crash,
                                    now,
                                );
                            }
                        }
                        // Cold restart: fresh chip, fresh scheduler, empty
                        // caches, rebuilt from this chip's own spec so a
                        // heterogeneous fleet keeps its silicon and role.
                        chips[chip] = ChipSim::new(cfg.fleet.chips[chip].hw.clone());
                        scheds[chip] = cfg.fleet.chips[chip].sched.build();
                        scheds[chip].prepare(&mut chips[chip], model, max_tokens)?;
                        if f.health[chip].hbm_factor < 1.0 {
                            // An unexpired HBM throttle survives a reboot.
                            chips[chip].set_hbm_throttle(f.health[chip].hbm_factor);
                        }
                        f.health[chip].down_since = None;
                        f.health[chip].detected = false;
                        f.stats.restarts += 1;
                    }
                }
                Ctrl::Expire { chip, hbm } => {
                    // Overlapping windows on one chip: last writer set the
                    // factor, earliest expiry restores it.
                    if hbm {
                        f.health[chip].hbm_factor = 1.0;
                        if f.health[chip].up() {
                            chips[chip].set_hbm_throttle(1.0);
                        }
                    } else {
                        f.health[chip].link_factor = 1.0;
                        icn.set_degrade(chip, 1.0);
                    }
                }
                Ctrl::Retry {
                    req,
                    attempt,
                    crash,
                    generated,
                } => {
                    let up: Vec<usize> = (0..n).filter(|&i| f.health[i].up()).collect();
                    // Role-aware retry: a prefill leg (or a request whose
                    // decode leg has not been created yet) goes back to a
                    // prefill-capable chip, a decode leg to a
                    // decode-capable one. If no capable chip is up, fall
                    // back to any up chip rather than shed — a wrong-role
                    // chip can still serve the request, just suboptimally.
                    let up: Vec<usize> = if fleet_disagg && !up.is_empty() {
                        let wants_prefill =
                            req.id & FLEET_LEG_BIT != 0 || !decode_ids.contains(&req.id);
                        let capable: Vec<usize> = up
                            .iter()
                            .copied()
                            .filter(|&i| if wants_prefill { prefill_ok[i] } else { decode_ok[i] })
                            .collect();
                        if capable.is_empty() {
                            up
                        } else {
                            capable
                        }
                    } else {
                        up
                    };
                    if up.is_empty() {
                        match f.restart_pending() {
                            // Hold the retry (same attempt) for the next
                            // restart; the schedule is finite, so this
                            // terminates.
                            Some(rc) => f.push(
                                rc.max(now) + 1,
                                Ctrl::Retry {
                                    req,
                                    attempt,
                                    crash,
                                    generated,
                                },
                            ),
                            None => {
                                control.shed_requests += 1;
                                control.shed_by_class[req.priority.index()] += 1;
                                f.stats.recovery_shed += 1;
                                done += 1;
                            }
                        }
                    } else {
                        // KV-aware placement: prefer the chip holding the
                        // longest surviving cached prefix of this prompt;
                        // ties and misses go least-loaded, then lowest
                        // index.
                        let keys = req.block_keys(KV_BLOCK_TOKENS);
                        let limit = (req.input_len as u64).saturating_sub(1);
                        let (std::cmp::Reverse(restored), _, c) = up
                            .iter()
                            .map(|&i| {
                                let hit = if keys.is_empty() {
                                    0
                                } else {
                                    scheds[i].probe_prefix_tiered(&keys, limit, now).total()
                                };
                                (std::cmp::Reverse(hit), scheds[i].pending_work(), i)
                            })
                            .min()
                            .expect("up is non-empty");
                        let restored = restored.min(limit);
                        let recomputed = (req.input_len as u64 - restored) + generated;
                        let mut req = req;
                        req.arrival_s = cycles_to_secs(now, freq);
                        if attempt == 1 {
                            f.stats.recovered += 1;
                        }
                        f.stats.retries += 1;
                        f.stats.tokens_restored += restored;
                        f.stats.tokens_recomputed += recomputed;
                        f.recovery.push(RecoveryRecord {
                            id: req.id,
                            retries: attempt,
                            recovery_cycles: now.saturating_sub(crash),
                            tokens_recomputed: recomputed,
                            tokens_restored: restored,
                        });
                        routed[c] += 1;
                        scheds[c].enqueue(&mut chips[c], req);
                    }
                }
            }
        } else if par_threads > 1 && !fleet_disagg {
            // Conservative-window parallel stepping (`--sim-threads N`).
            // Reaching this branch means the earliest event is a chip
            // action *strictly* below every other source (the branch chain
            // above admits arrivals/transit/control on ties), so every
            // chip action before `window = min(arr_t, tra_t, ctrl_t)` is
            // chip-local: in this fault-free-or-static window the act arm
            // touches only `scheds[i]`/`chips[i]`/`per_chip[i]` plus the
            // commutative `done` counter, and chip health cannot change
            // (health transitions are control events, which are >= the
            // window by construction). Draining each chip independently
            // until its next action reaches the window therefore performs
            // exactly the act events the sequential loop would, in the
            // same per-chip order — the rollup is byte-identical. The
            // fleet-disagg act arm routes handoffs through shared state,
            // so role-specialized fleets keep the sequential path.
            let window = arr_t.min(tra_t).min(ctrl_t);
            let up: Vec<bool> = (0..n)
                .map(|i| fault.as_ref().map_or(true, |f| f.health[i].up()))
                .collect();
            let (retired, steps) = drain_window(
                &mut scheds,
                &mut chips,
                &mut per_chip,
                &up,
                window,
                par_threads,
                model,
            )?;
            done += retired;
            // Mirror the sequential guard: one tick per drained act event
            // (the loop head already charged this pass's tick).
            guard += steps.saturating_sub(1);
        } else {
            let (_, i) = act.expect("act_t finite");
            done += scheds[i].step(&mut chips[i], model, &mut per_chip[i])?;
            // Fleet PD disaggregation: scan records this step finished for
            // prefill legs, and hand each one's decode leg — with its
            // prompt KV — to a decode-capable chip over the interconnect.
            if fleet_disagg {
                while rec_cursor[i] < per_chip[i].records().len() {
                    let r = per_chip[i].records()[rec_cursor[i]];
                    rec_cursor[i] += 1;
                    if r.id & FLEET_LEG_BIT == 0 {
                        continue;
                    }
                    let Some(orig) = handoff.remove(&r.id) else {
                        continue;
                    };
                    // The decode leg resumes the original request one
                    // token in. Its synthetic conversation prefix covers
                    // the whole prompt so the transit-seeded KV blocks
                    // match at enqueue; the leg-tagged `conv_id` keeps
                    // that coverage private to this request (genuine
                    // group-prefix sharing still uses `group_id`).
                    let mut leg = orig;
                    leg.output_len = orig.output_len - 1;
                    leg.prefix = Prefix {
                        group_id: orig.prefix.group_id,
                        group_tokens: orig.prefix.group_tokens,
                        conv_id: orig.id | FLEET_LEG_BIT,
                        conv_tokens: orig.input_len as u32,
                    };
                    // Least-loaded believed-up decode-capable chip, with
                    // in-flight transfers counted toward their target; if
                    // none is believed up, any up chip beats discarding a
                    // finished prefill.
                    let mut transit_load = vec![0usize; n];
                    for t in &transit {
                        transit_load[t.dst] += 1;
                    }
                    let believed = |j: usize| {
                        fault.as_ref().map_or(true, |f| f.health[j].believed_up())
                    };
                    let dst = (0..n)
                        .filter(|&j| decode_ok[j] && believed(j))
                        .min_by_key(|&j| (scheds[j].pending_work() + transit_load[j], j))
                        .or_else(|| {
                            (0..n)
                                .filter(|&j| believed(j))
                                .min_by_key(|&j| {
                                    (scheds[j].pending_work() + transit_load[j], j)
                                })
                        })
                        .expect("the chip that just stepped is believed up");
                    let keys = leg.block_keys(KV_BLOCK_TOKENS);
                    // Prompt KV plus the first generated token's entry.
                    let bytes = (orig.input_len as u64 + 1) * model.kv_bytes_per_token();
                    let landing = icn.transfer(i, dst, bytes, act_t.max(r.finish));
                    leg.arrival_s = cycles_to_secs(landing, freq);
                    decode_ids.insert(orig.id);
                    routed[dst] += 1;
                    handoffs += 1;
                    // The decode leg is a new unit of work the loop must
                    // wait for (`total` grows only here, never at the
                    // split, so a recovery-shed prefill leg cannot strand
                    // the loop waiting on a leg that will never exist).
                    total += 1;
                    transit.push(Transit {
                        landing,
                        dst,
                        req: leg,
                        keys,
                        leg: true,
                    });
                }
            }
        }
    }

    // Migrated requests were admitted at their KV-landing instant;
    // restore their true frontend arrivals so TTFT includes the transfer
    // wait instead of hiding it.
    for &(id, arrival, dst) in &migrated_log {
        per_chip[dst].rebase_arrival(id, arrival);
    }
    // Recovered (and resubmitted) requests were re-admitted long after
    // their true arrivals; rebase so TTFT honestly charges the downtime,
    // the detection lag, and the redone work.
    if let Some(f) = &fault {
        for &(id, arrival) in &f.rebase {
            for m in per_chip.iter_mut() {
                if m.rebase_arrival(id, arrival) {
                    break;
                }
            }
        }
    }
    // Fold each prefill-leg record into its decode leg so every original
    // request surfaces as exactly one record: decode-leg finish, true
    // (earliest) arrival and first token, summed output tokens. A prefill
    // leg whose decode leg was recovery-shed stays unmerged and is
    // dropped — the request already counted once as shed. Runs after both
    // rebase passes so the merge sees final arrivals.
    if fleet_disagg {
        let mut legs: Vec<RequestRecord> = Vec::new();
        for m in per_chip.iter_mut() {
            legs.extend(m.drain_records(|r| r.id & FLEET_LEG_BIT != 0));
        }
        legs.sort_by_key(|r| r.id);
        for p in legs {
            let id = p.id & !FLEET_LEG_BIT;
            let merged = per_chip.iter_mut().any(|m| m.merge_handoff(id, &p));
            let _ = merged; // unmerged = decode leg shed; drop the orphan
        }
    }
    for (i, s) in scheds.iter().enumerate() {
        let mut hw = CacheStats::default();
        s.collect_cache_stats(&mut hw);
        per_chip[i].cache.merge(&hw);
    }
    let (fault_stats, mut recovery) = match fault {
        Some(f) => (f.stats, f.recovery),
        None => (FaultStats::default(), Vec::new()),
    };
    recovery.sort_by_key(|r| (r.id, r.retries));
    Ok(ClusterMetrics {
        per_chip,
        routed,
        migrations,
        control,
        interconnect: icn.stats(),
        handoffs,
        faults: fault_stats,
        recovery,
        freq_mhz: freq,
    })
}

/// Worker-thread count actually used by the cluster driver: an explicit
/// [`ClusterConfig::sim_threads`] wins; a default of 1 can be overridden
/// by the `NPUSIM_SIM_THREADS` env var (how CI runs the whole suite over
/// the parallel path without touching call sites). Always at least 1.
pub fn effective_sim_threads(cfg_threads: usize) -> usize {
    if cfg_threads != 1 {
        return cfg_threads.max(1);
    }
    std::env::var("NPUSIM_SIM_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Advance every up chip whose next action is strictly before `window`,
/// spreading chips round-robin over `threads` scoped worker threads.
///
/// Safety of the parallelism is structural, not locked: each lane owns a
/// disjoint set of `(scheduler, chip, metrics)` triples by `&mut`
/// borrow-splitting, and within the window a chip's actions touch nothing
/// outside its triple (see the call-site invariant). Lanes are joined in
/// index order and their retirement/step counts summed, so the result —
/// like the per-chip state — is independent of thread interleaving.
fn drain_window(
    scheds: &mut [Box<dyn Scheduler>],
    chips: &mut [ChipSim],
    per_chip: &mut [Metrics],
    up: &[bool],
    window: Cycle,
    threads: usize,
    model: &ModelConfig,
) -> anyhow::Result<(usize, u64)> {
    let mut lanes: Vec<Vec<(&mut Box<dyn Scheduler>, &mut ChipSim, &mut Metrics)>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, ((s, c), m)) in scheds
        .iter_mut()
        .zip(chips.iter_mut())
        .zip(per_chip.iter_mut())
        .enumerate()
    {
        if up[i] {
            lanes[i % threads].push((s, c, m));
        }
    }
    let results: Vec<anyhow::Result<(usize, u64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = lanes
            .into_iter()
            .map(|lane| {
                scope.spawn(move || {
                    let (mut retired, mut steps) = (0usize, 0u64);
                    for (s, c, m) in lane {
                        while s.next_action(c).is_some_and(|t| t < window) {
                            steps += 1;
                            anyhow::ensure!(
                                steps < 64_000_000,
                                "cluster livelock inside a parallel window"
                            );
                            retired += s.step(c, model, m)?;
                        }
                    }
                    Ok((retired, steps))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sim worker thread panicked"))
            .collect()
    });
    let mut retired = 0usize;
    let mut steps = 0u64;
    for r in results {
        let (lane_retired, lane_steps) = r?;
        retired += lane_retired;
        steps += lane_steps;
    }
    Ok((retired, steps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PrefixSharing;
    use crate::serving::pd_fusion::FusionConfig;

    fn views(loads: &[usize]) -> Vec<ChipView> {
        loads
            .iter()
            .map(|&pending_work| ChipView {
                pending_work,
                kv_occupancy_milli: 0,
                prefix_match: 0,
                prefix_sram: 0,
            })
            .collect()
    }

    fn req() -> Request {
        Request {
            id: 0,
            arrival_s: 0.0,
            input_len: 128,
            output_len: 8,
            prefix: crate::serving::request::Prefix::default(),
            priority: Priority::Normal,
        }
    }

    #[test]
    fn router_policy_parses_and_names() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(
            RouterPolicy::parse("least-loaded").unwrap(),
            RouterPolicy::LeastLoaded
        );
        assert_eq!(
            RouterPolicy::parse("prefix").unwrap(),
            RouterPolicy::PrefixAware
        );
        assert!(RouterPolicy::parse("magic").is_err());
        for p in RouterPolicy::ALL {
            assert_eq!(p.build(0).name(), p.name());
        }
    }

    #[test]
    fn round_robin_cycles_chips() {
        let mut r = RouterPolicy::RoundRobin.build(0);
        let v = views(&[5, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &v).chip).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_breaks_ties_on_kv_then_index() {
        let mut r = RouterPolicy::LeastLoaded.build(0);
        assert_eq!(r.route(&req(), &views(&[3, 1, 2])).chip, 1);
        let mut v = views(&[2, 2, 2]);
        v[1].kv_occupancy_milli = 500;
        assert_eq!(r.route(&req(), &v).chip, 0);
    }

    #[test]
    fn prefix_router_follows_the_longest_ready_match() {
        let mut r = RouterPolicy::PrefixAware.build(8);
        let mut v = views(&[0, 3, 3]);
        v[1].prefix_match = 512;
        v[2].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 2);
        assert_eq!(d.migrate_from, None);
        // No match anywhere: least-loaded fallback.
        assert_eq!(r.route(&req(), &views(&[4, 1, 2])).chip, 1);
    }

    #[test]
    fn prefix_router_prefers_fast_tier_matches_at_equal_length() {
        // Two chips hold the same-length match, but chip 2's is entirely
        // SRAM-resident while chip 1's is HBM-demoted: the router must
        // pick the hit that shares for free over the one that pays a
        // promotion stream.
        let mut r = RouterPolicy::PrefixAware.build(8);
        let mut v = views(&[1, 1, 1]);
        v[1].prefix_match = 512; // all demoted (prefix_sram 0)
        v[2].prefix_match = 512;
        v[2].prefix_sram = 512;
        assert_eq!(r.route(&req(), &v).chip, 2);
        // Length still dominates tier quality.
        v[1].prefix_match = 2048;
        assert_eq!(r.route(&req(), &v).chip, 1);
    }

    #[test]
    fn prefix_router_migrates_off_an_overloaded_holder() {
        let mut r = RouterPolicy::PrefixAware.build(4);
        let mut v = views(&[20, 0, 1]);
        v[0].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 1);
        assert_eq!(d.migrate_from, Some(0));
        // Within the gap: stay on the holder.
        let mut v = views(&[3, 0, 1]);
        v[0].prefix_match = 1024;
        let d = r.route(&req(), &v);
        assert_eq!(d.chip, 0);
        assert_eq!(d.migrate_from, None);
    }

    #[test]
    fn cluster_serves_a_small_workload_on_every_router() {
        let model = ModelConfig::qwen3_4b();
        let mut w = WorkloadConfig::shared_prefix(8);
        w.prefix = Some(PrefixSharing {
            n_groups: 2,
            shared_prefix_len: 256,
            turns: 2,
            think_time_s: 1.0,
        });
        for router in RouterPolicy::ALL {
            let cfg = ClusterConfig::new(
                ChipConfig::large_core(),
                2,
                SchedulerConfig::Fusion(FusionConfig {
                    prefix_cache: true,
                    ..FusionConfig::default()
                }),
                router,
            );
            let cm = simulate_cluster(&cfg, &model, &w)
                .unwrap_or_else(|e| panic!("{} failed: {e:#}", router.name()));
            assert_eq!(cm.n_requests(), 8, "{}", router.name());
            assert_eq!(cm.routed.iter().sum::<usize>(), 8, "{}", router.name());
            let agg = cm.aggregate();
            assert_eq!(agg.n_requests(), 8);
            for r in agg.records() {
                assert!(r.first_token >= r.arrival, "{}: {r:?}", router.name());
                assert!(r.finish >= r.first_token, "{}: {r:?}", router.name());
            }
        }
    }

    #[test]
    fn single_chip_cluster_matches_the_batch_driver() {
        // With one chip and any router, streamed admission must reproduce
        // the single-chip simulate_requests timeline record for record
        // (same scheduler, same arrival order, same pipe assignment).
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6).with_seed(3);
        let reqs = request::generate(&w);
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let cm = simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let mut sched = crate::serving::scheduler::FusionScheduler::new(FusionConfig::default());
        let m = crate::serving::scheduler::simulate_requests(&mut chip, &model, reqs, &mut sched)
            .unwrap();
        let mut a = cm.aggregate().records().to_vec();
        let mut b = m.records().to_vec();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        assert_eq!(a, b);
    }

    #[test]
    fn shed_policy_parses_and_names() {
        assert_eq!(ShedPolicy::parse("none").unwrap(), ShedPolicy::None);
        assert_eq!(ShedPolicy::parse("drop").unwrap(), ShedPolicy::Drop);
        assert_eq!(ShedPolicy::parse("defer").unwrap(), ShedPolicy::Defer);
        assert!(ShedPolicy::parse("maybe").is_err());
        for p in [ShedPolicy::None, ShedPolicy::Drop, ShedPolicy::Defer] {
            assert_eq!(ShedPolicy::parse(p.name()).unwrap(), p);
        }
    }

    /// A burst of co-arriving requests with mixed classes against a tiny
    /// queue cap: the frontend must shed, sheds must hit the lower classes
    /// only, and completions + sheds must cover every request exactly once.
    #[test]
    fn drop_policy_sheds_low_classes_and_conserves_requests() {
        let model = ModelConfig::qwen3_4b();
        let mut reqs: Vec<Request> = (0..12)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len: 2048,
                output_len: 8,
                prefix: crate::serving::request::Prefix::default(),
                priority: match i % 3 {
                    0 => Priority::High,
                    1 => Priority::Low,
                    _ => Priority::Normal,
                },
            })
            .collect();
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Drop, 1);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        let shed = cm.shed_requests() as usize;
        assert!(shed > 0, "cap 1 under a 12-request burst must shed");
        assert_eq!(cm.n_requests() + shed, 12);
        // High is never shed; every High request completes.
        assert_eq!(cm.control.shed_by_class[Priority::High.index()], 0);
        let agg = cm.aggregate();
        assert_eq!(agg.n_requests_of(Priority::High), 4);
        assert_eq!(agg.control.shed_requests, cm.control.shed_requests);
    }

    /// Defer re-times arrivals instead of dropping them outright; under a
    /// transient burst everything still completes (possibly after
    /// deferrals), and sustained overload degrades to sheds rather than
    /// recycling arrivals forever.
    #[test]
    fn defer_policy_retries_then_completes_or_sheds() {
        let model = ModelConfig::qwen3_4b();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len: 2048,
                output_len: 8,
                prefix: crate::serving::request::Prefix::default(),
                priority: if i % 2 == 0 {
                    Priority::Normal
                } else {
                    Priority::Low
                },
            })
            .collect();
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            1,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Defer, 2);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert!(cm.control.deferrals > 0, "cap 2 burst must defer");
        assert_eq!(cm.n_requests() + cm.shed_requests() as usize, 8);
    }

    /// `ShedPolicy::None` leaves the run bit-identical to a driver build
    /// that never had admission control (the golden suite pins the default
    /// byte-stream; this pins it at the config level).
    #[test]
    fn shed_none_matches_the_legacy_admission_path() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6).with_seed(11);
        let reqs = request::generate(&w);
        let base = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let a = simulate_cluster_requests(&base, &model, reqs.clone()).unwrap();
        // Same config built through the builder with shedding explicitly
        // off must agree record for record.
        let b_cfg = base.clone().with_shed(ShedPolicy::None, 1);
        let b = simulate_cluster_requests(&b_cfg, &model, reqs).unwrap();
        assert_eq!(a.aggregate().records(), b.aggregate().records());
        assert_eq!(a.control, b.control);
        assert_eq!(a.control.shed_requests, 0);
    }

    #[test]
    fn mixed_scheduler_cluster_requires_matching_lengths() {
        let model = ModelConfig::qwen3_4b();
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::RoundRobin,
        );
        let err = simulate_cluster_mixed(&cfg, &model, Vec::new(), Vec::new());
        assert!(err.is_err());
    }

    #[test]
    fn shed_scope_parses_and_names() {
        assert_eq!(ShedScope::parse("global").unwrap(), ShedScope::Global);
        assert_eq!(ShedScope::parse("per-chip").unwrap(), ShedScope::PerChip);
        assert!(ShedScope::parse("everywhere").is_err());
        for s in [ShedScope::Global, ShedScope::PerChip] {
            assert_eq!(ShedScope::parse(s.name()).unwrap(), s);
        }
        assert_eq!(ShedScope::default(), ShedScope::Global);
    }

    fn fault_reqs(n: u64, input_len: usize, output_len: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len,
                output_len,
                prefix: crate::serving::request::Prefix::default(),
                priority: Priority::Normal,
            })
            .collect()
    }

    /// An empty fault schedule must leave the run bit-identical to the
    /// fault-free driver: the control event source stays permanently idle.
    #[test]
    fn empty_fault_schedule_is_bit_identical() {
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(6).with_seed(11);
        let reqs = request::generate(&w);
        let base = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let a = simulate_cluster_requests(&base, &model, reqs.clone()).unwrap();
        let faulty = base.clone().with_faults(FaultSchedule::new(Vec::new()));
        let b = simulate_cluster_requests(&faulty, &model, reqs).unwrap();
        assert_eq!(a.aggregate().records(), b.aggregate().records());
        assert_eq!(a.control, b.control);
        assert_eq!(b.faults, FaultStats::default());
        assert!(b.recovery.is_empty());
    }

    #[test]
    fn parallel_window_stepping_is_bit_identical() {
        // The tentpole invariant: any `--sim-threads N` produces the same
        // rollup as the sequential loop, per chip and per record.
        let model = ModelConfig::qwen3_4b();
        let w = WorkloadConfig::sharegpt_like(10).with_seed(5);
        let reqs = request::generate(&w);
        let base = ClusterConfig::new(
            ChipConfig::large_core(),
            4,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        );
        let seq = simulate_cluster_requests(&base, &model, reqs.clone()).unwrap();
        for threads in [2, 8] {
            let mut cfg = base.clone();
            cfg.sim_threads = threads;
            let par = simulate_cluster_requests(&cfg, &model, reqs.clone()).unwrap();
            assert_eq!(
                format!("{seq:?}"),
                format!("{par:?}"),
                "threads={threads} diverged from the sequential schedule"
            );
        }
    }

    #[test]
    fn effective_sim_threads_prefers_explicit_config() {
        // An explicit non-default config wins regardless of environment;
        // the floor is 1. (The env fallback itself is exercised by the CI
        // matrix leg, not here — tests must not mutate global env.)
        assert_eq!(effective_sim_threads(4), 4);
        assert_eq!(effective_sim_threads(0), 1);
    }

    /// A mid-run crash with no restart: the stranded requests recover onto
    /// the surviving chip, every request still completes exactly once with
    /// its original token counts, and TTFT charges the downtime.
    #[test]
    fn crash_recovers_stranded_requests_on_the_surviving_chip() {
        let model = ModelConfig::qwen3_4b();
        let reqs = fault_reqs(8, 2048, 16);
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::RoundRobin,
        )
        .with_faults(
            FaultSchedule::parse("crash:0@0.005")
                .unwrap()
                .with_retries(8, 0.002),
        );
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.faults.crashes, 1);
        assert_eq!(cm.faults.restarts, 0);
        assert!(cm.conserves(8), "completed {} shed {}", cm.n_requests(), cm.shed_requests());
        assert!(cm.faults.recovered > 0, "a 5ms crash must strand work: {:?}", cm.faults);
        assert!(!cm.recovery.is_empty());
        let agg = cm.aggregate();
        for r in agg.records() {
            assert_eq!(r.input_tokens, 2048, "{r:?}");
            assert_eq!(r.output_tokens, 16, "{r:?}");
            assert!(r.first_token >= r.arrival, "{r:?}");
        }
        for rec in &cm.recovery {
            assert!(rec.retries >= 1 && rec.recovery_cycles > 0, "{rec:?}");
            assert!(rec.tokens_recomputed + rec.tokens_restored >= 2048, "{rec:?}");
        }
        // Detection is heartbeat-bounded.
        assert!(cm.faults.mean_detect_s(500.0) <= crate::serving::faults::DEFAULT_HEARTBEAT_S + 1e-9);
    }

    /// A crash with a restart window brings the chip back cold; later
    /// arrivals use it again and everything conserves.
    #[test]
    fn crashed_chip_restarts_and_serves_again() {
        let model = ModelConfig::qwen3_4b();
        let mut reqs = fault_reqs(8, 1024, 8);
        // A late tail after the restart point.
        for (k, r) in reqs.iter_mut().enumerate().skip(6) {
            r.arrival_s = 0.2 + 0.01 * (k - 6) as f64;
        }
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::RoundRobin,
        )
        .with_faults(FaultSchedule::parse("crash:0@0.004:0.05").unwrap().with_retries(8, 0.002));
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.faults.crashes, 1);
        assert_eq!(cm.faults.restarts, 1);
        assert!(cm.conserves(8), "completed {} shed {}", cm.n_requests(), cm.shed_requests());
    }

    /// Link and HBM degradation windows slow chips down without losing
    /// work: no retries, no sheds, full completion, and the windows are
    /// restored on expiry (stats count both injections).
    #[test]
    fn degradation_windows_conserve_all_requests() {
        let model = ModelConfig::qwen3_4b();
        let reqs = fault_reqs(6, 512, 8);
        let cfg = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_faults(FaultSchedule::parse("link:0@0.001:0.25:0.1;hbm:1@0.002:0.5:0.1").unwrap());
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.faults.degradations, 2);
        assert_eq!(cm.faults.crashes, 0);
        assert_eq!(cm.shed_requests(), 0);
        assert_eq!(cm.n_requests(), 6);
        assert!(cm.recovery.is_empty());
    }

    /// Per-chip shed scope keeps admitting onto lightly loaded chips while
    /// one chip is saturated; work is conserved either way.
    #[test]
    fn per_chip_scope_conserves_and_sheds_no_more_than_global() {
        let model = ModelConfig::qwen3_4b();
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request {
                id: i,
                arrival_s: 0.0001 * i as f64,
                input_len: 2048,
                output_len: 8,
                prefix: crate::serving::request::Prefix::default(),
                priority: if i % 2 == 0 { Priority::Normal } else { Priority::Low },
            })
            .collect();
        let base = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            SchedulerConfig::Fusion(FusionConfig::default()),
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Drop, 2);
        let global = simulate_cluster_requests(&base, &model, reqs.clone()).unwrap();
        let per_chip = simulate_cluster_requests(
            &base.clone().with_shed_scope(ShedScope::PerChip),
            &model,
            reqs,
        )
        .unwrap();
        assert!(global.conserves(12));
        assert!(per_chip.conserves(12));
        // Least-loaded routing targets the lightest chip, so the per-chip
        // test is at least as permissive as demanding every chip be full.
        assert!(
            per_chip.shed_requests() <= global.shed_requests(),
            "per-chip shed {} vs global {}",
            per_chip.shed_requests(),
            global.shed_requests()
        );
    }

    /// Satellite contract of the API redesign: the legacy positional
    /// constructor and its `with_*` chain are thin shims over the builder,
    /// so the two paths must agree field for field.
    #[test]
    fn legacy_constructors_equal_builder_field_for_field() {
        let sched = SchedulerConfig::Fusion(FusionConfig::default());
        let legacy = ClusterConfig::new(
            ChipConfig::large_core(),
            2,
            sched,
            RouterPolicy::LeastLoaded,
        )
        .with_shed(ShedPolicy::Drop, 4)
        .with_shed_scope(ShedScope::PerChip)
        .with_faults(FaultSchedule::parse("crash:0@0.005").unwrap());
        let built = ClusterConfig::builder(FleetSpec::homogeneous(
            ChipConfig::large_core(),
            2,
            sched,
        ))
        .router(RouterPolicy::LeastLoaded)
        .shed(ShedPolicy::Drop, 4)
        .shed_scope(ShedScope::PerChip)
        .faults(FaultSchedule::parse("crash:0@0.005").unwrap())
        .build();
        assert_eq!(format!("{legacy:?}"), format!("{built:?}"));
    }

    fn fleet_disagg_cfg(n_prefill: usize, n_decode: usize) -> ClusterConfig {
        use crate::serving::fleet::ChipSpec;
        let sched = SchedulerConfig::Fusion(FusionConfig {
            prefix_cache: true,
            ..FusionConfig::default()
        });
        let mut chips = Vec::new();
        for _ in 0..n_prefill {
            chips.push(
                ChipSpec::new(ChipConfig::prefill_optimized(), sched)
                    .with_role(ChipRole::Prefill),
            );
        }
        for _ in 0..n_decode {
            chips.push(
                ChipSpec::new(ChipConfig::decode_optimized(), sched)
                    .with_role(ChipRole::Decode),
            );
        }
        ClusterConfig::builder(FleetSpec::new(chips))
            .router(RouterPolicy::LeastLoaded)
            .build()
    }

    /// A role-specialized fleet splits every multi-token request into a
    /// prefill leg and a decode leg joined by a cross-chip KV handoff; the
    /// merged records must cover every request exactly once with its exact
    /// token counts, and the handoff bytes must actually cross the fabric.
    #[test]
    fn fleet_disaggregation_hands_off_and_conserves_tokens() {
        let model = ModelConfig::qwen3_4b();
        let reqs = fault_reqs(6, 512, 8);
        let cfg = fleet_disagg_cfg(1, 1);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.handoffs, 6);
        assert!(cm.conserves(6), "completed {} shed {}", cm.n_requests(), cm.shed_requests());
        // Prefill legs all admit on chip 0, decode legs all land on chip 1.
        assert_eq!(cm.routed, vec![6, 6]);
        assert!(cm.interconnect.transfers >= 6);
        assert!(cm.interconnect.bytes > 0);
        let agg = cm.aggregate();
        let mut ids: Vec<u64> = agg.records().iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>(), "one merged record per request");
        for r in agg.records() {
            assert_eq!(r.input_tokens, 512, "{r:?}");
            assert_eq!(r.output_tokens, 8, "{r:?}");
            assert!(r.first_token >= r.arrival && r.finish >= r.first_token, "{r:?}");
        }
    }

    /// Single-token outputs have no decode leg: they run whole on a
    /// prefill-capable chip, and the fleet performs no handoff for them.
    #[test]
    fn fleet_disaggregation_keeps_single_token_requests_whole() {
        let model = ModelConfig::qwen3_4b();
        let reqs = fault_reqs(4, 256, 1);
        let cfg = fleet_disagg_cfg(1, 1);
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.handoffs, 0);
        assert_eq!(cm.routed, vec![4, 0]);
        assert!(cm.conserves(4));
        for r in cm.aggregate().records() {
            assert_eq!(r.output_tokens, 1, "{r:?}");
        }
    }

    /// Crashing a decode chip mid-run must not break exactly-once token
    /// conservation: stranded decode legs recover onto the surviving
    /// decode chip and every merged record keeps its exact token counts.
    #[test]
    fn decode_chip_crash_conserves_tokens_across_handoff() {
        let model = ModelConfig::qwen3_4b();
        let reqs = fault_reqs(8, 512, 16);
        let mut cfg = fleet_disagg_cfg(1, 2);
        // Chip 1 is the first decode chip; crash it while decode legs run.
        cfg = cfg.with_faults(
            FaultSchedule::parse("crash:1@0.01").unwrap().with_retries(8, 0.002),
        );
        let cm = simulate_cluster_requests(&cfg, &model, reqs).unwrap();
        assert_eq!(cm.faults.crashes, 1);
        assert!(cm.conserves(8), "completed {} shed {}", cm.n_requests(), cm.shed_requests());
        assert!(cm.handoffs >= 8, "every request hands off once: {}", cm.handoffs);
        for r in cm.aggregate().records() {
            assert_eq!(r.input_tokens, 512, "{r:?}");
            assert_eq!(r.output_tokens, 16, "{r:?}");
            assert!(r.first_token >= r.arrival && r.finish >= r.first_token, "{r:?}");
        }
    }

    /// Reserved-bit hygiene: the driver rejects trace ids that collide
    /// with the fleet leg tag instead of silently mis-merging them.
    #[test]
    fn driver_rejects_ids_using_the_reserved_leg_bit() {
        let model = ModelConfig::qwen3_4b();
        let mut reqs = fault_reqs(1, 64, 2);
        reqs[0].id |= FLEET_LEG_BIT;
        let cfg = fleet_disagg_cfg(1, 1);
        assert!(simulate_cluster_requests(&cfg, &model, reqs).is_err());
    }
}
