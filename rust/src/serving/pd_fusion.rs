//! PD fusion (§4.3.2): every worker pipeline co-locates prefill and decode.
//!
//! The scheduler gives each iteration a fixed token **budget**: a decode
//! step consumes one unit, a prefill chunk consumes `chunk` units. Decode
//! steps are admitted first (they bound TBT); leftover budget is assigned
//! to chunked prefill (SARATHI-style), so prefill never stalls decoding by
//! more than one chunk.
//!
//! The policy is implemented by
//! [`FusionScheduler`](crate::serving::scheduler::FusionScheduler) behind
//! the unified [`Scheduler`](crate::serving::scheduler::Scheduler) trait
//! (shared tick machinery in `scheduler::pipe`); the free functions here
//! are convenience wrappers kept for the original call sites.

use crate::config::{ModelConfig, WorkloadConfig};
use crate::model::memo::SimLevel;
use crate::parallel::partition::PartitionStrategy;
use crate::parallel::placement::Placement;
use crate::parallel::plan::{DeploymentPlan, PdMode, SpecConfig};
use crate::serving::metrics::Metrics;
use crate::serving::request::Request;
use crate::serving::scheduler::{self, FusionScheduler};
use crate::sim::chip::ChipSim;

/// PD-fusion serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct FusionConfig {
    /// TP degree of each pipeline stage.
    pub tp: usize,
    /// Pipeline stages (fewer stages = more layers and more DP pipelines).
    pub stages: usize,
    pub placement: Placement,
    /// Partition strategy for large-M GEMMs (and, with `m_threshold` 0,
    /// for everything — the pre-plan behaviour).
    pub strategy: PartitionStrategy,
    /// Partition strategy GEMMs below `m_threshold` fall back to
    /// (Fig. 9's phase-aware switch; only read when `m_threshold > 0`).
    pub small_m_strategy: PartitionStrategy,
    /// Per-GEMM M threshold of the phase switch; `0` = static strategy.
    pub m_threshold: u64,
    /// Chunked-prefill chunk size in tokens.
    pub chunk: usize,
    /// Per-iteration token budget (decode=1 unit, prefill chunk=`chunk`).
    pub budget: usize,
    /// Max concurrent requests per pipeline.
    pub max_batch: usize,
    /// SRAM remainder split between KV and weights.
    pub kv_share: f64,
    /// Prefix-sharing KV caching: admissions match their longest cached
    /// prompt prefix and skip those prefill chunks (off = legacy bit-exact
    /// behaviour).
    pub prefix_cache: bool,
    /// Two-tier prefix cache: SRAM pressure demotes cold prefix blocks to
    /// a bounded HBM region instead of dropping them; hits on demoted
    /// blocks re-promote at charged HBM→SRAM cost. Requires
    /// `prefix_cache`; off = single-tier bit-exact behaviour.
    pub hbm_tier: bool,
    /// Fraction of each worker's post-weight HBM KV capacity carved out
    /// for the demoted-prefix tier (only read with `hbm_tier`; the former
    /// fixed 1/8 share is the default).
    pub hbm_tier_frac: f64,
    /// Cross-pipe prefix sharing: `enqueue` becomes cache-affinity-aware
    /// (requests score pipes by probed prefix overlap minus load gap
    /// instead of round-robin), and when the holding pipe is overloaded
    /// the matched KV is imported to a lighter pipe over the on-chip NoC
    /// (charged, delayed-landing) instead of recomputed. Requires
    /// `prefix_cache`; off = static round-robin bit-exact behaviour.
    pub cross_pipe: bool,
    /// Pending-work excess over the lightest pipe above which the
    /// cache-affinity router imports the matched KV to the lightest pipe
    /// instead of queueing on the holder (the affinity weight knob; only
    /// read with `cross_pipe`).
    pub affinity_gap: usize,
    /// Operator-latency memoization (approximate fast path, off by
    /// default — see [`crate::model::memo`]).
    pub memo: bool,
    /// Simulation fidelity (`--sim-level`): transaction-level (default,
    /// bit-identical to the historical simulator) or the calibrated
    /// analytic surrogate — see [`crate::model::memo::Surrogate`].
    pub sim_level: SimLevel,
    /// SLO-deadline-triggered preemption (CLI `--slo-preempt`): a queued
    /// request that has burned more than half this TTFT budget (seconds)
    /// waiting for capacity preempts as if one priority class higher, so a
    /// projected TTFT breach can evict equal-class decodes — not only on
    /// priority. `None` (the default) keeps the legacy priority-only
    /// preemption bit-identical.
    pub slo_preempt: Option<f64>,
    /// Speculative decoding (`--spec gamma=K,accept=P`): decode requests
    /// draft `gamma` tokens and verify them in one batched iteration of
    /// `gamma+1` tokens per request, with rejected drafts rolled back on
    /// the paged KV. `None` (the default) keeps vanilla
    /// one-token-per-step decode bit-identical.
    pub spec: Option<SpecConfig>,
}

impl FusionConfig {
    /// Project a [`DeploymentPlan`] onto the fused-pipeline knobs — the
    /// only constructor besides [`FusionConfig::default`] (which is this,
    /// applied to [`DeploymentPlan::fusion_default`], so hardcoded
    /// defaults cannot drift from the plan presets).
    pub fn from_plan(plan: &DeploymentPlan) -> Self {
        FusionConfig {
            tp: plan.tp,
            stages: plan.stages,
            placement: plan.placement,
            strategy: plan.prefill_strategy,
            small_m_strategy: plan.decode_strategy,
            m_threshold: plan.m_threshold,
            chunk: plan.chunk,
            budget: plan.budget,
            max_batch: plan.max_batch,
            kv_share: plan.kv_share,
            prefix_cache: plan.prefix_cache,
            hbm_tier: plan.hbm_tier,
            hbm_tier_frac: plan.hbm_tier_frac,
            cross_pipe: plan.cross_pipe,
            affinity_gap: plan.affinity_gap,
            memo: plan.memo,
            sim_level: plan.sim_level,
            slo_preempt: None,
            spec: plan.spec,
        }
    }
}

impl Default for FusionConfig {
    fn default() -> Self {
        // §4.3.2: fusion prefers TP for both phases; chunked prefill keeps
        // the GEMM M small, where the AllReduce partition wins (§5.6).
        debug_assert_eq!(DeploymentPlan::fusion_default().mode, PdMode::Fusion);
        Self::from_plan(&DeploymentPlan::fusion_default())
    }
}

/// Simulate a full workload under PD fusion; returns the serving metrics.
pub fn simulate_fusion(
    chip: &mut ChipSim,
    model: &ModelConfig,
    workload: &WorkloadConfig,
    cfg: &FusionConfig,
) -> anyhow::Result<Metrics> {
    let mut sched = FusionScheduler::new(*cfg);
    scheduler::simulate(chip, model, workload, &mut sched)
}

/// Like [`simulate_fusion`] but over an explicit request list (trace
/// replay — see [`crate::serving::trace`]). Requests must be sorted by
/// arrival time.
pub fn simulate_fusion_requests(
    chip: &mut ChipSim,
    model: &ModelConfig,
    reqs: Vec<Request>,
    cfg: &FusionConfig,
) -> anyhow::Result<Metrics> {
    let mut sched = FusionScheduler::new(*cfg);
    scheduler::simulate_requests(chip, model, reqs, &mut sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;

    fn run(workload: &WorkloadConfig, cfg: &FusionConfig) -> Metrics {
        let mut chip = ChipSim::new(ChipConfig::large_core());
        let model = ModelConfig::qwen3_4b();
        simulate_fusion(&mut chip, &model, workload, cfg).unwrap()
    }

    #[test]
    fn default_pins_the_legacy_hardcoded_layout() {
        // `Default` now projects from `DeploymentPlan::fusion_default()`;
        // this pin keeps the plan preset honest about the values every
        // golden vector was recorded with.
        let f = FusionConfig::default();
        assert_eq!((f.tp, f.stages), (4, 4));
        assert_eq!(f.placement, Placement::Ring);
        assert_eq!(f.strategy, PartitionStrategy::OneDimK);
        assert_eq!(f.small_m_strategy, PartitionStrategy::OneDimK);
        assert_eq!(f.m_threshold, 0, "phase switch must default off");
        assert_eq!((f.chunk, f.budget, f.max_batch), (256, 288, 32));
        assert_eq!(f.kv_share, 0.6);
        assert_eq!(f.hbm_tier_frac, 0.125, "the former fixed 1/8 carve");
        assert_eq!(f.affinity_gap, 4);
        assert!(f.slo_preempt.is_none(), "SLO preemption must default off");
        assert!(f.spec.is_none(), "speculative decoding must default off");
        assert_eq!(
            f.sim_level,
            SimLevel::Txn,
            "the surrogate must default off — txn is the bit-exact level"
        );
    }

    #[test]
    fn completes_all_requests() {
        let w = WorkloadConfig::fixed_ratio(128, 16, 8);
        let m = run(&w, &FusionConfig::default());
        assert_eq!(m.n_requests(), 8);
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn ttft_before_finish_and_ordered() {
        let w = WorkloadConfig::fixed_ratio(256, 32, 4);
        let m = run(&w, &FusionConfig::default());
        for r in m.records() {
            assert!(r.first_token >= r.arrival);
            assert!(r.finish >= r.first_token);
            assert_eq!(r.output_tokens, 32);
        }
    }

    #[test]
    fn streaming_arrivals_work() {
        let w = WorkloadConfig::decode_dominated(6);
        let m = run(&w, &FusionConfig::default());
        assert_eq!(m.n_requests(), 6);
        // Later arrivals cannot finish before they arrive.
        for r in m.records() {
            assert!(r.finish > r.arrival);
        }
    }

    #[test]
    fn tp_beats_pp_for_decode_tbt_at_equal_cores() {
        // §4.3.1/§4.3.2: at the same core count, tensor parallelism gives
        // lower decode latency than pipeline parallelism (which is why
        // fusion prefers TP) — 32 cores as TP16×2 stages vs TP4×8 stages.
        let w = WorkloadConfig::fixed_ratio(64, 64, 2);
        let pp_heavy = run(
            &w,
            &FusionConfig {
                tp: 4,
                stages: 8,
                ..FusionConfig::default()
            },
        );
        let tp_heavy = run(
            &w,
            &FusionConfig {
                tp: 16,
                stages: 2,
                ..FusionConfig::default()
            },
        );
        assert!(
            tp_heavy.tbt_s().mean() < pp_heavy.tbt_s().mean(),
            "tp16/pp2 {} vs tp4/pp8 {}",
            tp_heavy.tbt_s().mean(),
            pp_heavy.tbt_s().mean()
        );
    }

    #[test]
    fn budget_bounds_prefill_interference() {
        // With decode in flight, an unbounded budget lets a whole long
        // prompt join one iteration and stall every decode step in it; the
        // chunked budget bounds that interference (tail TBT).
        let w = WorkloadConfig::fixed_ratio(2048, 256, 6)
            .with_arrival(crate::config::ArrivalProcess::Poisson { rate: 3.0 });
        let small = run(
            &w,
            &FusionConfig {
                budget: 160,
                chunk: 128,
                ..FusionConfig::default()
            },
        );
        let large = run(
            &w,
            &FusionConfig {
                budget: 4096,
                chunk: 4096,
                ..FusionConfig::default()
            },
        );
        let (s99, l99) = (small.tbt_s().p99(), large.tbt_s().p99());
        assert!(
            s99 <= l99,
            "chunked p99 TBT {s99} should not exceed unchunked {l99}"
        );
    }
}
