//! GEMM tensor-partition strategies (Fig. 3) and their analytic cost model
//! (Table 2).
//!
//! For a GEMM `[M,K] × [K,N]` distributed over `num` cores:
//!
//! | strategy        | collective        | total comm / core                  |
//! |-----------------|-------------------|------------------------------------|
//! | Input-only      | none              | 0                                  |
//! | 1-D M/N         | ring AllGather    | `(num-1)/num × K·N`                |
//! | 1-D K           | ring AllReduce    | `2 (num-1)/num × M·N`              |
//! | 2-D (R×C)       | row AR + col AG   | `(R-1)(2 (C-1)/C · M·N/C² + K·N/(C·R))` |
//!
//! The K-dimension partition moves *results* (`M·N`) instead of *weights*
//! (`K·N`), which is why it wins when the sequence length (M) is smaller
//! than the hidden dimension (K/N) — e.g. short prompts or chunked prefill
//! — and loses sharply once M outgrows the hidden size (Fig. 9).

use crate::config::ModelConfig;

/// How a GEMM is split across the TP group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Replicated weights, inputs split along M: no communication, but
    /// every core must hold the full weight tensor.
    InputOnly,
    /// 1-D split along M/N: weights sharded, rotated via ring AllGather
    /// (T10 / WaferLLM style).
    OneDimMN,
    /// 1-D split along K: partial results aggregated via ring AllReduce.
    OneDimK,
    /// 2-D split along M/N and K on an `rows × cols` logical grid:
    /// row-wise AllReduce + column-wise AllGather per iteration.
    TwoDim { rows: usize, cols: usize },
}

impl PartitionStrategy {
    /// Parse from a CLI string.
    pub fn parse(s: &str, tp: usize) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "input" | "input_only" => PartitionStrategy::InputOnly,
            "mn" | "allgather" => PartitionStrategy::OneDimMN,
            "k" | "allreduce" => PartitionStrategy::OneDimK,
            "mnk" | "2d" | "twodim" => {
                let rows = (1..=tp)
                    .rev()
                    .find(|r| tp % r == 0 && *r * *r <= tp)
                    .unwrap_or(1);
                // A 1×tp grid is not a 2-D partition at all: its "row ring"
                // is the whole group and the column rings are single cores,
                // so it silently degenerates to the 1-D cost while claiming
                // the 2-D label (prime tp always lands here).
                anyhow::ensure!(
                    rows > 1,
                    "2d partition needs a non-degenerate grid, but tp={tp} only \
                     factors as 1x{tp}; use \"mn\" or \"k\" instead"
                );
                PartitionStrategy::TwoDim {
                    rows,
                    cols: tp / rows,
                }
            }
            other => anyhow::bail!("unknown partition strategy {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::InputOnly => "input-only",
            PartitionStrategy::OneDimMN => "1d-mn(allgather)",
            PartitionStrategy::OneDimK => "1d-k(allreduce)",
            PartitionStrategy::TwoDim { .. } => "2d-mnk(hybrid)",
        }
    }

    /// Number of cores the strategy spans.
    pub fn degree(&self, tp: usize) -> usize {
        match self {
            PartitionStrategy::TwoDim { rows, cols } => rows * cols,
            _ => tp,
        }
    }
}

/// Table 2 analytic costs for one GEMM, in **elements** (multiply by dtype
/// size for bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionCost {
    /// Per-core input tensor elements.
    pub input_per_core: f64,
    /// Per-core weight tensor elements.
    pub weight_per_core: f64,
    /// Per-core output tensor elements.
    pub output_per_core: f64,
    /// Total elements communicated by one core over the whole GEMM.
    pub total_comm: f64,
    /// Worst-case hops between logically adjacent cores (`alpha` ≈ 2 for
    /// interleaved linear placements, 1 for ring).
    pub max_hop: u64,
}

/// Evaluate the Table 2 cost model.
pub fn partition_cost(
    strategy: PartitionStrategy,
    tp: usize,
    m: u64,
    k: u64,
    n: u64,
    alpha: u64,
) -> PartitionCost {
    let (m, k, n) = (m as f64, k as f64, n as f64);
    let num = tp as f64;
    match strategy {
        PartitionStrategy::InputOnly => PartitionCost {
            input_per_core: m * k / num,
            weight_per_core: k * n,
            output_per_core: m * n / num,
            total_comm: 0.0,
            max_hop: 0,
        },
        PartitionStrategy::OneDimMN => PartitionCost {
            input_per_core: m * k / num,
            weight_per_core: k * n / num,
            output_per_core: m * n / num,
            total_comm: (num - 1.0) / num * (k * n),
            max_hop: alpha,
        },
        PartitionStrategy::OneDimK => PartitionCost {
            input_per_core: m * k / num,
            weight_per_core: k * n / num,
            output_per_core: m * n / num,
            total_comm: 2.0 * (num - 1.0) / num * (m * n),
            max_hop: alpha,
        },
        PartitionStrategy::TwoDim { rows, cols } => {
            let (r, c) = (rows as f64, cols as f64);
            PartitionCost {
                input_per_core: m * k / (r * c),
                weight_per_core: k * n / (r * c),
                output_per_core: m * n / (r * c),
                total_comm: (r - 1.0) * (2.0 * (c - 1.0) / c * (m * n) / (c * c) + (k * n) / (c * r)),
                max_hop: alpha,
            }
        }
    }
}

/// The analytically optimal 1-D strategy for a GEMM: AllReduce when the
/// result (`M·N`) is smaller than the weights (`K·N`) — i.e. roughly when
/// `M < K/2` given AllReduce moves the result twice (§4.1, §5.6 guidance).
pub fn best_1d_strategy(m: u64, k: u64, _n: u64) -> PartitionStrategy {
    if 2 * m < k {
        PartitionStrategy::OneDimK
    } else {
        PartitionStrategy::OneDimMN
    }
}

/// Pick a per-scenario strategy following §5.6: AllReduce for short
/// sequences / chunked prefill, 2-D for long prompts at larger TP.
pub fn auto_strategy(model: &ModelConfig, seq_len: u64, tp: usize) -> PartitionStrategy {
    let hidden = model.hidden as u64;
    if 2 * seq_len < hidden {
        PartitionStrategy::OneDimK
    } else if tp >= 8 {
        // Factor tp into the squarest grid.
        let rows = (1..=tp).rev().find(|r| tp % r == 0 && r * r <= tp).unwrap_or(1);
        PartitionStrategy::TwoDim { rows, cols: tp / rows }
    } else {
        PartitionStrategy::OneDimMN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_only_has_no_comm_but_full_weights() {
        let c = partition_cost(PartitionStrategy::InputOnly, 4, 128, 4096, 4096, 2);
        assert_eq!(c.total_comm, 0.0);
        assert_eq!(c.weight_per_core, 4096.0 * 4096.0);
        assert_eq!(c.max_hop, 0);
    }

    #[test]
    fn table2_mn_formula() {
        let c = partition_cost(PartitionStrategy::OneDimMN, 4, 256, 1024, 2048, 2);
        assert!((c.total_comm - 0.75 * 1024.0 * 2048.0).abs() < 1e-6);
        assert_eq!(c.weight_per_core, 1024.0 * 2048.0 / 4.0);
    }

    #[test]
    fn table2_k_formula() {
        let c = partition_cost(PartitionStrategy::OneDimK, 4, 256, 1024, 2048, 2);
        assert!((c.total_comm - 2.0 * 0.75 * 256.0 * 2048.0).abs() < 1e-6);
    }

    #[test]
    fn table2_2d_formula() {
        let (r, c_) = (2.0f64, 2.0f64);
        let (m, k, n) = (256.0f64, 1024.0, 2048.0);
        let expect = (r - 1.0) * (2.0 * (c_ - 1.0) / c_ * m * n / (c_ * c_) + k * n / (c_ * r));
        let c = partition_cost(
            PartitionStrategy::TwoDim { rows: 2, cols: 2 },
            4,
            256,
            1024,
            2048,
            2,
        );
        assert!((c.total_comm - expect).abs() < 1e-6);
    }

    #[test]
    fn k_beats_mn_for_short_sequences() {
        // seq 256 << hidden 4096: AllReduce moves 2·(3/4)·256·4096 while
        // AllGather moves (3/4)·4096·4096 — 8x more.
        let mn = partition_cost(PartitionStrategy::OneDimMN, 4, 256, 4096, 4096, 2);
        let k = partition_cost(PartitionStrategy::OneDimK, 4, 256, 4096, 4096, 2);
        assert!(k.total_comm * 4.0 < mn.total_comm);
        assert_eq!(best_1d_strategy(256, 4096, 4096), PartitionStrategy::OneDimK);
    }

    #[test]
    fn mn_beats_k_for_long_sequences() {
        let mn = partition_cost(PartitionStrategy::OneDimMN, 4, 16384, 4096, 4096, 2);
        let k = partition_cost(PartitionStrategy::OneDimK, 4, 16384, 4096, 4096, 2);
        assert!(mn.total_comm < k.total_comm);
        assert_eq!(
            best_1d_strategy(16384, 4096, 4096),
            PartitionStrategy::OneDimMN
        );
    }

    #[test]
    fn parse_strategies() {
        assert_eq!(
            PartitionStrategy::parse("allreduce", 4).unwrap(),
            PartitionStrategy::OneDimK
        );
        assert_eq!(
            PartitionStrategy::parse("mnk", 16).unwrap(),
            PartitionStrategy::TwoDim { rows: 4, cols: 4 }
        );
        assert_eq!(
            PartitionStrategy::parse("2d", 8).unwrap(),
            PartitionStrategy::TwoDim { rows: 2, cols: 4 }
        );
        assert!(PartitionStrategy::parse("bogus", 4).is_err());
    }

    #[test]
    fn parse_2d_rejects_degenerate_grids() {
        // Prime tp only factors as 1×tp — identical to the 1-D cost while
        // claiming the 2-D label. The parse must refuse, pointing at the
        // honest alternatives.
        for tp in [2usize, 3, 5, 7, 13] {
            let err = PartitionStrategy::parse("2d", tp).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("mn") && msg.contains('k'), "tp={tp}: {msg}");
        }
        // Composite tp with a square-ish factorization still parses.
        assert_eq!(
            PartitionStrategy::parse("2d", 6).unwrap(),
            PartitionStrategy::TwoDim { rows: 2, cols: 3 }
        );
    }

    #[test]
    fn auto_strategy_follows_guidance() {
        let m = crate::config::ModelConfig::qwen3_4b(); // hidden 2560
        assert_eq!(auto_strategy(&m, 256, 4), PartitionStrategy::OneDimK);
        assert_eq!(auto_strategy(&m, 4096, 4), PartitionStrategy::OneDimMN);
        assert!(matches!(
            auto_strategy(&m, 4096, 16),
            PartitionStrategy::TwoDim { rows: 4, cols: 4 }
        ));
    }
}
