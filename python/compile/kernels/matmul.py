"""L1 Pallas kernel: tiled matmul — the paper's per-core GEMM hot-spot.

The paper's compute model streams `sa_dim x sa_dim` weight tiles through a
systolic array (T_comp = N_tiles * T_cycles + T_inject, section 3.1). On
TPU the same schedule is expressed with Pallas `BlockSpec`s: the grid walks
(M, N) output tiles, an inner fori_loop accumulates over K tiles, and the
BlockSpec index maps are the HBM->VMEM DMA schedule the paper's per-core
DMA engine performs (DESIGN.md section Hardware-Adaptation).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated in DESIGN.md section Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles (128x128 output tile, 128-deep K slices).
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_tiles: int):
    """Accumulate one (TILE_M, TILE_N) output tile over k_tiles K-slices."""

    @functools.partial(jax.lax.fori_loop, 0, k_tiles, init_val=jnp.zeros_like(o_ref))
    def acc(k, acc):
        xs = x_ref[:, pl.ds(k * TILE_K, TILE_K)]
        ws = w_ref[pl.ds(k * TILE_K, TILE_K), :]
        return acc + jnp.dot(xs, ws, preferred_element_type=jnp.float32)

    o_ref[...] = acc


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """`x @ w` via the Pallas tiled kernel (f32), any 2-D shapes.

    Inputs are zero-padded up to tile multiples (the paper's "pad the last
    tile" rule) and the result is sliced back.
    """
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        x.shape,
        w.shape,
    )
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(_pad_to(x.astype(jnp.float32), TILE_M, 0), TILE_K, 1)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), TILE_K, 0), TILE_N, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    k_tiles = kp // TILE_K

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_tiles=k_tiles),
        grid=(mp // TILE_M, np_ // TILE_N),
        in_specs=[
            # Row-band of X per M-tile: the VMEM-resident activation slab.
            pl.BlockSpec((TILE_M, kp), lambda i, j: (i, 0)),
            # Column-band of W per N-tile: streamed weight tiles.
            pl.BlockSpec((kp, TILE_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


def matmul_batched(x: jax.Array, w: jax.Array) -> jax.Array:
    """Batched wrapper: collapses leading dims of `x` into M."""
    lead = x.shape[:-1]
    out = matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(*lead, w.shape[-1])
